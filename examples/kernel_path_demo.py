"""A GCN layer computed entirely through the Pallas tile kernels.

    PYTHONPATH=src python examples/kernel_path_demo.py

The TPU execution path for the paper's core dataflow (DESIGN.md §2):
  1. sparse-tile the graph (compaction = the paper's sparse tiling),
  2. densify each tile's adjacency into an MXU-ready (Dmax × Smax) block,
  3. gather + transform source embeddings per tile (the sFunction),
  4. one `tile_spmm_pallas` call aggregates every tile into its destination
     partition — the Pallas grid is the inter-tile pipeline,
  5. same for GAT's edge softmax via the single-pass online-softmax kernel.
Both are validated against the whole-graph oracle here (interpret mode —
this container is CPU-only; on TPU pass interpret=False).
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reorder, tiling
from repro.gnn import graphs
from repro.kernels.tile_spmm import ops as tops


def main():
    g0 = graphs.paper_graph("ak2010", scale=0.05, seed=0)
    r = reorder.degree_sort(g0)
    g = r.graph
    tiles = tiling.grid_tile(g, 6, 6, sparse=True)
    print(f"graph {g.n_vertices}V/{g.n_edges}E -> {tiles.n_tiles} sparse tiles "
          f"(Smax={tiles.s_max}, Dmax={int(tiles.part_size.max())})")

    rng = np.random.default_rng(0)
    F_in, F_out = 64, 64
    x = rng.standard_normal((g.n_vertices, F_in)).astype(np.float32)
    W = (rng.standard_normal((F_in, F_out)) / np.sqrt(F_in)).astype(np.float32)
    deg = g.in_degrees().astype(np.float32)
    dnorm = (1 / np.sqrt(np.maximum(deg, 1)))[:, None]

    # offline: densify tiles (the paper's tiling pass)
    adj, flags = tops.densify_tiles(tiles)
    adj, flags = jnp.asarray(adj), jnp.asarray(flags)
    pid = jnp.asarray(tiles.part_id)

    # per-tile sFunction: gather + (x * dnorm) @ W on compacted sources
    h = jnp.asarray(x * dnorm) @ jnp.asarray(W)
    xsrc = tops.gather_sources(tiles, h)                       # (T, Smax, F)

    t0 = time.time()
    out_parts = tops.spmm(adj, xsrc, pid, flags, n_parts=tiles.n_dst_parts)
    out_parts = jax.block_until_ready(out_parts)
    print(f"tile_spmm_pallas (interpret): {time.time()-t0:.2f}s "
          f"-> {out_parts.shape}")

    # re-assemble (P, Dmax, F) -> (V, F), apply the dFunction (norm + relu)
    V = g.n_vertices
    out = np.zeros((V, F_out), np.float32)
    for p in range(tiles.n_dst_parts):
        n, lo = int(tiles.part_size[p]), int(tiles.part_start[p])
        out[lo:lo + n] = np.asarray(out_parts)[p, :n]
    out = np.maximum(out * dnorm, 0.0)

    # oracle: whole-graph segment-sum GCN layer
    seg = jax.ops.segment_sum(h[jnp.asarray(g.src)], jnp.asarray(g.dst),
                              num_segments=V)
    ref = np.maximum(np.asarray(seg) * dnorm, 0.0)
    print("max |kernel - oracle| =", float(np.abs(out - ref).max()))
    assert np.abs(out - ref).max() < 1e-4
    print("OK — ZIPPER tile dataflow on the MXU kernel matches the oracle")


if __name__ == "__main__":
    main()
