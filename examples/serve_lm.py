"""Serve a small LM with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b --tokens 16

Runs the reduced config of the chosen architecture on CPU: a batch of
synthetic prompts is prefetched through ``forward`` (prefill), then decoded
token-by-token through the KV-cache / recurrent-state ``decode_step`` —
the same code paths the decode_32k / long_500k dry-run cells lower.
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.common import materialize


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    mesh = make_host_mesh()
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    B, P, N = args.batch, args.prompt_len, args.tokens
    max_len = P + N
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)

    cache = materialize(jax.random.PRNGKey(1), lm.cache_template(cfg, B, max_len),
                        dtype_override="float32")
    decode = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos, mesh=mesh))

    # prefill by teacher-forcing the prompt through decode (fills the cache)
    t0 = time.time()
    tok = prompts[:, :1]
    for pos in range(P):
        logits, cache = decode(params, cache, prompts[:, pos:pos + 1],
                               jnp.asarray(pos, jnp.int32))
    print(f"prefill {P} tokens x {B} seqs: {time.time()-t0:.2f}s")

    # greedy decode
    out = []
    tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
    t0 = time.time()
    for i in range(N):
        out.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok, jnp.asarray(P + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"decoded {N} tokens x {B} seqs in {dt:.2f}s "
          f"({B*N/dt:.1f} tok/s on CPU, reduced config)")
    print("sampled ids (first seq):", gen[0].tolist())


if __name__ == "__main__":
    main()
