"""Quickstart: the whole ZIPPER pipeline on one small graph, in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Traces a 2-layer GCN written against the classic whole-graph programming
model (one trace spanning both layers), compiles it to the graph-native IR
(cross-layer CSE + E2V optimization included), tiles the graph (sparse
tiling + degree-sort reordering via the one-stop ``build_tiles`` entry),
executes it three ways — whole-graph oracle, phased tile executor,
scan-pipelined engine — and runs the cycle-level simulator for the ZIPPER
ASIC and a TPU-v5e-like config, with the inter-layer pipelined schedule
compared against the barrier schedule.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro.core import compiler, executor, isa, pipeline, simulator, tiling
from repro.core.streams import HWConfig, TPU_V5E_LIKE
from repro.gnn import graphs, models


def main():
    g0 = graphs.paper_graph("ak2010", scale=0.1, seed=0)
    print(f"graph: {g0.n_vertices} vertices, {g0.n_edges} edges")

    # 1. trace a 2-layer GCN (one program), compile to graph-native IR
    tr = models.trace_stacked("gcn", 2)
    c = compiler.compile_gnn(tr)
    print(f"IR: {c.n_layers} layers, {len(c.ir.segments)} segments, "
          f"{c.plan.max_level + 1} phases, opt report {c.opt_report}")

    # 2. reorder + sparse-tile (one-stop entry, degree sorting opted in)
    tiles, r = tiling.build_tiles(g0, 8, 8, sparse=True, reorder="degree")
    print(f"tiles: {tiles.n_tiles} (S_max={tiles.s_max}, E_max={tiles.e_max}); "
          f"src loads {tiles.src_vertex_loads()} vs regular "
          f"{tiling.grid_tile(r.graph, 8, 8, sparse=False).src_vertex_loads()}")

    # 3. execute three ways
    params = models.init_params(tr)
    inputs = {k: (r.permute_vertex_features(v) if v.shape[0] == g0.n_vertices else v)
              for k, v in models.init_inputs(tr, g0).items()}
    ref = executor.run_reference(tr, r.graph, inputs, params)
    tiled = executor.run_tiled(c, r.graph, tiles, inputs, params)
    piped = pipeline.run_pipelined(c, r.graph, tiles, inputs, params)
    print("max |oracle - tiled|    =", float(jnp.max(jnp.abs(ref[0] - tiled[0]))))
    print("max |oracle - pipelined| =", float(jnp.max(jnp.abs(ref[0] - piped[0]))))

    # 4. simulate the hardware: barrier vs inter-layer pipelined schedule
    sde = isa.emit_sde(c.plan)
    for label, hw in [("ZIPPER (paper cfg)", HWConfig()), ("TPU-v5e-like", TPU_V5E_LIKE)]:
        s = simulator.simulate_model(sde, tiles, hw)
        p = simulator.simulate_model(sde, tiles, hw, inter_layer="pipelined")
        print(f"{label:18s}: {s.time_ms:7.2f} ms barrier, {p.time_ms:7.2f} ms "
              f"pipelined ({s.cycles / p.cycles:.2f}x), "
              f"MU util {s.utilization['MU']:.2f}, energy {s.energy_mj:.1f} mJ")


if __name__ == "__main__":
    main()
