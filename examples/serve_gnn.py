"""Serve a stream of small graphs through the batched inference engine.

    PYTHONPATH=src python examples/serve_gnn.py --model gcn --requests 6 --batch 16

Walkthrough of the serving layer (src/repro/serve/): each request batch of
small graphs is merged into one block-diagonal super-graph, padded onto a
size class, and executed by a cached jitted runner — one compilation per
*structure*, reused across every request of the stream.  Compare the first
(cold, compiling) request latency against the warm ones, then inspect the
program-cache counters.
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import compiler
from repro.gnn import graphs, models
from repro.serve import InferenceServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="gcn", choices=sorted(models.MODELS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vertices", type=int, default=64)
    ap.add_argument("--edges", type=int, default=256)
    args = ap.parse_args(argv)
    if args.requests < 1 or args.batch < 1:
        ap.error("--requests and --batch must be >= 1")

    spec = models.MODELS[args.model]
    tr = models.trace_named(args.model)
    compiled = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    server = InferenceServer(compiled, params)

    print(f"serving {args.model}: {args.requests} requests x "
          f"{args.batch} graphs (~{args.vertices}V/{args.edges}E each)")
    for req in range(args.requests):
        gs, ins = [], []
        for k in range(args.batch):
            seed = req * 1000 + k
            g = graphs.random_graph(
                args.vertices, args.edges, seed=seed, model="powerlaw",
                n_edge_types=spec.n_edge_types if spec.needs_etype else None)
            gs.append(g)
            ins.append(models.init_inputs(tr, g, seed=seed))
        t0 = time.perf_counter()
        outs = server.submit(gs, ins)
        dt = time.perf_counter() - t0
        tag = "cold (compiling)" if req == 0 else "warm (cache hit)"
        print(f"  request {req}: {args.batch} graphs in {dt * 1e3:7.1f} ms "
              f"({args.batch / dt:8.1f} g/s)  {tag}")

    # per-graph vertex outputs come back exactly sliced; pool one for show
    last = np.asarray(outs[0][0])
    print(f"graph 0 output: {last.shape}, mean readout "
          f"{float(last.mean()):+.4f}")
    print("server stats:", server.stats())


if __name__ == "__main__":
    main()
