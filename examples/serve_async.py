"""Async serving walkthrough: continuous batching with deadlines and sheds.

    PYTHONPATH=src python examples/serve_async.py --requests 48

Builds on examples/serve_gnn.py (the synchronous engine) and drives the
async tier documented in docs/SERVING.md:

  1. register two tenants (gcn, gat) on one shared program cache, each
     with a per-tenant cache budget and a warmup set;
  2. start the server — canonical size classes compile in the background
     while requests are already being admitted;
  3. fire a burst of individual requests with deadlines and collect
     tickets; the scheduler forms batches per (model, size class);
  4. deliberately overload a tiny second server to show structured
     Overloaded results (no exceptions) under both shed policies;
  5. dump the metrics snapshot (p50/p99 latency, batch fill, sheds).
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.gnn import graphs, models
from repro.serve import AsyncInferenceServer, Overloaded


def make_requests(model, n, *, v, e, seed0=0):
    """n (graph, inputs) pairs for one tenant, same size class."""
    spec = models.MODELS[model]
    tr = models.trace_named(model)
    out = []
    for k in range(n):
        g = graphs.random_graph(
            v, e, seed=seed0 + k, model="powerlaw",
            n_edge_types=spec.n_edge_types if spec.needs_etype else None)
        out.append((g, models.init_inputs(tr, g, seed=seed0 + k)))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48,
                    help="requests per tenant in the main burst")
    ap.add_argument("--vertices", type=int, default=48)
    ap.add_argument("--edges", type=int, default=192)
    ap.add_argument("--deadline", type=float, default=3.0,
                    help="per-request deadline; a trailing partial batch "
                         "ships when its slack hits dispatch_margin_s, so "
                         "this also bounds the burst's tail")
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")

    # -- 1+2: two tenants, shared cache, background warmup ------------------
    reqs = {m: make_requests(m, args.requests,
                             v=args.vertices, e=args.edges)
            for m in ("gcn", "gat")}
    srv = AsyncInferenceServer(max_queue=4 * args.requests,
                               default_deadline_s=args.deadline,
                               n_workers=2)
    for m in ("gcn", "gat"):
        srv.register_model(m, m, models.init_params(models.trace_named(m)),
                           max_batch=16, cache_budget=8,
                           warmup_graphs=[reqs[m][0][0]])

    with srv:                      # start(): scheduler + workers + warmup
        while not srv.warmup_done():
            time.sleep(0.05)
        print("warmup done:", srv.stats()["metrics"]["warmup"])

        # -- 3: a mixed burst of individual requests ------------------------
        t0 = time.perf_counter()
        tickets = [(m, srv.submit(g, ins, model=m))
                   for m in ("gcn", "gat") for g, ins in reqs[m]]
        ok = 0
        for m, t in tickets:
            res = t.result(timeout=60.0)
            if t.ok:
                ok += 1
                last = np.asarray(res)  # this request's vertex outputs
        dt = time.perf_counter() - t0
        n = len(tickets)
        print(f"burst: {ok}/{n} served in {dt * 1e3:.0f} ms "
              f"({n / dt:.0f} req/s), last output {last.shape}")

        snap = srv.stats()["metrics"]
        print(f"latency p50/p99: {snap['latency_s']['p50'] * 1e3:.1f}/"
              f"{snap['latency_s']['p99'] * 1e3:.1f} ms, "
              f"mean batch fill {snap['batch_fill']['mean']:.2f}, "
              f"sheds {snap['shed']}")
        print("shared cache:", srv.stats()["cache"])

    # -- 4: overload a tiny server to show structured shedding --------------
    for policy in ("reject-new", "drop-oldest"):
        tiny = AsyncInferenceServer(max_queue=4, shed_policy=policy,
                                    default_deadline_s=args.deadline)
        tiny.register_model("gcn", "gcn",
                            models.init_params(models.trace_named("gcn")),
                            max_batch=4)
        # not started: nothing drains, so admission fills then sheds
        tix = [tiny.submit(g, ins) for g, ins in reqs["gcn"][:8]]
        tiny.close(drain=False)
        shed = [t.result() for t in tix if not t.ok]
        reasons = sorted({s.reason for s in shed
                          if isinstance(s, Overloaded)})
        print(f"{policy:>11}: {len(shed)}/8 shed, reasons={reasons}")


if __name__ == "__main__":
    main()
