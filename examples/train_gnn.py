"""End-to-end driver: train a ~100M-param GCN stack for a few hundred steps.

    PYTHONPATH=src python examples/train_gnn.py --steps 200

A 3-layer GCN (the paper's model family) with hidden width 1024 on a
synthetic citation-style graph, trained on a node-classification objective
with our AdamW.  The forward pass runs through the ZIPPER scan-pipelined
tile executor — the paper's execution model under autodiff.

(~100M params comes from 1024→8192→8192→1024 dense transforms plus vertex
embeddings; on CPU a few hundred steps of the reduced default completes in
minutes — pass --width 8192 on real hardware.)
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, pipeline, tiling
from repro.core.trace import trace_model
from repro.gnn import graphs
from repro.optim.adamw import adamw_init, adamw_update


def build_mlp_gcn(tr, g, in_dim, hidden, n_classes):
    """3-layer GCN with per-layer dense transforms (classic model)."""
    x = tr.input_vertex(in_dim, "x")
    dn = tr.input_vertex(1, "dnorm")
    h = x
    dims = [in_dim, hidden, hidden, n_classes]
    for i in range(3):
        w = tr.param(f"W{i}", (dims[i], dims[i + 1]))
        h = (h * dn).matmul(w)
        h = g.gather_sum(g.scatter_src(h))
        h = h * dn
        if i < 2:
            h = h.relu()
    tr.mark_output(h)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--width", type=int, default=512)
    ap.add_argument("--vertices", type=int, default=4000)
    ap.add_argument("--edges", type=int, default=16000)
    ap.add_argument("--classes", type=int, default=16)
    args = ap.parse_args(argv)

    g = graphs.random_graph(args.vertices, args.edges, seed=0, model="powerlaw")
    tr = trace_model(lambda t, gr: build_mlp_gcn(t, gr, 64, args.width, args.classes),
                     name="gcn3")
    c = compiler.compile_gnn(tr)
    tiles = tiling.grid_tile(g, 4, 4, sparse=True)
    runner = pipeline.PipelinedRunner(c, g, tiles)

    rng = np.random.default_rng(0)
    params = {n: jnp.asarray(rng.standard_normal(s) / np.sqrt(s[0]), jnp.float32)
              for n, s in tr.params.items()}
    n_params = sum(int(np.prod(v.shape)) for v in params.values())
    print(f"params: {n_params/1e6:.1f}M   tiles: {tiles.n_tiles}")
    deg = g.in_degrees().astype(np.float32)
    inputs = {"x": jnp.asarray(rng.standard_normal((g.n_vertices, 64)), jnp.float32),
              "dnorm": jnp.asarray((1 / np.sqrt(np.maximum(deg, 1)))[:, None])}
    labels = jnp.asarray(rng.integers(0, args.classes, g.n_vertices))

    def loss_fn(p):
        logits = runner(inputs, p)[0]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return (lse - gold).mean()

    opt = adamw_init(params)
    value_and_grad = jax.jit(jax.value_and_grad(loss_fn))
    t0 = time.time()
    for step in range(args.steps):
        loss, grads = value_and_grad(params)
        params, opt, gnorm = adamw_update(params, opt, grads, 3e-3)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  gnorm {float(gnorm):.2f} "
                  f" ({time.time()-t0:.1f}s)", flush=True)
    print("final loss:", float(loss))


if __name__ == "__main__":
    main()
