"""Golden cycle-count regression for the stream simulator (ISSUE 5).

The event-driven simulator is deterministic, so scheduler or cost-model
changes shift cycle counts *silently* — parity tests keep passing while the
modeled performance story drifts.  This test freezes the five paper models
on the cit-Patents-like configuration (2-layer stacked, 6x6 sparse grid)
across three schedules — barrier, inter-layer pipelined, and 4-chip sharded
— into ``tests/golden/simulator.json``.

Intentional changes follow the explicit-update workflow:

    UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_simulator.py

then commit the regenerated JSON together with the change that moved it.
"""
import json
import os

import pytest

from repro.core import compiler, isa, simulator, tiling
from repro.gnn import graphs, models

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "simulator.json")
N_LAYERS = 2
N_CHIPS = 4


def _measure():
    g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0, n_edge_types=3)
    ts = tiling.grid_tile(g, 6, 6, sparse=True)
    ts_csr = tiling.csr_tiles(ts)
    out = {}
    for name in models.PAPER_MODELS:
        c = compiler.compile_gnn(models.trace_stacked(name, N_LAYERS, 16, 16, 16))
        sde = isa.emit_sde(c.schedule(False))
        barrier = simulator.simulate_model(sde, ts)
        pipe = simulator.simulate_model(sde, ts, inter_layer="pipelined")
        shard = simulator.simulate_sharded(sde, ts, n_chips=N_CHIPS)
        # kernel-dispatch schedule costed under both tile edge layouts: the
        # COO dense-tile matmul vs the CSR row-pointer walk
        kern_coo = simulator.simulate_model(
            isa.emit_sde(c.schedule(True)), ts, padded=True)
        kern_csr = simulator.simulate_model(
            isa.emit_sde(c.schedule(True), layout="csr"), ts_csr, padded=True)
        out[name] = {
            "barrier_cycles": barrier.cycles,
            "pipelined_cycles": pipe.cycles,
            "sharded4_cycles": shard.cycles,
            "sharded4_exchange_cycles": shard.exchange_cycles,
            "macs": barrier.macs,
            "kernel_coo_cycles": kern_coo.cycles,
            "kernel_csr_cycles": kern_csr.cycles,
            "kernel_csr_read": kern_csr.offchip_read,
        }
    return out


def test_simulator_golden_cycles():
    got = _measure()
    if os.environ.get("UPDATE_GOLDEN"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
            f.write("\n")
        pytest.skip(f"golden file regenerated at {GOLDEN}; commit it")
    assert os.path.exists(GOLDEN), (
        f"missing {GOLDEN}; generate it with UPDATE_GOLDEN=1")
    with open(GOLDEN) as f:
        want = json.load(f)
    mismatches = {
        f"{name}.{key}": (want[name][key], got[name][key])
        for name in want for key in want[name]
        if got.get(name, {}).get(key) != want[name][key]
    }
    assert not mismatches, (
        "simulator cycle counts moved (golden, measured): "
        f"{mismatches}; if intentional rerun with UPDATE_GOLDEN=1 and commit "
        "the regenerated tests/golden/simulator.json")
    assert set(got) == set(want)


def test_golden_schedules_are_ordered():
    """Sanity on the frozen numbers themselves: pipelining and sharding must
    keep their modeled wins (the story the golden file protects)."""
    with open(GOLDEN) as f:
        want = json.load(f)
    for name, rec in want.items():
        assert rec["pipelined_cycles"] < rec["barrier_cycles"], name
        assert rec["sharded4_cycles"] < rec["pipelined_cycles"], name
        # CSR's E-proportional kernel blocks beat the dense COO tile matmul
        # on the heavy-tailed graph — the modeled win this PR exists for
        assert rec["kernel_csr_cycles"] < rec["kernel_coo_cycles"], name
