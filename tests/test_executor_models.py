"""Integration: compiled+tiled+pipelined execution ≡ whole-graph oracle for
all five paper models, across tiling strategies and reordering."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, executor, pipeline, reorder, tiling
from repro.gnn import graphs, models
from repro.kernels.tile_spmm import ops as tops

TOL = 5e-4


def _run_all(name, g, strategy):
    tr = models.trace_named(name, 24, 24)
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    tile_kernel = None
    if strategy == "regular":
        ts = tiling.grid_tile(g, 4, 4, sparse=False)
    else:
        ts = tiling.grid_tile(g, 4, 4, sparse=True)
    if strategy in ("bucketed", "bucketed+kernel"):
        ts = tiling.bucket_tiles(ts, 3)
    if strategy == "bucketed+kernel":
        tile_kernel = tops.spmm
    if strategy in ("regular", "sparse"):
        out_tiled = executor.run_tiled(c, g, ts, inputs, params)
        for a, b in zip(ref, out_tiled):
            assert float(jnp.max(jnp.abs(a - b))) < TOL, "tiled != oracle"
    out_pipe = pipeline.run_pipelined(c, g, ts, inputs, params,
                                      tile_kernel=tile_kernel)
    for a, b in zip(ref, out_pipe):
        assert float(jnp.max(jnp.abs(a - b))) < TOL, "pipelined != oracle"


@pytest.mark.parametrize("name", models.PAPER_MODELS + ("gin",))
@pytest.mark.parametrize("strategy", ["regular", "sparse", "bucketed",
                                      "bucketed+kernel"])
def test_tiled_matches_oracle(name, strategy):
    g = graphs.random_graph(220, 900, seed=1, model="powerlaw", n_edge_types=3)
    _run_all(name, g, strategy)


def test_kernel_engages_on_pure_spmm_models():
    """The scheduler pass must tag pure sum-gather phases ``pallas_spmm``
    so the Pallas inner body replaces the scan."""
    from repro.core import schedule
    for name, engaged in [("gcn", True), ("ggnn", True), ("gin", True),
                          ("rgcn", False), ("sage", False)]:
        c = compiler.compile_gnn(models.trace_named(name, 16, 16))
        kernels = {k for ks in c.schedule(True).kernels_by_level().values()
                   for k in ks}
        assert (schedule.KERNEL_SPMM in kernels) == engaged, name


@pytest.mark.parametrize("name", ["gcn", "gat"])
def test_with_reordering(name):
    g0 = graphs.random_graph(200, 800, seed=4, model="powerlaw", n_edge_types=3)
    r = reorder.degree_sort(g0)
    tr = models.trace_named(name, 16, 16)
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    inputs0 = models.init_inputs(tr, g0)
    # oracle on the ORIGINAL graph
    ref = executor.run_reference(tr, g0, inputs0, params)
    # tiled on the REORDERED graph with permuted inputs, outputs un-permuted
    inputs1 = {k: (r.permute_vertex_features(v) if v.shape[0] == g0.n_vertices else v)
               for k, v in inputs0.items()}
    ts = tiling.grid_tile(r.graph, 4, 4, sparse=True)
    out = executor.run_tiled(c, r.graph, ts, inputs1, params)
    for a, b in zip(ref, out):
        b_unperm = r.unpermute_vertex_outputs(np.asarray(b))
        assert float(jnp.max(jnp.abs(a - b_unperm))) < TOL


def test_empty_partition_handled():
    # a graph whose high partitions have no in-edges
    src = np.array([0, 1, 2, 3], np.int32)
    dst = np.array([0, 0, 1, 1], np.int32)
    g = graphs.Graph(src=src, dst=dst, n_vertices=64, name="skew")
    tr = models.trace_named("gcn", 8, 8)
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ts = tiling.grid_tile(g, 4, 4)
    ref = executor.run_reference(tr, g, inputs, params)
    out = executor.run_tiled(c, g, ts, inputs, params)
    assert float(jnp.max(jnp.abs(ref[0] - out[0]))) < TOL


def test_pipelined_uses_single_jit():
    g = graphs.random_graph(100, 400, seed=0)
    tr = models.trace_named("gcn", 8, 8)
    c = compiler.compile_gnn(tr)
    runner = pipeline.PipelinedRunner(c, g, tiling.grid_tile(g, 2, 2))
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    o1 = runner(inputs, params)
    o2 = runner(inputs, params)  # second call hits the jit cache
    assert float(jnp.max(jnp.abs(o1[0] - o2[0]))) == 0.0
