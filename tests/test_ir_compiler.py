"""Unit tests: IR construction, segmentation, channels, SDE planning, ISA."""
import numpy as np
import pytest

from repro.core import compiler, ir, isa, trace as TR
from repro.gnn import models


@pytest.mark.parametrize("name", models.PAPER_MODELS)
def test_ir_structure_valid(name):
    tr = models.trace_named(name)
    prog = compiler.construct_ir(tr)
    prog.validate()
    # every send has exactly one recv, correct direction
    for cid, (ssi, snid, rsi, rnid) in prog.channels.items():
        send = prog.segments[ssi].nodes[snid]
        recv = prog.segments[rsi].nodes[rnid]
        assert ir.SEND_TO_RECV[send.op] == recv.op
    # at least one vertex and one edge segment
    assert prog.vertex_segments() and prog.edge_segments()


def test_gcn_segmentation():
    tr = models.trace_named("gcn")
    prog = compiler.construct_ir(tr)
    # GCN: vertex compute, pass-through edge segment (SpMM), output vertex seg
    kinds = [s.kind for s in prog.segments]
    assert kinds.count("edge") == 1
    edge_seg = prog.edge_segments()[0]
    assert {n.op for n in edge_seg.nodes.values()} == {"recvSrc", "sendDstSum"}


def test_levels_single_gather():
    tr = models.trace_named("gcn")
    c = compiler.compile_gnn(tr)
    assert c.plan.max_level == 1  # one gather barrier


def test_levels_gat_multiphase():
    """GAT's edge softmax needs 3 gather barriers (max, sum, weighted sum)."""
    c = compiler.compile_gnn(models.trace_named("gat"))
    assert c.plan.max_level == 3


def test_roles_src_dst():
    c = compiler.compile_gnn(models.trace_named("gat"))
    plan = c.plan
    # h = xW feeds both message scatter (src) and is consumed at dst via a_dst
    both = [nid for nid, r in plan.role.items() if r == {"src", "dst"}]
    assert both, "GAT must have nodes in both source and destination replicas"


def test_sde_emission():
    c = compiler.compile_gnn(models.trace_named("gcn"))
    sde = isa.emit_sde(c.plan)
    # source function carries the GEMM; edge function the scatter+gather GOPs
    s_ops = [i.opcode for i in sde.s.get(0, [])]
    e_ops = [i.opcode for i in sde.e.get(0, [])]
    assert "GEMM" in s_ops
    assert any(o.startswith("SCTR") for o in e_ops)
    assert any(o.startswith("GTHR") for o in e_ops)
    assert sde.max_level == 1


def test_isa_units():
    c = compiler.compile_gnn(models.trace_named("rgcn"))
    sde = isa.emit_sde(c.plan)
    all_instrs = [i for lvl in sde.e.values() for i in lvl]
    bmm = [i for i in all_instrs if i.opcode == "BMM"]
    assert bmm and bmm[0].unit == "MU"  # edge-type BMM stays on the edge/MU


def test_mixed_space_rejected():
    """Direct vertex-edge op without a GOP must be impossible by construction."""
    tr = TR.GnnTrace("bad")
    g = TR.GraphRef(tr)
    x = tr.input_vertex(4, "x")
    e = tr.input_edge(4, "ef")
    with pytest.raises(AssertionError):
        _ = x + e  # space mismatch
