"""Substrate tests: optimizer, checkpoint/restart, fault tolerance,
gradient compression, data pipeline determinism, simulator invariants."""
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import (CheckpointManager, latest_step,
                                 restore_checkpoint, save_checkpoint)
from repro.distributed.compression import dequantize_grads, quantize_grads
from repro.distributed.fault import FailureDetector, plan_remesh, reassign_shards
from repro.data.pipeline import TokenPipeline
from repro.configs import get_config, reduced
from repro.optim.adamw import adamw_init, adamw_update


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _toy_params(rng):
    return {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32)}


def test_adamw_descends_quadratic(rng):
    params = _toy_params(rng)
    target = jax.tree.map(jnp.zeros_like, params)
    opt = adamw_init(params)

    def loss(p):
        return sum(jnp.sum((a - t) ** 2) for a, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, opt, g, 3e-2, weight_decay=0.0)
    assert float(loss(params)) < 0.2 * l0


def test_adamw_int8_state_tracks_fp32(rng):
    p32 = _toy_params(rng)
    p8 = jax.tree.map(jnp.copy, p32)  # adamw_update donates its inputs
    o32, o8 = adamw_init(p32), adamw_init(p8, state_bits=8)

    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))

    for _ in range(20):
        g32 = jax.grad(loss)(p32)
        g8 = jax.grad(loss)(p8)
        p32, o32, _ = adamw_update(p32, o32, g32, 1e-2)
        p8, o8, _ = adamw_update(p8, o8, g8, 1e-2, state_bits=8)
    # 8-bit states trade exactness for memory: ~1%/step drift is expected
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p8)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.1)


def test_grad_clip_caps_update_norm(rng):
    params = _toy_params(rng)
    opt = adamw_init(params)
    huge = jax.tree.map(lambda x: 1e6 * jnp.ones_like(x), params)
    p2, opt, gnorm = adamw_update(params, opt, huge, 1e-3, weight_decay=0.0,
                                  clip_norm=1.0)
    assert float(gnorm) > 1e5  # reported raw norm
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"params": _toy_params(rng), "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_commit(tmp_path, rng):
    """A stale .tmp dir (crash mid-write) must be invisible to readers."""
    tree = {"w": jnp.ones((4,))}
    save_checkpoint(str(tmp_path), 1, tree)
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "partial.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 1  # tmp dir not visible


def test_checkpoint_keep_k(tmp_path, rng):
    tree = {"w": jnp.ones((4,))}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_00000004", "step_00000005"]


def test_manager_resume(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), every=1)
    tree = {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    mgr.maybe_save(3, tree)
    mgr.wait()
    step, back = mgr.restore_latest(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


def test_train_restart_resumes(tmp_path):
    """End-to-end: kill after N steps, restart, final state must continue."""
    from repro.launch import train as T
    args = ["--arch", "smollm-135m", "--reduced", "--steps", "6",
            "--batch", "2", "--seq", "16", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "2"]
    T.main(args[:4] + ["3"] + args[5:])          # run steps 0..2 ("crash")
    assert latest_step(str(tmp_path)) is not None
    T.main(args)                                  # restart -> finishes 6
    assert latest_step(str(tmp_path)) == 5


# ---------------------------------------------------------------------------
# fault tolerance / elasticity
# ---------------------------------------------------------------------------

def test_failure_detector():
    fd = FailureDetector(timeout_s=10)
    fd.heartbeat(0, now=0.0)
    fd.heartbeat(1, now=0.0)
    fd.heartbeat(1, now=25.0)
    assert fd.dead_hosts(now=26.0) == [0]
    assert fd.alive_hosts(now=26.0) == [1]


def test_remesh_shrinks_data_axis():
    plan = plan_remesh(range(64), devices_per_host=4, model=16)
    assert plan.model == 16 and plan.data == 16
    smaller = plan_remesh(range(60), devices_per_host=4, model=16)
    assert smaller.model == 16 and smaller.data == 15
    assert smaller.n_devices == 240


def test_remesh_deterministic():
    a = plan_remesh([3, 1, 7, 5], devices_per_host=4, model=4)
    b = plan_remesh([7, 5, 3, 1], devices_per_host=4, model=4)
    assert a.host_of_coord == b.host_of_coord


def test_straggler_reassignment():
    m = reassign_shards(step=4, n_shards=8, alive=range(6), stragglers=[2])
    assert set(m.values()) <= {0, 1, 3, 4, 5}
    m2 = reassign_shards(step=4, n_shards=8, alive=range(6), stragglers=[2])
    assert m == m2  # deterministic


def test_elastic_restore_on_smaller_mesh(tmp_path, rng):
    """Checkpoint saved under one sharding restores under another."""
    tree = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}
    save_checkpoint(str(tmp_path), 0, tree)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back = restore_checkpoint(str(tmp_path), 0, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_feedback(rng):
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    q, s, res = quantize_grads(g)
    deq = dequantize_grads(q, s)
    err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    amax = float(jnp.max(jnp.abs(g["w"])))
    assert err <= amax / 127 + 1e-6
    # residual exactly captures the quantization error
    np.testing.assert_allclose(np.asarray(res["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)


def test_error_feedback_unbiased_over_steps(rng):
    """Constant gradient + error feedback: cumulative dequantized sum
    converges to the true sum (bias does not accumulate)."""
    g = {"w": jnp.asarray(rng.standard_normal((32,)) * 1e-3, jnp.float32)}
    res = None
    total = jnp.zeros_like(g["w"])
    N = 50
    for _ in range(N):
        q, s, res = quantize_grads(g, res)
        total = total + dequantize_grads(q, s)["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"] * N),
                               rtol=0.05, atol=1e-4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    cfg = reduced(get_config("smollm-135m"))
    pipe = TokenPipeline(cfg, seq_len=16, global_batch=8, seed=3)
    a = pipe.global_batch_at(5)
    b = pipe.global_batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < cfg.vocab
    # host shards tile the global batch
    shards = [pipe.shard_for(5, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), a["tokens"])
    # different steps differ
    c = pipe.global_batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
