"""Serving-layer tests (ISSUE 3): batching substrate, program-cache
semantics, and batched-vs-sequential parity.

Pinned here: (a) block-diagonal batching round-trips exactly; (b) the
structural signature hits on same-structure/different-edges and misses when
feature dims or kernel tags change; (c) a repeated-signature stream serves
with > 90% cache hits and ZERO recompilations after warmup (compile counter
+ jit-cache introspection); (d) batched outputs match the per-graph oracle
on >= 3 paper models.
"""
import numpy as np
import pytest

from repro.core import compiler, executor, pipeline, tiling
from repro.gnn import graphs, models
from repro.serve import (InferenceServer, ProgramCache, ShapeRegistry,
                         canonical_tiles, quantize, serving_grid, size_class,
                         structure_signature)

TOL = 5e-4


def _compiled(name, dim=16):
    tr = models.trace_named(name, dim, dim)
    return tr, compiler.compile_gnn(tr)


def _stream(tr, name, n, v=48, e=200, seed0=0):
    etypes = 3 if models.MODELS[name].needs_etype else None
    gs = [graphs.random_graph(v, e, seed=seed0 + k, model="powerlaw",
                              n_edge_types=etypes) for k in range(n)]
    ins = [models.init_inputs(tr, g, seed=seed0 + k)
           for k, g in enumerate(gs)]
    return gs, ins


# ---------------------------------------------------------------------------
# batching substrate
# ---------------------------------------------------------------------------

def test_batch_graphs_roundtrip():
    gs = [graphs.random_graph(10 + 3 * i, 30 + 5 * i, seed=i) for i in range(4)]
    batch = graphs.batch_graphs(gs)
    assert batch.n_graphs == 4
    assert batch.graph.n_vertices == sum(g.n_vertices for g in gs)
    assert batch.graph.n_edges == sum(g.n_edges for g in gs)
    batch.graph.validate()
    # block-diagonal: every edge stays inside its member's vertex range
    for i, g in enumerate(gs):
        lo, hi = batch.vertex_offsets[i], batch.vertex_offsets[i + 1]
        e0, e1 = batch.edge_offsets[i], batch.edge_offsets[i + 1]
        assert ((batch.graph.src[e0:e1] >= lo) & (batch.graph.src[e0:e1] < hi)).all()
        assert ((batch.graph.dst[e0:e1] >= lo) & (batch.graph.dst[e0:e1] < hi)).all()
        np.testing.assert_array_equal(batch.graph.src[e0:e1] - lo, g.src)
    # unbatch inverts the merge
    varr = np.arange(batch.graph.n_vertices, dtype=np.float32)[:, None]
    parts = batch.unbatch_vertex(varr)
    assert [p.shape[0] for p in parts] == [g.n_vertices for g in gs]
    np.testing.assert_array_equal(np.concatenate(parts), varr)
    earr = np.arange(batch.graph.n_edges, dtype=np.float32)[:, None]
    assert [p.shape[0] for p in batch.unbatch_edge(earr)] == \
        [g.n_edges for g in gs]
    # per-graph readout
    pooled = batch.graph_pool(np.ones((batch.graph.n_vertices, 2)), "sum")
    np.testing.assert_allclose(pooled[:, 0], [g.n_vertices for g in gs])
    np.testing.assert_allclose(batch.graph_pool(varr, "mean")[:, 0],
                               [varr[batch.vertex_offsets[i]:
                                     batch.vertex_offsets[i + 1]].mean()
                                for i in range(4)])
    # class-padded arrays pool identically; short arrays are rejected
    vpad = np.concatenate([varr, np.full((7, 1), 1e9, np.float32)])
    np.testing.assert_allclose(batch.graph_pool(vpad, "sum"),
                               batch.graph_pool(varr, "sum"))
    with pytest.raises(ValueError):
        batch.graph_pool(varr[:-1])
    # integer means stay fractional (no silent truncating cast)
    imean = batch.graph_pool(varr.astype(np.int32), "mean")
    assert np.issubdtype(imean.dtype, np.floating)
    np.testing.assert_allclose(imean, batch.graph_pool(varr, "mean"))


def test_batch_graphs_rejects_mixed_edge_types():
    g1 = graphs.random_graph(10, 20, seed=0, n_edge_types=3)
    g2 = graphs.random_graph(10, 20, seed=1)
    with pytest.raises(ValueError):
        graphs.batch_graphs([g1, g2])


def test_pad_graph_and_tileset_preserve_results():
    """Padding vertices + filler tiles is invisible to real-vertex outputs,
    under both the scan and the Pallas kernel paths."""
    g = graphs.random_graph(90, 380, seed=7, model="powerlaw")
    for name in ("gcn", "gat"):
        tr, c = _compiled(name)
        params = models.init_params(tr)
        inputs = models.init_inputs(tr, g)
        ref = executor.run_reference(tr, g, inputs, params)
        padded = graphs.pad_graph(g, 128)
        pin = {k: np.concatenate([v, np.zeros((128 - 90,) + v.shape[1:],
                                              v.dtype)])
               if k != "etype" else v for k, v in inputs.items()}
        ts = tiling.grid_tile(padded, 4, 4, sparse=True)
        pts = tiling.pad_tileset(ts, ts.n_tiles + 5, ts.s_max + 8,
                                 ts.e_max + 16)
        for kd in (False, True):
            out = pipeline.run_pipelined(c, padded, pts, pin, params,
                                         kernel_dispatch=kd)
            err = float(np.max(np.abs(np.asarray(out[0])[:90] - ref[0])))
            assert err < TOL, (name, kd, err)


# ---------------------------------------------------------------------------
# program-cache semantics
# ---------------------------------------------------------------------------

def test_program_cache_lru_and_counters():
    cache = ProgramCache(capacity=2)
    built = []
    for key in ("a", "b", "a", "c", "a"):   # c evicts b; final a still hits
        cache.get_or_build(key, lambda k=key: built.append(k) or k.upper())
    assert built == ["a", "b", "c"]
    assert cache.stats.compiles == 3 and cache.stats.hits == 2
    assert cache.stats.evictions == 1 and len(cache) == 2
    assert "b" not in cache and cache.get("a") == "A"


def test_signature_hits_same_structure_different_edges():
    """Two different random graphs of one size class share the cache key
    once the shape registry has seen the class."""
    _, c = _compiled("gcn")
    registry = ShapeRegistry()
    keys = []
    for seed in (0, 1, 2, 3):
        g = graphs.random_graph(64, 256, seed=seed, model="powerlaw")
        _, ts, e_rows, _ = registry.canonical(size_class(g), g)
        keys.append(structure_signature(c, ts, e_rows))
    assert len(set(keys[1:])) == 1      # everything after first sight hits
    assert keys[0] == keys[1]           # headroom absorbed seed-0's shapes


def test_signature_misses_on_feature_dim_change():
    _, c16 = _compiled("gcn", dim=16)
    _, c24 = _compiled("gcn", dim=24)
    g = graphs.random_graph(64, 256, seed=0)
    vq = quantize(g.n_vertices)
    ts = canonical_tiles(graphs.pad_graph(g, vq), serving_grid(vq))
    assert structure_signature(c16, ts) != structure_signature(c24, ts)


def test_signature_misses_on_kernel_tag_change():
    g = graphs.random_graph(64, 256, seed=0)
    vq = quantize(g.n_vertices)
    ts = canonical_tiles(graphs.pad_graph(g, vq), serving_grid(vq))
    _, cg = _compiled("gcn")
    _, ca = _compiled("gat")
    # different model -> different kernel tags (pallas_spmm vs segment_softmax)
    assert structure_signature(cg, ts) != structure_signature(ca, ts)
    # same model, dispatch off -> scan tags -> also a different program
    assert structure_signature(cg, ts, kernel_dispatch=True) != \
        structure_signature(cg, ts, kernel_dispatch=False)


def test_signature_misses_on_node_attr_change():
    """Trace-time constants (e.g. leaky_relu slope) bake into the compiled
    program, so programs differing only there must not share a runner."""
    from repro.core.trace import trace_model

    def build(slope):
        def b(tr, g):
            x = tr.input_vertex(8, "x")
            tr.mark_output(g.gather_sum(g.scatter_src(x.leaky_relu(slope))))
        return b

    ca = compiler.compile_gnn(trace_model(build(0.2), name="m"))
    cb = compiler.compile_gnn(trace_model(build(0.01), name="m"))
    assert ca.structure_signature() != cb.structure_signature()


def test_server_cache_hit_across_requests_miss_across_classes():
    tr, c = _compiled("gcn")
    params = models.init_params(tr)
    server = InferenceServer(c, params, cache_capacity=8)
    gs1, ins1 = _stream(tr, "gcn", 4, seed0=0)
    gs2, ins2 = _stream(tr, "gcn", 4, seed0=100)      # same class, new edges
    server.submit(gs1, ins1)
    server.submit(gs2, ins2)
    assert server.compile_count == 1 and server.cache.stats.hits == 1
    # a much bigger graph lands in a different size class -> one new compile
    gbig, ibig = _stream(tr, "gcn", 4, v=300, e=1400, seed0=7)
    server.submit(gbig, ibig)
    assert server.compile_count == 2


def test_repeated_stream_hit_rate_and_zero_recompiles():
    """Acceptance: > 90% hit rate and zero recompilations after warmup on a
    repeated-signature stream, via the compile counter AND jit introspection."""
    tr, c = _compiled("gcn")
    params = models.init_params(tr)
    server = InferenceServer(c, params)
    warm_g, warm_i = _stream(tr, "gcn", 6, seed0=0)
    server.submit(warm_g, warm_i)                     # warmup: one compile
    compiles_after_warmup = server.compile_count
    for req in range(1, 12):
        gs, ins = _stream(tr, "gcn", 6, seed0=req * 50)
        server.submit(gs, ins)
    st = server.cache.stats
    assert server.compile_count == compiles_after_warmup == 1
    post = st.hits / (st.requests - 1)                # exclude the warmup miss
    assert post > 0.9, f"post-warmup hit rate {post:.2f}"
    runner = next(iter(server.cache._entries.values()))
    if runner.jit_cache_size() >= 0:                  # no silent XLA retraces
        assert runner.jit_cache_size() == 1


# ---------------------------------------------------------------------------
# batched-vs-sequential parity (>= 3 paper models)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["gcn", "gat", "rgcn", "sage"])
def test_batched_serving_matches_per_graph_oracle(name):
    tr, c = _compiled(name)
    params = models.init_params(tr)
    server = InferenceServer(c, params)
    gs, ins = _stream(tr, name, 6, seed0=3)
    outs = server.submit(gs, ins)
    for g, inp, out in zip(gs, ins, outs):
        ref = executor.run_reference(tr, g, inp, params)
        assert len(ref) == len(out)
        for r, o in zip(ref, out):
            assert o.shape == np.asarray(r).shape
            err = float(np.max(np.abs(np.asarray(r) - o)))
            assert err < TOL, (name, err)


def test_server_groups_mixed_sizes_in_one_submit():
    """One submit with two size classes: every graph still comes back exact
    and in order; two compilations, one per class."""
    tr, c = _compiled("gcn")
    params = models.init_params(tr)
    server = InferenceServer(c, params)
    small_g, small_i = _stream(tr, "gcn", 3, v=40, e=150, seed0=0)
    big_g, big_i = _stream(tr, "gcn", 3, v=260, e=1200, seed0=9)
    gs = [small_g[0], big_g[0], small_g[1], big_g[1], small_g[2], big_g[2]]
    ins = [small_i[0], big_i[0], small_i[1], big_i[1], small_i[2], big_i[2]]
    outs = server.submit(gs, ins)
    assert server.compile_count == 2
    for g, inp, out in zip(gs, ins, outs):
        ref = executor.run_reference(tr, g, inp, params)
        assert float(np.max(np.abs(np.asarray(ref[0]) - out[0]))) < TOL


def test_server_handles_edgeless_graphs():
    """A graph with no edges must serve (zero aggregation), not crash the
    kernel grid with a zero-tile batch."""
    tr, c = _compiled("gcn")
    params = models.init_params(tr)
    server = InferenceServer(c, params)
    g = graphs.Graph(src=np.empty(0, np.int32), dst=np.empty(0, np.int32),
                     n_vertices=8, name="edgeless")
    inp = models.init_inputs(tr, g)
    (out,) = server.submit([g], [inp])[0]
    ref = executor.run_reference(tr, g, inp, params)
    assert float(np.max(np.abs(np.asarray(ref[0]) - out))) < TOL


def test_size_class_groups_similar_graphs():
    a = graphs.random_graph(60, 240, seed=0)
    b = graphs.random_graph(55, 230, seed=1)
    big = graphs.random_graph(400, 2000, seed=2)
    assert size_class(a) == size_class(b) != size_class(big)


# ---------------------------------------------------------------------------
# multi-layer programs in the serving cache (ISSUE 4)
# ---------------------------------------------------------------------------

def test_program_cache_distinguishes_layer_counts():
    """A 1-layer and a 2-layer GCN of the same dims must never share a
    compiled runner: their structure signatures differ."""
    tr1 = models.trace_named("gcn", 16, 16)
    tr2 = models.trace_stacked("gcn", 2, 16, 16, 16)
    c1, c2 = compiler.compile_gnn(tr1), compiler.compile_gnn(tr2)
    assert c1.structure_signature() != c2.structure_signature()
    g = graphs.random_graph(64, 256, seed=0)
    vq = quantize(g.n_vertices)
    ts = canonical_tiles(graphs.pad_graph(g, vq), serving_grid(vq))
    assert structure_signature(c1, ts) != structure_signature(c2, ts)
    cache = ProgramCache(capacity=4)
    cache.get_or_build(structure_signature(c1, ts), lambda: "one-layer")
    cache.get_or_build(structure_signature(c2, ts), lambda: "two-layer")
    assert cache.stats.compiles == 2 and len(cache) == 2


def test_multilayer_server_zero_recompiles_and_counters():
    """Acceptance: repeated same-structure submissions of a 2-layer model
    serve entirely from the warm runner — hit/miss/compile counters exposed
    on the server stay at one compile."""
    server = InferenceServer("gcn", n_layers=2, cache_capacity=8)
    tr = server.compiled.trace
    assert server.compiled.n_layers == 2
    params = models.init_params(tr)
    warm_g, warm_i = _stream(tr, "gcn", 4, seed0=0)
    server.submit(warm_g, warm_i, params)
    assert (server.cache_misses, server.compile_count) == (1, 1)
    for req in range(1, 6):
        gs, ins = _stream(tr, "gcn", 4, seed0=req * 40)
        server.submit(gs, ins, params)
    assert server.compile_count == 1, "multi-layer submissions recompiled"
    assert server.cache_hits == 5 and server.cache_misses == 1
    assert server.stats()["n_layers"] == 2
    # and the batched results still match the per-graph stacked oracle
    gs, ins = _stream(tr, "gcn", 3, seed0=777)
    outs = server.submit(gs, ins, params)
    for g, inp, out in zip(gs, ins, outs):
        ref = executor.run_reference(tr, g, inp, params)
        assert float(np.max(np.abs(np.asarray(ref[0]) - out[0]))) < TOL


# ---------------------------------------------------------------------------
# property: batch -> pad -> run -> unbatch round-trip (hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_batch_pad_run_unbatch_matches_per_graph_oracle():
    """Random small multigraphs batched block-diagonally, tiled, padded with
    filler tiles, run through the pipelined engine, and unbatched must match
    every member's whole-graph oracle (small default profile, slow marker)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need the optional hypothesis dep")
    from hypothesis import given, settings, strategies as st

    tr, c = _compiled("gcn", dim=8)
    params = models.init_params(tr)

    @given(sizes=st.lists(st.tuples(st.integers(4, 32), st.integers(0, 90)),
                          min_size=2, max_size=4),
           seed=st.integers(0, 100), kd=st.booleans())
    @settings(max_examples=10, deadline=None)
    def check(sizes, seed, kd):
        gs = [graphs.random_graph(v, e, seed=seed + i, model="powerlaw")
              for i, (v, e) in enumerate(sizes)]
        ins = [models.init_inputs(tr, g, seed=seed + i)
               for i, g in enumerate(gs)]
        batch = graphs.batch_graphs(gs)
        merged = {name: np.concatenate([np.asarray(i[name]) for i in ins])
                  for name in ("x", "dnorm")}
        ts = tiling.grid_tile(batch.graph, 3, 3, sparse=True)
        pts = tiling.pad_tileset(ts, ts.n_tiles + 2, ts.s_max + 8,
                                 ts.e_max + 8)
        out = pipeline.run_pipelined(c, batch.graph, pts, merged, params,
                                     kernel_dispatch=kd)
        parts = batch.unbatch_vertex(np.asarray(out[0]))
        for g, inp, got in zip(gs, ins, parts):
            ref = np.asarray(executor.run_reference(tr, g, inp, params)[0])
            assert got.shape == ref.shape
            np.testing.assert_allclose(got, ref, atol=TOL, rtol=0)

    check()
