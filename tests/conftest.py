import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# 512-device flag in its own process) — do not set device-count flags here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
