"""Static analysis & verification layer (ISSUE 6).

Pinned here: (a) all five paper models × {1,2,3} layers × both
kernel-dispatch settings analyze *clean* (zero error-severity diagnostics)
through the IR verifier, the schedule verifier, the exchange census, and the
task-graph race detector; (b) a negative-path suite — each seeded mutation
of a valid artifact is caught with its expected stable diagnostic code,
including a dropped drain dependency the hazard analyzer must flag as a
ZH201 race; (c) the ``compile_gnn(verify=True)`` default hook and the
satellite fixes (``rebuild_channels`` raising on orphaned recvs,
``toposort`` naming cycle members); (d) the static exchange census equals
``n_layers`` for every paper model (the HLO regex cross-check lives in
``test_sharded.py``).
"""
import copy
import random

import pytest

from repro.core import analysis as A
from repro.core import compiler, isa, tiling
from repro.core import ir as IR
from repro.core import schedule as S
from repro.core.streams import HWConfig, build_task_graph
from repro.gnn import graphs, models

DIM = 16


def _compiled(name, n_layers=2, dim=DIM, **kw):
    tr = models.trace_stacked(name, n_layers, dim, dim, dim)
    return compiler.compile_gnn(tr, **kw)


def _codes(diags):
    return {d.code for d in diags}


def _error_codes(diags):
    return {d.code for d in A.errors(diags)}


def _first(prog, pred):
    for seg in prog.segments:
        for n in seg.nodes.values():
            if pred(n):
                return seg, n
    raise AssertionError("no node matches")


# ---------------------------------------------------------------------------
# clean matrix: five paper models x {1,2,3} layers x both dispatch modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", models.PAPER_MODELS)
def test_paper_models_analyze_clean(name):
    for n_layers in (1, 2, 3):
        c = _compiled(name, n_layers)           # verify=True is the default
        diags = A.analyze(c)                    # IR + both schedules + census
        assert not A.errors(diags), (name, n_layers,
                                     A.format_report(diags, "dirty"))


@pytest.mark.parametrize("inter_layer", ["barrier", "pipelined"])
def test_task_graphs_analyze_clean(inter_layer):
    g = graphs.random_graph(150, 600, seed=3, model="powerlaw",
                            n_edge_types=3)
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    for name in ("gcn", "gat"):
        c = _compiled(name, 2)
        sde = isa.emit_sde(c.schedule(True))
        tasks, _ = build_task_graph(sde, ts, HWConfig(),
                                    inter_layer=inter_layer)
        diags = A.analyze(tasks, sde=sde, tiles=ts, inter_layer=inter_layer)
        assert not A.errors(diags), A.format_report(diags, name)
        # structured Task identity: no label parsing needed downstream
        assert all(t.level >= 0 and t.part >= 0 and t.role for t in tasks)


def test_bucketed_and_per_chip_task_graphs_analyze_clean():
    g = graphs.random_graph(150, 600, seed=3, model="powerlaw")
    bt = tiling.bucket_tiles(tiling.grid_tile(g, 5, 5, sparse=True), 3)
    c = _compiled("gcn", 2)
    sde = isa.emit_sde(c.schedule(True))
    tasks, _ = build_task_graph(sde, bt, HWConfig(), inter_layer="pipelined",
                                parts=[0, 1])
    diags = A.analyze(tasks, sde=sde, tiles=bt, inter_layer="pipelined",
                      parts=[0, 1])
    assert not A.errors(diags), A.format_report(diags, "per-chip")
    # boundary reads landing on the other chip surface as info, not races
    assert "ZH206" in _codes(diags)


def test_static_exchange_census_counts_one_collective_per_layer():
    # the census invariant must hold for BOTH schedule variants — the
    # sharded runner executes either one, Pallas kernels on or off
    for name in models.PAPER_MODELS:
        for n_layers in (1, 2, 3):
            for dispatch in (False, True):
                sp = _compiled(name, n_layers).schedule(dispatch)
                cen = A.exchange_census(sp)
                assert cen.n_collectives == n_layers, \
                    (name, n_layers, dispatch, cen.events)
                assert cen.publish <= cen.tainted   # nothing untainted moves
                assert not A.verify_exchange(sp)


def test_sharded_runner_publish_set_matches_static_census():
    """The census is only a proof if it derives the SAME publish set the
    runner actually drains — check the dynamic set against the static one
    for scan and kernel schedules alike."""
    from repro.core.pipeline import ShardedRunner
    g = graphs.random_graph(120, 480, seed=7, model="powerlaw")
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    for name in ("gcn", "gat", "ggnn"):        # spmm_w / segsoftmax / spmm
        c = _compiled(name, 2)
        for dispatch in (False, True):
            r = ShardedRunner(c, g, ts, 1, kernel_dispatch=dispatch)
            cen = A.exchange_census(c.schedule(dispatch))
            assert r._publish == set(cen.publish), (name, dispatch)


# ---------------------------------------------------------------------------
# negative paths: IR verifier (ZA0xx)
# ---------------------------------------------------------------------------

def test_orphaned_recv_is_caught_and_rebuild_channels_raises():
    c = _compiled("gcn")
    prog = copy.deepcopy(c.ir)
    _, recv = _first(prog, IR.IRNode.is_recv)
    recv.comm_id = 9999
    assert "ZA009" in _error_codes(A.verify_ir(prog))
    # satellite regression: rebuild_channels must raise, not drop, the recv
    with pytest.raises(ValueError, match="recv comm 9999 has no send"):
        prog.rebuild_channels()
    with pytest.raises(ValueError, match="has no send"):
        prog.validate()


def test_channel_dim_mismatch_is_caught():
    prog = copy.deepcopy(_compiled("gcn").ir)
    _, recv = _first(prog, IR.IRNode.is_recv)
    recv.dim += 3
    assert "ZA008" in _error_codes(A.verify_ir(prog))


def test_unknown_op_is_caught_and_op_unit_strict_raises():
    prog = copy.deepcopy(_compiled("gcn").ir)
    _, n = _first(prog, lambda n: n.op == "mul")
    n.op = "frobnicate"
    assert "ZA001" in _error_codes(A.verify_ir(prog))
    assert IR.op_unit("frobnicate") == "CTRL"         # legacy: silent bucket
    with pytest.raises(ValueError, match="not in the IR vocabulary"):
        IR.op_unit("frobnicate", strict=True)


def test_broadcast_and_contraction_dim_mutations_are_caught():
    prog = copy.deepcopy(_compiled("gat").ir)
    _, n = _first(prog, lambda n: n.op in IR.ELW_BINARY)
    n.dim += 5
    assert "ZA004" in _error_codes(A.verify_ir(prog))

    prog = copy.deepcopy(_compiled("gcn").ir)
    _, mm = _first(prog, lambda n: n.op == "matmul")
    mm.attrs["wshape"] = (mm.attrs["wshape"][0] + 1, mm.attrs["wshape"][1])
    assert "ZA005" in _error_codes(A.verify_ir(prog))


def test_cycle_is_caught_and_toposort_names_the_nodes():
    prog = copy.deepcopy(_compiled("gcn").ir)
    seg, n = _first(prog, lambda n: not n.is_recv() and n.inputs)
    dep = seg.nodes[n.inputs[0]]
    dep.inputs.append(n.id)
    assert "ZA003" in _error_codes(A.verify_ir(prog))
    # satellite regression: the exception names the cycle members
    with pytest.raises(ValueError,
                       match=rf"cycle in segment {seg.label}:.*%{n.id}"):
        seg.toposort()


def test_layer_monotonicity_violation_is_caught():
    prog = copy.deepcopy(_compiled("gcn").ir)
    _, n = _first(prog, lambda n: n.layer == 0 and n.inputs)
    seg, dep = _first(prog, lambda m: m.id == n.inputs[0])
    dep.layer = 1
    assert "ZA012" in _error_codes(A.verify_ir(prog))


def test_dead_node_and_unused_channel_warn_not_error():
    prog = copy.deepcopy(_compiled("gcn").ir)
    seg = prog.segments[0]
    _, src = _first(prog, lambda n: n.inputs)
    seg.add(IR.IRNode(id=prog.fresh_id(), op="relu", inputs=[src.inputs[0]],
                      dim=seg.nodes[src.inputs[0]].dim))
    diags = A.verify_ir(prog)
    assert not A.errors(diags)
    assert "ZA013" in _codes(diags)

    prog = copy.deepcopy(_compiled("gcn").ir)
    _, recv = _first(prog, lambda n: n.op == "recvSrc")
    for sg in prog.segments:
        for m in sg.nodes.values():
            m.inputs = [i for i in m.inputs if i != recv.id]
    diags = A.verify_ir(prog)
    assert "ZA014" in _codes(diags)
    assert "ZA014" not in _error_codes(diags)


def test_recv_with_inputs_is_caught():
    prog = copy.deepcopy(_compiled("gcn").ir)
    seg, recv = _first(prog, IR.IRNode.is_recv)
    other = next(n for n in seg.nodes.values() if n.id != recv.id)
    recv.inputs = [other.id]
    assert "ZA015" in _error_codes(A.verify_ir(prog))


# ---------------------------------------------------------------------------
# negative paths: schedule verifier (ZS1xx)
# ---------------------------------------------------------------------------

def _gather_blocks(sp):
    return [(ph, g) for ph in sp.phases for g in ph.gathers]


def test_swapped_kernel_tag_is_caught():
    sp = copy.deepcopy(_compiled("gcn").schedule(True))
    ph, g = next((ph, g) for ph, g in _gather_blocks(sp)
                 if g.kernel != S.KERNEL_SCAN)
    swapped = (S.KERNEL_SPMM if g.kernel != S.KERNEL_SPMM
               else S.KERNEL_SPMM_WEIGHTED)
    g.kernel = swapped
    want = {S.KERNEL_SPMM: "ZS104", S.KERNEL_SPMM_WEIGHTED: "ZS105"}[swapped]
    assert want in _error_codes(A.verify_schedule(sp))


def test_softmax_tag_on_non_softmax_gather_is_caught():
    sp = copy.deepcopy(_compiled("gcn").schedule(True))
    ph, g = next((ph, g) for ph, g in _gather_blocks(sp)
                 if g.kernel != S.KERNEL_SCAN)
    g.kernel = S.KERNEL_SEGMENT_SOFTMAX
    codes = _error_codes(A.verify_schedule(sp))
    assert "ZS106" in codes or "ZS103" in codes


def test_gather_ownership_and_covered_overlap_are_caught():
    sp = copy.deepcopy(_compiled("gcn").schedule(True))
    blocks = _gather_blocks(sp)
    assert len(blocks) >= 2
    (_, g0), (_, g1) = blocks[0], blocks[1]
    g0.covered.add(g1.acc.send_id)        # g1's channel now has two owners
    codes = _error_codes(A.verify_schedule(sp))
    assert "ZS101" in codes


def test_covered_node_leaking_into_a_block_is_caught():
    sp = copy.deepcopy(_compiled("gcn").schedule(True))
    ph, g = next((ph, g) for ph, g in _gather_blocks(sp)
                 if g.kernel != S.KERNEL_SCAN)
    leaked = sp.prog.find_node(g.acc.value_id)[1]
    ph.edge.nodes.append(leaked)
    assert "ZS109" in _error_codes(A.verify_schedule(sp))


def test_fused_levels_mutation_is_caught():
    sp = copy.deepcopy(_compiled("gat").schedule(True))
    ph, g = next((ph, g) for ph, g in _gather_blocks(sp)
                 if g.kernel == S.KERNEL_SEGMENT_SOFTMAX)
    g.fused_levels = (g.fused_levels[0], g.fused_levels[1],
                      g.fused_levels[2] + 7)
    codes = _error_codes(A.verify_schedule(sp))
    assert "ZS103" in codes or "ZS106" in codes


def test_dropped_output_store_is_caught():
    sp = copy.deepcopy(_compiled("gcn").schedule(True))
    for ph in reversed(sp.phases):
        if sp.outputs[0] in ph.dst.store_ids:
            ph.dst.store_ids.remove(sp.outputs[0])
            break
    else:
        raise AssertionError("output never stored")
    assert "ZS107" in _error_codes(A.verify_schedule(sp))


def test_accum_spec_mutation_is_caught():
    sp = copy.deepcopy(_compiled("gcn").schedule(True))
    _, g = _gather_blocks(sp)[0]
    g.acc.kind = "max" if g.acc.kind != "max" else "sum"
    assert "ZS111" in _error_codes(A.verify_schedule(sp))


def test_phase_layer_tag_regression_is_caught():
    sp = copy.deepcopy(_compiled("gcn").schedule(True))
    sp.phases[-1].layer = 0                  # layers must be monotone
    assert "ZS108" in _error_codes(A.verify_schedule(sp))


def test_missed_kernel_lint_explains_scan_fallbacks():
    # sage: max-reduce aggregate has no kernel; the lint says why
    sp = _compiled("sage").schedule(True)
    lints = [d for d in A.verify_schedule(sp) if d.code == "ZS110"]
    assert lints and all(d.severity == A.INFO for d in lints)
    assert any("max-reduce" in d.message for d in lints)
    # rgcn: per-edge-type bmm feeds the gather — no kernel matches
    sp = _compiled("rgcn").schedule(True)
    lints = [d for d in A.verify_schedule(sp) if d.code == "ZS110"]
    assert any("bmm_edge" in d.message for d in lints)
    # without kernel dispatch the scan path is intended: no lint
    sp = _compiled("sage").schedule(False)
    assert not [d for d in A.verify_schedule(sp) if d.code == "ZS110"]


# ---------------------------------------------------------------------------
# negative paths: hazard analyzer & census (ZH2xx)
# ---------------------------------------------------------------------------

def _pipelined_graph(name="gcn", n_layers=2):
    g = graphs.random_graph(150, 600, seed=3, model="powerlaw")
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    c = _compiled(name, n_layers)
    sde = isa.emit_sde(c.schedule(True))
    tasks, _ = build_task_graph(sde, ts, HWConfig(), inter_layer="pipelined")
    return tasks, sde, ts


def test_dropped_drain_dependency_is_flagged_as_race():
    """Acceptance: the race analyzer must flag a drain-ordering hazard."""
    tasks, sde, ts = _pipelined_graph()
    victim = next(
        t for t in tasks if t.role == "s" and any(
            tasks[d].role == "drain" and tasks[d].part != t.part
            for d in t.deps))
    dropped = next(d for d in victim.deps
                   if tasks[d].role == "drain" and tasks[d].part != victim.part)
    victim.deps.remove(dropped)
    diags = A.analyze_task_graph(tasks, sde=sde, tiles=ts,
                                 inter_layer="pipelined")
    races = [d for d in diags if d.code == "ZH201"]
    assert races and any(d.block == victim.label for d in races)
    assert any(f"partition {tasks[dropped].part}" in d.message for d in races)


def test_barrier_mode_ordering_violation_is_flagged():
    g = graphs.random_graph(100, 400, seed=5)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    c = _compiled("gcn", 2)
    sde = isa.emit_sde(c.schedule(True))
    tasks, _ = build_task_graph(sde, ts, HWConfig(), inter_layer="barrier")
    # cut a mid-chain d-task loose: downstream levels lose the global barrier
    victim = next(t for t in tasks if t.kind == "d" and t.level == 1 and t.deps)
    victim.deps.clear()
    diags = A.analyze_task_graph(tasks, sde=sde, tiles=ts,
                                 inter_layer="barrier")
    assert "ZH201" in _error_codes(diags)


def test_corrupt_task_graph_structure_is_flagged():
    tasks, sde, ts = _pipelined_graph()
    tasks[0].deps.append(len(tasks) + 5)        # unknown/forward reference
    diags = A.analyze_task_graph(tasks, sde=sde, tiles=ts,
                                 inter_layer="pipelined")
    assert _error_codes(diags) == {"ZH202"}


def test_barrier_not_covering_its_tiles_is_flagged():
    tasks, sde, ts = _pipelined_graph()
    barrier = next(t for t in tasks if t.role == "barrier" and len(t.deps) > 1)
    barrier.deps.pop()
    diags = A.analyze_task_graph(tasks, sde=sde, tiles=ts,
                                 inter_layer="pipelined")
    assert "ZH203" in _error_codes(diags)


def test_census_mismatch_and_untainted_exchange_are_flagged():
    sp = copy.deepcopy(_compiled("gcn").schedule(False))
    sp.n_layers += 1
    assert "ZH204" in _error_codes(A.verify_exchange(sp))

    sp = copy.deepcopy(_compiled("gcn").schedule(False))
    _, h = _first(sp.prog, lambda n: n.op == "matmul")   # untainted h = xW
    sp.outputs.append(h.id)
    diags = A.verify_exchange(sp)
    assert any(d.code == "ZH205" and d.node == h.id for d in diags)


@pytest.mark.parametrize("seed", range(6))
def test_seeded_mutation_of_kernel_schedule_is_flagged(seed):
    """The negative paths must cover the KERNEL-dispatch variant too —
    seeded structural corruptions of the schedule the sharded Pallas path
    executes may not slip past the census + schedule verifier."""
    rng = random.Random(seed)
    name = rng.choice(["gcn", "gat", "ggnn"])
    sp = copy.deepcopy(_compiled(name, 2).schedule(True))
    kind = rng.choice(["layer_count", "untainted_publish", "dropped_phase"])
    if kind == "layer_count":
        sp.n_layers += rng.randint(1, 2)
        assert "ZH204" in _error_codes(A.verify_exchange(sp)), (name, kind)
    elif kind == "untainted_publish":
        _, h = _first(sp.prog, lambda n: n.op == "matmul")
        sp.outputs.append(h.id)
        diags = A.verify_exchange(sp)
        assert any(d.code == "ZH205" and d.node == h.id for d in diags), \
            (name, kind)
    else:
        # drop a gather-bearing phase: its collective disappears from the
        # replayed event stream, so the per-layer census count breaks
        victim = next(ph for ph in reversed(sp.phases) if ph.gathers)
        sp.phases.remove(victim)
        diags = A.verify_exchange(sp) + A.verify_schedule(sp)
        assert _error_codes(diags), (name, kind)


# ---------------------------------------------------------------------------
# compile-time hook, analyze() dispatch, diagnostics plumbing
# ---------------------------------------------------------------------------

def test_compile_gnn_verifies_by_default_and_collects_diagnostics():
    c = _compiled("sage")                     # verify=True is the default
    assert c.verify
    c.schedule(True)
    assert any(d.code == "ZS110" for d in c.diagnostics)
    assert not A.errors(c.diagnostics)
    # opt-out still compiles and keeps the hook off for later lowerings
    c2 = _compiled("sage", verify=False)
    c2.schedule(True)
    assert not c2.diagnostics


def test_verification_error_carries_diagnostics():
    d = A.Diagnostic("ZA008", "send dim 4 != recv dim 7", node=3)
    err = A.VerificationError([d], context="unit")
    assert err.diagnostics == [d]
    assert "ZA008" in str(err) and "unit" in str(err)
    with pytest.raises(ValueError, match="unknown diagnostic code"):
        A.Diagnostic("ZZ999", "nope")


def test_analyze_dispatches_on_artifact_type():
    c = _compiled("gcn")
    assert not A.errors(A.analyze(c.ir))
    assert not A.errors(A.analyze(c.schedule(True)))
    assert not A.errors(A.analyze(c))
    with pytest.raises(TypeError):
        A.analyze(42)


def test_diagnostic_formatting_and_code_registry():
    assert all(sev in A.SEVERITIES and meaning
               for sev, meaning in A.CODES.values())
    d = A.Diagnostic("ZS107", "value read early", phase=2, node=9,
                     block="dst")
    assert d.severity == A.ERROR
    assert "%9" in d.anchor and "phase 2" in d.anchor
    assert d.to_dict()["code"] == "ZS107"
    report = A.format_report([d], title="t")
    assert "ZS107" in report and "1 error" in report


def test_cli_runs_clean_and_fail_on_gates():
    from repro.analyze import main
    assert main(["--models", "gcn", "--layers", "1"]) == 0
    # sage emits ZS110 info findings: --fail-on info must gate on them
    assert main(["--models", "sage", "--layers", "1",
                 "--fail-on", "info"]) == 1
    assert main(["--models", "sage", "--layers", "1"]) == 0


# ---------------------------------------------------------------------------
# randomized sweep (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    pass                                              # deterministic sweep above still runs
else:
    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    @given(name=st.sampled_from(list(models.PAPER_MODELS)),
           n_layers=st.integers(1, 3),
           dim=st.sampled_from([4, 8, 16]))
    def test_analysis_clean_property(name, n_layers, dim):
        c = _compiled(name, n_layers, dim=dim)
        diags = A.analyze(c)
        assert not A.errors(diags), A.format_report(diags, "dirty")
