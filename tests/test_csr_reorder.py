"""CSR-within-tile layout + reorder provenance (§5.3 satellites).

Three invariant families pinned here:

* **CSR structure** — :func:`~repro.core.tiling.csr_tiles` produces monotone
  per-tile row pointers whose runs partition exactly the real edge slots,
  padded slots stay unreachable past ``row_ptr[t, -1]`` (so the kernels need
  no tail masking — asserted by poisoning the padding), and the byte model
  charges one column index per edge plus the row-pointer tables.
* **Reorder coverage** — out-degree sorting, degenerate graphs (zero-edge,
  single-vertex), and the permute/unpermute round trip (property-based when
  hypothesis is installed).
* **Cache isolation** — CSR vs COO tile sets and identity vs degree reorder
  modes always produce distinct ``structure_signature`` keys and distinct
  :class:`~repro.serve.signature.ShapeRegistry` registrations; a layout or
  reorder change can never silently reuse a compiled program.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reorder, tiling
from repro.gnn import graphs
from repro.kernels.tile_spmm import ops as tops
from repro.kernels.tile_spmm.kernel import tile_flags
from repro.kernels.tile_spmm.ref import (segment_softmax_csr_ref,
                                         tile_spmm_csr_ref)
from repro.serve.signature import ShapeRegistry, structure_signature


def _graph(v=120, e=500, seed=3):
    return graphs.random_graph(v, e, seed=seed, model="powerlaw")


# ---------------------------------------------------------------------------
# CSR tile structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,s", [(4, 4), (3, 5), (1, 1)])
def test_csr_row_ptr_partitions_real_edges(p, s):
    g = _graph()
    ts = tiling.grid_tile(g, p, s, sparse=True)
    cs = tiling.csr_tiles(ts)
    assert cs.layout == "csr" and cs.row_ptr is not None
    dmax = int(ts.part_size.max())
    assert cs.row_ptr.shape == (ts.n_tiles, dmax + 1)
    for t in range(cs.n_tiles):
        rp = cs.row_ptr[t]
        ne = int(cs.n_edge[t])
        assert rp[0] == 0 and rp[-1] == ne      # padded slots unreachable
        assert (np.diff(rp) >= 0).all()
        for d in range(dmax):
            run = cs.edge_dst[t, rp[d]:rp[d + 1]]
            assert (run == d).all(), (t, d)
        # same edges, same src/dst pairs — only the intra-tile order moved
        assert sorted(cs.edge_gid[t, :ne]) == sorted(ts.edge_gid[t, :ne])
        pairs = {(int(a), int(b)) for a, b in
                 zip(ts.edge_src[t, :ne], ts.edge_dst[t, :ne])}
        assert pairs == {(int(a), int(b)) for a, b in
                         zip(cs.edge_src[t, :ne], cs.edge_dst[t, :ne])}
    # idempotent, and grid_tile(layout=) is the same construction
    assert tiling.csr_tiles(cs) is cs
    direct = tiling.grid_tile(g, p, s, sparse=True, layout="csr")
    assert direct.shape_signature() == cs.shape_signature()
    np.testing.assert_array_equal(direct.row_ptr, cs.row_ptr)


def test_csr_edge_index_bytes_model():
    g = _graph()
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    cs = tiling.csr_tiles(ts)
    E = int(ts.n_edge.sum())
    assert ts.edge_index_bytes() == E * 8                   # (src, dst) pairs
    width = cs.row_ptr.shape[1]
    assert cs.edge_index_bytes() == E * 4 + cs.n_tiles * width * 4
    # the layouts diverge only in index traffic, not vertex traffic
    assert cs.src_vertex_loads() == ts.src_vertex_loads()


def test_grid_tile_rejects_unknown_layout():
    with pytest.raises(ValueError, match="layout"):
        tiling.grid_tile(_graph(), 2, 2, sparse=True, layout="ell")


# ---------------------------------------------------------------------------
# CSR kernels vs whole-graph oracles (padding poisoned on purpose)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_csr_spmm_matches_whole_graph(use_pallas, rng):
    g = _graph(100, 420, seed=5)
    cs = tiling.grid_tile(g, 4, 3, sparse=True, layout="csr")
    F = 16
    x = rng.standard_normal((g.n_vertices, F)).astype(np.float32)
    w_g = rng.standard_normal(g.n_edges).astype(np.float32)

    xs = tops.gather_sources(cs, x)
    w = w_g[cs.edge_gid].astype(np.float32)
    for t in range(cs.n_tiles):                 # poison padded edge slots:
        w[t, int(cs.n_edge[t]):] = 1e9          # row_ptr must never reach them
    out = tops.spmm_csr(jnp.asarray(cs.row_ptr), jnp.asarray(cs.edge_src),
                        jnp.asarray(w), xs, jnp.asarray(cs.part_id),
                        jnp.asarray(tile_flags(cs.part_id)),
                        n_parts=cs.n_dst_parts, use_pallas=use_pallas)

    whole = np.zeros((g.n_vertices, F), np.float32)
    np.add.at(whole, g.dst, w_g[:, None] * x[g.src])
    for p in range(cs.n_dst_parts):
        n, lo = int(cs.part_size[p]), int(cs.part_start[p])
        np.testing.assert_allclose(np.asarray(out)[p, :n], whole[lo:lo + n],
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_csr_segment_softmax_matches_whole_graph(use_pallas, rng):
    g = _graph(80, 360, seed=7)
    cs = tiling.grid_tile(g, 3, 3, sparse=True, layout="csr")
    F = 8
    s_g = rng.standard_normal(g.n_edges).astype(np.float32)
    v_g = rng.standard_normal((g.n_edges, F)).astype(np.float32)

    scores = s_g[cs.edge_gid].astype(np.float32)
    vals = v_g[cs.edge_gid].astype(np.float32)
    for t in range(cs.n_tiles):
        scores[t, int(cs.n_edge[t]):] = 1e9     # poisoned padding again
    out = tops.gat_aggregate_csr(
        jnp.asarray(cs.row_ptr), jnp.asarray(scores), jnp.asarray(vals),
        jnp.asarray(cs.part_id), jnp.asarray(tile_flags(cs.part_id)),
        n_parts=cs.n_dst_parts, use_pallas=use_pallas)

    whole = np.zeros((g.n_vertices, F), np.float32)
    for v in np.unique(g.dst):
        e = np.nonzero(g.dst == v)[0]
        p = np.exp(s_g[e] - s_g[e].max())
        whole[v] = (p[:, None] * v_g[e]).sum(0) / p.sum()
    for p in range(cs.n_dst_parts):
        n, lo = int(cs.part_size[p]), int(cs.part_start[p])
        got = np.asarray(out)[p, :n]
        mask = np.isin(np.arange(lo, lo + n), g.dst)
        np.testing.assert_allclose(got[mask], whole[lo:lo + n][mask],
                                   atol=1e-4, rtol=1e-4)


def test_csr_refs_agree_with_each_other(rng):
    """The within-layout oracles used by the dispatch fallback agree with
    the kernel entry points on a bucketed batch."""
    g = _graph(90, 380, seed=11)
    ts, _ = tiling.build_tiles(g, 4, 4, reorder="degree", layout="csr",
                               n_buckets=2)
    for b in ts.buckets:
        F = 8
        x = rng.standard_normal((g.n_vertices, F)).astype(np.float32)
        xs = tops.gather_sources(b, x)
        w = rng.standard_normal(b.edge_src.shape).astype(np.float32)
        args = (jnp.asarray(b.row_ptr), jnp.asarray(b.edge_src),
                jnp.asarray(w), xs, jnp.asarray(b.part_id))
        ref = tile_spmm_csr_ref(*args, b.n_dst_parts)
        out = tops.spmm_csr(*args, jnp.asarray(tile_flags(b.part_id)),
                            n_parts=b.n_dst_parts)
        # partitions with no tile in this bucket are never flushed — the
        # runner masks them the same way before summing across buckets
        present = np.isin(np.arange(b.n_dst_parts), b.part_id)
        np.testing.assert_allclose(np.asarray(out)[present],
                                   np.asarray(ref)[present],
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# reorder coverage: out-degree sorting + degenerate graphs
# ---------------------------------------------------------------------------

def test_degree_sort_by_out_orders_out_degrees():
    g = _graph(150, 600, seed=2)
    ro = reorder.degree_sort(g, by="out")
    assert ro.mode == "degree-out"
    deg = ro.graph.out_degrees()
    assert (np.diff(deg) <= 0).all()            # non-increasing after sort
    # still the same graph up to relabeling
    assert ro.graph.n_edges == g.n_edges
    np.testing.assert_array_equal(ro.order[ro.rank],
                                  np.arange(g.n_vertices))
    np.testing.assert_array_equal(ro.order[ro.graph.src], g.src)
    np.testing.assert_array_equal(ro.order[ro.graph.dst], g.dst)


def test_degree_sort_rejects_unknown_axis():
    with pytest.raises(ValueError, match="'in' or 'out'"):
        reorder.degree_sort(_graph(), by="total")


@pytest.mark.parametrize("by", ["in", "out"])
def test_degree_sort_zero_edge_graph(by):
    g = graphs.Graph(src=np.zeros(0, np.int32), dst=np.zeros(0, np.int32),
                     n_vertices=6, name="empty")
    ro = reorder.degree_sort(g, by=by)
    # all-equal degrees: the stable sort is the identity permutation
    np.testing.assert_array_equal(ro.order, np.arange(6))
    assert ro.graph.n_edges == 0
    x = np.arange(12.0).reshape(6, 2)
    np.testing.assert_array_equal(
        ro.unpermute_vertex_outputs(ro.permute_vertex_features(x)), x)


def test_degree_sort_single_vertex_graph():
    g = graphs.Graph(src=np.zeros(3, np.int32), dst=np.zeros(3, np.int32),
                     n_vertices=1, name="loop")
    for ro in (reorder.degree_sort(g), reorder.identity_order(g)):
        np.testing.assert_array_equal(ro.order, [0])
        np.testing.assert_array_equal(ro.rank, [0])
        assert ro.graph.n_edges == g.n_edges


def test_identity_order_is_identity():
    g = _graph(40, 100, seed=0)
    ro = reorder.identity_order(g)
    assert ro.is_identity and ro.mode == "identity"
    x = np.random.default_rng(0).standard_normal((40, 4))
    np.testing.assert_array_equal(ro.permute_vertex_features(x), x)
    np.testing.assert_array_equal(ro.unpermute_vertex_outputs(x), x)


# ---------------------------------------------------------------------------
# hypothesis: permute ∘ unpermute == id for every reordering
# ---------------------------------------------------------------------------

def test_reorder_round_trip_property():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property test needs the optional hypothesis dep")
    from hypothesis import given, settings, strategies as st

    graph_st = st.builds(
        lambda v, e, seed, model: graphs.random_graph(v, e, seed=seed,
                                                      model=model),
        v=st.integers(1, 150), e=st.integers(0, 600),
        seed=st.integers(0, 10),
        model=st.sampled_from(["powerlaw", "uniform"]),
    )

    @given(g=graph_st, mode=st.sampled_from(["identity", "in", "out"]),
           f=st.integers(1, 8))
    @settings(max_examples=40, deadline=None)
    def round_trip(g, mode, f):
        ro = (reorder.identity_order(g) if mode == "identity"
              else reorder.degree_sort(g, by=mode))
        x = np.arange(g.n_vertices * f, dtype=np.float32).reshape(-1, f)
        np.testing.assert_array_equal(
            ro.unpermute_vertex_outputs(ro.permute_vertex_features(x)), x)
        # and the permutation really is a bijection
        assert len(set(ro.order.tolist())) == g.n_vertices

    round_trip()


# ---------------------------------------------------------------------------
# cache isolation: layout + reorder provenance in every key
# ---------------------------------------------------------------------------

def test_structure_signature_separates_layouts_and_reorders():
    g = _graph()
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    cs = tiling.csr_tiles(ts)
    sigs = {
        structure_signature("gcn", ts),
        structure_signature("gcn", cs),
        structure_signature("gcn", ts, reorder="degree"),
        structure_signature("gcn", cs, reorder="degree"),
    }
    assert len(sigs) == 4                       # no pair ever aliases
    assert ts.shape_signature()[1] == "coo"
    assert cs.shape_signature()[1] == "csr"


def test_shape_registry_keys_layout_and_reorder_apart():
    g = _graph(100, 400, seed=6)
    reg = ShapeRegistry()
    variants = [("coo", "identity"), ("csr", "identity"),
                ("coo", "degree"), ("csr", "degree")]
    sigs = {}
    for layout, ro_mode in variants:
        key = ("cls", layout, ro_mode)          # engine keys by tuned config
        _, ts, e_rows, ro = reg.canonical(key, g, grid=(4, 4),
                                          reorder=ro_mode, layout=layout)
        assert ro.mode == ro_mode
        assert ts.layout == layout
        sigs[(layout, ro_mode)] = structure_signature(
            "gcn", ts, padded_edges=e_rows, reorder=ro.mode)
    assert len(reg) == len(variants)            # four distinct registrations
    assert len(set(sigs.values())) == len(variants)
    # a second request of each variant lands on the registered shapes —
    # byte-identical signature, i.e. a guaranteed program-cache hit
    for layout, ro_mode in variants:
        _, ts, e_rows, ro = reg.canonical(("cls", layout, ro_mode), g,
                                          grid=(4, 4), reorder=ro_mode,
                                          layout=layout)
        assert structure_signature("gcn", ts, padded_edges=e_rows,
                                   reorder=ro.mode) == sigs[(layout, ro_mode)]


def test_shape_registry_rejects_unknown_reorder():
    reg = ShapeRegistry()
    with pytest.raises(ValueError, match="reorder"):
        reg.canonical("k", _graph(30, 60), reorder="random")


def test_bucketed_csr_tiles_keep_layout_in_signature():
    g = _graph(110, 450, seed=8)
    bt_coo, _ = tiling.build_tiles(g, 4, 4, layout="coo", n_buckets=2)
    bt_csr, _ = tiling.build_tiles(g, 4, 4, layout="csr", n_buckets=2)
    assert bt_coo.shape_signature() != bt_csr.shape_signature()
    assert all(b.layout == "csr" and b.row_ptr is not None
               for b in bt_csr.buckets)
