"""Schedule-aware tile autotuner (ISSUE 7): search harness, tune cache,
and the serving engine's tuned route.

Pinned here: (a) the hill-climb is deterministic and never worse than the
default config on the simulated objective; (b) wall-clock confirmation
measures the finalists on the real runner; (c) :class:`TuneCache` JSON
round-trips with layout-signature provenance and keys scan/kernel tunings
separately; (d) the server routes a tuned size class onto the tuned grid +
bucketed tile batch, stays conformant with the oracle, and still converges
to zero recompiles on a repeated stream; (e) the ``bucket_tiles`` bound
construction realizes exactly ``min(n_buckets, n_tiles)`` buckets for every
(T, n_buckets) the autotuner can sweep.
"""
import numpy as np
import pytest

from repro.core import compiler, executor, tiling
from repro.gnn import graphs, models
from repro.launch import autotune as AT
from repro.serve import InferenceServer, quantize, size_class

DIM = 16


def _compiled(name, n_layers=1, dim=DIM):
    tr = (models.trace_named(name, dim, dim) if n_layers == 1
          else models.trace_stacked(name, n_layers, dim, dim, dim))
    return tr, compiler.compile_gnn(tr)


def _graph(v=200, e=800, seed=2):
    return graphs.random_graph(v, e, seed=seed, model="powerlaw")


# ---------------------------------------------------------------------------
# search harness
# ---------------------------------------------------------------------------

def test_tileconfig_and_trial_roundtrip():
    cfg = AT.TileConfig(16, 8, 2, 4)
    assert AT.TileConfig.from_dict(cfg.to_dict()) == cfg
    assert cfg.key() == (16, 8, 2, 4, "identity", "coo", "cost")
    t = AT.padded_cost(_compiled("gcn")[1], _graph(), cfg)
    assert t.cycles > 0 and t.config is cfg
    assert t.to_dict()["config"] == cfg.to_dict()


def test_neighbors_step_one_ladder_rung_and_respect_caps():
    cfg = AT.TileConfig()                     # (8, 8, 4, 1)
    g = _graph()
    moves = AT.neighbors(cfg, g, max_shards=2)
    keys = {m.key() for m in moves}
    assert (4, 8, 4, 1, "identity", "coo", "cost") in keys
    assert (16, 8, 4, 1, "identity", "coo", "cost") in keys
    assert (8, 8, 4, 2, "identity", "coo", "cost") in keys  # shards cap at 2
    assert (8, 8, 4, 4, "identity", "coo", "cost") not in keys  # no 4-shard
    # ...and one toggle per categorical dimension
    assert (8, 8, 4, 1, "degree", "coo", "cost") in keys
    assert (8, 8, 4, 1, "identity", "csr", "cost") in keys
    # single-shard configs offer no planner toggle (the plan is a no-op)
    assert not any(m.shard_mode == "mincut" for m in moves)
    # ...but real meshes search the planner dimension too
    sharded = AT.TileConfig(n_shards=2)
    assert (8, 8, 4, 2, "identity", "coo", "mincut") in \
        {m.key() for m in AT.neighbors(sharded, g, max_shards=2)}
    # the scan engine needs the dense per-tile adjacency: no CSR move there
    scan_moves = AT.neighbors(cfg, g, max_shards=2, kernel_dispatch=False)
    assert all(m.layout == "coo" for m in scan_moves)
    assert any(m.reorder == "degree" for m in scan_moves)
    # every move changes exactly one dimension by one rung
    for m in moves:
        assert sum(a != b for a, b in zip(m.key(), cfg.key())) == 1
    with pytest.raises(ValueError, match="unknown shard mode"):
        AT.TileConfig(shard_mode="zigzag")
    # a tiny graph cannot tile onto more partitions than vertices
    tiny = graphs.random_graph(12, 30, seed=0)
    assert all(m.n_dst_parts <= 12 and m.n_src_parts <= 12
               for m in AT.neighbors(cfg, tiny))


def test_hillclimb_is_deterministic_and_beats_the_default():
    _, c = _compiled("gcn", 2)
    g = _graph()
    a = AT.hillclimb(c, g, max_evals=24)
    b = AT.hillclimb(c, g, max_evals=24)
    assert [(t.config.key(), t.cycles) for t in a] == \
           [(t.config.key(), t.cycles) for t in b]
    default = AT.padded_cost(c, g, AT.TileConfig())
    assert a[0].cycles <= default.cycles      # sorted ascending; never worse
    assert len(a) <= 24


def test_autotune_confirms_finalists_by_wallclock():
    tr, c = _compiled("gcn")
    g = _graph(120, 480, seed=4)
    inputs = models.init_inputs(tr, g)
    params = models.init_params(tr)
    res = AT.autotune(c, g, inputs=inputs, params=params,
                      max_evals=6, top=2, repeats=1)
    assert res.n_evals == len(res.trials) <= 6
    assert res.confirmed and all(t.wall_s is not None and t.wall_s > 0
                                 for t in res.confirmed)
    assert res.best in res.confirmed          # measured winner, not simulated
    d = res.to_dict()
    assert d["best"]["wall_s"] == res.best.wall_s


# ---------------------------------------------------------------------------
# tune cache
# ---------------------------------------------------------------------------

def test_tune_cache_roundtrips_and_keys_dispatch_variants_apart(tmp_path):
    _, c = _compiled("gat", 2)
    cache = AT.TuneCache()
    cfg = AT.TileConfig(8, 4, 2, 4)
    cache.put(AT.program_key(c, True), ("cls", 256), cfg,
              layout_signature=("shardlayout", 4), cycles=123)
    cache.put(AT.program_key(c, False), ("cls", 256), AT.TileConfig())
    assert len(cache) == 2
    assert cache.get(AT.program_key(c, True), ("cls", 256)) == cfg
    # scan and kernel tunings never alias
    assert cache.get(AT.program_key(c, False), ("cls", 256)) == AT.TileConfig()
    assert cache.get(AT.program_key(c, True), ("other", 1)) is None
    entry = cache.entry(AT.program_key(c, True), ("cls", 256))
    assert entry["layout_signature"] == repr(("shardlayout", 4))
    assert entry["cycles"] == 123

    path = str(tmp_path / "tune.json")
    cache.save(path)
    loaded = AT.TuneCache.load(path)
    assert len(loaded) == 2
    assert loaded.get(AT.program_key(c, True), ("cls", 256)) == cfg
    assert loaded.entry(AT.program_key(c, True),
                        ("cls", 256)) == entry


def test_tune_for_class_records_winner_with_layout_provenance():
    _, c = _compiled("gcn", 2)
    g = _graph()
    cache = AT.TuneCache()
    res = AT.tune_for_class(c, g, ("powerlaw", 256), cache=cache,
                            max_evals=8)
    entry = cache.entry(AT.program_key(c, True), ("powerlaw", 256))
    assert entry is not None
    assert AT.TileConfig.from_dict(entry["config"]) == res.best.config
    assert entry["cycles"] == res.best.cycles
    assert "shardlayout" in entry["layout_signature"]
    assert "True" in entry["layout_signature"]     # kernel_dispatch recorded


# ---------------------------------------------------------------------------
# serving: the tuned route
# ---------------------------------------------------------------------------

def test_server_routes_tuned_class_and_stays_conformant():
    tr, c = _compiled("gcn")
    gs = [graphs.random_graph(48, 200, seed=k, model="powerlaw")
          for k in range(3)]
    ins = [models.init_inputs(tr, g, seed=k) for k, g in enumerate(gs)]
    params = models.init_params(tr)

    cache = AT.TuneCache()
    class_key = (c.name, c.n_layers, size_class(gs[0]),
                 quantize(len(gs), floor=1))
    tuned_cfg = AT.TileConfig(n_dst_parts=4, n_src_parts=4,
                              n_buckets=2, n_shards=1)
    cache.put(AT.program_key(c, True), class_key, tuned_cfg)

    srv = InferenceServer(c, params, tune_cache=cache)
    outs = srv.submit(gs, ins)
    for g, inp, out in zip(gs, ins, outs):
        ref = executor.run_reference(tr, g, inp, params)
        rel = float(np.max(np.abs(out[0] - np.asarray(ref[0])))
                    / max(1.0, float(np.max(np.abs(np.asarray(ref[0]))))))
        assert rel < 5e-4
    # the registration landed under the tuned key, on the tuned grid
    tuned_regs = [k for k in srv.shapes._shapes
                  if ("tuned",) + tuned_cfg.key() in k]
    assert tuned_regs, list(srv.shapes._shapes)
    # repeated stream: warm cache, zero new compiles
    compiles = srv.compile_count
    srv.submit(gs, ins)
    assert srv.compile_count == compiles
    assert srv.cache_hits >= 1

    # an un-tuned server of the same model uses the default route — its
    # cache key must not alias the tuned one
    srv2 = InferenceServer(c, params)
    srv2.submit(gs, ins)
    assert not any(("tuned",) + tuned_cfg.key() in k
                   for k in srv2.shapes._shapes)


# ---------------------------------------------------------------------------
# bucket bounds under the autotuner sweep
# ---------------------------------------------------------------------------

def test_bucket_count_is_deterministic_under_autotuner_sweep():
    """The realized bucket count is exactly min(n_buckets, n_tiles) for
    every (T, n_buckets) pair the sweep can produce — the bound
    construction is strictly increasing so no bucket ever collapses."""
    g = _graph(150, 600, seed=9)
    for n_dst in (2, 4, 8, 16):
        ts = tiling.grid_tile(g, n_dst, n_dst, sparse=True)
        for nb in (1, 2, 3, 4, 7, 8):
            if nb == 1:
                continue                       # build_tiles skips bucketing
            bt = tiling.bucket_tiles(ts, nb)
            assert bt.n_buckets == min(nb, ts.n_tiles), \
                (n_dst, nb, ts.n_tiles)
            assert sum(b.n_tiles for b in bt.buckets) == ts.n_tiles
            assert all(b.n_tiles > 0 for b in bt.buckets)


def test_quantize_buckets_snaps_shapes_and_preserves_content():
    g = _graph(150, 600, seed=9)
    bt = tiling.bucket_tiles(tiling.grid_tile(g, 4, 4, sparse=True), 3)
    qt = tiling.quantize_buckets(bt, pad_multiple=8)
    assert qt.n_buckets == bt.n_buckets
    for qb, b in zip(qt.buckets, bt.buckets):
        assert qb.n_tiles == b.n_tiles
        for dim in (qb.s_max, qb.e_max):       # pow2, >= pad_multiple
            assert dim >= 8 and (dim & (dim - 1)) == 0
        assert qb.s_max >= b.s_max and qb.e_max >= b.e_max
        # real tile payload is untouched by the padding
        np.testing.assert_array_equal(qb.n_edge[: b.n_tiles],
                                      b.n_edge[: b.n_tiles])
