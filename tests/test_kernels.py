"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_dispatch import ops as mops
from repro.kernels.moe_dispatch import ref as mref
from repro.kernels.tile_spmm import ops as tops
from repro.kernels.tile_spmm.ref import segment_softmax_ref, tile_spmm_ref
from repro.core import tiling
from repro.gnn import graphs


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    # B, Sq, Sk, H, K, D, causal, window
    (2, 64, 64, 4, 2, 32, True, None),
    (1, 128, 128, 8, 8, 64, True, None),
    (2, 33, 97, 6, 3, 16, True, None),      # ragged, GQA
    (1, 64, 64, 4, 4, 32, False, None),     # bidirectional (whisper enc)
    (2, 128, 128, 4, 2, 32, True, 48),      # sliding window (zamba)
    (1, 1, 256, 8, 2, 64, True, None),      # decode
]


@pytest.mark.parametrize("B,Sq,Sk,H,K,D,causal,window", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_scan_vs_ref(B, Sq, Sk, H, K, D, causal, window, dtype, rng):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Sk, K, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Sk, K, D)), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window, block_k=32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,Sq,Sk,H,K,D,causal,window", FLASH_SHAPES)
def test_flash_pallas_vs_ref(B, Sq, Sk, H, K, D, causal, window, rng):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, K, D)), jnp.float32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=16, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_mla_asymmetric_v_dim(rng):
    """MLA: qk head dim 48, v head dim 32."""
    q = jnp.asarray(rng.standard_normal((2, 16, 8, 48)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, 8, 48)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, 8, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_k=8)
    # oracle with explicit softmax
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * 48 ** -0.5
    mask = jnp.tril(jnp.ones((16, 16), bool))
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_kv_len_masking(rng):
    q = jnp.asarray(rng.standard_normal((3, 1, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((3, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((3, 64, 2, 32)), jnp.float32)
    kvlen = jnp.array([10, 64, 33], jnp.int32)
    ref = attention_ref(q, k, v, causal=True, kv_len=kvlen)
    out = flash_attention(q, k, v, causal=False, block_k=16, kv_len=kvlen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# MoE dispatch / grouped FFN
# ---------------------------------------------------------------------------

MOE_SHAPES = [  # T, d, E, f, k
    (64, 32, 8, 48, 2),
    (128, 16, 16, 16, 1),
    (96, 24, 4, 64, 4),
]


@pytest.mark.parametrize("T,d,E,f,k", MOE_SHAPES)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_moe_matches_dense_oracle(T, d, E, f, k, use_pallas, rng):
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    rw = jnp.asarray(rng.standard_normal((d, E)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) / np.sqrt(d), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((E, d, f)) / np.sqrt(d), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((E, f, d)) / np.sqrt(f), jnp.float32)
    ref = mref.moe_ref(x, rw, wg, wu, wd, top_k=k)
    y, aux = mops.moe_block(x, rw, wg, wu, wd, top_k=k, capacity=T * k,
                            use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5, rtol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drop_is_masked(rng):
    """Over-capacity assignments are dropped, never mis-routed."""
    T, d, E, f, k = 64, 16, 4, 16, 2
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    rw = jnp.asarray(rng.standard_normal((d, E)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) / 4, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((E, d, f)) / 4, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((E, f, d)) / 4, jnp.float32)
    y, _ = mops.moe_block(x, rw, wg, wu, wd, top_k=k, capacity=4)
    assert bool(jnp.isfinite(y).all())


def test_route_counts_and_positions(rng):
    x = jnp.asarray(rng.standard_normal((40, 8)), jnp.float32)
    rw = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    r = mops.route(x, rw, top_k=2, capacity=100)
    assert int(r.counts.sum()) == 80  # T*k assignments
    assert bool(r.keep.all())          # capacity ample -> nothing dropped
    # bucket indices unique among kept assignments
    b = np.asarray(r.bucket_idx)
    assert len(np.unique(b)) == len(b)


# ---------------------------------------------------------------------------
# tile SpMM + segment softmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,E,p,s,F", [(120, 500, 4, 4, 16), (80, 200, 2, 5, 8),
                                       (50, 600, 6, 2, 32)])
def test_tile_spmm_sweep(V, E, p, s, F, rng):
    g = graphs.random_graph(V, E, seed=V)
    ts = tiling.grid_tile(g, p, s, sparse=True)
    x = rng.standard_normal((V, F)).astype(np.float32)
    adj, flags = tops.densify_tiles(ts)
    xs = tops.gather_sources(ts, x)
    ref = tile_spmm_ref(jnp.asarray(adj), xs, jnp.asarray(ts.part_id), ts.n_dst_parts)
    out = tops.spmm(jnp.asarray(adj), xs, jnp.asarray(ts.part_id),
                    jnp.asarray(flags), n_parts=ts.n_dst_parts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)
    # cross-check against whole-graph segment-sum
    seg = jax.ops.segment_sum(jnp.asarray(x)[g.src], jnp.asarray(g.dst),
                              num_segments=V)
    for pi in range(ts.n_dst_parts):
        n, lo = int(ts.part_size[pi]), int(ts.part_start[pi])
        np.testing.assert_allclose(np.asarray(out)[pi, :n],
                                   np.asarray(seg)[lo:lo + n], atol=1e-4, rtol=1e-4)


def test_tile_spmm_bucketed_matches_global(rng):
    """Per-bucket kernel calls (bucket-aware densify/gather), partition
    outputs summed across buckets == one global-pad kernel call."""
    g = graphs.random_graph(140, 700, seed=9, model="powerlaw")
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    bt = tiling.bucket_tiles(ts, 3)
    x = rng.standard_normal((g.n_vertices, 16)).astype(np.float32)

    adj, flags = tops.densify_tiles(ts)
    ref = tops.spmm(jnp.asarray(adj), tops.gather_sources(ts, x),
                    jnp.asarray(ts.part_id), jnp.asarray(flags),
                    n_parts=ts.n_dst_parts)

    total = jnp.zeros_like(ref)
    for b, (adj_b, flags_b), xs_b in zip(bt.buckets, tops.densify_tiles(bt),
                                         tops.gather_sources(bt, x)):
        out = tops.spmm(jnp.asarray(adj_b), xs_b, jnp.asarray(b.part_id),
                        jnp.asarray(flags_b), n_parts=b.n_dst_parts)
        present = jnp.asarray(np.isin(np.arange(b.n_dst_parts), b.part_id))
        total = total + jnp.where(present[:, None, None], out, 0.0)
    np.testing.assert_allclose(np.asarray(total), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_segment_softmax_online_vs_ref(rng):
    g = graphs.random_graph(90, 400, seed=7)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    F = 12
    x = rng.standard_normal((g.n_vertices, F)).astype(np.float32)
    adj, flags = tops.densify_tiles(ts)
    xs = tops.gather_sources(ts, x)
    scores = np.where(adj > 0, rng.standard_normal(adj.shape).astype(np.float32), -1e30)
    ref = segment_softmax_ref(jnp.asarray(scores), xs, jnp.asarray(ts.part_id),
                              ts.n_dst_parts)
    out = tops.gat_aggregate(jnp.asarray(scores), xs, jnp.asarray(ts.part_id),
                             jnp.asarray(flags), n_parts=ts.n_dst_parts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-4)
