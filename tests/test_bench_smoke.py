"""Tier-1 smoke: the stream-DSE benchmark is importable and runs end-to-end
(compile → schedule → ISA → task graph → simulator) in --smoke mode."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_bench_streams_smoke():
    from benchmarks import bench_streams

    rows = bench_streams.run(smoke=True)
    assert rows and all(len(r) == 7 for r in rows)
    # the reference point (2 streams, 1 MU, 2 VU) normalizes to exactly 1x
    assert any(r[4] == "1.00x" for r in rows)
    # both smoke models are covered
    assert {r[0] for r in rows} == {"gcn", "gat"}


def test_bench_multilayer_smoke():
    """Acceptance (ISSUE 4): on the cit-Patents-like config the pipelined
    2-layer fused schedule simulates fewer cycles than the barrier schedule,
    and stacked GCN's cross-layer CSE fires."""
    from benchmarks import bench_multilayer

    metrics = bench_multilayer.run(smoke=True)
    assert set(metrics) == {"gcn", "gat"}
    for name, m in metrics.items():
        assert m["fused_pipelined_cycles"] < m["fused_barrier_cycles"], (name, m)
    assert metrics["gcn"]["cse_removed"] >= 1


def test_bench_sharded_smoke():
    """Acceptance (ISSUE 5 + 10): the simulated multi-chip scaling curve is
    monotone and 8 chips beat 1 chip comfortably on the cit-Patents-like
    config, with nonzero modeled exchange traffic — and the mincut plan's
    restricted exchange ships no more bytes than the all-gather baseline on
    all five models at 4 and 8 chips at unchanged reported balance."""
    from benchmarks import bench_sharded

    chips = bench_sharded.run_chip_scaling(smoke=True)
    assert set(chips) == {"gcn", "gat"}
    for name, curve in chips.items():
        assert [c["n_chips"] for c in curve] == [1, 2, 4, 8]
        assert curve[-1]["speedup"] > 2.0, (name, curve)
        assert all(c["exchange_cycles"] > 0 for c in curve[1:]), (name, curve)
        assert all(c["exchange_bytes"] <= c["allgather_bytes"]
                   for c in curve[1:]), (name, curve)
    # the gate itself asserts bytes + balance internally; re-check coverage
    gate = bench_sharded.run_exchange_gate(smoke=True)
    assert {(r["model"], r["n_chips"]) for r in gate} \
        == {(m, k) for m in ("gcn", "gat", "sage", "ggnn", "rgcn")
            for k in (4, 8)}
    assert all(r["restricted_bytes"] < r["allgather_bytes"] for r in gate)
    planner = bench_sharded.run_planner_comparison(smoke=True)
    assert all(r["mincut_edge_cut"] <= r["lpt_edge_cut"] for r in planner)
    assert any(r["mincut_edge_cut"] < r["lpt_edge_cut"] for r in planner)


def test_bench_serving_smoke():
    """Acceptance (ISSUE 3): batched serving >= 2x graphs/sec over the
    per-graph sequential baseline at batch 64, with a > 90% post-warmup
    cache hit rate and zero recompilations on the repeated stream."""
    from benchmarks import bench_serving

    metrics = bench_serving.run(smoke=True)
    m = metrics["gcn"]
    assert m["speedup_b64"] >= 2.0, m
    for b, st in m["cache"].items():
        assert st["recompiles_after_warmup"] == 0, (b, st)
        assert st["post_warmup_hit_rate"] > 0.9, (b, st)
