"""Tier-1 smoke: the stream-DSE benchmark is importable and runs end-to-end
(compile → schedule → ISA → task graph → simulator) in --smoke mode."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_bench_streams_smoke():
    from benchmarks import bench_streams

    rows = bench_streams.run(smoke=True)
    assert rows and all(len(r) == 7 for r in rows)
    # the reference point (2 streams, 1 MU, 2 VU) normalizes to exactly 1x
    assert any(r[4] == "1.00x" for r in rows)
    # both smoke models are covered
    assert {r[0] for r in rows} == {"gcn", "gat"}
