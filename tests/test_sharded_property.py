"""Property-based sharded-execution conformance (ISSUE 5, hypothesis).

Random small graphs × {gcn, gat, sage} × {1, 2} layers: the
:class:`~repro.core.pipeline.ShardedRunner` on a ``min(4, visible)``-device
mesh matches the single-device ``PipelinedRunner`` and the whole-graph dense
oracle to rel 1e-4, including partition counts not divisible by the mesh
size and both bucketed and global-pad tile batches.  Under the CI
sharded-smoke environment (8 forced host devices) this sweeps a REAL 4-way
mesh; on a bare CPU it still exercises the full shard_map path on one shard.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compiler, executor, pipeline, tiling  # noqa: E402
from repro.gnn import graphs, models  # noqa: E402

REL_TOL = 1e-4


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b)) / max(1.0, np.max(np.abs(a))))


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(name=st.sampled_from(["gcn", "gat", "sage"]),
       n_layers=st.integers(1, 2),
       n_vertices=st.integers(12, 60),
       edge_factor=st.integers(1, 4),
       n_parts=st.integers(2, 7),
       n_buckets=st.sampled_from([1, 3]),
       seed=st.integers(0, 2**16))
def test_sharded_conformance_property(name, n_layers, n_vertices, edge_factor,
                                      n_parts, n_buckets, seed):
    import jax
    g = graphs.random_graph(n_vertices, n_vertices * edge_factor, seed=seed,
                            model="powerlaw")
    tr = (models.trace_named(name, 8, 8) if n_layers == 1
          else models.trace_stacked(name, n_layers, 8, 8, 8))
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts = tiling.grid_tile(g, n_parts, n_parts, sparse=True)
    tiles = tiling.bucket_tiles(ts, n_buckets) if n_buckets > 1 else ts
    out_p = pipeline.run_pipelined(c, g, tiles, inputs, params,
                                   kernel_dispatch=False)
    out_s = pipeline.run_sharded(c, g, tiles, inputs, params,
                                 n_devices=min(4, len(jax.devices())))
    assert _rel_err(out_p[0], out_s[0]) < REL_TOL
    assert _rel_err(ref[0], out_s[0]) < REL_TOL


@settings(max_examples=40, deadline=None)
@given(n_vertices=st.integers(40, 300),
       edge_factor=st.integers(2, 8),
       n_parts=st.integers(4, 24),
       n_shards=st.sampled_from([2, 4, 8]),
       balance_tol=st.sampled_from([1.0, 1.05, 1.2]),
       seed=st.integers(0, 2**16))
def test_mincut_never_worse_than_lpt_property(n_vertices, edge_factor,
                                              n_parts, n_shards, balance_tol,
                                              seed):
    """Planner invariant: at EQUAL balance tolerance the mincut refinement's
    cross-shard read cut never exceeds the LPT seed's (strictly-positive-
    gain moves only), and its load cap is the same one LPT establishes."""
    g = graphs.random_graph(n_vertices, n_vertices * edge_factor, seed=seed,
                            model="powerlaw")
    ts = tiling.grid_tile(g, n_parts, n_parts, sparse=True)
    lpt = tiling.plan_shards(ts, n_shards, mode="cost",
                             balance_tol=balance_tol)
    mc = tiling.plan_shards(ts, n_shards, mode="mincut",
                            balance_tol=balance_tol)
    assert mc.edge_cut() <= lpt.edge_cut()
    cap = max(int(lpt.shard_costs().max()),
              int(np.ceil(balance_tol * lpt.part_cost.sum() / n_shards)))
    assert int(mc.shard_costs().max()) <= cap
    # every partition still owned exactly once after refinement
    owned = np.concatenate([np.asarray(p, np.int64)
                            for p in mc.parts_of_shard])
    assert sorted(owned.tolist()) == list(range(ts.n_dst_parts))
