"""Property-based tests (hypothesis): tiling/reordering/stream invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core import reorder, tiling
from repro.core.streams import HWConfig, build_task_graph
from repro.core import compiler, isa
from repro.gnn import graphs, models


graph_st = st.builds(
    lambda v, e, seed, model: graphs.random_graph(v, e, seed=seed, model=model),
    v=st.integers(5, 200), e=st.integers(1, 800), seed=st.integers(0, 10),
    model=st.sampled_from(["powerlaw", "uniform"]),
)


@given(g=graph_st, p=st.integers(1, 8), s=st.integers(1, 8),
       sparse=st.booleans())
@settings(max_examples=40, deadline=None)
def test_tiles_cover_every_edge_exactly_once(g, p, s, sparse):
    ts = tiling.grid_tile(g, p, s, sparse=sparse)
    seen = []
    for t in range(ts.n_tiles):
        ne = int(ts.n_edge[t])
        seen.extend(ts.edge_gid[t, :ne].tolist())
    assert sorted(seen) == list(range(g.n_edges))


@given(g=graph_st, p=st.integers(1, 6), s=st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_tile_edges_map_to_correct_vertices(g, p, s):
    ts = tiling.grid_tile(g, p, s, sparse=True)
    for t in range(ts.n_tiles):
        ne = int(ts.n_edge[t])
        pid = int(ts.part_id[t])
        src_g = ts.src_ids[t, ts.edge_src[t, :ne]]
        dst_g = ts.part_start[pid] + ts.edge_dst[t, :ne]
        gid = ts.edge_gid[t, :ne]
        assert (g.src[gid] == src_g).all()
        assert (g.dst[gid] == dst_g).all()
        # destination offsets stay inside the partition
        assert (ts.edge_dst[t, :ne] < ts.part_size[pid]).all()


@given(g=graph_st, p=st.integers(1, 6), s=st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_sparse_tiling_never_loads_more(g, p, s):
    """Sparse tiles load a subset of the regular tiles' source rows."""
    reg = tiling.grid_tile(g, p, s, sparse=False)
    spr = tiling.grid_tile(g, p, s, sparse=True)
    assert spr.src_vertex_loads() <= reg.src_vertex_loads()
    # sparse tiles keep exactly the sources with >= 1 edge
    for t in range(spr.n_tiles):
        ns, ne = int(spr.n_src[t]), int(spr.n_edge[t])
        used = set(spr.edge_src[t, :ne].tolist())
        assert used == set(range(ns))


@given(g=graph_st)
@settings(max_examples=25, deadline=None)
def test_degree_sort_is_permutation(g):
    r = reorder.degree_sort(g)
    assert sorted(r.order.tolist()) == list(range(g.n_vertices))
    assert (r.order[r.rank] == np.arange(g.n_vertices)).all()
    # graph is isomorphic: edge multiset preserved under the mapping
    orig = sorted(zip(g.src.tolist(), g.dst.tolist()))
    back = sorted(zip(r.order[r.graph.src].tolist(), r.order[r.graph.dst].tolist()))
    assert orig == back
    # in-degrees are non-increasing in the new order
    deg = r.graph.in_degrees()
    assert (np.diff(deg) <= 0).all() or g.n_vertices <= 1


@given(g=graph_st, p=st.integers(1, 6), s=st.integers(1, 6),
       nb=st.integers(1, 5), sparse=st.booleans())
@settings(max_examples=30, deadline=None)
def test_bucketing_preserves_tiles_and_reduces_padding(g, p, s, nb, sparse):
    ts = tiling.grid_tile(g, p, s, sparse=sparse)
    bt = tiling.bucket_tiles(ts, nb)
    # every edge appears exactly once across all buckets
    seen = []
    for b in bt.buckets:
        for t in range(b.n_tiles):
            seen.extend(b.edge_gid[t, :int(b.n_edge[t])].tolist())
    assert sorted(seen) == list(range(g.n_edges))
    # per-bucket tile order is partition-major (Pallas FIRST/LAST protocol)
    for b in bt.buckets:
        assert (np.diff(b.part_id) >= 0).all()
        # edges map to the same global vertices as in the source tile set
        for t in range(b.n_tiles):
            ne_ = int(b.n_edge[t])
            src_g = b.src_ids[t, b.edge_src[t, :ne_]]
            dst_g = b.part_start[int(b.part_id[t])] + b.edge_dst[t, :ne_]
            gid = b.edge_gid[t, :ne_]
            assert (g.src[gid] == src_g).all()
            assert (g.dst[gid] == dst_g).all()
    # bucketing never pads more than the global batch
    assert bt.padded_edge_slots() <= ts.padded_edge_slots()
    assert bt.padded_src_slots() <= ts.padded_src_slots()
    assert bt.n_tiles == ts.n_tiles
    assert bt.src_vertex_loads() == ts.src_vertex_loads()


@given(g=graph_st, ns=st.integers(1, 6), ne=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_stream_task_graph_is_acyclic_and_respects_barriers(g, ns, ne):
    ts = tiling.grid_tile(g, 3, 3)
    c = compiler.compile_gnn(models.trace_named("gcn"))
    sde = isa.emit_sde(c.plan)
    hw = HWConfig(n_sstreams=ns, n_estreams=ne)
    tasks, _ = build_task_graph(sde, ts, hw)
    # acyclic: deps only reference earlier task ids (construction order)
    for t in tasks:
        assert all(d < t.tid for d in t.deps)
    # every e-task depends on its s-task; d-barriers collect all partition tiles
    kinds = {t.tid: t.kind for t in tasks}
    for t in tasks:
        if t.kind == "e":
            assert any(kinds[d] == "s" for d in t.deps)
