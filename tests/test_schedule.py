"""Scheduler-layer tests (ISSUE 2): one lowering, three engines.

The :mod:`repro.core.schedule` pass is the single source of truth for phase
structure and kernel-block dispatch; both JAX engines interpret it, and the
ISA codegen costs it.  These tests pin (a) engine parity against the
whole-graph oracle on all five paper models under both dispatch modes,
(b) the kernel tags the pattern matcher must pick, and (c) that the engines
really do contain no level/role derivation of their own.
"""
import inspect

import jax.numpy as jnp
import pytest

from repro.core import compiler, executor, pipeline, schedule, tiling
from repro.gnn import graphs, models

TOL = 5e-4


def _compiled(name, dim=24):
    tr = models.trace_named(name, dim, dim)
    c = compiler.compile_gnn(tr)
    return tr, c


@pytest.mark.parametrize("name", models.PAPER_MODELS)
@pytest.mark.parametrize("kernel_dispatch", [False, True])
def test_both_engines_match_oracle(name, kernel_dispatch):
    """run_tiled and PipelinedRunner interpret the same ScheduledProgram and
    agree with run_reference, with and without Pallas kernel dispatch."""
    g = graphs.random_graph(180, 750, seed=3, model="powerlaw", n_edge_types=3)
    tr, c = _compiled(name)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)

    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    out_tiled = executor.run_tiled(c, g, ts, inputs, params,
                                   kernel_dispatch=kernel_dispatch)
    for a, b in zip(ref, out_tiled):
        assert float(jnp.max(jnp.abs(a - b))) < TOL, "run_tiled != oracle"

    bt = tiling.bucket_tiles(ts, 3)
    out_pipe = pipeline.run_pipelined(c, g, bt, inputs, params,
                                      kernel_dispatch=kernel_dispatch)
    for a, b in zip(ref, out_pipe):
        assert float(jnp.max(jnp.abs(a - b))) < TOL, "pipelined != oracle"


def test_gcn_aggregation_selects_pallas_spmm():
    _, c = _compiled("gcn")
    sp = c.schedule(True)
    assert sp.gather_kernel(0) == schedule.KERNEL_SPMM
    # and the block knows which vertex value feeds the kernel's X operand
    (g,) = sp.phases[0].gathers
    assert g.src_value_id is not None and g.acc.kind == "sum"


@pytest.mark.parametrize("name", ["gat", "gat_naive"])
def test_gat_softmax_selects_pallas_segment_softmax(name):
    """The three-level softmax motif fuses into ONE online-softmax block."""
    _, c = _compiled(name)
    sp = c.schedule(True)
    assert sp.gather_kernel(0) == schedule.KERNEL_SEGMENT_SOFTMAX
    (g,) = sp.phases[0].gathers
    assert g.fused_levels == (0, 1, 2)
    # the fused block subsumes the intermediate gathers: no other gather
    # blocks and no leftover edge work anywhere in the program
    for phase in sp.phases[1:]:
        assert not phase.gathers and not phase.edge.nodes


def test_scan_lowering_has_no_kernel_blocks():
    for name in models.PAPER_MODELS:
        _, c = _compiled(name)
        sp = c.schedule(False)
        kernels = {k for ks in sp.kernels_by_level().values() for k in ks}
        assert kernels <= {schedule.KERNEL_SCAN}, name


def test_gat_fused_softmax_matches_reference_tightly():
    """Acceptance: GAT's edge softmax executes through the Pallas
    segment-softmax block with outputs matching run_reference to 1e-4."""
    g = graphs.random_graph(150, 650, seed=11, model="powerlaw")
    tr, c = _compiled("gat", dim=16)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    assert c.schedule(True).gather_kernel(0) == schedule.KERNEL_SEGMENT_SOFTMAX
    out_t = executor.run_tiled(c, g, ts, inputs, params, kernel_dispatch=True)
    out_p = pipeline.run_pipelined(c, g, ts, inputs, params,
                                   kernel_dispatch=True)
    for out in (out_t, out_p):
        assert float(jnp.max(jnp.abs(ref[0] - out[0]))) < 1e-4


def test_engines_have_no_phase_derivation():
    """Acceptance: neither engine consults plan.level / plan.role — block
    membership comes entirely from schedule.lower."""
    for mod in (executor, pipeline):
        src = inspect.getsource(mod)
        assert "plan.level" not in src and "plan.role" not in src, mod.__name__
        assert ".level[" not in src and ".role[" not in src, mod.__name__


def test_isa_costs_kernel_blocks():
    """emit_sde consumes the same blocks: the kernel-dispatched program emits
    fused kernel instructions, the scan program the SCTR/GTHR pairs."""
    from repro.core import isa

    _, c = _compiled("gcn")
    e_scan = [i.opcode for i in isa.emit_sde(c.schedule(False)).e.get(0, [])]
    e_ker = [i.opcode for i in isa.emit_sde(c.schedule(True)).e.get(0, [])]
    assert "SCTR.OUTE" in e_scan and "GTHR.DST.SUM" in e_scan
    assert e_ker == ["SPMM.TILE"]

    _, cg = _compiled("gat")
    sde = isa.emit_sde(cg.schedule(True))
    e0 = [i.opcode for i in sde.e.get(0, [])]
    assert "SFTM.MM" in e0 and "SFTM.EDGE" in e0
    # fused levels emit no edge work of their own
    assert not sde.e.get(1, []) and not sde.e.get(2, [])


def test_simulator_runs_kernel_schedule():
    from repro.core import isa, simulator

    g = graphs.random_graph(150, 600, seed=5, model="powerlaw")
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    for name in ("gcn", "gat"):
        _, c = _compiled(name)
        r = simulator.simulate_model(isa.emit_sde(c.schedule(True)), ts)
        assert r.cycles > 0 and r.macs > 0


def test_edge_feature_weighted_gather_dispatches_and_runs():
    """recvSrc * w_e -> sendDstSum with a per-edge INPUT weight must select
    the weighted-SpMM block, and both engines must evaluate it (edge inputs
    are read lazily, never fed to apply_compute)."""
    from repro.core.trace import trace_model

    def build(tr, g):
        x = tr.input_vertex(8, "x")
        w = tr.input_edge(1, "w")
        tr.mark_output(g.gather_sum(g.scatter_src(x) * w))

    tr = trace_model(build, name="edge-weighted-sum")
    c = compiler.compile_gnn(tr)
    assert c.schedule(True).gather_kernel(0) == schedule.KERNEL_SPMM_WEIGHTED

    g = graphs.random_graph(100, 420, seed=8, model="powerlaw")
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    out_t = executor.run_tiled(c, g, ts, inputs, params, kernel_dispatch=True)
    out_p = pipeline.run_pipelined(c, g, ts, inputs, params,
                                   kernel_dispatch=True)
    for out in (out_t, out_p):
        assert float(jnp.max(jnp.abs(ref[0] - out[0]))) < TOL


def test_multigraph_parallel_edges_stay_exact():
    """Per-edge-column score densification keeps parallel edges in separate
    softmax slots — GAT on a multigraph still matches the oracle."""
    import numpy as np

    src = np.array([0, 0, 0, 1, 2, 2, 3, 3], np.int32)  # two (0->4), two (3->5)
    dst = np.array([4, 4, 5, 4, 5, 6, 5, 5], np.int32)
    g = graphs.Graph(src=src, dst=dst, n_vertices=8, name="multi")
    tr, c = _compiled("gat", dim=8)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts = tiling.grid_tile(g, 2, 2, sparse=True)
    out = executor.run_tiled(c, g, ts, inputs, params, kernel_dispatch=True)
    assert float(jnp.max(jnp.abs(ref[0] - out[0]))) < 1e-4
