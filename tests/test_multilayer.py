"""Multi-layer GNN programs (ISSUE 4): one lowering from stacked layers to a
cross-layer ScheduledProgram.

Pinned here: (a) ``trace_model`` accepts stacked layer builders and tags
every node with its layer; (b) all five paper models run stacked through all
three engines (run_tiled, PipelinedRunner, emit_sde + simulator) and the JAX
engines match a whole-graph *layer-by-layer* oracle; (c) the cross-layer
CSE pass removes repeated structure-only ops on stacked GCN and E2V hoists
across layer boundaries; (d) the pipelined inter-layer schedule simulates
fewer cycles than the barrier schedule; (e) program signatures distinguish
layer counts.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, executor, isa, pipeline, simulator, tiling
from repro.core.streams import HWConfig, build_task_graph
from repro.gnn import graphs, models

DIM = 16
REL_TOL = 1e-4   # acceptance: engines match the layer-by-layer oracle


def _stacked(name, n_layers, dim=DIM):
    tr = models.trace_stacked(name, n_layers, dim, dim, dim)
    return tr, compiler.compile_gnn(tr)


def _layer_by_layer_oracle(name, n_layers, g, inputs, params, dim=DIM):
    """Chain n_layers SINGLE-layer whole-graph references: layer l's output
    becomes layer l+1's input, per-layer params stripped of their prefix."""
    x = np.asarray(inputs["x"])
    for layer in range(n_layers):
        tr_l = models.trace_named(name, dim, dim)
        prefix = f"l{layer}."
        p_l = {k[len(prefix):]: v for k, v in params.items()
               if k.startswith(prefix)}
        inp_l = {"x": x}
        for shared in ("dnorm", "etype"):
            if shared in inputs:
                inp_l[shared] = inputs[shared]
        x = np.asarray(executor.run_reference(tr_l, g, inp_l, p_l)[0])
    return x


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b)) / max(1.0, np.max(np.abs(a))))


# ---------------------------------------------------------------------------
# trace-level: stacked layer builders, layer tags
# ---------------------------------------------------------------------------

def test_trace_model_accepts_layer_builder_list():
    tr = models.trace_stacked("gcn", 3, 8, 8, 8)
    assert tr.n_layers == 3
    layers = set(tr.layer_of.values())
    assert layers == {0, 1, 2}
    # per-layer params, shared structure inputs declared once
    assert {"l0.W", "l1.W", "l2.W"} <= set(tr.params)
    input_names = [n.attrs["name"] for n in tr.nodes if n.op == "input"]
    assert input_names == ["x", "dnorm"]


def test_stacking_guards():
    """Misuse fails loudly: empty builder lists, GGNN dim changes, and
    n_layers conflicting with a pre-compiled model all raise."""
    from repro.core.trace import trace_model
    from repro.serve import InferenceServer

    with pytest.raises(ValueError, match="empty layer-builder"):
        trace_model([], name="m")
    with pytest.raises(ValueError, match="preserves the feature dim"):
        models.trace_stacked("ggnn", 2, 64, 128, 32)
    c = compiler.compile_gnn(models.trace_named("gcn", 8, 8))
    with pytest.raises(ValueError, match="conflicts"):
        InferenceServer(c, n_layers=2)
    # a builders list is reusable across traces (shared inputs reset)
    builders = models.build_stacked("gcn", 2, 8, 8, 8)
    assert trace_model(builders, "a").n_layers == 2
    assert trace_model(builders, "b").n_layers == 2


def test_single_layer_traces_unchanged_by_refactor():
    """The layer-fn refactor must not perturb single-layer traces (program
    signatures are cache keys in serving)."""
    for name in models.PAPER_MODELS:
        tr = models.trace_named(name, DIM, DIM)
        assert tr.n_layers == 1
        assert set(tr.layer_of.values()) == {0}


def test_scheduled_phases_carry_layer_tags():
    _, c = _stacked("gcn", 2)
    sp = c.schedule(False)
    assert sp.n_layers == 2
    assert [(p.level, p.layer) for p in sp.phases] == [(0, 0), (1, 1), (2, 1)]
    _, cg = _stacked("gat", 2)
    spg = cg.schedule(False)
    # GAT: 3 softmax levels per layer; the boundary sits at level 3
    assert spg.layer_of_level()[0] == 0 and spg.layer_of_level()[3] == 1
    sde = isa.emit_sde(spg)
    assert sde.n_layers == 2 and sde.layer_of(3) == 1


# ---------------------------------------------------------------------------
# acceptance: five paper models, stacked, three engines, one oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", models.PAPER_MODELS)
@pytest.mark.parametrize("n_layers", [2, 3])
def test_stacked_models_match_layer_by_layer_oracle(name, n_layers):
    g = graphs.random_graph(150, 600, seed=3, model="powerlaw", n_edge_types=3)
    tr, c = _stacked(name, n_layers)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    oracle = _layer_by_layer_oracle(name, n_layers, g, inputs, params)

    # whole-graph reference on the stacked trace agrees with the chained
    # single-layer references (the stacked builders are the same layers)
    ref = executor.run_reference(tr, g, inputs, params)
    assert _rel_err(oracle, ref[0]) < REL_TOL

    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    bt = tiling.bucket_tiles(ts, 3)
    for kd in (False, True):
        out_t = executor.run_tiled(c, g, ts, inputs, params, kernel_dispatch=kd)
        assert _rel_err(oracle, out_t[0]) < REL_TOL, (name, "run_tiled", kd)
        out_p = pipeline.run_pipelined(c, g, bt, inputs, params,
                                       kernel_dispatch=kd)
        assert _rel_err(oracle, out_p[0]) < REL_TOL, (name, "pipelined", kd)

    # third engine: the multi-layer program lowers to SDE instructions and
    # executes through the cycle simulator in ONE pass (both schedules)
    for kd in (False, True):
        r = simulator.simulate_model(isa.emit_sde(c.schedule(kd)), ts)
        assert r.cycles > 0 and r.macs > 0


# ---------------------------------------------------------------------------
# cross-layer optimization passes
# ---------------------------------------------------------------------------

def test_cross_layer_cse_removes_ops_on_stacked_gcn():
    """Acceptance: stacked GCN re-emits the structure-only normalized
    adjacency (scatter_src(dn) * scatter_dst(dn)) per layer; CSE must
    deduplicate it across layers."""
    _, c1 = _stacked("gcn", 1)
    _, c2 = _stacked("gcn", 2)
    _, c3 = _stacked("gcn", 3)
    assert c1.opt_report["cse_removed"] == 0
    assert c2.opt_report["cse_removed"] >= 1
    # one deduplicated motif per extra layer
    assert c3.opt_report["cse_removed"] > c2.opt_report["cse_removed"]
    # the optimized IR is genuinely smaller than the naive lowering
    assert c2.ir.op_count() < c2.naive_ir.op_count()


def test_cse_preserves_kernel_dispatch_on_stacked_gcn():
    """After dedup, every GCN layer's gather still pattern-matches onto a
    Pallas block (weighted SpMM: the shared edge-norm scalar is its α)."""
    from repro.core import schedule
    _, c = _stacked("gcn", 2)
    kernels = c.schedule(True).kernels_by_level()
    assert all(ks == [schedule.KERNEL_SPMM_WEIGHTED]
               for ks in kernels.values())
    assert len(kernels) == 2


def test_e2v_hoists_across_layer_boundaries():
    """A stacked naive-SAGE (per-edge pooling MLP in every layer) must get
    every layer's MLP hoisted by the global E2V pass."""
    from repro.core.trace import trace_model

    def make(layer):
        def build(tr, g, x):
            if x is None:
                x = tr.input_vertex(DIM, "x")
            return models.layer_sage(tr, g, x, DIM, prefix=f"l{layer}.",
                                     naive=True)
        return build

    tr = trace_model([make(0), make(1)], name="sage_naive_x2")
    c = compiler.compile_gnn(tr)
    # matmul+bias+relu hoisted per layer (>= 6 moves), none left on edges
    assert c.opt_report["e2v_moved"] >= 6
    for seg in c.ir.edge_segments():
        assert all(n.op not in ("matmul", "bias_add", "relu")
                   for n in seg.nodes.values())
    # and the hoisted program still matches the naive one numerically
    g = graphs.random_graph(100, 400, seed=5, model="powerlaw")
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    out = executor.run_tiled(c, g, ts, inputs, params)
    assert _rel_err(ref[0], out[0]) < REL_TOL


# ---------------------------------------------------------------------------
# inter-layer pipelining (streams / simulator)
# ---------------------------------------------------------------------------

def test_pipelined_task_graph_is_valid_and_faster():
    """Acceptance: the pipelined 2-layer schedule beats the barrier schedule
    on the cit-Patents-like configuration."""
    g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0, n_edge_types=3)
    ts = tiling.grid_tile(g, 6, 6, sparse=True)
    for name in ("gcn", "gat"):
        _, c = _stacked(name, 2)
        sde = isa.emit_sde(c.schedule(False))
        tasks, _ = build_task_graph(sde, ts, HWConfig(),
                                    inter_layer="pipelined")
        for t in tasks:   # acyclic by construction order
            assert all(d < t.tid for d in t.deps)
        bar = simulator.simulate_model(sde, ts)
        pipe = simulator.simulate_model(sde, ts, inter_layer="pipelined")
        assert pipe.cycles < bar.cycles, (name, pipe.cycles, bar.cycles)
        # identical work, different schedule: op counts must not move
        assert (pipe.macs, pipe.elw_ops) == (bar.macs, bar.elw_ops)


def test_single_layer_unaffected_by_pipelined_mode():
    """Without a layer boundary the two modes build the identical DAG."""
    g = graphs.random_graph(120, 500, seed=1, model="powerlaw")
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    c = compiler.compile_gnn(models.trace_named("gcn", DIM, DIM))
    sde = isa.emit_sde(c.schedule(False))
    bar = simulator.simulate_model(sde, ts)
    pipe = simulator.simulate_model(sde, ts, inter_layer="pipelined")
    assert bar.cycles == pipe.cycles


# ---------------------------------------------------------------------------
# serving-facing identity
# ---------------------------------------------------------------------------

def test_structure_signature_distinguishes_layer_counts():
    _, c1 = _stacked("gcn", 1)
    _, c2 = _stacked("gcn", 2)
    assert c1.structure_signature() != c2.structure_signature()
    assert c1.n_layers == 1 and c2.n_layers == 2
    sig2 = c2.schedule(True).structure_signature()
    assert c2.schedule(True).n_layers == 2 and sig2 == \
        c2.schedule(True).structure_signature()  # memoized & stable
