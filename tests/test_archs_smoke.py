"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step + decode steps on CPU; shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.launch.steps import make_decode_step, make_train_step
from repro.models import lm
from repro.models.common import materialize
from repro.optim.adamw import adamw_init


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(rng.standard_normal((B, 8, cfg.d_model)),
                                        jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(rng.standard_normal((B, cfg.enc_len, cfg.d_model)),
                                  jnp.float32)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch, host_mesh):
    cfg = reduced(get_config(arch))
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    batch = _batch(cfg)
    out = lm.forward(cfg, params, batch, mesh=host_mesh)
    logits = out[0] if cfg.family == "moe" else out
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    step = make_train_step(cfg, host_mesh)
    params, opt, m = step(params, adamw_init(params), batch)
    assert bool(jnp.isfinite(m["loss"])), f"{arch}: non-finite loss"
    assert bool(jnp.isfinite(m["grad_norm"])), f"{arch}: non-finite grads"
    # a second step must reduce nothing to NaN
    params, opt, m2 = step(params, opt, _batch(cfg, seed=1))
    assert bool(jnp.isfinite(m2["loss"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_steps(arch, host_mesh):
    cfg = reduced(get_config(arch))
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    B, max_len = 2, 16
    cache = materialize(jax.random.PRNGKey(1), lm.cache_template(cfg, B, max_len),
                        dtype_override="float32")  # state templates carry their init
    step = make_decode_step(cfg, host_mesh)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = step(params, cache, tok, jnp.asarray(pos, jnp.int32))
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: decode NaN at {pos}"
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)


def test_decode_matches_prefill_dense(host_mesh):
    """Teacher-forced decode must reproduce the prefill logits (KV-cache
    correctness), checked on the dense family."""
    cfg = reduced(get_config("qwen3-32b"))
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    B, S = 1, 8
    toks = jnp.asarray(np.random.default_rng(3).integers(0, cfg.vocab, (B, S)),
                       jnp.int32)
    full = lm.forward(cfg, params, {"tokens": toks}, mesh=host_mesh)
    cache = materialize(jax.random.PRNGKey(1), lm.cache_template(cfg, B, S),
                        dtype_override="float32")
    for pos in range(S):
        logits, cache = lm.decode_step(cfg, params, cache, toks[:, pos:pos + 1],
                                       jnp.asarray(pos, jnp.int32), mesh=host_mesh)
        np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, pos]),
                                   atol=2e-3, rtol=2e-3)


def test_decode_matches_prefill_mla(host_mesh):
    """Absorbed MLA decode ≡ expanded prefill attention."""
    cfg = reduced(get_config("deepseek-v2-236b"))
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    B, S = 1, 8
    toks = jnp.asarray(np.random.default_rng(5).integers(0, cfg.vocab, (B, S)),
                       jnp.int32)
    out = lm.forward(cfg, params, {"tokens": toks}, mesh=host_mesh)
    full = out[0]
    cache = materialize(jax.random.PRNGKey(1), lm.cache_template(cfg, B, S),
                        dtype_override="float32")
    for pos in range(S):
        logits, cache = lm.decode_step(cfg, params, cache, toks[:, pos:pos + 1],
                                       jnp.asarray(pos, jnp.int32), mesh=host_mesh)
        np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, pos]),
                                   atol=5e-3, rtol=5e-3)


def test_ssm_decode_matches_prefill(host_mesh):
    """Chunked mLSTM/sLSTM prefill ≡ step-by-step recurrent decode."""
    cfg = reduced(get_config("xlstm-1.3b"))
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    B, S = 1, 12
    toks = jnp.asarray(np.random.default_rng(7).integers(0, cfg.vocab, (B, S)),
                       jnp.int32)
    full = lm.forward(cfg, params, {"tokens": toks}, mesh=host_mesh)
    cache = materialize(jax.random.PRNGKey(1), lm.cache_template(cfg, B, S),
                        dtype_override="float32")
    for pos in range(S):
        logits, cache = lm.decode_step(cfg, params, cache, toks[:, pos:pos + 1],
                                       jnp.asarray(pos, jnp.int32), mesh=host_mesh)
        np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, pos]),
                                   atol=5e-3, rtol=5e-3)


def test_mamba_decode_matches_prefill(host_mesh):
    cfg = reduced(get_config("zamba2-2.7b"))
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    B, S = 1, 10
    toks = jnp.asarray(np.random.default_rng(9).integers(0, cfg.vocab, (B, S)),
                       jnp.int32)
    full = lm.forward(cfg, params, {"tokens": toks}, mesh=host_mesh)
    cache = materialize(jax.random.PRNGKey(1), lm.cache_template(cfg, B, S),
                        dtype_override="float32")
    for pos in range(S):
        logits, cache = lm.decode_step(cfg, params, cache, toks[:, pos:pos + 1],
                                       jnp.asarray(pos, jnp.int32), mesh=host_mesh)
        np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, pos]),
                                   atol=5e-3, rtol=5e-3)
