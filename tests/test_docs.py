"""Docs-integrity tests: generated diagnostics catalog + markdown links.

Pins ``docs/DIAGNOSTICS.md`` byte-for-byte to ``repro.analyze.render_codes_doc``
so the catalog can never drift from the ``CODES`` registry, and runs the
intra-repo markdown link checker (``tools/check_links.py``) as a test so a
broken link fails locally, not just in the CI docs job.
"""
import pathlib
import subprocess
import sys

import pytest

from repro import analyze
from repro.core import analysis as A

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC = ROOT / "docs" / "DIAGNOSTICS.md"


def test_diagnostics_doc_is_current():
    """docs/DIAGNOSTICS.md must equal render_codes_doc() byte-for-byte."""
    assert DOC.exists(), (
        "docs/DIAGNOSTICS.md missing; regenerate with "
        "`python -m repro.analyze --write-codes-doc docs/DIAGNOSTICS.md`")
    assert DOC.read_text() == analyze.render_codes_doc(), (
        "docs/DIAGNOSTICS.md is stale; regenerate with "
        "`python -m repro.analyze --write-codes-doc docs/DIAGNOSTICS.md`")


def test_diagnostics_doc_covers_every_code():
    """Every registered code (and its severity) appears in the catalog."""
    text = DOC.read_text()
    for code, (sev, _meaning) in A.CODES.items():
        assert f"`{code}`" in text, f"{code} missing from DIAGNOSTICS.md"
        assert f"| `{code}` | {sev} |" in text, (
            f"{code} listed with wrong severity (expected {sev})")
    assert f"Total: {len(A.CODES)} registered codes" in text


def test_codes_doc_families_partition_registry():
    """The three rendered families (ZA/ZS/ZH) cover the whole registry."""
    prefixes = ("ZA", "ZS", "ZH")
    stray = [c for c in A.CODES if not c.startswith(prefixes)]
    assert not stray, (
        f"codes outside the documented families: {stray}; add a section "
        "to render_codes_doc()")


def test_write_codes_doc_cli(tmp_path):
    """--write-codes-doc writes the same bytes the test pins."""
    out = tmp_path / "DIAG.md"
    rc = analyze.main(["--write-codes-doc", str(out)])
    assert rc == 0
    assert out.read_text() == analyze.render_codes_doc()


def test_intra_repo_markdown_links():
    """No markdown file may link to a missing intra-repo path."""
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    broken, checked = check_links.check_links()
    assert not broken, "broken markdown links:\n" + "\n".join(broken)
    assert checked > 0, "link checker found no links at all (regex broken?)"


def test_examples_compile():
    """Every example must at least byte-compile (CI docs job parity)."""
    proc = subprocess.run(
        [sys.executable, "-m", "compileall", "-q", str(ROOT / "examples")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.parametrize("doc", ["README.md", "ARCHITECTURE.md"])
def test_top_level_docs_link_serving_guide(doc):
    """README and ARCHITECTURE must point readers at docs/SERVING.md."""
    assert "docs/SERVING.md" in (ROOT / doc).read_text(), (
        f"{doc} does not link docs/SERVING.md")
