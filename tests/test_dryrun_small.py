"""Small-mesh dry-run test: the full lowering path (abstract params w/
shardings -> jit.lower -> compile -> cost/memory/collective census) on an
8-fake-device mesh, in a subprocess (device count must be set before jax
initializes)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs import get_config
    from repro.launch.steps import abstract_state, make_train_step, make_decode_step
    from repro.launch.dryrun import collective_census

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("smollm-135m")

    params, opt, _, batch = abstract_state(cfg, mesh, "train_4k", with_opt=True)
    # shrink the batch for an 8-device test: reuse shape machinery w/ train_4k
    lowered = jax.jit(make_train_step(cfg, mesh), donate_argnums=(0, 1)).lower(
        params, opt, batch)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll, counts = collective_census(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({
        "flops": float(cost.get("flops", -1)),
        "coll": coll, "counts": counts,
        "temp_gb": int(mem.temp_size_in_bytes) / 2**30,
    }))
""")


@pytest.mark.slow
def test_small_mesh_multipod_lowering():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    # the pod axis must actually shard something: gradient sync across pods
    assert sum(rec["counts"].values()) > 0, "no collectives on a 3-axis mesh?"
    # this test runs the full-size global batch on 8 devices (32× fewer than
    # the production pod): the fit criterion scales to 16 GB * 256/8
    assert rec["temp_gb"] < 16 * 256 / 8, "would not fit the production pod"
