"""§Perf optimization flags: numerical equivalence of the optimized paths.

Each `runtime_flags.OPT` toggle must be a pure layout/communication change —
the model function's values may not move (fp8 dispatch excepted: it is a
precision trade and is checked for boundedness).  Multi-device semantics
(psum_scatter, all_to_all, DPM constraints) need >1 device, so these run in
an 8-fake-device subprocess.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro import runtime_flags
    from repro.configs import get_config, reduced
    from repro.models import lm
    from repro.models.common import materialize

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    out = {}

    cfg = reduced(get_config("deepseek-v2-236b"))
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32")
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}
    base = lm.forward(cfg, params, batch, mesh=mesh)[0]
    runtime_flags.OPT["moe_rs_combine"] = True
    rs = lm.forward(cfg, params, batch, mesh=mesh)[0]
    runtime_flags.OPT["moe_fp8_dispatch"] = True
    f8 = lm.forward(cfg, params, batch, mesh=mesh)[0]
    runtime_flags.OPT["moe_rs_combine"] = False
    runtime_flags.OPT["moe_fp8_dispatch"] = False
    out["rs_err"] = float(jnp.max(jnp.abs(base - rs)))
    out["f8_err"] = float(jnp.max(jnp.abs(base - f8)))
    out["f8_finite"] = bool(jnp.isfinite(f8).all())

    cfg2 = reduced(get_config("smollm-135m"))
    p2 = materialize(jax.random.PRNGKey(0), lm.model_template(cfg2),
                     dtype_override="float32")
    b2 = {"tokens": jnp.asarray(rng.integers(0, cfg2.vocab, (8, 16)), jnp.int32)}
    o1 = lm.forward(cfg2, p2, b2, mesh=mesh)
    runtime_flags.OPT["attn_batch_shard"] = True
    o2 = lm.forward(cfg2, p2, b2, mesh=mesh)
    runtime_flags.OPT["attn_batch_shard"] = False
    out["attn_err"] = float(jnp.max(jnp.abs(o1 - o2)))

    # zero1 + fsdp + microbatching: the train step must produce the same
    # params as the plain step (modulo accumulation-order float noise)
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import adamw_init
    p3a = materialize(jax.random.PRNGKey(1), lm.model_template(cfg2),
                      dtype_override="float32")
    p3b = jax.tree.map(jnp.copy, p3a)
    b3 = {"tokens": jnp.asarray(rng.integers(0, cfg2.vocab, (8, 16)), jnp.int32)}
    sa = make_train_step(cfg2, mesh)
    pa, _, ma = sa(p3a, adamw_init(p3a), b3)
    runtime_flags.OPT["zero1_opt_state"] = True
    runtime_flags.OPT["fsdp_params"] = True
    sb = make_train_step(cfg2, mesh, microbatches=2)
    pb, _, mb = sb(p3b, adamw_init(p3b), b3)
    runtime_flags.OPT["zero1_opt_state"] = False
    runtime_flags.OPT["fsdp_params"] = False
    out["train_param_err"] = max(float(jnp.max(jnp.abs(x - y)))
                                 for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
    out["loss_a"] = float(ma["loss"]); out["loss_b"] = float(mb["loss"])
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_opt_flags_equivalence():
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["rs_err"] < 1e-5, out
    assert out["attn_err"] < 1e-5, out
    assert out["f8_finite"] and out["f8_err"] < 0.2, out  # fp8: bounded, not exact
    assert abs(out["loss_a"] - out["loss_b"]) < 2e-3, out
    assert out["train_param_err"] < 5e-3, out
