"""Property-based optimization-pass conformance (ISSUE 5).

``passes.cse_trace`` and the E2V + DCE pipeline must (a) never increase
node/op counts and (b) preserve program outputs, over randomly generated
stacked traces.  The deterministic companions below run even without
hypothesis (the pinned stacked-GCN dedupe count lives in
``test_multilayer.py::test_cross_layer_cse_removes_ops_on_stacked_gcn``).
"""
import numpy as np
import pytest

from repro.core import compiler, executor, passes
from repro.gnn import graphs, models

REL_TOL = 1e-5   # pass round-trips re-run identical float ops


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b)) / max(1.0, np.max(np.abs(a))))


def _trace(name, n_layers, dim):
    return (models.trace_named(name, dim, dim) if n_layers == 1
            else models.trace_stacked(name, n_layers, dim, dim, dim))


def _check_cse_roundtrip(name, n_layers, dim, seed):
    """cse_trace: fewer-or-equal nodes, identical outputs, idempotent."""
    tr = _trace(name, n_layers, dim)
    tr2, removed = passes.cse_trace(tr)
    assert removed >= 0
    assert len(tr2.nodes) == len(tr.nodes) - removed
    assert len(tr2.nodes) <= len(tr.nodes)
    assert len(tr2.outputs) == len(tr.outputs)
    _, removed_again = passes.cse_trace(tr2)
    assert removed_again == 0          # value numbering converges in one pass

    g = graphs.random_graph(40, 160, seed=seed, model="powerlaw",
                            n_edge_types=3)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    out = executor.run_reference(tr2, g, inputs, params)
    for a, b in zip(ref, out):
        assert _rel_err(a, b) < REL_TOL


def _check_optimize_roundtrip(name, n_layers, dim, seed):
    """E2V + DCE through compile_gnn: op count never grows, outputs hold."""
    tr = _trace(name, n_layers, dim)
    c = compiler.compile_gnn(tr)
    assert c.ir.op_count() <= c.naive_ir.op_count()
    assert c.opt_report["dce_removed"] >= 0
    g = graphs.random_graph(36, 150, seed=seed, model="powerlaw",
                            n_edge_types=3)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    from repro.core import tiling
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    out = executor.run_tiled(c, g, ts, inputs, params, kernel_dispatch=False)
    for a, b in zip(ref, out):
        assert _rel_err(a, b) < 1e-4


# ---------------------------------------------------------------------------
# deterministic sweep (runs everywhere, hypothesis or not)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", models.PAPER_MODELS)
def test_cse_roundtrip_deterministic(name):
    _check_cse_roundtrip(name, 2, 8, seed=11)


def test_cse_dedupe_count_pin():
    """Regression pin (PR 4): stacked GCN's re-emitted normalized adjacency
    costs exactly 3 deduplicated nodes per extra layer."""
    counts = {L: compiler.compile_gnn(
        models.trace_stacked("gcn", L, 16, 16, 16)).opt_report["cse_removed"]
        for L in (1, 2, 3)}
    assert counts[1] == 0
    assert counts[2] - counts[1] == 3
    assert counts[3] - counts[2] == 3


def test_optimize_roundtrip_deterministic():
    for name in ("gat_naive", "sage_naive"):
        if name in models.MODELS:
            _check_optimize_roundtrip(name, 1, 8, seed=13)
    _check_optimize_roundtrip("sage", 2, 8, seed=13)


# ---------------------------------------------------------------------------
# randomized sweep (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    pass                                              # deterministic tests above still run
else:
    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    @given(name=st.sampled_from(list(models.PAPER_MODELS)),
           n_layers=st.integers(1, 3),
           dim=st.sampled_from([4, 8]),
           seed=st.integers(0, 2**16))
    def test_cse_trace_property(name, n_layers, dim, seed):
        _check_cse_roundtrip(name, n_layers, dim, seed)

    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(name=st.sampled_from(["gcn", "gat", "sage", "ggnn", "rgcn"]),
           n_layers=st.integers(1, 2),
           seed=st.integers(0, 2**16))
    def test_optimize_property(name, n_layers, seed):
        _check_optimize_roundtrip(name, n_layers, 8, seed)
