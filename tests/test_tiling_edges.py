"""Serving-path tiling boundaries (ISSUE 5): ``tiling.pad_tileset`` and
``serve.signature.ShapeRegistry`` on the degenerate graphs a public serving
endpoint will eventually receive — zero-edge graphs, single-vertex graphs,
and requests whose padded class exactly equals the registered canonical
shape (no growth, no recompile).
"""
import numpy as np
import pytest

from repro.core import compiler, executor, pipeline, tiling
from repro.gnn import graphs, models
from repro.serve import InferenceServer
from repro.serve.signature import ShapeRegistry


def _zero_edge(v=6):
    return graphs.Graph(src=np.empty(0, np.int32), dst=np.empty(0, np.int32),
                        n_vertices=v)


def _single_vertex(self_loop=True):
    n = 1 if self_loop else 0
    return graphs.Graph(src=np.zeros(n, np.int32), dst=np.zeros(n, np.int32),
                        n_vertices=1)


# ---------------------------------------------------------------------------
# pad_tileset
# ---------------------------------------------------------------------------

def test_pad_tileset_zero_edge_graph():
    ts = tiling.grid_tile(_zero_edge(), 2, 2, sparse=True)
    assert ts.n_tiles == 0 and ts.n_edges == 0
    pt = tiling.pad_tileset(ts, 3, 8, 8)
    assert pt.n_tiles == 3 and pt.s_max == 8 and pt.e_max == 8
    # filler tiles: zero edges, attached to the last partition
    assert pt.n_edge.tolist() == [0, 0, 0]
    assert pt.part_id.tolist() == [1, 1, 1]
    assert pt.part_start.tolist() == ts.part_start.tolist()


def test_pad_tileset_single_vertex_graph():
    g = _single_vertex()
    ts = tiling.grid_tile(g, 2, 2, sparse=True)
    # one self-loop edge; the 1-vertex range still splits into 2 partitions
    # (one empty) without index errors
    assert ts.n_tiles == 1 and int(ts.n_edge.sum()) == 1
    assert ts.part_size.sum() == 1
    pt = tiling.pad_tileset(ts, 2, ts.s_max, ts.e_max)
    assert pt.n_tiles == 2 and int(pt.n_edge.sum()) == 1


def test_pad_tileset_no_growth_is_identity():
    g = graphs.random_graph(40, 160, seed=0)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    assert tiling.pad_tileset(ts, ts.n_tiles, ts.s_max, ts.e_max) is ts


def test_pad_tileset_rejects_shrink():
    g = graphs.random_graph(40, 160, seed=0)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    with pytest.raises(ValueError, match="cannot shrink"):
        tiling.pad_tileset(ts, ts.n_tiles - 1, ts.s_max, ts.e_max)
    with pytest.raises(ValueError, match="cannot shrink"):
        tiling.pad_tileset(ts, ts.n_tiles, ts.s_max, ts.e_max - 8)


def test_padded_zero_edge_tiles_execute_correctly():
    """Engines must treat filler tiles as no-ops: a padded zero-edge graph
    equals the whole-graph reference on both runners and kernel paths."""
    tr = models.trace_named("gcn", 8, 8)
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    g = _zero_edge()
    inputs = models.init_inputs(tr, g)
    ref = np.asarray(executor.run_reference(tr, g, inputs, params)[0])
    pt = tiling.pad_tileset(tiling.grid_tile(g, 2, 2, sparse=True), 2, 8, 8)
    for kd in (False, True):
        out = pipeline.run_pipelined(c, g, pt, inputs, params,
                                     kernel_dispatch=kd)
        assert np.max(np.abs(np.asarray(out[0]) - ref)) < 1e-5
    out = pipeline.run_sharded(c, g, pt, inputs, params, n_devices=1)
    assert np.max(np.abs(np.asarray(out[0]) - ref)) < 1e-5


# ---------------------------------------------------------------------------
# ShapeRegistry
# ---------------------------------------------------------------------------

def test_registry_zero_edge_graph_keeps_one_filler_tile():
    reg = ShapeRegistry()
    padded, tiles, e_rows, _ = reg.canonical(("k",), _zero_edge())
    assert tiles.n_tiles >= 1          # kernels always see a non-empty grid
    assert int(tiles.n_edge.sum()) == 0
    assert e_rows >= 1                 # edge-input rows padded to >= 1
    assert padded.n_vertices >= 6


def test_registry_single_vertex_graph():
    reg = ShapeRegistry()
    padded, tiles, e_rows, _ = reg.canonical(("k",), _single_vertex())
    assert padded.n_vertices >= 1
    assert int(tiles.n_edge.sum()) == 1


def test_registry_exact_shape_no_growth():
    """A request that realizes exactly the registered canonical shape must
    not bump the class (no recompile): signatures stay identical."""
    reg = ShapeRegistry()
    g = graphs.random_graph(40, 160, seed=0)
    _, t1, e1, _ = reg.canonical(("k",), g)
    entry = dict(reg._shapes[("k",)])
    # a graph realizing the registered v_pad exactly (equality, not excess)
    g2 = graphs.random_graph(entry["v_pad"], 160, seed=1)
    _, t2, e2, _ = reg.canonical(("k",), g2)
    assert reg._shapes[("k",)]["v_pad"] == entry["v_pad"]
    assert t2.shape_signature() == t1.shape_signature()
    assert e2 == e1
    assert len(reg) == 1


def test_serving_end_to_end_degenerate_graphs():
    """The full submit path (batch -> pad -> cached runner -> unbatch)
    serves zero-edge and single-vertex graphs and matches the reference."""
    tr = models.trace_named("gcn", 8, 8)
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    srv = InferenceServer(c, params)
    for g in (_zero_edge(), _single_vertex()):
        inp = models.init_inputs(tr, g)
        out = srv.submit([g], [inp])
        ref = np.asarray(executor.run_reference(tr, g, inp, params)[0])
        assert out[0][0].shape == ref.shape
        assert np.max(np.abs(out[0][0] - ref)) < 1e-5
    assert srv.stats()["graphs"] == 2
