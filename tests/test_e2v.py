"""E2V compiler-optimization tests (paper §6.2, Fig 8b / Fig 12)."""
import jax.numpy as jnp
import pytest

from repro.core import compiler, executor, isa, tiling
from repro.gnn import graphs, models


def _edge_compute_ops(prog):
    return [n for s in prog.edge_segments() for n in s.nodes.values()
            if n.op in ("matmul", "gemv", "bias_add", "relu", "add", "mul")]


def test_e2v_moves_gat_mvs():
    """The two attention mat-vecs on edges move to the vertex segment."""
    tr = models.trace_named("gat_naive")
    c = compiler.compile_gnn(tr)
    assert c.opt_report["e2v_moved"] >= 2
    naive_gemvs = [n for s in c.naive_ir.edge_segments()
                   for n in s.nodes.values() if n.op == "gemv"]
    opt_gemvs = [n for s in c.ir.edge_segments()
                 for n in s.nodes.values() if n.op == "gemv"]
    assert len(naive_gemvs) == 2 and len(opt_gemvs) == 0


def test_e2v_moves_sage_pool_mlp():
    c = compiler.compile_gnn(models.trace_named("sage_naive"))
    assert c.opt_report["e2v_moved"] >= 3  # matmul + bias_add + relu chain
    assert not _edge_compute_ops(c.ir)


def test_e2v_does_not_move_bmm():
    """R-GCN's edge-type BMM depends on per-edge data: must NOT be hoisted."""
    c = compiler.compile_gnn(models.trace_named("rgcn"))
    assert c.opt_report["e2v_moved"] == 0
    assert any(n.op == "bmm_edge" for s in c.ir.edge_segments()
               for n in s.nodes.values())


@pytest.mark.parametrize("name", ["gat_naive", "sage_naive"])
def test_e2v_numerically_equivalent(name):
    g = graphs.random_graph(150, 600, seed=2)
    tr = models.trace_named(name, 16, 16)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ts = tiling.grid_tile(g, 3, 3)
    c_opt = compiler.compile_gnn(tr, optimize=True)
    c_naive = compiler.compile_gnn(tr, optimize=False)
    o1 = executor.run_tiled(c_opt, g, ts, inputs, params)
    o2 = executor.run_tiled(c_naive, g, ts, inputs, params)
    for a, b in zip(o1, o2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_e2v_reduces_simulated_cost():
    """The point of E2V: per-edge work becomes per-vertex work."""
    from repro.core import simulator
    g = graphs.paper_graph("ak2010", scale=0.05, seed=0)
    ts = tiling.grid_tile(g, 4, 4)
    tr = models.trace_named("gat_naive")
    sde_naive = isa.emit_sde(compiler.compile_gnn(tr, optimize=False).plan)
    sde_opt = isa.emit_sde(compiler.compile_gnn(tr, optimize=True).plan)
    r_naive = simulator.simulate_model(sde_naive, ts)
    r_opt = simulator.simulate_model(sde_opt, ts)
    assert r_opt.cycles < r_naive.cycles
