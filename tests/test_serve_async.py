"""Async serving tier tests (ISSUE 8): deadlines, shedding, warmup races,
graceful shutdown, multi-tenant cache budgets, and the metrics layer.

Pinned here: (a) async results match the per-graph oracle; (b) an
already-expired deadline sheds immediately as a structured ``Overloaded``;
(c) a full queue sheds under BOTH policies (reject-new bounces the arrival,
drop-oldest evicts the oldest pending ticket); (d) a background warmup
racing a real request for the same size class compiles exactly once;
(e) a zero-request server shuts down gracefully and drains its queue on
close; (f) per-tenant cache budgets evict within the owner only."""
import threading
import time

import numpy as np
import pytest

from repro.core import compiler, executor
from repro.gnn import graphs, models
from repro.serve import (AsyncInferenceServer, Overloaded, ProgramCache,
                         ServeMetrics)
from repro.serve.metrics import Histogram, percentile
from repro.serve.server import (DEADLINE_EXPIRED, DROPPED_OLDEST, QUEUE_FULL,
                                SHUTDOWN)

TOL = 5e-4
DIM = 8


def _compiled(name="gcn", dim=DIM):
    tr = models.trace_named(name, dim, dim)
    return tr, compiler.compile_gnn(tr)


def _stream(tr, n, v=32, e=120, seed0=0):
    gs = [graphs.random_graph(v, e, seed=seed0 + k, model="powerlaw")
          for k in range(n)]
    ins = [models.init_inputs(tr, g, seed=seed0 + k) for k, g in enumerate(gs)]
    return gs, ins


def _server(**kw):
    kw.setdefault("default_deadline_s", 30.0)
    kw.setdefault("dispatch_margin_s", 0.05)
    kw.setdefault("n_workers", 2)
    return AsyncInferenceServer(**kw)


# ---------------------------------------------------------------------------
# request lifecycle: submit -> batch -> oracle-exact results
# ---------------------------------------------------------------------------

def test_async_results_match_oracle_and_ticket_api():
    tr, c = _compiled()
    params = models.init_params(tr)
    with _server() as srv:
        srv.register_model("gcn", c, params, max_batch=4)
        gs, ins = _stream(tr, 6)
        tickets = srv.submit_many(gs, ins)
        outs = [t.result(timeout=60) for t in tickets]
        for t in tickets:
            assert t.done() and t.ok
        for g, inp, out in zip(gs, ins, outs):
            ref = executor.run_reference(tr, g, inp, params)
            err = float(np.max(np.abs(np.asarray(ref[0]) - out[0])))
            assert err < TOL, err
        snap = srv.metrics.snapshot()
        assert snap["completed"] == 6 and snap["shed_total"] == 0
        assert snap["latency_s"]["count"] == 6
    # context-manager exit closed the server: late submits shed structurally
    late = srv.submit(gs[0], ins[0])
    res = late.result(timeout=5)
    assert isinstance(res, Overloaded) and res.reason == SHUTDOWN


def test_model_routing_errors():
    tr, c = _compiled()
    params = models.init_params(tr)
    srv = _server()
    with pytest.raises(ValueError):          # nothing registered
        srv.submit(graphs.random_graph(8, 16, seed=0), {})
    srv.register_model("a", c, params)
    srv.register_model("b", c, params)
    with pytest.raises(KeyError):
        srv.submit(graphs.random_graph(8, 16, seed=0), {}, model="nope")
    with pytest.raises(ValueError):          # ambiguous default
        srv.submit(graphs.random_graph(8, 16, seed=0), {})
    with pytest.raises(ValueError):          # duplicate tenant
        srv.register_model("a", c, params)
    srv.close()


# ---------------------------------------------------------------------------
# deadline edge cases
# ---------------------------------------------------------------------------

def test_already_expired_deadline_sheds_immediately():
    tr, c = _compiled()
    params = models.init_params(tr)
    srv = _server()
    srv.register_model("gcn", c, params)
    g, = _stream(tr, 1)[0]
    t = srv.submit(g, {}, deadline_s=0.0)     # asked for an answer in the past
    assert t.done()                           # resolved without the scheduler
    res = t.result()
    assert isinstance(res, Overloaded) and res.reason == DEADLINE_EXPIRED
    assert not t.ok
    assert srv.metrics.snapshot()["shed"][DEADLINE_EXPIRED] == 1
    assert srv.queue_depth == 0
    srv.close()


def test_partial_batch_ships_when_slack_expires():
    """3 requests against a cap of 8: nothing fills the batch, so the
    deadline must ship it — well before the full deadline elapses."""
    tr, c = _compiled()
    params = models.init_params(tr)
    with _server(dispatch_margin_s=0.2) as srv:
        srv.register_model("gcn", c, params, max_batch=8)
        gs, ins = _stream(tr, 3)
        t0 = time.monotonic()
        tickets = srv.submit_many(gs, ins, deadline_s=1.0)
        outs = [t.result(timeout=30) for t in tickets]
        took = time.monotonic() - t0
        assert all(t.ok for t in tickets)
        for g, inp, out in zip(gs, ins, outs):
            ref = executor.run_reference(tr, g, inp, params)
            assert float(np.max(np.abs(np.asarray(ref[0]) - out[0]))) < TOL
        snap = srv.metrics.snapshot()
        assert snap["batches"] == 1                      # one partial batch
        assert snap["batch_fill"]["max"] == pytest.approx(3 / 8)
        assert took < 30, "partial batch never shipped"


# ---------------------------------------------------------------------------
# admission control: queue-full shed under both policies
# ---------------------------------------------------------------------------

def test_queue_full_reject_new():
    tr, c = _compiled()
    params = models.init_params(tr)
    # not started: nothing drains the queue, so the bound is hit exactly
    srv = _server(max_queue=2, shed_policy="reject-new")
    srv.register_model("gcn", c, params)
    gs, ins = _stream(tr, 3)
    t1 = srv.submit(gs[0], ins[0])
    t2 = srv.submit(gs[1], ins[1])
    t3 = srv.submit(gs[2], ins[2])
    assert not t1.done() and not t2.done()
    res = t3.result(timeout=5)
    assert isinstance(res, Overloaded) and res.reason == QUEUE_FULL
    assert res.queue_depth == 2 and res.model == "gcn"
    assert srv.queue_depth == 2
    srv.close()                                  # unstarted close drains
    assert isinstance(t1.result(timeout=5), Overloaded)
    assert t1.result().reason == SHUTDOWN


def test_queue_full_drop_oldest():
    tr, c = _compiled()
    params = models.init_params(tr)
    srv = _server(max_queue=2, shed_policy="drop-oldest")
    srv.register_model("gcn", c, params)
    gs, ins = _stream(tr, 3)
    t1 = srv.submit(gs[0], ins[0])
    t2 = srv.submit(gs[1], ins[1])
    t3 = srv.submit(gs[2], ins[2])
    res = t1.result(timeout=5)                   # the OLDEST was evicted
    assert isinstance(res, Overloaded) and res.reason == DROPPED_OLDEST
    assert not t2.done() and not t3.done()       # newcomer was admitted
    assert srv.queue_depth == 2
    assert srv.metrics.snapshot()["shed"] == {DROPPED_OLDEST: 1}
    srv.close(drain=False)
    assert t2.result(timeout=5).reason == SHUTDOWN
    assert t3.result(timeout=5).reason == SHUTDOWN


# ---------------------------------------------------------------------------
# warmup racing a real request for the same size class
# ---------------------------------------------------------------------------

def test_warmup_races_real_request_single_compile():
    tr, c = _compiled()
    params = models.init_params(tr)
    warm_g = graphs.random_graph(32, 120, seed=777, model="powerlaw")
    srv = _server()
    engine = srv.register_model("gcn", c, params, max_batch=4,
                                warmup_graphs=[warm_g])
    srv.start()                                   # warmup compile kicks off
    gs, ins = _stream(tr, 4)                      # same size class, right now
    tickets = srv.submit_many(gs, ins)
    for t in tickets:
        assert t.result(timeout=120) is not None and t.ok
    deadline = time.monotonic() + 60
    while not srv.warmup_done() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert srv.warmup_done()
    # the race resolved inside the cache: one build, everyone else waited
    assert engine.compile_count == 1, \
        f"warmup raced a duplicate compile ({engine.compile_count})"
    assert srv.cache.stats.hits >= 1
    snap = srv.metrics.snapshot()
    assert snap["warmup"] == dict(done=1, total=1)
    srv.close()


def test_concurrent_same_key_builds_once():
    """ProgramCache per-key build lock: N threads racing one key invoke the
    builder once; losers block and come back as hits."""
    cache = ProgramCache(capacity=4)
    built = []

    def build():
        time.sleep(0.05)                  # widen the race window
        built.append(1)
        return "value"

    results = []
    threads = [threading.Thread(
        target=lambda: results.append(cache.get_or_build("k", build)))
        for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert built == [1] and results == ["value"] * 4
    assert cache.stats.misses == 1 and cache.stats.hits == 3


# ---------------------------------------------------------------------------
# graceful shutdown
# ---------------------------------------------------------------------------

def test_zero_request_graceful_shutdown():
    """A started server that never saw a request closes promptly and its
    scheduler thread exits."""
    tr, c = _compiled()
    params = models.init_params(tr)
    srv = _server()
    srv.register_model("gcn", c, params)
    srv.start(warmup=False)
    t0 = time.monotonic()
    srv.close(drain=True)
    assert time.monotonic() - t0 < 10
    assert not srv._scheduler.is_alive()
    srv.close()                                   # idempotent


def test_close_drains_pending_requests():
    """close(drain=True) serves what is already queued (partial batch, far
    deadline) instead of abandoning it."""
    tr, c = _compiled()
    params = models.init_params(tr)
    srv = _server(n_workers=1, dispatch_margin_s=0.05)
    srv.register_model("gcn", c, params, max_batch=8)
    srv.start(warmup=False)
    gs, ins = _stream(tr, 2)
    tickets = srv.submit_many(gs, ins, deadline_s=300.0)   # never ripe
    srv.close(drain=True)
    for t, g, inp in zip(tickets, gs, ins):
        out = t.result(timeout=5)
        assert t.ok, out
        ref = executor.run_reference(tr, g, inp, params)
        assert float(np.max(np.abs(np.asarray(ref[0]) - out[0]))) < TOL


# ---------------------------------------------------------------------------
# multi-tenancy: shared cache, per-owner budgets
# ---------------------------------------------------------------------------

def test_multi_tenant_budgets_evict_within_owner_only():
    tr_a, c_a = _compiled("gcn")
    tr_b = models.trace_stacked("gcn", 2, DIM, DIM, DIM)
    c_b = compiler.compile_gnn(tr_b)
    srv = _server()
    eng_a = srv.register_model("tenant-a", c_a, models.init_params(tr_a),
                               cache_budget=1)
    eng_b = srv.register_model("tenant-b", c_b, models.init_params(tr_b),
                               cache_budget=2)
    # drive the engines synchronously: two size classes per tenant
    small_g, small_i = _stream(tr_a, 2, v=24, e=80)
    big_g, big_i = _stream(tr_a, 2, v=200, e=900, seed0=9)
    eng_a.submit(small_g, small_i)
    eng_b.submit(small_g, small_i)
    eng_b.submit(big_g, big_i)
    owners = srv.cache.owner_counts()
    assert owners == {"tenant-a": 1, "tenant-b": 2}
    # tenant-a overflowing its budget of 1 evicts ITS entry, not b's
    evictions_before = srv.cache.stats.evictions
    eng_a.submit(big_g, big_i)
    owners = srv.cache.owner_counts()
    assert owners == {"tenant-a": 1, "tenant-b": 2}
    assert srv.cache.stats.evictions == evictions_before + 1
    # b's warm runners survived: same-class resubmission is a pure hit
    compiles = srv.cache.stats.compiles
    eng_b.submit(small_g, small_i)
    eng_b.submit(big_g, big_i)
    assert srv.cache.stats.compiles == compiles
    srv.close()


def test_cache_budget_validation():
    cache = ProgramCache(capacity=4)
    with pytest.raises(ValueError):
        cache.set_budget("x", 0)
    srv = _server()
    with pytest.raises(ValueError):
        AsyncInferenceServer(shed_policy="lifo")
    with pytest.raises(ValueError):
        AsyncInferenceServer(fill_policy="truncate")
    with pytest.raises(ValueError):
        AsyncInferenceServer(max_queue=0)
    srv.close()


# ---------------------------------------------------------------------------
# metrics layer
# ---------------------------------------------------------------------------

def test_percentile_and_histogram():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    xs = [float(i) for i in range(1, 101)]
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 99) == 99.0
    assert percentile(xs, 100) == 100.0
    assert percentile(xs, 0) == 1.0
    h = Histogram(window=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):      # window keeps last 4
        h.record(v)
    assert h.count == 6 and h.max == 6.0
    assert h.percentile(50) == 4.0                # over {3,4,5,6}
    assert h.mean == pytest.approx(21 / 6)
    with pytest.raises(ValueError):
        Histogram(window=0)


def test_serve_metrics_snapshot_shape():
    m = ServeMetrics()
    m.on_submit(queue_depth=3)
    m.on_batch(n_requests=2, cap=4, queue_depth=1)
    m.on_complete(0.25, queue_wait_s=0.1)
    m.on_shed("queue-full")
    m.on_warmup(1, 2)
    snap = m.snapshot()
    assert snap["submitted"] == 1 and snap["completed"] == 1
    assert snap["batches"] == 1 and snap["shed"] == {"queue-full": 1}
    assert snap["shed_total"] == 1 and m.shed_count == 1
    assert snap["warmup"] == dict(done=1, total=2)
    assert snap["latency_s"]["p50"] == 0.25
    assert snap["batch_fill"]["p50"] == 0.5
    for family in ("latency_s", "queue_wait_s", "batch_fill", "queue_depth"):
        assert set(snap[family]) == {"count", "mean", "max",
                                     "p50", "p90", "p99"}
    assert "queue-full" in m.to_json()
