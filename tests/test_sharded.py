"""Sharded execution over a device mesh (ISSUE 5).

Pinned here: (a) :func:`~repro.core.tiling.plan_shards` balances padded-edge
cost and handles ragged partition counts; (b) the
:class:`~repro.core.pipeline.ShardedRunner` matches the single-device
``PipelinedRunner`` and the whole-graph oracle on all five paper models,
with kernel dispatch ON (Pallas gather blocks inside ``shard_map``) and OFF
(lax.scan fallback) — in-process on ``min(4, visible devices)`` shards (the
CI sharded-smoke step forces 8 host devices so this is a REAL multi-device
run there), and in a subprocess on a forced 8-host-device mesh across
{1, 2, 4, 8}-shard meshes; (c) the lowered program contains exactly ONE
cross-device collective per layer boundary, both schedule variants;
(d) the multi-chip simulator cost model scales; (e) a
hypothesis conformance sweep over random graphs × models × layers × ragged
partition/bucket counts.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import compiler, executor, pipeline, simulator, tiling, isa
from repro.gnn import graphs, models

DIM = 16
REL_TOL = 1e-4

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b)) / max(1.0, np.max(np.abs(a))))


def _compiled(name, n_layers, dim=DIM):
    tr = (models.trace_named(name, dim, dim) if n_layers == 1
          else models.trace_stacked(name, n_layers, dim, dim, dim))
    return tr, compiler.compile_gnn(tr)


def _avail_mesh(cap=4):
    import jax
    return min(cap, len(jax.devices()))


# ---------------------------------------------------------------------------
# ShardPlan
# ---------------------------------------------------------------------------

def test_shard_plan_cost_balance():
    g = graphs.random_graph(300, 1500, seed=0, model="powerlaw")
    bt = tiling.bucket_tiles(tiling.grid_tile(g, 8, 8, sparse=True), 3)
    plan = tiling.plan_shards(bt, 4, mode="cost")
    costs = plan.shard_costs()
    assert costs.sum() == tiling.partition_costs(bt).sum()
    # LPT greedy: no shard more than 2x the mean (loose, deterministic bound)
    assert costs.max() <= 2 * max(costs.mean(), 1)
    # every partition owned exactly once
    owned = np.concatenate(plan.parts_of_shard)
    assert sorted(owned.tolist()) == list(range(8))
    for k, parts in enumerate(plan.parts_of_shard):
        assert all(plan.shard_of_part[p] == k for p in parts)
        assert [plan.local_slot_of_part[p] for p in parts] == list(range(len(parts)))


def test_shard_plan_ragged_and_modes():
    g = graphs.random_graph(100, 400, seed=1, model="powerlaw")
    ts = tiling.grid_tile(g, 5, 5, sparse=True)
    # 5 partitions over 4 shards: ragged — one shard owns 2, slots padded
    plan = tiling.plan_shards(ts, 4, mode="cost")
    assert plan.n_local_parts == 2
    assert sorted(len(p) for p in plan.parts_of_shard) == [1, 1, 1, 2]
    # contiguous mode is a pure function of (P, K): ranges in order
    pc = tiling.plan_shards(ts, 4, mode="contiguous")
    flat = np.concatenate(pc.parts_of_shard)
    assert flat.tolist() == sorted(flat.tolist())
    # determinism
    assert (tiling.plan_shards(ts, 4, mode="cost").signature()
            == plan.signature())
    # more shards than partitions: trailing shards stay empty
    p7 = tiling.plan_shards(ts, 7, mode="cost")
    assert sum(len(p) for p in p7.parts_of_shard) == 5
    assert p7.n_local_parts == 1
    with pytest.raises(ValueError, match="n_shards"):
        tiling.plan_shards(ts, 0)
    with pytest.raises(ValueError, match="unknown shard mode"):
        tiling.plan_shards(ts, 2, mode="zigzag")


def test_mincut_plan_cut_and_accessors():
    g = graphs.random_graph(400, 2400, seed=3, model="powerlaw")
    ts = tiling.grid_tile(g, 32, 32, sparse=True)
    lpt = tiling.plan_shards(ts, 4, mode="cost")
    mc = tiling.plan_shards(ts, 4, mode="mincut")
    # refinement never worsens the symmetric cut (strictly-positive-gain
    # moves only) and never exceeds the LPT/balance-tol load cap
    assert mc.edge_cut() <= lpt.edge_cut()
    cap = max(int(lpt.shard_costs().max()),
              int(np.ceil(1.05 * lpt.part_cost.sum() / 4)))
    assert int(mc.shard_costs().max()) <= cap
    # exact assignment accessor mirrors parts_of_shard
    assert mc.assignment() == tuple(
        tuple(int(p) for p in ps) for ps in mc.parts_of_shard)
    # stable digest: deterministic, and mode/assignment changes change it
    assert mc.signature() == tiling.plan_shards(ts, 4, mode="mincut").signature()
    assert mc.signature() != lpt.signature()
    # the restricted exchange derives from the same plan
    ex = tiling.exchange_sets(ts, mc)
    assert ex.n_shards == 4 and ex.cut_rows >= 0
    assert ex.max_send == max(len(r) for r in ex.send_rows)
    # edge_cut demands the adjacency the planner stores
    bare = tiling.ShardPlan(
        n_shards=mc.n_shards, parts_of_shard=mc.parts_of_shard,
        shard_of_part=mc.shard_of_part,
        local_slot_of_part=mc.local_slot_of_part,
        part_cost=mc.part_cost, mode=mc.mode)
    with pytest.raises(ValueError, match="partition adjacency"):
        bare.edge_cut()


def test_shard_layout_signature_distinguishes_meshes():
    g = graphs.random_graph(120, 500, seed=2, model="powerlaw")
    bt = tiling.bucket_tiles(tiling.grid_tile(g, 6, 6, sparse=True), 3)
    sigs = {pipeline.shard_layout_signature(bt, k) for k in (1, 2, 4, 8)}
    assert len(sigs) == 4    # device count can never alias in a cache key
    # quantized caps differ from exact caps (pow2 snap) but are deterministic
    q = pipeline.shard_layout_signature(bt, 4, quantize_tile_cap=True)
    assert q == pipeline.shard_layout_signature(bt, 4, quantize_tile_cap=True)


# ---------------------------------------------------------------------------
# conformance: ShardedRunner vs PipelinedRunner vs whole-graph oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", models.PAPER_MODELS)
@pytest.mark.parametrize("n_layers", [1, 2])
@pytest.mark.parametrize("dispatch", [False, True],
                         ids=["scan", "kernel"])
def test_sharded_matches_pipelined_and_oracle(name, n_layers, dispatch):
    """Runs on min(4, visible) shards: a real 4-way mesh under the CI
    sharded-smoke step (8 forced host devices), a 1-shard mesh in plain
    tier-1 — the full shard_map/all-gather path executes either way, with
    the tile work going through the Pallas gather blocks when ``dispatch``
    is on and the lax.scan fallback when it is off."""
    g = graphs.random_graph(150, 600, seed=3, model="powerlaw", n_edge_types=3)
    tr, c = _compiled(name, n_layers)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    bt = tiling.bucket_tiles(tiling.grid_tile(g, 5, 5, sparse=True), 3)
    out_p = pipeline.run_pipelined(c, g, bt, inputs, params,
                                   kernel_dispatch=False)
    out_s = pipeline.run_sharded(c, g, bt, inputs, params,
                                 n_devices=_avail_mesh(),
                                 kernel_dispatch=dispatch)
    assert _rel_err(out_p[0], out_s[0]) < REL_TOL, (name, n_layers, dispatch)
    assert _rel_err(ref[0], out_s[0]) < REL_TOL, (name, n_layers, dispatch)


@pytest.mark.parametrize("name", models.PAPER_MODELS)
@pytest.mark.parametrize("n_layers", [1, 2])
def test_layout_reorder_conformance_vs_oracle(name, n_layers):
    """The full {CSR, COO} x {identity, degree} lattice the autotuner now
    searches stays conformant with the dense whole-graph oracle — features
    permuted in, outputs permuted back, Pallas CSR row-pointer walk and the
    COO dense-tile matmul both within rel 1e-4, single- and multi-layer."""
    g = graphs.random_graph(100, 400, seed=3, model="powerlaw",
                            n_edge_types=3)
    tr, c = _compiled(name, n_layers)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    for layout in ("coo", "csr"):
        for mode in ("identity", "degree"):
            ts, ro = tiling.build_tiles(g, 4, 4, reorder=mode,
                                        layout=layout, n_buckets=2)
            assert ts.layout == layout and ro.mode == mode
            out = pipeline.run_pipelined(c, ro.graph, ts, inputs, params,
                                         kernel_dispatch=True, reordering=ro)
            assert _rel_err(ref[0], out[0]) < REL_TOL, \
                (name, n_layers, layout, mode)


def test_sharded_layout_reorder_conformance():
    """CSR + degree reorder through the ShardedRunner (shard_map path, real
    mesh under the CI sharded-smoke step): matches the oracle, and the
    permutation operands ride along as plain replicated gathers — the
    forced-8 subprocess census below pins that no extra collective
    appears."""
    g = graphs.random_graph(150, 600, seed=3, model="powerlaw",
                            n_edge_types=3)
    tr, c = _compiled("gcn", 2)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts, ro = tiling.build_tiles(g, 5, 5, reorder="degree", layout="csr",
                                n_buckets=3)
    out = pipeline.run_sharded(c, ro.graph, ts, inputs, params,
                               n_devices=_avail_mesh(),
                               kernel_dispatch=True, reordering=ro)
    assert _rel_err(ref[0], out[0]) < REL_TOL


def test_sharded_runner_bind_and_run_with():
    """A structurally-identical tile set rebinds through the warm
    compilation: same outputs as a fresh runner, no retrace."""
    tr, c = _compiled("gcn", 2)
    params = models.init_params(tr)
    g1 = graphs.random_graph(120, 480, seed=4, model="powerlaw")
    g2 = graphs.random_graph(120, 480, seed=5, model="powerlaw")
    t1 = tiling.grid_tile(g1, 4, 4, sparse=True)
    t2 = tiling.grid_tile(g2, 4, 4, sparse=True)
    # snap both onto one shape envelope (what the serving registry does)
    env = (max(t1.n_tiles, t2.n_tiles), max(t1.s_max, t2.s_max),
           max(t1.e_max, t2.e_max))
    t1, t2 = tiling.pad_tileset(t1, *env), tiling.pad_tileset(t2, *env)
    assert t1.shape_signature() == t2.shape_signature()
    n_dev = _avail_mesh()
    r = pipeline.ShardedRunner(c, g1, t1, n_dev, mode="contiguous",
                               quantize_tile_cap=True)
    i1, i2 = models.init_inputs(tr, g1), models.init_inputs(tr, g2)
    out_warm = r.run_with(t2, i2, params)
    fresh = pipeline.ShardedRunner(c, g2, t2, n_dev, mode="contiguous",
                                   quantize_tile_cap=True)
    out_fresh = fresh(i2, params)
    assert _rel_err(out_fresh[0], out_warm[0]) < REL_TOL
    r(i1, params)
    assert r.jit_cache_size() in (-1, 1)     # no silent retrace
    # identical layout => identical signature: the premise of the cache hit
    assert r.signature == fresh.signature


def test_sharded_runner_validation():
    import jax
    tr, c = _compiled("gcn", 1)
    g = graphs.random_graph(60, 240, seed=6)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        pipeline.ShardedRunner(c, g, ts, len(jax.devices()) + 1)
    r = pipeline.ShardedRunner(c, g, ts, 1)
    other = tiling.grid_tile(graphs.random_graph(61, 250, seed=7), 3, 3,
                             sparse=True)
    if other.shape_signature() != ts.shape_signature():
        with pytest.raises(ValueError, match="not structurally identical"):
            r.bind(other)


def test_sharded_runner_zero_edge_and_tiny_graphs():
    """Serving-path boundaries run through the sharded engine too."""
    tr, c = _compiled("gcn", 1, dim=8)
    params = models.init_params(tr)
    for g in (graphs.Graph(src=np.empty(0, np.int32),
                           dst=np.empty(0, np.int32), n_vertices=6),
              graphs.Graph(src=np.zeros(1, np.int32),
                           dst=np.zeros(1, np.int32), n_vertices=1)):
        inputs = models.init_inputs(tr, g)
        ref = executor.run_reference(tr, g, inputs, params)
        ts = tiling.grid_tile(g, 2, 2, sparse=True)
        ts = tiling.pad_tileset(ts, max(ts.n_tiles, 2), max(ts.s_max, 8),
                                max(ts.e_max, 8))
        out = pipeline.run_sharded(c, g, ts, inputs, params,
                                   n_devices=_avail_mesh())
        assert _rel_err(ref[0], out[0]) < REL_TOL, (g.n_vertices, g.n_edges)


# ---------------------------------------------------------------------------
# serving route
# ---------------------------------------------------------------------------

def test_serving_shard_route_validation():
    import jax
    from repro.serve import InferenceServer
    tr, c = _compiled("gcn", 1, dim=8)
    with pytest.raises(ValueError, match="shard_devices must be"):
        InferenceServer(c, models.init_params(tr), shard_devices=0)
    # misconfiguration fails at construction, not at the first large batch
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        InferenceServer(c, models.init_params(tr),
                        shard_devices=len(jax.devices()) + 1)


def test_serving_shard_route_in_process():
    """Large classes go sharded, small classes stay single-device, repeat
    requests hit the warm sharded runner.  Needs >= 2 devices (the CI
    sharded-smoke step forces 8); the subprocess variant below covers plain
    tier-1 hosts."""
    import jax
    from repro.serve import InferenceServer
    if len(jax.devices()) < 2:
        pytest.skip("needs a multi-device mesh (XLA_FLAGS host device count)")
    _run_serving_shard_route(min(4, len(jax.devices())))


def _run_serving_shard_route(n_dev):
    from repro.serve import InferenceServer
    tr, c = _compiled("gcn", 2, dim=16)
    params = models.init_params(tr)
    # threshold sits between the small class's padded V (64) and the big
    # class's (~480): the two routes must coexist in one server
    srv = InferenceServer(c, params, n_layers=2, shard_devices=n_dev,
                          shard_min_vertices=256)
    for rnd in range(3):
        big = [graphs.random_graph(120 + rnd, 500, seed=10 * rnd + i)
               for i in range(3)]
        small = [graphs.random_graph(16, 60, seed=20 * rnd + i)
                 for i in range(2)]
        gs = big + small
        outs = srv.submit(gs, [models.init_inputs(tr, g) for g in gs])
        for g, out in zip(gs, outs):
            inp = models.init_inputs(tr, g)
            ref = executor.run_reference(tr, g, inp, params)
            assert _rel_err(ref[0], out[0]) < REL_TOL, (rnd, g.n_vertices)
    st = srv.stats()
    assert st["sharded_batches"] == 3          # one big batch per round
    assert st["batches"] == 6                  # + one small batch per round
    # the sharded route amortizes: rounds 2 and 3 hit the warm runner
    assert srv.compile_count <= 3              # <= one per distinct class
    assert srv.cache_hits >= 3


_SERVE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {tests!r})
    from test_sharded import _run_serving_shard_route
    _run_serving_shard_route(4)
    print("SERVE_ROUTE_OK")
""")


@pytest.mark.slow
def test_serving_shard_route_forced_mesh():
    env = dict(os.environ, PYTHONPATH=SRC)
    script = _SERVE_SCRIPT.format(src=os.path.abspath(SRC),
                                  tests=os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SERVE_ROUTE_OK" in out.stdout


# ---------------------------------------------------------------------------
# multi-chip simulator axis
# ---------------------------------------------------------------------------

def test_simulated_chip_scaling():
    g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0, n_edge_types=3)
    ts = tiling.grid_tile(g, 6, 6, sparse=True)
    _, c = _compiled("gcn", 2)
    sde = isa.emit_sde(c.schedule(False))
    base = simulator.simulate_model(sde, ts, inter_layer="pipelined")
    prev = base.cycles
    for k in (2, 4):
        r = simulator.simulate_sharded(sde, ts, n_chips=k)
        assert len(r.per_chip_cycles) == k
        assert r.cycles < prev, (k, r.cycles, prev)   # monotone scaling here
        assert r.n_exchanges == 1 and r.exchange_cycles > 0
        assert r.exchange_bytes > 0 and r.balance >= 1.0
        prev = r.cycles
    # a 1-chip "sharded" run degenerates to the plain simulation, no exchange
    r1 = simulator.simulate_sharded(sde, ts, n_chips=1)
    assert r1.exchange_cycles == 0 and r1.cycles == base.cycles


def test_task_graph_parts_restriction():
    from repro.core.streams import HWConfig, build_task_graph
    g = graphs.random_graph(120, 500, seed=8, model="powerlaw")
    ts = tiling.grid_tile(g, 4, 4, sparse=True)
    _, c = _compiled("gcn", 2)
    sde = isa.emit_sde(c.schedule(False))
    full, _ = build_task_graph(sde, ts, HWConfig(), inter_layer="pipelined")
    plan = tiling.plan_shards(ts, 2)
    halves = [build_task_graph(sde, ts, HWConfig(), inter_layer="pipelined",
                               parts=plan.parts_of_shard[k])[0]
              for k in range(2)]
    # per-chip graphs are valid DAGs and together cover every tile task
    for tasks in halves:
        for t in tasks:
            assert all(d < t.tid for d in t.deps)
    n_tile = sum(1 for t in full if t.kind in ("s", "e"))
    assert sum(sum(1 for t in h if t.kind in ("s", "e")) for h in halves) == n_tile


# ---------------------------------------------------------------------------
# forced multi-device mesh (subprocess: device count binds at jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, re, sys
    import numpy as np
    from repro.core import compiler, pipeline, tiling
    from repro.gnn import graphs, models

    DIM = 16
    out = []
    g = graphs.random_graph(150, 600, seed=3, model="powerlaw", n_edge_types=3)
    for name in ("gcn", "gat", "sage", "ggnn", "rgcn"):
        tr = models.trace_stacked(name, 2, DIM, DIM, DIM)
        c = compiler.compile_gnn(tr)
        params = models.init_params(tr)
        inputs = models.init_inputs(tr, g)
        ts = tiling.grid_tile(g, 5, 5, sparse=True)   # ragged: 5 parts
        bt = tiling.bucket_tiles(ts, 3)
        ref = pipeline.run_pipelined(c, g, bt, inputs, params,
                                     kernel_dispatch=False)
        for dispatch in (False, True):
            for n_dev in ((1, 2, 4, 8) if not dispatch else (1, 4, 8)):
                r = pipeline.ShardedRunner(c, g, bt, n_dev,
                                           kernel_dispatch=dispatch)
                got = r(inputs, params)
                err = float(np.max(np.abs(np.asarray(got[0]) - np.asarray(ref[0])))
                            / max(1.0, float(np.max(np.abs(np.asarray(ref[0]))))))
                rec = {"model": name, "n_dev": n_dev, "dispatch": dispatch,
                       "rel": err}
                if n_dev == 4 and name in ("gcn", "gat"):
                    # representative HLO cross-check for BOTH schedule
                    # variants; the per-model census is asserted statically
                    # (analysis.exchange_census) below
                    hlo = r.lower_text(inputs, params)
                    rec["collectives"] = len(re.findall(r"all-gather(?:-start)?\\(", hlo))
                    rec["n_layers"] = c.n_layers
                out.append(rec)
        # the tuned CSR + degree-reorder route on the full 8-device mesh:
        # conformant, and the (order, rank) permutation operands are plain
        # replicated gathers — the all-gather census must stay exactly
        # n_layers, same as the identity/COO runs above
        bt2, ro = tiling.build_tiles(g, 5, 5, reorder="degree",
                                     layout="csr", n_buckets=3)
        r = pipeline.ShardedRunner(c, ro.graph, bt2, 8,
                                   kernel_dispatch=True, reordering=ro)
        got = r(inputs, params)
        err = float(np.max(np.abs(np.asarray(got[0]) - np.asarray(ref[0])))
                    / max(1.0, float(np.max(np.abs(np.asarray(ref[0]))))))
        rec = {"model": name, "n_dev": 8, "dispatch": True, "rel": err,
               "reorder": "degree", "layout": "csr"}
        if name in ("gcn", "gat"):
            hlo = r.lower_text(inputs, params)
            rec["collectives"] = len(re.findall(r"all-gather(?:-start)?\\(", hlo))
            rec["n_layers"] = c.n_layers
        out.append(rec)
        # mincut plan + restricted exchange on a 2-D (shards, model) mesh:
        # 4 graph shards x 2 model ranks over the same 8 forced devices
        r2 = pipeline.ShardedRunner(c, g, bt, 4, mode="mincut",
                                    model_axis=2, kernel_dispatch=True)
        got = r2(inputs, params)
        err = float(np.max(np.abs(np.asarray(got[0]) - np.asarray(ref[0])))
                    / max(1.0, float(np.max(np.abs(np.asarray(ref[0]))))))
        out.append({"model": name, "n_dev": 4, "dispatch": True, "rel": err,
                    "mode": "mincut", "model_axis": 2})
    print(json.dumps(out))
""")


def test_static_collective_census_per_model():
    """Every paper model's sharded execution exchanges exactly one
    collective per layer boundary — asserted from the program itself via
    :func:`analysis.exchange_census`, no lowering required."""
    from repro.core import analysis as A

    for name in models.PAPER_MODELS:
        _, c = _compiled(name, 2)
        for dispatch in (False, True):
            cen = A.exchange_census(c.schedule(dispatch))
            assert cen.n_collectives == c.n_layers, (name, dispatch, cen.events)
            assert not A.verify_exchange(c.schedule(dispatch)), (name, dispatch)


def test_exchange_coverage_proof_scan_and_kernel(monkeypatch):
    """The restricted exchange is PROVEN to cover every sharded read —
    statically, for every paper model, on both schedule variants — and the
    prover actually bites when the send sets or the plan are corrupted."""
    from repro.core import analysis as A

    g = graphs.random_graph(300, 1500, seed=0, model="powerlaw",
                            n_edge_types=3)
    bt, _ = tiling.build_tiles(g, 16, 16, n_buckets=3)
    plan = tiling.plan_shards(bt, 8, mode="mincut")
    for name in models.PAPER_MODELS:
        _, c = _compiled(name, 2)
        for dispatch in (False, True):
            diags = A.verify_exchange(c.schedule(dispatch), tiles=bt,
                                      plan=plan)
            assert not [d for d in diags if d.severity == "error"], \
                (name, dispatch, [d.format() for d in diags])
            assert [d.code for d in diags] == ["ZH210"], (name, dispatch)
    sp = _compiled("gcn", 2)[1].schedule(False)
    # n_shards= builds the plan internally; tiles without a plan spec raise
    assert [d.code for d in A.verify_exchange(sp, tiles=bt, n_shards=4)] \
        == ["ZH210"]
    with pytest.raises(ValueError, match="plan= or n_shards"):
        A.verify_exchange(sp, tiles=bt)
    # a send set that loses a row is caught as an uncovered read (ZH207)
    real = tiling.exchange_sets

    def lossy(tiles, plan):
        ex = real(tiles, plan)
        trimmed = tuple(r[:-1] if len(r) else r for r in ex.send_rows)
        return tiling.ExchangePlan(
            n_shards=ex.n_shards, n_vertices=ex.n_vertices,
            read_rows=ex.read_rows, owner_of_row=ex.owner_of_row,
            send_rows=trimmed, pair_rows=ex.pair_rows)

    monkeypatch.setattr(tiling, "exchange_sets", lossy)
    codes = {d.code for d in A.verify_exchange(sp, tiles=bt, plan=plan)
             if d.severity == "error"}
    assert codes == {"ZH207"}
    monkeypatch.undo()
    # an inconsistent plan breaks recvDst locality (ZH208)
    import dataclasses as dc
    bad = dc.replace(plan, shard_of_part=plan.shard_of_part.copy())
    bad.shard_of_part[0] = (plan.shard_of_part[0] + 1) % plan.n_shards
    codes = {d.code for d in A.verify_exchange(sp, tiles=bt, plan=bad)
             if d.severity == "error"}
    assert "ZH208" in codes


def test_mincut_empty_shards_end_to_end():
    """More shards than destination partitions: trailing shards own nothing
    and the mincut planner + restricted exchange must still be conformant
    (a REAL multi-device run under the CI sharded-smoke step)."""
    tr, c = _compiled("gcn", 2)
    params = models.init_params(tr)
    g = graphs.random_graph(90, 360, seed=9, model="powerlaw")
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts = tiling.grid_tile(g, 3, 3, sparse=True)     # 3 dst parts
    n_dev = _avail_mesh()                            # up to 4 shards
    plan = tiling.plan_shards(ts, n_dev, mode="mincut")
    if n_dev > 3:
        assert min(len(p) for p in plan.parts_of_shard) == 0
    for dispatch in (False, True):
        r = pipeline.ShardedRunner(c, g, ts, n_dev, mode="mincut",
                                   kernel_dispatch=dispatch)
        assert _rel_err(ref[0], r(inputs, params)[0]) < REL_TOL, dispatch
    # the simulator cost model tolerates empty shards too
    sde = isa.emit_sde(c.schedule(False))
    r = simulator.simulate_sharded(sde, ts, n_chips=max(4, n_dev),
                                   mode="mincut")
    assert len(r.per_chip_cycles) == max(4, n_dev) and r.cycles > 0


def test_sharded_2d_mesh_conformance():
    """(shards, model) 2-D mesh: model-parallel column split on top of the
    graph shards stays conformant.  Needs >= 4 devices (CI forces 8)."""
    import jax
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (CI sharded-smoke forces 8)")
    tr = models.trace_stacked("gat", 2, DIM, 2 * DIM, DIM)
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    g = graphs.random_graph(150, 600, seed=11, model="powerlaw")
    inputs = models.init_inputs(tr, g)
    ref = executor.run_reference(tr, g, inputs, params)
    ts = tiling.grid_tile(g, 5, 5, sparse=True)
    meshes = [(2, 2)]
    if len(jax.devices()) >= 8:
        meshes += [(4, 2), (2, 4)]
    for k, m in meshes:
        r = pipeline.ShardedRunner(c, g, ts, k, mode="mincut", model_axis=m,
                                   kernel_dispatch=False)
        assert _rel_err(ref[0], r(inputs, params)[0]) < REL_TOL, (k, m)


@pytest.mark.slow
def test_forced_mesh_conformance_and_collective_census():
    """Acceptance: all five paper models × {1,2,4,8} forced host devices
    match the single-device PipelinedRunner to rel 1e-4, and — on the
    representative model — the HLO all-gather count agrees with the static
    exchange census (so the two censuses can never drift apart silently)."""
    from repro.core import analysis as A

    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    recs = json.loads(out.stdout.strip().splitlines()[-1])
    # 5 models x (4 scan + 3 kernel + 1 csr-degree-reorder + 1 2-D mincut)
    assert len(recs) == 45
    for rec in recs:
        assert rec["rel"] < REL_TOL, rec
    reordered = [rec for rec in recs if rec.get("reorder") == "degree"]
    assert len(reordered) == 5 and all(rec["layout"] == "csr"
                                       for rec in reordered)
    mesh2d = [rec for rec in recs if rec.get("model_axis") == 2]
    assert len(mesh2d) == 5 and all(rec["mode"] == "mincut"
                                    for rec in mesh2d)
    checked = [rec for rec in recs if "collectives" in rec]
    assert len(checked) == 6, \
        "gcn/gat x scan/kernel/reorder HLO census missing"
    for rec in checked:
        _, c = _compiled(rec["model"], 2)
        static = A.exchange_census(c.schedule(rec["dispatch"])).n_collectives
        assert rec["collectives"] == static == rec["n_layers"], rec


# The hypothesis conformance sweep lives in test_sharded_property.py (its
# module-level importorskip must not skip the deterministic tests above).
