"""Intra-repo markdown link checker (CI docs job; no network).

Scans every tracked markdown file (repo root + ``docs/``) for
``[text](target)`` links, resolves relative targets against the linking
file, and fails if any target does not exist.  External (``http(s)://``,
``mailto:``) and pure-anchor (``#...``) links are skipped; a ``#fragment``
on a relative link is stripped before the existence check.

Usage:
    python tools/check_links.py            # check, exit 1 on broken links
    python tools/check_links.py --list     # also print every checked link
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys
from typing import List, Tuple

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: [text](target) — target captured up to the closing paren (no nesting)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def markdown_files() -> List[pathlib.Path]:
    """Every markdown file the repo's docs surface consists of."""
    files = sorted(ROOT.glob("*.md"))
    for sub in ("docs", "examples", "tools"):
        d = ROOT / sub
        if d.is_dir():
            files += sorted(d.glob("**/*.md"))
    return files


def check_links() -> Tuple[List[str], int]:
    """Returns (broken-link messages, total links checked)."""
    broken: List[str] = []
    checked = 0
    for md in markdown_files():
        text = md.read_text()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_SKIP_PREFIXES):
                continue
            checked += 1
            path = target.split("#", 1)[0]
            if not path:                       # pure in-page anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                line = text[:m.start()].count("\n") + 1
                broken.append(f"{md.relative_to(ROOT)}:{line}: "
                              f"broken link -> {target}")
    return broken, checked


def main(argv=None) -> int:
    """CLI entry: prints broken links and returns a process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="print every file scanned")
    args = ap.parse_args(argv)
    if args.list:
        for md in markdown_files():
            print(md.relative_to(ROOT))
    broken, checked = check_links()
    for msg in broken:
        print(msg, file=sys.stderr)
    print(f"{checked} intra-repo links checked across "
          f"{len(markdown_files())} markdown files; {len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
