"""Paper Fig 12 analogue: compiler (E2V) optimization speedup on GAT and
SAGE — naive per-edge implementations vs compiler-optimized, both on the
ZIPPER simulator and measured on the CPU pipelined executor (the paper also
reports the optimization's effect on its V100 baseline)."""
from __future__ import annotations

from repro.core import compiler, isa, pipeline, simulator, tiling
from repro.gnn import graphs, models

from .common import fmt_table, timeit, write_report


def run(quick: bool = False):
    g = graphs.paper_graph("cit-Patents", scale=0.002, seed=0)
    ts = tiling.grid_tile(g, 8, 8, sparse=True)
    rows = []
    for name in ("gat_naive", "sage_naive"):
        tr = models.trace_named(name)
        c_nv = compiler.compile_gnn(tr, optimize=False)
        c_opt = compiler.compile_gnn(tr, optimize=True)
        sim_nv = simulator.simulate_model(isa.emit_sde(c_nv.plan), ts)
        sim_opt = simulator.simulate_model(isa.emit_sde(c_opt.plan), ts)
        params = models.init_params(tr)
        inputs = models.init_inputs(tr, g)
        t_nv = timeit(pipeline.PipelinedRunner(c_nv, g, ts), inputs, params)
        t_opt = timeit(pipeline.PipelinedRunner(c_opt, g, ts), inputs, params)
        rows.append([name.replace("_naive", ""),
                     c_opt.opt_report["e2v_moved"],
                     f"{sim_nv.cycles/sim_opt.cycles:.2f}x",
                     f"{t_nv/t_opt:.2f}x"])
    headers = ["model", "ops_hoisted", "sim_speedup", "cpu_measured_speedup"]
    print("== Fig 12: E2V compiling optimization ==")
    print(fmt_table(rows, headers))
    write_report("bench_e2v", {"headers": headers, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
