"""Schedule-aware tile autotuner study (ISSUE 7).

For each paper model, three configurations of the sharded padded cost model
(:func:`~repro.core.simulator.simulate_sharded`):

* **scan default** — the scan-schedule incumbent on the default config
  (8x8 grid, 4 buckets, 4 chips);
* **kernel default** — the Pallas kernel schedule on the same config.  The
  dense (Dmax x Smax) tile kernels are *slower* than the scan under a naive
  config — padding dominates — which is exactly why the tuner exists;
* **kernel tuned** — the :mod:`repro.launch.autotune` hill-climb winner
  (grid x buckets x shard count x vertex reorder x tile edge layout,
  kernel schedule objective).

The acceptance gates (asserted here, and run under ``--smoke`` in CI):

* the tuned kernel config strictly beats BOTH incumbents on all five
  models, on the power-law graphs where the tile kernels have work to
  amortize;
* the cit-Patents-like table — ungated before the CSR-within-tile layout
  landed, because the heavy tail kept gcn's one-weighted-sum scan ahead of
  every dense-tile config — is now gated too: the E-proportional CSR
  kernels close that gap, and gcn's winner must carry ``layout="csr"``.

The search owns the reorder dimension, and on these heavy-tailed graphs it
*selects identity*: global degree sorting concentrates ~70% of the edges
into one destination partition, so the balance/padding loss outweighs the
sparse-tile shrinkage (the PR-4 tension, now measured inside the lattice
instead of assumed away).  CSR is what closes the cit-Patents gap; the
degree toggle stays searchable for graphs where it pays.

Usage::

    python -m benchmarks.bench_autotune [--smoke]
"""
from __future__ import annotations

import argparse

from repro.core import compiler
from repro.gnn import graphs, models
from repro.launch import autotune as AT

from benchmarks.common import fmt_table, write_report

#: the config the rest of the bench suite uses when nothing is tuned
DEFAULT = AT.TileConfig(n_dst_parts=8, n_src_parts=8, n_buckets=4, n_shards=4)


def tuned_vs_default(graph, names=models.PAPER_MODELS, *, n_layers=2,
                     dim=16, start=DEFAULT, max_evals=32, max_shards=8):
    """Per model: both incumbent costs + the tuned winner (one row each)."""
    rows = []
    for name in names:
        c = compiler.compile_gnn(
            models.trace_stacked(name, n_layers, dim, dim, dim))
        scan = AT.padded_cost(c, graph, start, kernel_dispatch=False)
        kern = AT.padded_cost(c, graph, start, kernel_dispatch=True)
        trials = AT.hillclimb(c, graph, start, max_evals=max_evals,
                              max_shards=max_shards)
        best = trials[0]
        incumbent = min(scan.cycles, kern.cycles)
        rows.append(dict(
            model=name, scan_default=scan.cycles, kernel_default=kern.cycles,
            kernel_tuned=best.cycles, config=best.config.to_dict(),
            n_evals=len(trials),
            speedup_vs_best=round(incumbent / best.cycles, 3)))
    return rows


def assert_tuned_wins(rows):
    """ISSUE 7 acceptance: tuned+kernel beats the best incumbent (scan
    default AND untuned kernel) on every model in the table."""
    losers = [r["model"] for r in rows
              if r["kernel_tuned"] >= min(r["scan_default"],
                                          r["kernel_default"])]
    assert not losers, \
        f"tuned kernel config loses to an incumbent on: {losers}"


def assert_cit_gap_closed(rows):
    """ISSUE 9 acceptance: on the cit-Patents-like heavy tail the tuned
    config beats the scan incumbent on every model AND gcn's winner is a
    CSR layout — the E-proportional row-pointer walk, not the dense tile
    matmul, is what closes the previously ungated gap."""
    assert_tuned_wins(rows)
    gcn = next(r for r in rows if r["model"] == "gcn")
    cfg = AT.TileConfig.from_dict(gcn["config"])
    assert cfg.layout == "csr", \
        f"cit-Patents gcn winner is not CSR: {cfg.key()}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small power-law graph + fewer simulator evals (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        g = graphs.random_graph(400, 2000, seed=1, model="powerlaw",
                                n_edge_types=3)
        graph_label, max_evals = "powerlaw-400", 32
    else:
        g = graphs.random_graph(2000, 10000, seed=1, model="powerlaw",
                                n_edge_types=3)
        graph_label, max_evals = "powerlaw-2000", 64

    def show(label, rows):
        print(f"== autotuned kernel dispatch vs incumbents ({label}, "
              "2-layer, padded cycles) ==")
        print(fmt_table(
            [[r["model"], r["scan_default"], r["kernel_default"],
              r["kernel_tuned"],
              "x".join(str(v)
                       for v in AT.TileConfig.from_dict(r["config"]).key()),
              f"{r['speedup_vs_best']}x", r["n_evals"]] for r in rows],
            ["model", "scan_default", "kernel_default", "kernel_tuned",
             "tuned_cfg", "vs_best", "evals"]))

    rows = tuned_vs_default(g, max_evals=max_evals)
    assert_tuned_wins(rows)
    show(graph_label, rows)

    # gated in smoke AND full: the CSR-within-tile layout closes the
    # heavy-tail gap that kept this table informational-only before
    cit = graphs.paper_graph("cit-Patents", scale=0.001, seed=0,
                             n_edge_types=3)
    cit_rows = tuned_vs_default(cit, max_evals=max_evals)
    assert_cit_gap_closed(cit_rows)
    print()
    show("cit-Patents-like, gated", cit_rows)

    path = write_report("bench_autotune", {
        "graph": graph_label, "default": DEFAULT.to_dict(),
        "rows": rows, "cit_patents_rows": cit_rows, "smoke": args.smoke,
    })
    print(f"\nreport: {path}")


if __name__ == "__main__":
    main()
