"""Paper Fig 11 analogue: sparse tiling + reordering vs regular tiling —
off-chip memory-access reduction and simulated speedup, per model, on the
cit-Patents-like graph (the paper's Fig 11 dataset)."""
from __future__ import annotations

from repro.core import compiler, isa, reorder, simulator, tiling
from repro.gnn import graphs, models

from .common import fmt_table, write_report


def run(quick: bool = False):
    g = graphs.paper_graph("cit-Patents", scale=0.002, seed=0, n_edge_types=3)
    rows = []
    model_names = models.PAPER_MODELS[:2] if quick else models.PAPER_MODELS
    for name in model_names:
        tr = models.trace_named(name)
        sde = isa.emit_sde(compiler.compile_gnn(tr).plan)
        variants = {
            "regular": tiling.grid_tile(g, 8, 8, sparse=False),
            "sparse": tiling.grid_tile(g, 8, 8, sparse=True),
            "sparse+reorder": tiling.grid_tile(reorder.degree_sort(g).graph,
                                               8, 8, sparse=True),
        }
        sims = {k: simulator.simulate_model(sde, t) for k, t in variants.items()}
        base_read = sims["regular"].offchip_read
        base_cyc = sims["regular"].cycles
        rows.append([name,
                     f"{base_read/1e6:.1f}MB",
                     f"{base_read/max(sims['sparse'].offchip_read,1):.1f}x",
                     f"{base_read/max(sims['sparse+reorder'].offchip_read,1):.1f}x",
                     f"{base_cyc/sims['sparse'].cycles:.2f}x",
                     f"{base_cyc/sims['sparse+reorder'].cycles:.2f}x"])
    headers = ["model", "regular_read", "read_reduction_sparse",
               "read_reduction_sparse+reorder", "speedup_sparse",
               "speedup_sparse+reorder"]
    print("== Fig 11: tiling ablation (cit-Patents-like) ==")
    print(fmt_table(rows, headers))
    write_report("bench_tiling", {"headers": headers, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
