"""Paper Fig 11 analogue: sparse tiling + reordering vs regular tiling —
off-chip memory-access reduction and simulated speedup, per model, on the
cit-Patents-like graph (the paper's Fig 11 dataset).

Extended with the bucketed-batching study: size-bucketed tile batches
(``tiling.bucket_tiles``) vs one global pad — padding efficiency (real vs
padded edge slots), padded-cost simulated cycles, and wall-clock of the
pipelined executor (scan and Pallas-kernel inner bodies).  The autotuned
study (``benchmarks.bench_autotune``) closes the loop: the searched tile
config makes the kernel schedule win outright on the power-law graphs.

The CSR-within-tile study (gated, also under ``--smoke``) compares the two
kernel-schedule edge layouts on identical tile grids: CSR's E-proportional
row-pointer walk must beat COO's dense per-tile matmul cycles on the
heavy-tailed graph.  Edge-index traffic is reported, not gated: CSR trades
the COO (src, dst) pair (8 B/edge) for one column index (4 B/edge) plus a
per-tile row-pointer vector, so it only *shrinks* traffic when the mean
degree exceeds the source-partition count — at cit-Patents' downscaled
degree ~4 the row pointers give most of the pair saving back.

Usage::

    python -m benchmarks.bench_tiling [--smoke]
"""
from __future__ import annotations

import argparse

from repro.core import compiler, isa, pipeline, reorder, simulator, tiling
from repro.gnn import graphs, models
from repro.kernels.tile_spmm import ops as tops

from .common import fmt_table, timeit, write_report


def run(quick: bool = False):
    g = graphs.paper_graph("cit-Patents", scale=0.002, seed=0, n_edge_types=3)
    rows = []
    model_names = models.PAPER_MODELS[:2] if quick else models.PAPER_MODELS
    for name in model_names:
        tr = models.trace_named(name)
        sde = isa.emit_sde(compiler.compile_gnn(tr).plan)
        variants = {
            "regular": tiling.grid_tile(g, 8, 8, sparse=False),
            "sparse": tiling.grid_tile(g, 8, 8, sparse=True),
            "sparse+reorder": tiling.grid_tile(reorder.degree_sort(g).graph,
                                               8, 8, sparse=True),
        }
        sims = {k: simulator.simulate_model(sde, t) for k, t in variants.items()}
        base_read = sims["regular"].offchip_read
        base_cyc = sims["regular"].cycles
        rows.append([name,
                     f"{base_read/1e6:.1f}MB",
                     f"{base_read/max(sims['sparse'].offchip_read,1):.1f}x",
                     f"{base_read/max(sims['sparse+reorder'].offchip_read,1):.1f}x",
                     f"{base_cyc/sims['sparse'].cycles:.2f}x",
                     f"{base_cyc/sims['sparse+reorder'].cycles:.2f}x"])
    headers = ["model", "regular_read", "read_reduction_sparse",
               "read_reduction_sparse+reorder", "speedup_sparse",
               "speedup_sparse+reorder"]
    print("== Fig 11: tiling ablation (cit-Patents-like) ==")
    print(fmt_table(rows, headers))
    write_report("bench_tiling", {"headers": headers, "rows": rows})

    csr_rows = csr_vs_coo_study(g, quick=quick)
    pad_rows = bucketing_study(g, quick=quick)
    tuned_rows = autotuned_study(quick=quick)
    return rows + csr_rows + pad_rows + tuned_rows


def csr_vs_coo_study(g, quick: bool = False):
    """CSR-within-tile vs COO on identical tile grids, kernel schedule,
    padded cost — gated: CSR must win cycles on every model (the
    E-proportional row-pointer walk vs the dense per-tile matmul).  The
    read ratio is informational; see the module docstring for why a
    degree-4 graph gives the (src, dst)-pair saving back in row pointers."""
    model_names = models.PAPER_MODELS[:2] if quick else models.PAPER_MODELS
    rows = []
    for name in model_names:
        c = compiler.compile_gnn(models.trace_named(name))
        sims, reads = {}, {}
        for layout in ("coo", "csr"):
            sde = isa.emit_sde(c.schedule(True), layout=layout)
            ts, _ = tiling.build_tiles(g, 8, 8, layout=layout, n_buckets=2)
            r = simulator.simulate_model(sde, ts, padded=True)
            sims[layout], reads[layout] = r.cycles, r.offchip_read
        rows.append([name, sims["coo"], sims["csr"],
                     f"{sims['coo']/sims['csr']:.2f}x",
                     f"{reads['coo']/max(reads['csr'],1):.2f}x"])
        assert sims["csr"] < sims["coo"], \
            f"CSR does not beat COO for {name}: {sims}"
    headers = ["model", "coo_cycles", "csr_cycles", "csr_speedup",
               "read_ratio"]
    print("\n== CSR-within-tile vs COO (kernel schedule, cycles gated) ==")
    print(fmt_table(rows, headers))
    write_report("bench_tiling_csr", {"headers": headers, "rows": rows})
    return rows


def autotuned_study(quick: bool = False):
    """Tile-config autotuning closes the loop on the ablations above: the
    searched grid x bucket x shard config makes the Pallas kernel schedule
    beat both incumbents (scan default, untuned kernel) on every model —
    asserted, not just reported."""
    from benchmarks.bench_autotune import assert_tuned_wins, tuned_vs_default

    g = graphs.random_graph(400 if quick else 2000, 2000 if quick else 10000,
                            seed=1, model="powerlaw", n_edge_types=3)
    recs = tuned_vs_default(g, max_evals=24 if quick else 48)
    assert_tuned_wins(recs)
    headers = ["model", "scan_default", "kernel_default", "kernel_tuned",
               "vs_best"]
    rows = [[r["model"], r["scan_default"], r["kernel_default"],
             r["kernel_tuned"], f"{r['speedup_vs_best']}x"] for r in recs]
    print("\n== autotuned kernel dispatch vs incumbents (power-law, "
          "padded cycles) ==")
    print(fmt_table(rows, headers))
    write_report("bench_tiling_autotuned", {"headers": headers, "rows": rows})
    return rows


def bucketing_study(g, quick: bool = False):
    """Global pad vs size-bucketed batches vs degree reordering on the
    power-law graph — all through the one-stop ``tiling.build_tiles`` entry,
    with the opt-in ``reorder`` flag's padding-efficiency effect isolated."""
    ts, _ = tiling.build_tiles(g, 8, 8, sparse=True)
    sde = isa.emit_sde(compiler.compile_gnn(models.trace_named("gcn")).plan)
    E = g.n_edges

    variants = {"global-pad": ts}
    for nb in (2, 4):
        variants[f"bucketed-{nb}"] = tiling.bucket_tiles(ts, nb)
    # opt-in degree reordering: high-degree vertices concentrate into the
    # low-id partitions, tightening every other tile's padded envelope
    variants["reorder"], _ = tiling.build_tiles(g, 8, 8, reorder="degree")
    variants["reorder+bucketed-4"], _ = tiling.build_tiles(
        g, 8, 8, reorder="degree", n_buckets=4)

    base_waste = ts.padded_edge_slots() - E
    base_cyc = None
    rows = []
    for label, t in variants.items():
        slots = t.padded_edge_slots()
        waste = slots - E
        cyc = simulator.simulate_model(sde, t, padded=True).cycles
        if base_cyc is None:  # first variant is the global-pad baseline
            base_cyc = cyc
        rows.append([label, E, slots, f"{t.padding_efficiency():.3f}",
                     f"{base_waste/max(waste,1):.1f}x", f"{base_cyc/cyc:.2f}x"])
    headers = ["variant", "real_edges", "padded_edge_slots", "pad_efficiency",
               "waste_reduction", "padded_cycle_speedup"]
    print("\n== bucketed tile batching: padding efficiency (cit-Patents-like) ==")
    print(fmt_table(rows, headers))
    print("NB: degree sorting cuts off-chip reads (Fig 11 table above) but "
          "concentrates the heavy vertices into a few dense tiles, so under "
          "a single static (S_max, E_max) pad its padding efficiency is "
          "WORSE — pair `reorder=` with `n_buckets=` on static-shape "
          "executors, or use it for the dynamic-shape simulator path only.")
    write_report("bench_tiling_bucketing", {"headers": headers, "rows": rows})

    # wall-clock of the pipelined executor (scan + kernel inner bodies)
    tr = models.trace_named("gcn", 32, 32)
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    inputs = models.init_inputs(tr, g)
    bt = tiling.bucket_tiles(ts, 4)
    # NB: tops.spmm defaults to interpret=True (this container is CPU-only),
    # so the kernel row measures the Pallas *emulator*, not the MXU; on TPU
    # pass functools.partial(tops.spmm, interpret=False) as tile_kernel.
    runners = {
        "global-pad scan": pipeline.PipelinedRunner(c, g, ts),
        "bucketed scan": pipeline.PipelinedRunner(c, g, bt),
        "bucketed + pallas spmm (interpret)": pipeline.PipelinedRunner(
            c, g, bt, tile_kernel=tops.spmm),
    }
    wall_rows = []
    repeats = 1 if quick else 3
    for label, r in runners.items():
        t_s = timeit(lambda r=r: r(inputs, params), repeats=repeats)
        wall_rows.append([label, f"{t_s*1e3:.1f}ms"])
    print("\n== pipelined executor wall-clock (gcn, cit-Patents-like) ==")
    print(fmt_table(wall_rows, ["executor", "median_wall"]))
    write_report("bench_tiling_wallclock",
                 {"headers": ["executor", "median_wall"], "rows": wall_rows})
    return rows + wall_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 models + 1 wall-clock repeat (CI bench-smoke)")
    run(quick=ap.parse_args().smoke)
