"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §1):
  bench_memory   — Fig 2   memory footprint
  bench_speedup  — Fig 9/10 speedup + energy vs baselines
  bench_tiling   — Fig 11  sparse tiling + reordering ablation
  bench_e2v      — Fig 12  compiler (E2V) optimization
  bench_streams  — Fig 13  stream/unit design-space exploration
  bench_area     — Table 5 area model
  roofline       — §Roofline terms for the LM cells (reads reports/dryrun)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sweep")
    ap.add_argument("--only", default="", help="comma-separated bench names")
    args = ap.parse_args(argv)

    from . import (bench_area, bench_e2v, bench_memory, bench_speedup,
                   bench_streams, bench_tiling, perf_report, roofline)
    benches = {
        "memory": bench_memory, "speedup": bench_speedup, "tiling": bench_tiling,
        "e2v": bench_e2v, "streams": bench_streams, "area": bench_area,
        "roofline": roofline, "perf": perf_report,
    }
    selected = args.only.split(",") if args.only else list(benches)
    failures = 0
    for name in selected:
        mod = benches[name.strip()]
        t0 = time.time()
        print(f"\n###### {name} " + "#" * 40, flush=True)
        try:
            mod.run(quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"[{name}] FAILED", flush=True)
    print(f"\nbenchmarks complete: {len(selected)-failures}/{len(selected)} ok")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
