"""Serving throughput: batched multi-graph inference vs the per-graph path.

The workload is the paper's target serving regime — a stream of many small
graphs (molecular / recommendation scale) — where the status-quo cost is one
fresh compilation per (model, graph).  The :class:`~repro.serve.engine.
InferenceServer` amortizes ONE compilation per structure class across the
whole stream and fills tiles by block-diagonal batching.

Measured per batch size {1, 16, 64}: graphs/sec over the stream (after a
one-batch warmup, i.e. steady-state serving) against the sequential baseline
(fresh ``PipelinedRunner`` per graph — compile included, because that is what
serving without the cache costs), plus program-cache behavior on the
repeated-signature stream: post-warmup hit rate and recompile count.

``--smoke`` shrinks the stream for CI and writes
``reports/bench_serving_smoke.json`` (full runs write
``reports/bench_serving.json``), so the perf trajectory is a build artifact
with per-PR smoke history kept distinct from full sweeps.
"""
from __future__ import annotations

import time

import jax

from repro.core import compiler, pipeline, tiling
from repro.gnn import graphs, models
from repro.serve import InferenceServer

from .common import fmt_table, write_report

BATCH_SIZES = (1, 16, 64)


def _workload(tr, n_graphs: int, v: int, e: int, name: str, seed0: int = 0):
    etypes = 3 if models.MODELS[name].needs_etype else None
    gs, ins = [], []
    for k in range(n_graphs):
        g = graphs.random_graph(v, e, seed=seed0 + k, model="powerlaw",
                                n_edge_types=etypes)
        gs.append(g)
        ins.append(models.init_inputs(tr, g, seed=seed0 + k))
    return gs, ins


def _sequential_gps(c, gs, ins, params, n_probe: int) -> float:
    """Status-quo path: a fresh runner (lower + jit) for every graph."""
    t0 = time.perf_counter()
    for g, inp in zip(gs[:n_probe], ins[:n_probe]):
        ts = tiling.grid_tile(g, 4, 4, sparse=True)
        out = pipeline.PipelinedRunner(c, g, ts, kernel_dispatch=True)(inp, params)
        jax.block_until_ready(out)
    return n_probe / (time.perf_counter() - t0)


def _batched_gps(server, gs, ins, batch: int) -> float:
    chunks = [(gs[i:i + batch], ins[i:i + batch])
              for i in range(0, len(gs), batch)]
    server.submit(*chunks[0])                      # warmup: compile the class
    t0 = time.perf_counter()
    for cg, ci in chunks:
        server.submit(cg, ci)
    return len(gs) / (time.perf_counter() - t0)


def run(smoke: bool = False):
    if smoke:
        model_names, n_graphs, v, e, n_probe = ("gcn",), 64, 48, 192, 3
    else:
        model_names, n_graphs, v, e, n_probe = ("gcn", "gat"), 192, 96, 420, 12

    rows, metrics = [], {}
    for name in model_names:
        tr = models.trace_named(name)
        c = compiler.compile_gnn(tr)
        params = models.init_params(tr)
        gs, ins = _workload(tr, n_graphs, v, e, name)

        seq_gps = _sequential_gps(c, gs, ins, params, n_probe)
        batched = {}
        cache_stats = {}
        for b in BATCH_SIZES:
            server = InferenceServer(c, params)
            gps = _batched_gps(server, gs, ins, b)
            batched[b] = gps
            st = server.cache.stats
            # the warmup submit is the only allowed compile; everything after
            # it must hit (requests counts one lookup per submitted batch)
            cache_stats[b] = dict(
                post_warmup_hit_rate=(st.hits / max(st.requests - 1, 1)),
                recompiles_after_warmup=st.compiles - 1,
                compiles=st.compiles)
            rows.append([name, b, f"{seq_gps:.1f}", f"{gps:.1f}",
                         f"{gps / seq_gps:.1f}x",
                         f"{cache_stats[b]['post_warmup_hit_rate']:.2f}",
                         cache_stats[b]["recompiles_after_warmup"]])
        metrics[name] = dict(seq_gps=seq_gps, batched_gps=batched,
                             speedup_b64=batched[64] / seq_gps,
                             cache=cache_stats)

    headers = ["model", "batch", "seq_g/s", "batched_g/s", "speedup",
               "hit_rate", "recompiles"]
    print("== serving throughput: batched + cached vs per-graph compile ==")
    print(fmt_table(rows, headers))

    tuned = tuned_reorder_stream(n_graphs=16 if smoke else 48)
    metrics["tuned_reorder"] = tuned
    print("\n== tuned CSR+degree route: steady-state recompiles "
          f"(gated) == {tuned}")

    write_report("bench_serving_smoke" if smoke else "bench_serving",
                 {"smoke": smoke,
                  "workload": dict(n_graphs=n_graphs, v=v, e=e),
                  "headers": headers, "rows": rows, "metrics": metrics})
    return metrics


def tuned_reorder_stream(n_graphs: int = 16):
    """Gated: a stream routed through a tuned CSR + degree-reorder config
    still converges to zero steady-state recompiles — the degree
    permutation is a traced operand rebound per request, never a new
    compilation, and the reorder/layout provenance in the cache key keeps
    the tuned route from aliasing the default one."""
    from repro.launch import autotune as AT
    from repro.serve.signature import quantize, size_class

    tr = models.trace_named("gcn")
    c = compiler.compile_gnn(tr)
    params = models.init_params(tr)
    gs, ins = _workload(tr, n_graphs, 120, 500, "gcn")

    cache = AT.TuneCache()
    class_key = (c.name, c.n_layers, size_class(gs[0]), quantize(1, floor=1))
    cache.put(AT.program_key(c), class_key,
              AT.TileConfig(4, 4, 2, 1, reorder="degree", layout="csr"))
    server = InferenceServer(c, params, tune_cache=cache)
    # warmup: first compile + monotone shape growth (the degree sort makes
    # the realized tile envelope vary per graph until headroom registers)
    n_warm = max(4, n_graphs // 4)
    for g, inp in zip(gs[:n_warm], ins[:n_warm]):
        server.submit([g], [inp])
    warm = server.compile_count
    for g, inp in zip(gs[n_warm:], ins[n_warm:]):
        server.submit([g], [inp])
    steady = server.compile_count - warm
    assert steady == 0, \
        f"tuned CSR+degree route recompiled {steady}x after warmup"
    return dict(warmup_compiles=warm, steady_state_recompiles=steady,
                graphs=n_graphs)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small stream (CI smoke); still writes the report")
    args = ap.parse_args()
    run(smoke=args.smoke)
