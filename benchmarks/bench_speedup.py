"""Paper Fig 9/10 analogue: end-to-end speedup + energy.

Columns per (model × dataset):
  * cpu_whole_graph_s — measured: the classic whole-graph execution (the
    paper's DGL-CPU baseline role), jit-compiled JAX on this host;
  * cpu_pipelined_s  — measured: ZIPPER tiling + scan-pipelined execution
    on the same host (software benefit of the tiling alone);
  * zipper_sim_ms    — simulated: ZIPPER ASIC (paper Table-4 config);
  * zipper_energy_mJ — simulated energy (paper §8.1 model);
  * tpu_sim_ms       — simulated: TPU-v5e-like config (hardware adaptation).

Graphs are the paper's datasets at reduced scale (structure preserved);
the simulated speedups are scale-free comparisons against the same-sized
baseline, so trends are comparable with the paper's Fig 9/10.
"""
from __future__ import annotations

import functools

import jax

from repro.core import compiler, executor, isa, pipeline, reorder, simulator, tiling
from repro.core.streams import HWConfig, TPU_V5E_LIKE
from repro.gnn import graphs, models

from .common import BENCH_GRAPHS, fmt_table, timeit, write_report


def run(quick: bool = False, layers: int = 1):
    rows = []
    # two datasets in the default run (per-model jit compiles dominate);
    # the tiling/E2V/stream benches cover the remaining datasets' trends
    bench_graphs = dict(list(BENCH_GRAPHS.items())[:1 if quick else 2])
    model_names = models.PAPER_MODELS[:2] if quick else models.PAPER_MODELS
    for ds, scale in bench_graphs.items():
        g0 = graphs.paper_graph(ds, scale=scale, seed=0, n_edge_types=3)
        r = reorder.degree_sort(g0)
        ts = tiling.grid_tile(r.graph, 8, 8, sparse=True)
        for name in model_names:
            tr = (models.trace_named(name) if layers == 1
                  else models.trace_stacked(name, layers))
            c = compiler.compile_gnn(tr)
            params = models.init_params(tr)
            inputs0 = models.init_inputs(tr, g0)
            inputs = {k: (r.permute_vertex_features(v) if v.shape[0] == g0.n_vertices
                          else v) for k, v in inputs0.items()}

            whole = jax.jit(lambda i, p: executor.run_reference(tr, r.graph, i, p))
            t_whole = timeit(whole, inputs, params)
            runner = pipeline.PipelinedRunner(c, r.graph, ts)
            t_pipe = timeit(runner, inputs, params)

            sde = isa.emit_sde(c.plan)
            sim = simulator.simulate_model(sde, ts, HWConfig())
            sim_tpu = simulator.simulate_model(sde, ts, TPU_V5E_LIKE)
            rows.append([ds, name,
                         f"{t_whole*1e3:.1f}", f"{t_pipe*1e3:.1f}",
                         f"{t_whole/t_pipe:.2f}x",
                         f"{sim.time_ms:.2f}", f"{t_whole*1e3/sim.time_ms:.0f}x",
                         f"{sim.energy_mj:.2f}",
                         f"{sim_tpu.time_ms:.2f}"])
    headers = ["dataset", "model", "cpu_whole_ms", "cpu_tiled_ms", "sw_speedup",
               "zipper_sim_ms", "sim_speedup_vs_cpu", "zipper_energy_mJ",
               "tpuv5e_sim_ms"]
    print(f"== Fig 9/10: speedup & energy (layers={layers}) ==")
    print(fmt_table(rows, headers))
    write_report("bench_speedup",
                 {"headers": headers, "rows": rows, "layers": layers})
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sweep")
    ap.add_argument("--layers", type=int, default=1,
                    help="stack depth of the benchmarked models")
    args = ap.parse_args()
    run(quick=args.quick, layers=args.layers)
