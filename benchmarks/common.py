"""Shared benchmark utilities."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict

import jax

REPORTS = pathlib.Path(__file__).resolve().parents[1] / "reports"
REPORTS.mkdir(exist_ok=True)

#: paper Table-3 datasets at CPU-tractable scale (structure preserved)
BENCH_GRAPHS = {
    "ak2010": 0.1,           # 4.5k V / 11k E
    "coAuthorsDBLP": 0.015,  # 4.5k V / 15k E
    "cit-Patents": 0.001,    # 3.8k V / 17k E
    "soc-LiveJournal1": 0.0008,  # 3.9k V / 35k E
}


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time (s); blocks on jax async dispatch."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def write_report(name: str, payload: Dict):
    path = REPORTS / f"{name}.json"
    path.write_text(json.dumps(payload, indent=1, default=str))
    return path


def fmt_table(rows, headers) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) for i, h in enumerate(headers)]
    def line(vals):
        return "  ".join(str(v).ljust(w) for v, w in zip(vals, widths))
    out = [line(headers), line(["-" * w for w in widths])]
    out += [line(r) for r in rows]
    return "\n".join(out)
