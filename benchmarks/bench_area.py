"""Paper Table 5 analogue: area model of the ZIPPER configuration."""
from __future__ import annotations

from repro.core import simulator
from repro.core.streams import HWConfig

from .common import fmt_table, write_report


def run(quick: bool = False):
    hw = HWConfig()
    rows = [
        ["One MU", f"{simulator.AREA_MM2['MU']:.2f}"],
        ["One VU", f"{simulator.AREA_MM2['VU']:.2f}"],
        ["Embedding Mem (21MB eDRAM)", f"{simulator.AREA_MM2['UEM']:.2f}"],
        ["Tile Hub", f"{simulator.AREA_MM2['TH']:.2f}"],
        ["Total (1 MU + 2 VU)", f"{simulator.area_mm2(hw):.2f}"],
    ]
    headers = ["component", "area_mm2"]
    print("== Table 5: area ==")
    print(fmt_table(rows, headers))
    write_report("bench_area", {"headers": headers, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
