"""Sharded-execution scaling benchmark (ISSUE 5).

Three axes, one report (``reports/bench_sharded.json``):

* **host-device scaling** — the real :class:`~repro.core.pipeline
  .ShardedRunner` wall clock on {1, 2, 4, 8} forced host devices.  The
  device count binds when jax initializes, so each count runs in a
  subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
  On a CPU host the shards share the same silicon — this measures the
  *overhead* of the shard_map + all-gather path (near-parity is the win),
  not a speedup; the speedup axis is simulated.
* **simulated chip scaling** — the multi-chip cost model
  (:func:`~repro.core.simulator.simulate_sharded`) for all five paper
  models on the cit-Patents-like configuration: per-chip cycles, exchange
  traffic, and the scaling curve over {1, 2, 4, 8} chips.
* **autotuned kernel dispatch** — tuned grid/bucket/shard config for the
  Pallas kernel schedule vs the scan-sharded and untuned-kernel incumbents
  (:mod:`benchmarks.bench_autotune`); asserted to win on all five models.

Usage::

    python -m benchmarks.bench_sharded [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import fmt_table, write_report

DEVICE_COUNTS = (1, 2, 4, 8)
CHIP_COUNTS = (1, 2, 4, 8)

_WORKER = """
import os, sys
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%d "
                           + os.environ.get("XLA_FLAGS", "")).strip()
import json, time
import numpy as np
import jax
from repro.core import compiler, pipeline, tiling
from repro.gnn import graphs, models

n_dev, n_vertices, n_edges, layers, repeats = %d, %d, %d, %d, %d
g = graphs.random_graph(n_vertices, n_edges, seed=0, model="powerlaw")
tr = models.trace_stacked("gcn", layers, 64, 64, 64)
c = compiler.compile_gnn(tr)
params = models.init_params(tr)
inputs = models.init_inputs(tr, g)
bt = tiling.bucket_tiles(tiling.grid_tile(g, 8, 8, sparse=True), 4)
r = pipeline.ShardedRunner(c, g, bt, n_dev)
out = r(inputs, params); jax.block_until_ready(out)   # compile + warm
ts = []
for _ in range(repeats):
    t0 = time.perf_counter()
    out = r(inputs, params)
    jax.block_until_ready(out)
    ts.append(time.perf_counter() - t0)
ts.sort()
print(json.dumps({"n_dev": n_dev, "devices": len(jax.devices()),
                  "wall_s": ts[len(ts) // 2],
                  "checksum": float(np.asarray(out[0]).sum())}))
"""


def run_device_scaling(smoke: bool):
    n_vertices, n_edges = (800, 4000) if smoke else (3000, 18000)
    repeats = 3 if smoke else 5
    counts = (1, 2) if smoke else DEVICE_COUNTS
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    py = os.environ.get("PYTHONPATH", "")
    env = dict(os.environ, PYTHONPATH=src + (os.pathsep + py if py else ""))
    rows = []
    for n_dev in counts:
        script = _WORKER % (n_dev, n_dev, n_vertices, n_edges, 2, repeats)
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=1200)
        if out.returncode != 0:
            raise RuntimeError(f"worker n_dev={n_dev} failed:\n"
                               + out.stderr[-2000:])
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    base = rows[0]["wall_s"]
    for r in rows:
        r["vs_1dev"] = round(base / r["wall_s"], 3)
    # CPU shards share one socket: assert the sharded path stays within a
    # sane overhead envelope instead of pretending a hardware speedup
    checks = {r["n_dev"]: r["checksum"] for r in rows}
    assert all(abs(v - rows[0]["checksum"]) < 1e-2 * max(1.0, abs(rows[0]["checksum"]))
               for v in checks.values()), f"device counts disagree: {checks}"
    return rows


def run_chip_scaling(smoke: bool):
    from repro.core import compiler, isa, simulator, tiling
    from repro.gnn import graphs, models

    g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0, n_edge_types=3)
    ts = tiling.grid_tile(g, 8, 8, sparse=True)
    names = ("gcn", "gat") if smoke else models.PAPER_MODELS
    out = {}
    for name in names:
        c = compiler.compile_gnn(models.trace_stacked(name, 2, 16, 16, 16))
        sde = isa.emit_sde(c.schedule(False))
        base = simulator.simulate_model(sde, ts, inter_layer="pipelined")
        curve = []
        for k in CHIP_COUNTS:
            if k == 1:
                curve.append({"n_chips": 1, "cycles": base.cycles,
                              "speedup": 1.0, "exchange_cycles": 0,
                              "balance": 1.0})
                continue
            r = simulator.simulate_sharded(sde, ts, n_chips=k)
            curve.append({"n_chips": k, "cycles": r.cycles,
                          "speedup": round(base.cycles / r.cycles, 3),
                          "exchange_cycles": r.exchange_cycles,
                          "balance": round(r.balance, 3)})
        out[name] = curve
        # scaling sanity: more chips never loses to fewer on this config
        cyc = [c_["cycles"] for c_ in curve]
        assert all(b <= a for a, b in zip(cyc, cyc[1:])), (name, cyc)
    return out


def run_autotuned(smoke: bool):
    """Tuned kernel dispatch vs the scan-sharded / untuned-kernel
    incumbents (padded cycles, all five models) — the ISSUE 7 acceptance
    row set, asserted via :func:`benchmarks.bench_autotune.assert_tuned_wins`."""
    from repro.gnn import graphs

    from benchmarks.bench_autotune import assert_tuned_wins, tuned_vs_default

    v, e = (400, 2000) if smoke else (2000, 10000)
    g = graphs.random_graph(v, e, seed=1, model="powerlaw", n_edge_types=3)
    rows = tuned_vs_default(g, max_evals=24 if smoke else 48)
    assert_tuned_wins(rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + {1,2} devices (CI)")
    ap.add_argument("--skip-devices", action="store_true",
                    help="simulated chip scaling only (no subprocesses)")
    args = ap.parse_args(argv)

    chips = run_chip_scaling(args.smoke)
    rows = [[name, *(f"{c['speedup']}x" for c in curve)]
            for name, curve in chips.items()]
    print("simulated chip scaling (2-layer, cit-Patents-like, speedup vs 1 chip)")
    print(fmt_table(rows, ["model"] + [f"{k}ch" for k in CHIP_COUNTS]))

    tuned = run_autotuned(args.smoke)
    print("\nautotuned kernel dispatch vs incumbents (power-law, padded cycles)")
    print(fmt_table([[r["model"], r["scan_default"], r["kernel_default"],
                      r["kernel_tuned"], f"{r['speedup_vs_best']}x"]
                     for r in tuned],
                    ["model", "scan_default", "kernel_default",
                     "kernel_tuned", "vs_best"]))

    devices = None
    if not args.skip_devices:
        devices = run_device_scaling(args.smoke)
        print("\nhost-device wall clock (gcn x2, shard_map path)")
        print(fmt_table([[r["n_dev"], round(r["wall_s"] * 1e3, 2), r["vs_1dev"]]
                         for r in devices],
                        ["devices", "ms", "vs 1dev"]))

    path = write_report("bench_sharded", {
        "chip_scaling": chips, "device_scaling": devices,
        "autotuned": tuned, "smoke": args.smoke,
    })
    print(f"\nreport: {path}")


if __name__ == "__main__":
    main()
