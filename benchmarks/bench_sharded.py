"""Sharded-execution scaling benchmark (ISSUE 5).

Three axes, one report (``reports/bench_sharded.json``):

* **host-device scaling** — the real :class:`~repro.core.pipeline
  .ShardedRunner` wall clock on {1, 2, 4, 8} forced host devices.  The
  device count binds when jax initializes, so each count runs in a
  subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
  On a CPU host the shards share the same silicon — this measures the
  *overhead* of the shard_map + all-gather path (near-parity is the win),
  not a speedup; the speedup axis is simulated.
* **simulated chip scaling** — the multi-chip cost model
  (:func:`~repro.core.simulator.simulate_sharded`) for all five paper
  models on the cit-Patents-like configuration: per-chip cycles, exchange
  traffic, and the scaling curve over {1, 2, 4, 8} chips.
* **autotuned kernel dispatch** — tuned grid/bucket/shard config for the
  Pallas kernel schedule vs the scan-sharded and untuned-kernel incumbents
  (:mod:`benchmarks.bench_autotune`); asserted to win on all five models.

Usage::

    python -m benchmarks.bench_sharded [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import fmt_table, write_report

DEVICE_COUNTS = (1, 2, 4, 8)
CHIP_COUNTS = (1, 2, 4, 8)

_WORKER = """
import os, sys
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%d "
                           + os.environ.get("XLA_FLAGS", "")).strip()
import json, time
import numpy as np
import jax
from repro.core import compiler, pipeline, tiling
from repro.gnn import graphs, models

n_dev, n_vertices, n_edges, layers, repeats = %d, %d, %d, %d, %d
g = graphs.random_graph(n_vertices, n_edges, seed=0, model="powerlaw")
tr = models.trace_stacked("gcn", layers, 64, 64, 64)
c = compiler.compile_gnn(tr)
params = models.init_params(tr)
inputs = models.init_inputs(tr, g)
bt = tiling.bucket_tiles(tiling.grid_tile(g, 8, 8, sparse=True), 4)
r = pipeline.ShardedRunner(c, g, bt, n_dev)
out = r(inputs, params); jax.block_until_ready(out)   # compile + warm
ts = []
for _ in range(repeats):
    t0 = time.perf_counter()
    out = r(inputs, params)
    jax.block_until_ready(out)
    ts.append(time.perf_counter() - t0)
ts.sort()
print(json.dumps({"n_dev": n_dev, "devices": len(jax.devices()),
                  "wall_s": ts[len(ts) // 2],
                  "checksum": float(np.asarray(out[0]).sum())}))
"""


def run_device_scaling(smoke: bool):
    n_vertices, n_edges = (800, 4000) if smoke else (3000, 18000)
    repeats = 3 if smoke else 5
    counts = (1, 2) if smoke else DEVICE_COUNTS
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    py = os.environ.get("PYTHONPATH", "")
    env = dict(os.environ, PYTHONPATH=src + (os.pathsep + py if py else ""))
    rows = []
    for n_dev in counts:
        script = _WORKER % (n_dev, n_dev, n_vertices, n_edges, 2, repeats)
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=1200)
        if out.returncode != 0:
            raise RuntimeError(f"worker n_dev={n_dev} failed:\n"
                               + out.stderr[-2000:])
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    base = rows[0]["wall_s"]
    for r in rows:
        r["vs_1dev"] = round(base / r["wall_s"], 3)
        # CPU shards share one socket: these rows measure shard_map + exchange
        # OVERHEAD, so they carry this tag and are excluded from every speedup
        # assertion — the speedup axis is the simulated chip curve
        r["host_shared_silicon"] = True
    checks = {r["n_dev"]: r["checksum"] for r in rows}
    assert all(abs(v - rows[0]["checksum"]) < 1e-2 * max(1.0, abs(rows[0]["checksum"]))
               for v in checks.values()), f"device counts disagree: {checks}"
    return rows


def run_chip_scaling(smoke: bool):
    from repro.core import compiler, isa, simulator, tiling
    from repro.gnn import graphs, models

    g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0, n_edge_types=3)
    ts = tiling.grid_tile(g, 8, 8, sparse=True)
    names = ("gcn", "gat") if smoke else models.PAPER_MODELS
    out = {}
    for name in names:
        c = compiler.compile_gnn(models.trace_stacked(name, 2, 16, 16, 16))
        sde = isa.emit_sde(c.schedule(False))
        base = simulator.simulate_model(sde, ts, inter_layer="pipelined")
        curve = []
        for k in CHIP_COUNTS:
            if k == 1:
                curve.append({"n_chips": 1, "cycles": base.cycles,
                              "speedup": 1.0, "exchange_cycles": 0,
                              "balance": 1.0})
                continue
            r = simulator.simulate_sharded(sde, ts, n_chips=k, mode="mincut",
                                           exchange="restricted")
            ag = simulator.simulate_sharded(sde, ts, n_chips=k, mode="cost",
                                            exchange="allgather")
            curve.append({"n_chips": k, "cycles": r.cycles,
                          "speedup": round(base.cycles / r.cycles, 3),
                          "exchange_cycles": r.exchange_cycles,
                          "exchange_bytes": r.exchange_bytes,
                          "edge_cut_rows": r.edge_cut_rows,
                          "allgather_bytes": ag.exchange_bytes,
                          "balance": round(r.balance, 3)})
        out[name] = curve
        # scaling sanity: more chips never loses to fewer on this config
        cyc = [c_["cycles"] for c_ in curve]
        assert all(b <= a for a, b in zip(cyc, cyc[1:])), (name, cyc)
    return out


def run_exchange_gate(smoke: bool):
    """ISSUE 10 acceptance gate: on the cit-Patents-like graph the mincut
    plan's restricted exchange ships FEWER bytes than the all-gather
    baseline on all five models at 4 and 8 chips, without giving up the
    reported load balance (per-model mincut balance <= max(all-gather
    balance, 1.244) at 8 chips)."""
    from repro.core import compiler, isa, simulator, tiling
    from repro.gnn import graphs, models

    g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0, n_edge_types=3)
    ts = tiling.grid_tile(g, 8, 8, sparse=True)
    rows = []
    for name in models.PAPER_MODELS:
        c = compiler.compile_gnn(models.trace_stacked(name, 2, 16, 16, 16))
        sde = isa.emit_sde(c.schedule(False))
        for k in (4, 8):
            mc = simulator.simulate_sharded(sde, ts, n_chips=k, mode="mincut",
                                            exchange="restricted")
            ag = simulator.simulate_sharded(sde, ts, n_chips=k, mode="cost",
                                            exchange="allgather")
            row = {"model": name, "n_chips": k,
                   "restricted_bytes": mc.exchange_bytes,
                   "allgather_bytes": ag.exchange_bytes,
                   "edge_cut_rows": mc.edge_cut_rows,
                   "balance": round(mc.balance, 3),
                   "allgather_balance": round(ag.balance, 3)}
            rows.append(row)
            assert mc.exchange_bytes <= ag.exchange_bytes, row
            if k == 8:
                assert mc.balance <= max(ag.balance, 1.244), row
    return rows


def run_planner_comparison(smoke: bool):
    """LPT vs mincut shard planning on a finer grid (P=32), where the
    refinement has real freedom: the cut shrinks at EQUAL balance
    tolerance.  Plan-level metrics only — the planner is model-agnostic."""
    from repro.core import tiling
    from repro.gnn import graphs

    g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0, n_edge_types=3)
    ts = tiling.grid_tile(g, 32, 32, sparse=True)
    rows = []
    for k in (4, 8):
        lpt = tiling.plan_shards(ts, k, mode="cost")
        mc = tiling.plan_shards(ts, k, mode="mincut")
        lc, mcc = lpt.shard_costs(), mc.shard_costs()
        rows.append({
            "n_shards": k, "n_parts": ts.n_dst_parts,
            "lpt_edge_cut": lpt.edge_cut(), "mincut_edge_cut": mc.edge_cut(),
            "cut_reduction": round(1 - mc.edge_cut() / max(1, lpt.edge_cut()), 4),
            "lpt_cost_balance": round(float(lc.max() / max(1, lc.mean())), 4),
            "mincut_cost_balance": round(float(mcc.max() / max(1, mcc.mean())), 4),
            "lpt_cut_rows": tiling.exchange_sets(ts, lpt).cut_rows,
            "mincut_cut_rows": tiling.exchange_sets(ts, mc).cut_rows,
        })
        assert rows[-1]["mincut_edge_cut"] <= rows[-1]["lpt_edge_cut"], rows[-1]
    return rows


def run_autotuned(smoke: bool):
    """Tuned kernel dispatch vs the scan-sharded / untuned-kernel
    incumbents (padded cycles, all five models) — the ISSUE 7 acceptance
    row set, asserted via :func:`benchmarks.bench_autotune.assert_tuned_wins`."""
    from repro.gnn import graphs

    from benchmarks.bench_autotune import assert_tuned_wins, tuned_vs_default

    v, e = (400, 2000) if smoke else (2000, 10000)
    g = graphs.random_graph(v, e, seed=1, model="powerlaw", n_edge_types=3)
    rows = tuned_vs_default(g, max_evals=24 if smoke else 48)
    assert_tuned_wins(rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + {1,2} devices (CI)")
    ap.add_argument("--skip-devices", action="store_true",
                    help="simulated chip scaling only (no subprocesses)")
    args = ap.parse_args(argv)

    chips = run_chip_scaling(args.smoke)
    rows = [[name, *(f"{c['speedup']}x" for c in curve)]
            for name, curve in chips.items()]
    print("simulated chip scaling (2-layer, cit-Patents-like, speedup vs 1 chip)")
    print(fmt_table(rows, ["model"] + [f"{k}ch" for k in CHIP_COUNTS]))

    gate = run_exchange_gate(args.smoke)
    print("\nrestricted mincut exchange vs all-gather (bytes/boundary)")
    print(fmt_table([[r["model"], r["n_chips"], r["restricted_bytes"],
                      r["allgather_bytes"], r["edge_cut_rows"], r["balance"]]
                     for r in gate],
                    ["model", "chips", "restricted", "allgather",
                     "cut rows", "balance"]))

    planner = run_planner_comparison(args.smoke)
    print("\nshard planner comparison (P=32, LPT vs mincut)")
    print(fmt_table([[r["n_shards"], r["lpt_edge_cut"], r["mincut_edge_cut"],
                      f"{100 * r['cut_reduction']:.1f}%",
                      r["mincut_cost_balance"]]
                     for r in planner],
                    ["shards", "lpt cut", "mincut cut", "reduction",
                     "balance"]))

    tuned = run_autotuned(args.smoke)
    print("\nautotuned kernel dispatch vs incumbents (power-law, padded cycles)")
    print(fmt_table([[r["model"], r["scan_default"], r["kernel_default"],
                      r["kernel_tuned"], f"{r['speedup_vs_best']}x"]
                     for r in tuned],
                    ["model", "scan_default", "kernel_default",
                     "kernel_tuned", "vs_best"]))

    devices = None
    if not args.skip_devices:
        devices = run_device_scaling(args.smoke)
        print("\nhost-device wall clock (gcn x2, shard_map path)")
        print(fmt_table([[r["n_dev"], round(r["wall_s"] * 1e3, 2), r["vs_1dev"]]
                         for r in devices],
                        ["devices", "ms", "vs 1dev"]))

    path = write_report("bench_sharded", {
        "chip_scaling": chips, "exchange_gate": gate,
        "planner_comparison": planner, "device_scaling": devices,
        "autotuned": tuned, "smoke": args.smoke,
    })
    print(f"\nreport: {path}")


if __name__ == "__main__":
    main()
