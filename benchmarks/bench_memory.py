"""Paper Fig 2 analogue: memory footprint — whole-graph workspace vs tiled.

The paper's Observation 1: classic whole-graph execution materializes
per-edge intermediates for the entire graph; tiling bounds the working set
to a tile.  We account the peak intermediate bytes analytically from the IR
(edge-space tensors × E vs × tile E_max) and cross-check the whole-graph
number against jax's live-buffer view on the small graphs.
"""
from __future__ import annotations

from repro.core import compiler, tiling
from repro.gnn import graphs, models

from .common import BENCH_GRAPHS, fmt_table, write_report


def _ir_footprint(c, n_vertices, n_edges, dtype_bytes=4):
    """Bytes of vertex- and edge-space intermediates in the optimized IR."""
    v_bytes = e_bytes = 0
    for seg in c.ir.segments:
        for n in seg.nodes.values():
            if n.op in ("input", "output"):
                continue
            if seg.kind == "vertex":
                v_bytes += n.dim * n_vertices * dtype_bytes
            else:
                e_bytes += n.dim * n_edges * dtype_bytes
    return v_bytes, e_bytes


def run(quick: bool = False):
    rows = []
    model_names = ("gat", "sage") if quick else models.PAPER_MODELS
    for ds, scale in list(BENCH_GRAPHS.items())[:3]:
        g = graphs.paper_graph(ds, scale=scale, seed=0, n_edge_types=3)
        ts = tiling.grid_tile(g, 8, 8, sparse=True)
        for name in model_names:
            c = compiler.compile_gnn(models.trace_named(name))
            v_b, e_b = _ir_footprint(c, g.n_vertices, g.n_edges)
            # tiled: edge intermediates live per tile (E_max), dst block per partition
            _, e_tile = _ir_footprint(c, 0, ts.e_max)
            v_tile_rows = int(ts.n_src.max()) + int(ts.part_size.max())
            v_tile, _ = _ir_footprint(c, v_tile_rows, 0)
            whole = v_b + e_b
            tiled = v_tile + e_tile + v_b  # persistent V-state + one tile in flight
            rows.append([ds, name, f"{whole/1e6:.1f}", f"{(v_tile+e_tile)/1e6:.2f}",
                         f"{whole/max(v_tile+e_tile,1):.0f}x"])
    headers = ["dataset", "model", "whole_graph_workspace_MB",
               "tile_workspace_MB", "workspace_reduction"]
    print("== Fig 2: memory footprint (workspace) ==")
    print(fmt_table(rows, headers))
    write_report("bench_memory", {"headers": headers, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
