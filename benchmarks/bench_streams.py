"""Paper Fig 13 analogue: multi-stream / hybrid-architecture design-space
exploration — latency vs (#s/eStreams, #MU, #VU), normalized to the paper's
reference point (2 streams, 1 MU, 2 VU).

``--smoke`` (or ``run(smoke=True)``) exercises the full pipeline on a tiny
graph with a minimal sweep — importable from tier-1 tests as a fast
end-to-end check of the compile → schedule → ISA → simulate path.
"""
from __future__ import annotations

from repro.core import compiler, isa, simulator, tiling
from repro.core.streams import HWConfig
from repro.gnn import graphs, models

from .common import fmt_table, write_report


def run(quick: bool = False, smoke: bool = False, layers: int = 1):
    if smoke:
        g = graphs.random_graph(200, 800, seed=0, model="powerlaw",
                                n_edge_types=3)
        ts = tiling.grid_tile(g, 4, 4, sparse=True)
        model_names = ("gcn", "gat")
        sweep = [(2,), (1,), (2,)]
    else:
        g = graphs.paper_graph("cit-Patents", scale=0.002, seed=0, n_edge_types=3)
        ts = tiling.grid_tile(g, 8, 8, sparse=True)
        model_names = (("gat", "sage") if quick
                       else ("gcn", "gat", "sage", "ggnn", "rgcn"))
        sweep = [(2, 4, 8), (1, 2), (2, 4)]
    streams_sw, mu_sw, vu_sw = sweep

    rows = []
    for name in model_names:
        tr = (models.trace_named(name) if layers == 1
              else models.trace_stacked(name, layers))
        sde = isa.emit_sde(compiler.compile_gnn(tr).plan)
        base = simulator.simulate_model(
            sde, ts, HWConfig(n_sstreams=2, n_estreams=2, n_mu=1, n_vu=2)).cycles
        for streams in streams_sw:
            for n_mu in mu_sw:
                for n_vu in vu_sw:
                    r = simulator.simulate_model(
                        sde, ts, HWConfig(n_sstreams=streams, n_estreams=streams,
                                          n_mu=n_mu, n_vu=n_vu))
                    rows.append([name, streams, n_mu, n_vu,
                                 f"{base/r.cycles:.2f}x",
                                 f"{r.utilization['MU']:.2f}",
                                 f"{r.utilization['VU']:.2f}"])
    headers = ["model", "s/e_streams", "MU", "VU", "speedup_vs_(2,1,2)",
               "MU_util", "VU_util"]
    print("== Fig 13: stream/unit design-space exploration ==")
    print(fmt_table(rows, headers))
    # smoke runs report under their own name so the CI artifact keeps the
    # full-sweep history distinct from the per-PR smoke trajectory
    write_report("bench_streams_smoke" if smoke else "bench_streams",
                 {"headers": headers, "rows": rows})
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + minimal sweep (CI smoke)")
    ap.add_argument("--layers", type=int, default=1,
                    help="stack depth of the benchmarked models")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke, layers=args.layers)
