"""§Perf hillclimb report: baseline vs optimization variants per cell.

Reads the baseline cells from reports/dryrun/single and the variant records
from reports/dryrun/hillclimb, normalizes per-STEP quantities (microbatched
records store per-step totals already scaled), and prints roofline terms +
memory side by side.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import all_configs

from .common import REPORTS, fmt_table, write_report
from .roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, model_flops_per_device,
                       modeled_hbm_bytes)


def _row(rec, cfg, label):
    tot = rec.get("probe", {}).get("totals", {})
    mb = rec.get("microbatches", 1)
    flops = tot.get("flops", rec.get("flops", 0)) / mb
    coll = sum(v for k, v in tot.items() if k.startswith("coll_")) / mb
    t_c, t_x = flops / PEAK_FLOPS, coll / ICI_BW
    t_m = modeled_hbm_bytes(cfg, rec) / HBM_BW
    mem = rec.get("memory", {})
    gb = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 2**30
    mf = model_flops_per_device(cfg, rec["shape"], rec["n_devices"])
    step = max(t_c, t_m, t_x)
    return [rec["arch"], rec["shape"], label,
            f"{t_c*1e3:.1f}", f"{t_m*1e3:.1f}", f"{t_x*1e3:.1f}",
            f"{mf/flops:.2f}" if flops else "-",
            f"{mf/PEAK_FLOPS/step:.3f}" if step else "-",
            f"{gb:.1f}"], mf / PEAK_FLOPS / step if step else 0.0


def run(quick: bool = False):
    cfgs = all_configs()
    base_dir = pathlib.Path(REPORTS) / "dryrun" / "single"
    hc_dir = pathlib.Path(REPORTS) / "dryrun" / "hillclimb"
    rows, payload = [], []
    cells = sorted({f.name.split("__")[0] + "__" + f.name.split("__")[1]
                    for f in hc_dir.glob("*.json")}) if hc_dir.exists() else []
    for cell in cells:
        arch, shape = cell.split("__")
        shape = shape.replace(".json", "")
        base = json.loads((base_dir / f"{arch}__{shape}.json").read_text())
        if base.get("status") == "ok":
            r, frac = _row(base, cfgs[arch], "baseline (paper-faithful)")
            rows.append(r)
            payload.append({"cell": cell, "variant": "baseline", "frac": frac})
        for f in sorted(hc_dir.glob(f"{arch}__{shape}__*.json")):
            rec = json.loads(f.read_text())
            if rec.get("status") != "ok":
                rows.append([arch, shape, rec["variant"], "ERR", "-", "-", "-", "-", "-"])
                continue
            r, frac = _row(rec, cfgs[arch], rec["variant"])
            rows.append(r)
            payload.append({"cell": cell, "variant": rec["variant"], "frac": frac})
        rows.append(["-"] * 9)
    headers = ["arch", "shape", "variant", "compute_ms", "memory_ms",
               "collective_ms", "useful", "roofline_frac", "mem_GB/dev"]
    print("== §Perf: hillclimb iterations (per step, per device) ==")
    print(fmt_table(rows, headers))
    write_report("perf_report", {"rows": payload})
    return rows


if __name__ == "__main__":
    run()
