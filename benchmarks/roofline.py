"""§Roofline: three-term roofline per (arch × shape) from the dry-run.

Reads ``reports/dryrun/single/*.json`` (written by repro.launch.dryrun) and
derives, per cell (all quantities PER DEVICE, from the SPMD module):

  compute_s    = probe FLOPs / 197e12             (bf16 peak per v5e chip)
  memory_s     = modeled HBM bytes / 819e9        (see below)
  collective_s = probe collective bytes / 50e9    (per-chip ICI link class)

FLOPs and collective bytes come from the unrolled cost probes (XLA's
cost_analysis does not scale while-loop bodies — launch/dryrun._probe_costs).

Memory term: the CPU backend's "bytes accessed" counts every unfused HLO
op's operands — on TPU, XLA fuses elementwise chains, so that number
overstates HBM traffic by ~an order of magnitude.  We therefore report BOTH:
``hlo_bytes`` (the raw compiled-artifact number, an upper bound) and a
fusion-modeled estimate used for the roofline terms:

  train:   3×params (fwd + remat + bwd reads) + param write + 4-byte grads
           r/w + opt-state r/w + C_act·tokens·d·L activation round-trips
  prefill: params + C_act·tokens·d·L + KV-cache write
  decode:  params + KV/state-cache read (from memory_analysis arg bytes)

MODEL_FLOPS includes the attention term (6·N·D alone under-credits
long-context cells): train 6·N_act·T + 6·T·S·H·Dh·L; prefill 2·N_act·T +
2·T·S·H·Dh·L (causal half); decode 2·N_act·B + 4·B·S·H·Dh·L.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from repro.configs import SHAPES, all_configs
from repro.launch.steps import opt_state_bits

from .common import REPORTS, fmt_table, write_report

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s / chip
ICI_BW = 50e9         # B/s / link
C_ACT_TRAIN = 20      # activation round-trips per layer, fwd+bwd, post-fusion
C_ACT_FWD = 6


def _attn_dims(cfg):
    if cfg.family in ("ssm",):
        return 0, 0, 0
    L = cfg.n_layers if cfg.family != "hybrid" else cfg.n_layers // cfg.shared_attn_every
    if cfg.family == "moe":
        H, Dh = cfg.n_heads, cfg.mla.qk_nope + cfg.mla.qk_rope
    else:
        H, Dh = cfg.n_heads, cfg.hdim
    return L, H, Dh


def model_flops_per_device(cfg, shape_name: str, n_devices: int) -> float:
    S, B, kind = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    L, H, Dh = _attn_dims(cfg)
    S_eff = min(S, cfg.attn_window) if cfg.attn_window else S
    if kind == "train":
        T = S * B
        return (6.0 * n_active * T + 6.0 * T * S_eff * H * Dh * L / 2) / n_devices
    if kind == "prefill":
        T = S * B
        return (2.0 * n_active * T + 2.0 * T * S_eff * H * Dh * L / 2) / n_devices
    return (2.0 * n_active * B + 4.0 * B * S_eff * H * Dh * L) / n_devices


def modeled_hbm_bytes(cfg, cell: Dict) -> float:
    """Fusion-modeled per-device HBM traffic per step (see module doc)."""
    S, B, kind = SHAPES[cell["shape"]]
    n_dev = cell["n_devices"]
    P = cfg.param_count()
    p_dev = P * 2 / n_dev                      # bf16 params resident/device
    tokens_dev = S * B / n_dev
    d, L = cfg.d_model, cfg.n_layers
    args = cell["memory"].get("argument_size_in_bytes", 0)
    if kind == "train":
        bits = opt_state_bits(cfg)
        opt_dev = P * (3.1 if bits == 8 else 8.0) / n_dev
        grads = P * 4 / n_dev
        act = C_ACT_TRAIN * tokens_dev * d * L * 2
        return 4 * p_dev + 2 * grads + 2 * opt_dev + act
    if kind == "prefill":
        act = C_ACT_FWD * tokens_dev * d * L * 2
        return p_dev + act
    # decode: weights + the cache (arg bytes minus params ~= cache+state)
    cache_dev = max(args - p_dev, 0)
    return p_dev + cache_dev


def load_cells(mesh: str = "single") -> List[Dict]:
    cells = []
    d = pathlib.Path(REPORTS) / "dryrun" / mesh
    for f in sorted(d.glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def analyze(cells: Optional[List[Dict]] = None, mesh: str = "single") -> List[Dict]:
    cells = cells if cells is not None else load_cells(mesh)
    cfgs = all_configs()
    out = []
    for c in cells:
        if c.get("status") != "ok":
            out.append({"arch": c["arch"], "shape": c["shape"],
                        "status": c.get("status"),
                        "reason": c.get("reason", c.get("error", ""))[:90]})
            continue
        cfg = cfgs[c["arch"]]
        probe = c.get("probe", {}).get("totals", {})
        flops = probe.get("flops", c["flops"])
        hlo_bytes = probe.get("bytes", c.get("hlo_bytes_accessed", 0))
        coll = sum(v for k, v in probe.items() if k.startswith("coll_")) if probe \
            else sum(c["collective_bytes"].values())
        mdl_bytes = modeled_hbm_bytes(cfg, c)
        t_c = flops / PEAK_FLOPS
        t_m = mdl_bytes / HBM_BW
        t_x = coll / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        step_s = max(terms.values())  # perfectly-overlapped lower bound
        mf = model_flops_per_device(cfg, c["shape"], c["n_devices"])
        mfu = mf / PEAK_FLOPS / step_s if step_s > 0 else 0.0
        out.append({
            "arch": c["arch"], "shape": c["shape"], "status": "ok",
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "hlo_bytes_s": hlo_bytes / HBM_BW,
            "dominant": dom, "model_flops": mf, "hlo_flops": flops,
            "useful_ratio": mf / flops if flops else 0.0,
            "roofline_frac": mfu,
            "mem_temp_gb": c["memory"].get("temp_size_in_bytes", 0) / 2**30,
            "mem_args_gb": c["memory"].get("argument_size_in_bytes", 0) / 2**30,
        })
    return out


def run(quick: bool = False, mesh: str = "single"):
    rows = analyze(mesh=mesh)
    table = []
    for r in rows:
        if r.get("status") != "ok":
            table.append([r["arch"], r["shape"], r.get("status"),
                          "-", "-", "-", "-", "-", "-", "-"])
            continue
        table.append([r["arch"], r["shape"], r["dominant"],
                      f"{r['compute_s']*1e3:.2f}", f"{r['memory_s']*1e3:.2f}",
                      f"{r['collective_s']*1e3:.2f}", f"{r['hlo_bytes_s']*1e3:.0f}",
                      f"{r['useful_ratio']:.2f}", f"{r['roofline_frac']:.3f}",
                      f"{r['mem_temp_gb']+r['mem_args_gb']:.1f}"])
    headers = ["arch", "shape", "dominant", "compute_ms", "memory_ms",
               "collective_ms", "hloB_ms", "useful", "roofline_frac", "mem_GB/dev"]
    print(f"== §Roofline ({mesh} pod, per device) ==")
    print(fmt_table(table, headers))
    write_report(f"roofline_{mesh}", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
