"""Multi-layer lowering study: per-layer vs fused-schedule simulated cycles.

ZIPPER's evaluation runs stacked GNNs (§8.1); this bench quantifies what the
cross-layer lowering buys on the cit-Patents-like configuration:

* ``per_layer_cycles`` — L independent single-layer programs, summed (the
  pre-multi-layer execution model: one host-level barrier per layer);
* ``fused_barrier_cycles`` — ONE compiled program spanning all layers,
  scheduled with full gather barriers between levels;
* ``fused_pipelined_cycles`` — the same program with layer boundaries
  relaxed to their true data dependencies (``inter_layer="pipelined"``):
  next-layer tile compute interleaves with the previous layer's gather
  drain, the paper's tile × operator parallelism applied across layers.

Also reported: the cross-layer CSE count (stacked GCN dedupes its re-emitted
normalized-adjacency scatters) and wall-clock of the fused multi-layer
``PipelinedRunner`` vs running a single-layer runner L times (one jit and
zero host round-trips vs L compiled calls).
"""
from __future__ import annotations

from repro.core import compiler, isa, pipeline, simulator, tiling
from repro.gnn import graphs, models

from .common import fmt_table, timeit, write_report


def run(quick: bool = False, smoke: bool = False, layers: int = 2):
    if smoke:
        g = graphs.paper_graph("cit-Patents", scale=0.001, seed=0,
                               n_edge_types=3)
        model_names = ("gcn", "gat")
        grid = 6
    else:
        g = graphs.paper_graph("cit-Patents", scale=0.002, seed=0,
                               n_edge_types=3)
        model_names = (models.PAPER_MODELS[:2] if quick
                       else models.PAPER_MODELS)
        grid = 8
    ts = tiling.grid_tile(g, grid, grid, sparse=True)

    rows = []
    metrics = {}
    for name in model_names:
        single = compiler.compile_gnn(models.trace_named(name))
        stacked = compiler.compile_gnn(models.trace_stacked(name, layers))
        sde_single = isa.emit_sde(single.schedule(False))
        sde_stacked = isa.emit_sde(stacked.schedule(False))
        per_layer = simulator.simulate_model(sde_single, ts).cycles * layers
        barrier = simulator.simulate_model(sde_stacked, ts).cycles
        pipelined = simulator.simulate_model(sde_stacked, ts,
                                             inter_layer="pipelined").cycles
        rows.append([name, layers,
                     stacked.opt_report["cse_removed"],
                     per_layer, barrier, pipelined,
                     f"{barrier / pipelined:.3f}x"])
        metrics[name] = dict(layers=layers,
                             cse_removed=stacked.opt_report["cse_removed"],
                             per_layer_cycles=per_layer,
                             fused_barrier_cycles=barrier,
                             fused_pipelined_cycles=pipelined)
    headers = ["model", "layers", "cse_removed", "per_layer_cycles",
               "fused_barrier_cycles", "fused_pipelined_cycles",
               "pipeline_speedup"]
    print(f"== multi-layer lowering: barrier vs pipelined ({layers} layers, "
          "cit-Patents-like) ==")
    print(fmt_table(rows, headers))

    # wall-clock: L single-layer runner calls (host round-trip per layer)
    # vs one fused multi-layer jit.  GGNN keeps both variants on the same
    # pure-SpMM kernel path, so the comparison isolates the schedule; on CPU
    # expect rough parity (the structural win is the simulated overlap
    # above — XLA-CPU cannot interleave the layer boundary itself).
    wall_rows = []
    if not smoke and "ggnn" in model_names:
        dim = 32
        tr1 = models.trace_named("ggnn", dim, dim)
        trL = models.trace_stacked("ggnn", layers, dim, dim, dim)
        c1, cL = compiler.compile_gnn(tr1), compiler.compile_gnn(trL)
        r1 = pipeline.PipelinedRunner(c1, g, ts)
        rL = pipeline.PipelinedRunner(cL, g, ts)
        p1 = models.init_params(tr1)
        pL = models.init_params(trL)
        inputs = models.init_inputs(trL, g)

        def chained():
            x = inputs["x"]
            for _ in range(layers):
                x = r1({"x": x}, p1)[0]
            return x

        repeats = 1 if quick else 3
        t_chain = timeit(chained, repeats=repeats)
        t_fused = timeit(lambda: rL(inputs, pL), repeats=repeats)
        wall_rows = [[f"{layers}x single-layer runner", f"{t_chain*1e3:.1f}ms"],
                     ["fused multi-layer runner", f"{t_fused*1e3:.1f}ms"]]
        print("\n== wall-clock: chained per-layer vs fused (ggnn) ==")
        print(fmt_table(wall_rows, ["executor", "median_wall"]))
        metrics["ggnn"]["wall_chained_s"] = t_chain
        metrics["ggnn"]["wall_fused_s"] = t_fused

    write_report("bench_multilayer",
                 {"headers": headers, "rows": rows, "metrics": metrics,
                  "wall": wall_rows})
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer models")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, two models (CI smoke)")
    ap.add_argument("--layers", type=int, default=2,
                    help="stack depth for the fused schedules")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke, layers=args.layers)
