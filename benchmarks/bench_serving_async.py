"""Async serving tier vs sequential per-request submit, under SLOs.

The workload is batch-forming load: a burst of individual graph requests
(one graph per request, each with a deadline) arrives faster than they can
be served one-by-one.  The sequential baseline is the status quo before the
async tier — a warm :class:`~repro.serve.engine.InferenceServer` driven one
``submit([g], [i])`` call per request (no batching; the cache is warm, so
this isolates the batching win from the compile-amortization win that
``bench_serving`` already measures).  The async tier
(:class:`~repro.serve.server.AsyncInferenceServer`) forms size-class
batches behind a request queue, pads partial batches onto canonical
shapes, and overlaps dispatch across a worker pool.

Asserted (the ISSUE 8 acceptance bar):

* async throughput >= 2x the sequential per-request baseline;
* zero steady-state recompiles (after background warmup, the program-cache
  compile counter is flat across the whole measured stream);
* p99 end-to-end latency bounded by the configured request deadline.

``--smoke`` shrinks the stream for CI; both modes write
``reports/bench_serving_async.json`` (the acceptance artifact) with the
p50/p99 latency, queue-depth, batch-fill, and shed metrics embedded.
"""
from __future__ import annotations

import time

from repro.core import compiler
from repro.gnn import graphs, models
from repro.serve import AsyncInferenceServer, InferenceServer

from .common import fmt_table, write_report


def _workload(tr, name: str, n: int, v: int, e: int, seed0: int = 0):
    etypes = 3 if models.MODELS[name].needs_etype else None
    gs, ins = [], []
    for k in range(n):
        g = graphs.random_graph(v, e, seed=seed0 + k, model="powerlaw",
                                n_edge_types=etypes)
        gs.append(g)
        ins.append(models.init_inputs(tr, g, seed=seed0 + k))
    return gs, ins


def _sequential_rps(compiled, params, gs, ins) -> float:
    """Warm per-request baseline: one submit([g], [i]) call per request."""
    server = InferenceServer(compiled, params)
    server.submit(gs[:1], ins[:1])                   # warm the class
    t0 = time.perf_counter()
    for g, inp in zip(gs, ins):
        server.submit([g], [inp])
    return len(gs) / (time.perf_counter() - t0)


def _async_rps(server, name, gs, ins, deadline_s):
    """Burst the whole stream at the async tier; returns (rps, tickets)."""
    t0 = time.perf_counter()
    tickets = server.submit_many(gs, ins, model=name, deadline_s=deadline_s)
    for t in tickets:
        t.result(timeout=deadline_s + 60)
    return len(gs) / (time.perf_counter() - t0), tickets


def run(smoke: bool = False):
    if smoke:
        model_names, n_requests, v, e = ("gcn",), 64, 48, 192
        max_batch, deadline_s = 16, 10.0
    else:
        model_names, n_requests, v, e = ("gcn", "gat"), 192, 96, 420
        max_batch, deadline_s = 16, 20.0

    server = AsyncInferenceServer(max_queue=4 * n_requests,
                                  n_workers=2,
                                  default_deadline_s=deadline_s,
                                  dispatch_margin_s=0.25)
    compiled, params, streams = {}, {}, {}
    for name in model_names:
        tr = models.trace_named(name)
        compiled[name] = compiler.compile_gnn(tr)
        params[name] = models.init_params(tr)
        streams[name] = _workload(tr, name, n_requests, v, e)
        warm_g = graphs.random_graph(
            v, e, seed=10_000, model="powerlaw",
            n_edge_types=3 if models.MODELS[name].needs_etype else None)
        server.register_model(name, compiled[name], params[name],
                              max_batch=max_batch, warmup_graphs=[warm_g])

    server.start()
    t_warm = time.perf_counter()
    while not server.warmup_done():                   # background warmup
        if time.perf_counter() - t_warm > 300:
            raise RuntimeError("warmup did not finish")
        time.sleep(0.02)
    warmup_s = time.perf_counter() - t_warm

    rows, metrics = [], {}
    for name in model_names:
        gs, ins = streams[name]
        # wall-clock CI gate: one re-measure absorbs scheduler jitter on a
        # noisy shared runner (the bar itself stays at the 2x acceptance)
        for attempt in range(2):
            seq_rps = _sequential_rps(compiled[name], params[name], gs, ins)
            compiles_before = server.cache.stats.compiles
            async_rps, tickets = _async_rps(server, name, gs, ins, deadline_s)
            recompiles = server.cache.stats.compiles - compiles_before
            served = sum(1 for t in tickets if t.ok)
            speedup = async_rps / seq_rps
            snap = server.metrics.snapshot()
            p50, p99 = snap["latency_s"]["p50"], snap["latency_s"]["p99"]
            checks = dict(
                speedup_ge_2x=speedup >= 2.0,
                zero_steady_state_recompiles=recompiles == 0,
                p99_within_deadline=p99 <= deadline_s,
                all_served=served == n_requests,
            )
            if all(checks.values()):
                break
        rows.append([name, f"{seq_rps:.1f}", f"{async_rps:.1f}",
                     f"{speedup:.1f}x", f"{p50 * 1e3:.1f}",
                     f"{p99 * 1e3:.1f}", recompiles,
                     snap["shed_total"],
                     f"{snap['batch_fill']['mean']:.2f}"])
        metrics[name] = dict(seq_rps=seq_rps, async_rps=async_rps,
                             speedup=speedup, served=served,
                             recompiles_steady_state=recompiles,
                             checks=checks)

    final = server.metrics.snapshot()
    server.close()

    headers = ["model", "seq_r/s", "async_r/s", "speedup", "p50_ms",
               "p99_ms", "recompiles", "shed", "fill"]
    print("== async serving tier vs sequential per-request submit ==")
    print(fmt_table(rows, headers))
    print(f"(background warmup {warmup_s:.1f}s; deadline {deadline_s}s; "
          f"batch cap {max_batch})")
    write_report("bench_serving_async",
                 {"smoke": smoke,
                  "workload": dict(n_requests=n_requests, v=v, e=e,
                                   max_batch=max_batch,
                                   deadline_s=deadline_s),
                  "warmup_s": warmup_s,
                  "headers": headers, "rows": rows,
                  "metrics": metrics,
                  "serve_metrics": final})
    for name, m in metrics.items():
        for check, passed in m["checks"].items():
            assert passed, (name, check, m)
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small stream (CI smoke); still writes the report")
    args = ap.parse_args()
    run(smoke=args.smoke)
