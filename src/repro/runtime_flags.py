"""Process-global tracing flags.

``PROBE`` drives the dry-run's *cost probes*: XLA's ``cost_analysis()`` does
not multiply FLOPs/bytes by ``while``-loop trip counts, so the production
step (scan-over-layers) undercounts.  The dry-run therefore lowers extra
"probe" variants with python-unrolled layer stacks (1 and 2 layers) and
unrolled inner scans, and extrapolates:  total = f(1) + (L-1)·(f(2) - f(1))
per stack.  Memory analysis and the collective *schedule* always come from
the real (scanned) compile.

  PROBE["stack_counts"]: None, or {stack_name: n_layers_to_trace}
  PROBE["unroll"]:       unroll inner scans (flash kv blocks, ssm chunks,
                         MoE token chunks) so their FLOPs are visible.
"""
from typing import Dict, Optional

PROBE: Dict = {"stack_counts": None, "unroll": False}

#: beyond-baseline optimization toggles (§Perf hillclimbs) — default OFF so
#: the recorded baselines stay reproducible; the hillclimb driver flips them.
OPT: Dict = {
    "attn_batch_shard": False,   # batch-shard attention when heads % model != 0
    "moe_rs_combine": False,     # reduce-scatter + thin return-a2a MoE combine
    "moe_fp8_dispatch": False,   # fp8 payload on the forward dispatch all_to_all
    "zero1_opt_state": False,    # shard optimizer moments over the data axes
    "fsdp_params": False,        # shard params over data too (per-layer all-gather)
    "remat_save_dots": False,    # checkpoint policy: save matmul/collective outs
}


def probe_stacks() -> Optional[Dict[str, int]]:
    return PROBE["stack_counts"]


def probe_unroll() -> bool:
    return bool(PROBE["unroll"])
