"""Mamba2 (SSD) block — chunked selective-state-space layer (Zamba2 backbone).

The chunked SSD algorithm is ZIPPER's tiling transplanted to the sequence
axis: chunks are tiles; the intra-chunk quadratic part is the compute-bound
"GEMM" phase and the inter-chunk state scan is the memory-bound recurrent
phase; ``lax.scan`` over chunks pipelines them (DESIGN.md §4/§5).

Shapes follow the Mamba2 paper: d_inner = expand·d, heads = d_inner/head_dim,
scalar decay A per head, grouped B/C (n_groups).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import DP, leaf, rms_norm, shard_hint

Array = Any


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    conv_ch = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_ch


def mamba2_template(cfg: ArchConfig) -> Dict:
    s, di, nh, conv_ch = _dims(cfg)
    d = cfg.d_model
    return {
        # [z (di), xBC (di + 2*G*N), dt (nh)]
        "w_in": leaf((d, 2 * di + 2 * s.n_groups * s.d_state + nh), (None, "model")),
        "conv_w": leaf((s.d_conv, conv_ch), (None, "model"), scale=0.5),
        "conv_b": leaf((conv_ch,), ("model",), init="zeros"),
        "dt_bias": leaf((nh,), ("model",), init="zeros"),
        "a_log": leaf((nh,), ("model",), init="ones"),
        "d_skip": leaf((nh,), ("model",), init="ones"),
        "norm_w": leaf((di,), ("model",), init="ones"),
        "w_out": leaf((di, d), ("model", None)),
    }


def mamba2_state_template(cfg: ArchConfig, batch: int) -> Dict:
    s, di, nh, conv_ch = _dims(cfg)
    return {
        "ssm": leaf((batch, nh, s.head_dim, s.d_state), (DP, "model", None, None), init="zeros"),
        "conv": leaf((batch, s.d_conv - 1, conv_ch), (DP, None, "model"), init="zeros"),
    }


def _split_proj(cfg, zxbcdt):
    s, di, nh, conv_ch = _dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + conv_ch]
    dt = zxbcdt[..., di + conv_ch:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state: Optional[Array] = None):
    """Depthwise causal conv along time. xbc: (B,S,C); conv_w: (W,C)."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)            # (B, S+W-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(W))
    out = jax.nn.silu(out + conv_b)
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return out, new_state


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    x:  (B, S, nh, hd)    dt: (B, S, nh)   A: (nh,) (negative)
    Bm/Cm: (B, S, G, N);  heads are grouped G | nh.
    Returns y (B, S, nh, hd) and final state (B, nh, hd, N).
    """
    Bsz, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    L = min(chunk, S)
    nchunk = -(-S // L)
    pad = nchunk * L - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xs = x.reshape(Bsz, nchunk, L, nh, hd).transpose(1, 0, 2, 3, 4)
    dts = dt.reshape(Bsz, nchunk, L, nh).transpose(1, 0, 2, 3)
    Bs = Bm.reshape(Bsz, nchunk, L, G, N).transpose(1, 0, 2, 3, 4)
    Cs = Cm.reshape(Bsz, nchunk, L, G, N).transpose(1, 0, 2, 3, 4)

    h0 = (jnp.zeros((Bsz, nh, hd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(h, inp):
        xc, dtc, Bc, Cc = inp                         # (B,L,nh,hd) (B,L,nh) (B,L,G,N)
        dA = dtc * A[None, None, :]                    # (B,L,nh) negative
        cum = jnp.cumsum(dA, axis=1)                   # (B,L,nh)
        Bh = jnp.repeat(Bc, rep, axis=2)               # (B,L,nh,N)
        Ch = jnp.repeat(Cc, rep, axis=2)
        # intra-chunk (the "GEMM tile"): attention-like lower-tri matrix
        scores = jnp.einsum("blhn,bshn->bhls", Ch, Bh)  # (B,nh,L,L)
        decay = cum[:, :, None, :].transpose(0, 3, 1, 2) - cum[:, None, :, :].transpose(0, 3, 1, 2)
        # decay[b,h,l,s] = cum[b,l,h] - cum[b,s,h]
        mask = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(mask, jnp.exp(decay) , 0.0) * scores
        xdt = xc * dtc[..., None]                      # (B,L,nh,hd)
        y_intra = jnp.einsum("bhls,bshd->blhd", w, xdt)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("blhn,bhdn->blhd", Ch * jnp.exp(cum)[..., None], h)
        # state update
        tail = jnp.exp(cum[:, -1:, :] - cum)           # (B,L,nh)
        chunk_state = jnp.einsum("bshd,bshn->bhdn", xdt * tail[..., None], Bh)
        h_new = h * jnp.exp(dA.sum(1))[:, :, None, None] + chunk_state
        return h_new, y_intra + y_inter

    from .. import runtime_flags
    # checkpointed chunk body: bwd recomputes the intra-chunk (L,L) decay
    # matrices instead of saving one per chunk (carry is the small state)
    h_final, ys = jax.lax.scan(jax.checkpoint(body), h0,
                               (xs.astype(jnp.float32), dts.astype(jnp.float32),
                                Bs.astype(jnp.float32), Cs.astype(jnp.float32)),
                               unroll=runtime_flags.probe_unroll())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nchunk * L, nh, hd)[:, :S]
    return y, h_final


def mamba2_block(cfg: ArchConfig, p: Dict, x: Array, *, mesh=None,
                 state: Optional[Dict] = None) -> Tuple[Array, Optional[Dict]]:
    """x: (B, S, d) -> (B, S, d). With ``state``: single-step decode
    (S should be 1), returning the updated recurrent+conv state."""
    s, di, nh, conv_ch = _dims(cfg)
    B, S, d = x.shape
    zxbcdt = x @ p["w_in"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :di].reshape(B, S, nh, s.head_dim)
    Bm = xbc[..., di:di + s.n_groups * s.d_state].reshape(B, S, s.n_groups, s.d_state)
    Cm = xbc[..., di + s.n_groups * s.d_state:].reshape(B, S, s.n_groups, s.d_state)

    if state is None:
        y, _ = _ssd_chunked(xs, dt, A, Bm, Cm, s.chunk)
        new_state = None
    else:
        # single-step recurrence: h = h*exp(dt*A) + dt*B x ; y = C·h
        h = state["ssm"].astype(jnp.float32)           # (B,nh,hd,N)
        rep = nh // s.n_groups
        Bh = jnp.repeat(Bm[:, 0], rep, axis=1)         # (B,nh,N)
        Ch = jnp.repeat(Cm[:, 0], rep, axis=1)
        dt0 = dt[:, 0]                                  # (B,nh)
        xdt = xs[:, 0].astype(jnp.float32) * dt0[..., None]  # (B,nh,hd)
        h = h * jnp.exp(dt0 * A)[:, :, None, None] + jnp.einsum(
            "bhd,bhn->bhdn", xdt, Bh.astype(jnp.float32))
        y = jnp.einsum("bhdn,bhn->bhd", h, Ch.astype(jnp.float32))[:, None]
        new_state = {"ssm": h, "conv": new_conv}
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    y = shard_hint(y, mesh, DP, None, "model")
    return y @ p["w_out"], new_state
