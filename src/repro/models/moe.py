"""Expert-parallel MoE layer (DeepSeek V2/V3 family).

ZIPPER's full pipeline applied to MoE (DESIGN.md §4): token->expert routes
are the sparse graph; routing sorts tokens by expert (degree-sort reorder),
capacity buckets are the tiles, dead bucket blocks are skipped structurally
(Pallas kernel), and token *chunking* scans tiles through the layer to bound
the transient dispatch footprint — inter-tile pipelining along the token
axis.

Distribution (under ``jax.shard_map``):
  * experts sharded over the **data** axis (E_loc = E / n_data per device),
  * expert FFN width sharded over the **model** axis (f_loc = f / n_model),
  * dispatch/return via ``all_to_all`` over data; down-proj reduced by
    ``psum`` over model;
  * shared experts are a dense SwiGLU, TP over model.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..jax_compat import shard_map
from ..kernels.moe_dispatch import ops as moe_ops
from .common import DP, leaf

Array = Any


def moe_template(cfg: ArchConfig) -> Dict:
    mo = cfg.moe
    d, f = cfg.d_model, mo.d_ff_expert
    t = {
        "router": leaf((d, mo.n_routed), (None, None), dtype="float32"),
        # experts: E over the data axes (expert parallel), ff over model (TP)
        "wg": leaf((mo.n_routed, d, f), (DP, None, "model")),
        "wu": leaf((mo.n_routed, d, f), (DP, None, "model")),
        "wd": leaf((mo.n_routed, f, d), (DP, "model", None)),
    }
    if mo.aux_free_bias:
        t["router_bias"] = leaf((mo.n_routed,), (None,), init="zeros", dtype="float32")
    if mo.n_shared:
        fs = mo.d_ff_expert * mo.n_shared
        t["shared_wg"] = leaf((d, fs), (None, "model"))
        t["shared_wu"] = leaf((d, fs), (None, "model"))
        t["shared_wd"] = leaf((fs, d), ("model", None))
    return t


def _local_moe(cfg: ArchConfig, x_loc: Array, router, router_bias, wg, wu, wd,
               *, n_data: int, capacity: int, axis_data: Tuple[str, ...],
               axis_model: str):
    """Per-device body (inside shard_map).

    x_loc: (T_loc, d); wg/wu: (E_loc, d, f_loc); wd: (E_loc, f_loc, d)."""
    mo = cfg.moe
    E = mo.n_routed
    E_loc = E // n_data
    r = moe_ops.route(x_loc, router.astype(x_loc.dtype), mo.top_k, capacity,
                      norm_topk=mo.norm_topk, router_bias=router_bias)
    buckets = moe_ops.dispatch(x_loc, r, E, capacity)          # (E, C, d)
    d = x_loc.shape[-1]
    # ---- expert-parallel all_to_all over the data axis ----------------------
    from .. import runtime_flags
    fp8 = runtime_flags.OPT["moe_fp8_dispatch"]
    b = buckets.reshape(n_data, E_loc, capacity, d)
    if fp8:
        # §Perf: halve the forward dispatch wire bytes (per-chunk scale kept
        # bf16; gradients flow through the straight-through cast in bf16)
        bscale = jnp.maximum(jnp.max(jnp.abs(b)), 1e-6) / 448.0
        b = (b / bscale).astype(jnp.float8_e4m3fn)
    if n_data > 1:
        b = jax.lax.all_to_all(b, axis_data, split_axis=0, concat_axis=0, tiled=False)
    if fp8:
        b = b.astype(x_loc.dtype) * bscale
    # b[j] now holds source-shard j's buckets for MY experts
    b = b.transpose(1, 0, 2, 3).reshape(E_loc, n_data * capacity, d)
    # ---- grouped FFN over local experts (ff sharded over model) -------------
    h = jnp.einsum("ecd,edf->ecf", b, wg)
    u = jnp.einsum("ecd,edf->ecf", b, wu)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h.astype(jnp.float32)).astype(h.dtype) * u,
                   wd)
    from .. import runtime_flags
    rs_mode = runtime_flags.OPT["moe_rs_combine"]
    n_model = jax.lax.psum(1, axis_model)
    if rs_mode and d % n_model == 0:
        # §Perf: reduce-scatter (half an all-reduce) and carry only d/n_model
        # through the return all_to_all; re-assemble tokens with one thin
        # all-gather at the end.
        y = jax.lax.psum_scatter(y, axis_model, scatter_dimension=2, tiled=True)
        d_s = d // n_model
    else:
        y = jax.lax.psum(y, axis_model)
        d_s = d
    # ---- return path ---------------------------------------------------------
    y = y.reshape(E_loc, n_data, capacity, d_s).transpose(1, 0, 2, 3)
    if n_data > 1:
        y = jax.lax.all_to_all(y, axis_data, split_axis=0, concat_axis=0, tiled=False)
    y = y.reshape(E, capacity, d_s)
    out = moe_ops.combine(y, r, x_loc.shape[0])          # (T_loc, d_s)
    if rs_mode and d_s != d:
        out = jax.lax.all_gather(out, axis_model, axis=1, tiled=True)  # (T_loc, d)
    aux = r.aux_loss
    if n_data > 1:
        aux = jax.lax.pmean(aux, axis_data)
    return out, aux


def moe_layer(cfg: ArchConfig, p: Dict, x: Array, *, mesh,
              token_chunks: int = 4) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss).  Requires a mesh with a 'model' axis;
    the data axes carry both tokens and experts."""
    mo = cfg.moe
    B, S, d = x.shape
    axis_data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in axis_data:
        n_data *= mesh.shape[a]
    assert mo.n_routed % n_data == 0, (mo.n_routed, n_data)
    dp_spec = axis_data if len(axis_data) > 1 else axis_data[0]
    has_bias = "router_bias" in p

    def body(x_blk):  # (n_data * T_loc, d) global-view chunk
        T_loc = x_blk.shape[0] // n_data
        cap = max(8, int(T_loc * mo.top_k / mo.n_routed * mo.capacity_factor))

        def device_fn(xd, router, router_bias, wg, wu, wd):
            return _local_moe(cfg, xd.reshape(T_loc, d), router, router_bias,
                              wg, wu, wd, n_data=n_data, capacity=cap,
                              axis_data=axis_data, axis_model="model")

        fn = shard_map(
            device_fn, mesh=mesh,
            in_specs=(P(dp_spec, None),               # tokens over data axes
                      P(None, None),                  # router (replicated)
                      P(None) if has_bias else P(),   # balancing bias
                      P(dp_spec, None, "model"),      # experts: E over data, f over model
                      P(dp_spec, None, "model"),
                      P(dp_spec, "model", None)),
            out_specs=(P(dp_spec, None), P()),
            check_vma=False)
        y, aux = fn(x_blk, p["router"],
                    p["router_bias"] if has_bias else jnp.zeros((), x.dtype),
                    p["wg"], p["wu"], p["wd"])
        return y, aux

    flat = x.reshape(B * S, d)
    from .. import runtime_flags
    if runtime_flags.probe_stacks() is not None:
        token_chunks = 1  # cost probe: all tokens through one dispatch
    if token_chunks > 1 and (B * S) % (token_chunks * n_data) == 0:
        chunks = flat.reshape(token_chunks, (B * S) // token_chunks, d)
        ys, auxs = jax.lax.map(body, chunks)
        y = ys.reshape(B * S, d)
        aux = auxs.mean()
    else:
        y, aux = body(flat)
    y = y.reshape(B, S, d)

    if mo.n_shared:
        h = jax.nn.silu((x @ p["shared_wg"]).astype(jnp.float32)).astype(x.dtype)
        y = y + (h * (x @ p["shared_wu"])) @ p["shared_wd"]
    return y, aux


def dense_ffn_template(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": leaf((d, f), (None, "model")),
        "wu": leaf((d, f), (None, "model")),
        "wd": leaf((f, d), ("model", None)),
    }


def dense_ffn(p: Dict, x: Array) -> Array:
    h = jax.nn.silu((x @ p["wg"]).astype(jnp.float32)).astype(x.dtype)
    return (h * (x @ p["wu"])) @ p["wd"]


def gelu_ffn_template(cfg: ArchConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    return {"w1": leaf((d, f), (None, "model")),
            "b1": leaf((f,), ("model",), init="zeros"),
            "w2": leaf((f, d), ("model", None)),
            "b2": leaf((d,), (None,), init="zeros")}


def gelu_ffn(p: Dict, x: Array) -> Array:
    h = jax.nn.gelu((x @ p["w1"] + p["b1"]).astype(jnp.float32)).astype(x.dtype)
    return h @ p["w2"] + p["b2"]
