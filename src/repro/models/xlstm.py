"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, step recurrence).  [arXiv:2405.04517]

mLSTM uses exponential input gates with the paper's max-stabilizer m_t; we
implement the exact stabilized recurrence in chunked form — chunks are the
ZIPPER tiles of the sequence axis (intra-chunk matmuls on the MXU, the
inter-chunk state scan is the recurrent phase), mirroring mamba2.py.

State per mLSTM head: (C: dk×dv matrix memory, n: dk normalizer, m: scalar
max-stabilizer) — stored as Ĉ,n̂ with true value Ĉ·exp(m).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import DP, leaf, rms_norm, shard_hint

Array = Any


def _mdims(cfg: ArchConfig):
    xc = cfg.xlstm
    di = int(cfg.d_model * xc.proj_factor)
    nh = cfg.n_heads
    dk = di // nh
    return xc, di, nh, dk


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_template(cfg: ArchConfig) -> Dict:
    xc, di, nh, dk = _mdims(cfg)
    d = cfg.d_model
    return {
        "w_up": leaf((d, 2 * di), (None, "model")),        # [x_inner, z-gate]
        "conv_w": leaf((xc.conv_width, di), (None, "model"), scale=0.5),
        "conv_b": leaf((di,), ("model",), init="zeros"),
        "wq": leaf((di, di), (None, "model")),
        "wk": leaf((di, di), (None, "model")),
        "wv": leaf((di, di), (None, "model")),
        "w_if": leaf((di, 2 * nh), (None, "model")),       # input/forget gate logits
        "b_if": leaf((2 * nh,), ("model",), init="zeros"),
        "norm_w": leaf((di,), ("model",), init="ones"),
        "w_down": leaf((di, d), ("model", None)),
    }


def mlstm_state_template(cfg: ArchConfig, batch: int) -> Dict:
    xc, di, nh, dk = _mdims(cfg)
    return {
        "C": leaf((batch, nh, dk, dk), (DP, "model", None, None), init="zeros"),
        "n": leaf((batch, nh, dk), (DP, "model", None), init="zeros"),
        # the max-stabilizer starts at -inf (matches the chunked prefill init)
        "m": leaf((batch, nh), (DP, "model"), init="full", scale=-1e30),
        "conv": leaf((batch, xc.conv_width - 1, di), (DP, None, "model"), init="zeros"),
    }


def _chunked_mlstm(q, k, v, ig, fg, chunk: int, state=None):
    """Stabilized chunkwise mLSTM.

    q/k/v: (B, S, nh, dk); ig/fg: (B, S, nh) raw gate logits.
    Returns h (B,S,nh,dk) and final (C,n,m) state.
    """
    B, S, nh, dk = q.shape
    L = min(chunk, S)
    nchunk = -(-S // L)
    pad = nchunk * L - S
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)))
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)

    def chunks(t):  # (B, S, ...) -> (nc, B, L, ...)
        return t.reshape((B, nchunk, L) + t.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = chunks(q), chunks(k), chunks(v)
    igs, fgs = chunks(ig), chunks(fg)

    if state is None:
        C0 = jnp.zeros((B, nh, dk, dk), jnp.float32)
        n0 = jnp.zeros((B, nh, dk), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    scale = dk ** -0.5

    def body(carry, inp):
        C, n, m_prev = carry
        qc, kc, vc, a, g = inp          # a: input-gate logits, g: log-forget
        g = jax.nn.log_sigmoid(g.astype(jnp.float32))       # (B,L,nh)
        a = a.astype(jnp.float32)
        Bcum = jnp.cumsum(g, axis=1)                        # (B,L,nh)
        # weight(t,s) = B_t - B_s + a_s  (s's own input is NOT decayed)
        # per-position stabilizer m_t = max(m_prev + B_t, B_t + max_{s<=t}(a_s - B_s))
        run_max = jax.lax.cummax(a - Bcum, axis=1)
        m_t = jnp.maximum(m_prev[:, None] + Bcum, run_max + Bcum)
        # intra-chunk weights: exp(B_t - B_s + a_s - m_t)  (s <= t)
        logw = (Bcum[:, :, None, :] - Bcum[:, None, :, :]
                + a[:, None, :, :] - m_t[:, :, None, :])
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        w = jnp.where(mask, jnp.exp(logw), 0.0)              # (B,L,L,nh)
        scores = jnp.einsum("blhd,bshd->blsh", qc.astype(jnp.float32),
                            kc.astype(jnp.float32)) * scale
        inter = jnp.exp(m_prev[:, None] + Bcum - m_t)        # (B,L,nh)
        num = (jnp.einsum("blsh,bshd->blhd", w * scores, vc.astype(jnp.float32))
               + jnp.einsum("blhd,bhde->blhe", qc.astype(jnp.float32) * scale
                            * inter[..., None], C))
        den = ((w * scores).sum(2)
               + jnp.einsum("blhd,bhd->blh", qc.astype(jnp.float32) * scale, n) * inter)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- end-of-chunk state
        BL = Bcum[:, -1, :]                                   # (B,nh)
        m_new = jnp.maximum(m_prev + BL, run_max[:, -1] + BL)
        tailw = jnp.exp(BL[:, None] - Bcum + a - m_new[:, None])  # exp(B_L - B_s + a_s - m_new)
        C_new = (C * jnp.exp(m_prev + BL - m_new)[:, :, None, None]
                 + jnp.einsum("bshd,bshe->bhde", kc.astype(jnp.float32) * tailw[..., None],
                              vc.astype(jnp.float32)))
        n_new = (n * jnp.exp(m_prev + BL - m_new)[:, :, None]
                 + (kc.astype(jnp.float32) * tailw[..., None]).sum(1))
        return (C_new, n_new, m_new), h

    from .. import runtime_flags
    # checkpointed chunk body (see mamba2): bwd recomputes intra-chunk mats
    (C, n, m), hs = jax.lax.scan(jax.checkpoint(body), (C0, n0, m0),
                                 (qs, ks, vs, igs, fgs),
                                 unroll=runtime_flags.probe_unroll())
    h = hs.swapaxes(0, 1).reshape(B, nchunk * L, nh, dk)[:, :S]
    return h, (C, n, m)


def _causal_conv(x, conv_w, conv_b, conv_state=None):
    W = conv_w.shape[0]
    pad = (jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
           if conv_state is None else conv_state)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(W))
    out = jax.nn.silu(out + conv_b)
    return out, (xp[:, -(W - 1):] if W > 1 else pad)


def mlstm_block(cfg: ArchConfig, p: Dict, x: Array, *, mesh=None,
                state: Optional[Dict] = None) -> Tuple[Array, Optional[Dict]]:
    xc, di, nh, dk = _mdims(cfg)
    B, S, d = x.shape
    up = x @ p["w_up"]
    inner, z = up[..., :di], up[..., di:]
    conv_state = state["conv"] if state is not None else None
    cx, new_conv = _causal_conv(inner, p["conv_w"], p["conv_b"], conv_state)
    q = (cx @ p["wq"]).reshape(B, S, nh, dk)
    k = (cx @ p["wk"]).reshape(B, S, nh, dk)
    v = (inner @ p["wv"]).reshape(B, S, nh, dk)
    gates = cx @ p["w_if"] + p["b_if"]
    ig, fg = gates[..., :nh], gates[..., nh:]
    mstate = (state["C"].astype(jnp.float32), state["n"].astype(jnp.float32),
              state["m"].astype(jnp.float32)) if state is not None else None
    h, (C, n, m) = _chunked_mlstm(q, k, v, ig, fg, xc.chunk, mstate)
    h = h.reshape(B, S, di).astype(x.dtype)
    h = rms_norm(h, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    h = shard_hint(h, mesh, DP, None, "model")
    out = h @ p["w_down"]
    new_state = None
    if state is not None:
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_template(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    dff = int(d * 4 / 3)
    return {
        "w_x": leaf((d, 4 * d), (None, "model")),           # i,f,z,o input proj
        "r_h": leaf((nh, hd, 4 * hd), (None, None, "model"), scale=0.05),  # block-diag recurrent
        "b": leaf((4 * d,), ("model",), init="zeros"),
        "norm_w": leaf((d,), (None,), init="ones"),
        "w_up1": leaf((d, dff), (None, "model")),
        "w_up2": leaf((d, dff), (None, "model")),
        "w_down": leaf((dff, d), ("model", None)),
    }


def slstm_state_template(cfg: ArchConfig, batch: int) -> Dict:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    sp = (DP, "model", None)
    return {"c": leaf((batch, nh, hd), sp, init="zeros"),
            "n": leaf((batch, nh, hd), sp, init="zeros"),
            "h": leaf((batch, nh, hd), sp, init="zeros"),
            "m": leaf((batch, nh, hd), sp, init="zeros")}


def _slstm_cell(p, nh, hd, carry, xw):
    """One step. carry: (c, n, h, m) each (B, nh, hd); xw: (B, 4d) pre-proj."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hde->bhe", h, p["r_h"].astype(jnp.float32))  # (B,nh,4hd)
    g = xw.reshape(xw.shape[0], nh, 4 * hd).astype(jnp.float32) + rec
    i_t, f_t, z_t, o_t = jnp.split(g, 4, axis=-1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(jax.nn.log_sigmoid(f_t) + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_t)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_block(cfg: ArchConfig, p: Dict, x: Array, *, mesh=None,
                state: Optional[Dict] = None) -> Tuple[Array, Optional[Dict]]:
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xw = x @ p["w_x"] + p["b"]
    if state is None:
        z = jnp.zeros((B, nh, hd), jnp.float32)
        carry0 = (z, z, z, z)
    else:
        carry0 = (state["c"].astype(jnp.float32), state["n"].astype(jnp.float32),
                  state["h"].astype(jnp.float32), state["m"].astype(jnp.float32))

    def step(carry, xw_t):
        new = _slstm_cell(p, nh, hd, carry, xw_t)
        return new, new[2]

    carry, hs = jax.lax.scan(step, carry0, xw.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, p["norm_w"], cfg.norm_eps)
    # post-up-projection GeGLU (paper's sLSTM block, factor 4/3)
    y = (jax.nn.gelu((h @ p["w_up1"]).astype(jnp.float32))
         * (h @ p["w_up2"]).astype(jnp.float32)).astype(x.dtype)
    y = shard_hint(y, mesh, DP, None, "model")
    out = y @ p["w_down"]
    new_state = None
    if state is not None:
        c, n, hh, m = carry
        new_state = {"c": c, "n": n, "h": hh, "m": m}
    return out, new_state
