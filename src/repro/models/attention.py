"""Attention blocks: GQA (dense family) and MLA (DeepSeek family).

Both run on the blocked flash path (``kernels/flash_attention``) for train /
prefill, and a cache-resident decode path for serving.  Heads are
tensor-parallel over the ``model`` mesh axis; the KV cache shards batch over
``data``(+``pod``) and heads over ``model`` (MLA's latent cache has no head
axis — it shards sequence over ``model`` instead, see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.flash_attention.ops import flash_attention
from .common import (DP, DPM, apply_rope, leaf, rms_norm, rope_freqs, shard_hint)

Array = Any


def _attn_batch_spec(cfg: ArchConfig, mesh, batch: int):
    """Head-sharded attention needs n_heads % model_size == 0.  When it
    doesn't divide (smollm: 9 heads on a 16-wide model axis) the baseline
    silently replicates the whole attention computation across the model
    axis; instead, shard the *batch* over every mesh axis (§Perf lever)."""
    from .. import runtime_flags
    if mesh is None or not runtime_flags.OPT["attn_batch_shard"]:
        return DP, "model"
    msize = mesh.shape.get("model", 1)
    total = 1
    for s in mesh.shape.values():
        total *= s
    if cfg.n_heads % msize == 0 or batch % total != 0:
        return DP, "model"
    return DPM, None


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_template(cfg: ArchConfig) -> Dict:
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    t = {
        "wq": leaf((d, H * Dh), (None, "model")),
        "wk": leaf((d, K * Dh), (None, "model")),
        "wv": leaf((d, K * Dh), (None, "model")),
        "wo": leaf((H * Dh, d), ("model", None)),
    }
    if cfg.qkv_bias:
        t["bq"] = leaf((H * Dh,), ("model",), init="zeros")
        t["bk"] = leaf((K * Dh,), ("model",), init="zeros")
        t["bv"] = leaf((K * Dh,), ("model",), init="zeros")
    if cfg.qk_norm:
        t["q_norm"] = leaf((Dh,), (None,), init="ones")
        t["k_norm"] = leaf((Dh,), (None,), init="ones")
    return t


def gqa_cache_template(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    K, Dh = cfg.n_kv_heads, cfg.hdim
    kv_spec = (DP, None, "model", None)
    return {
        "k": leaf((batch, max_len, K, Dh), kv_spec, init="zeros"),
        "v": leaf((batch, max_len, K, Dh), kv_spec, init="zeros"),
    }


def gqa_attention(cfg: ArchConfig, p: Dict, x: Array, positions: Array, *,
                  mesh=None, cache: Optional[Dict] = None,
                  cache_index: Optional[Array] = None,
                  causal: bool = True, kv_x: Optional[Array] = None,
                  use_rope: bool = True) -> Tuple[Array, Optional[Dict]]:
    """x: (B, S, d).  With ``cache`` + ``cache_index``: decode/incremental
    (writes K/V at cache_index, attends the filled prefix).  ``kv_x`` enables
    cross-attention (whisper decoder)."""
    B, S, d = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, Skv, K, Dh)
    v = v.reshape(B, Skv, K, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        cos_q, sin_q = rope_freqs(Dh, cfg.rope_theta, positions)
        q = apply_rope(q, cos_q, sin_q)
        if kv_x is None:
            k = apply_rope(k, cos_q, sin_q) if S == Skv else k
    bspec, hspec = _attn_batch_spec(cfg, mesh, B)
    q = shard_hint(q, mesh, bspec, None, hspec, None)
    k = shard_hint(k, mesh, bspec, None, hspec, None)
    v = shard_hint(v, mesh, bspec, None, hspec, None)

    if cache is not None:
        # decode / chunked prefill: append at cache_index, attend the prefix
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cache_index, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cache_index, 0, 0))
        kv_len = jnp.full((B,), cache_index + S, jnp.int32)
        o = flash_attention(q, kc, vc, causal=False, window=cfg.attn_window,
                            kv_len=kv_len)
        new_cache = {"k": kc, "v": vc}
    else:
        o = flash_attention(q, k, v, causal=causal, window=cfg.attn_window)
        new_cache = None
    o = o.reshape(B, S, H * Dh)
    return o @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek V2/V3)
# ---------------------------------------------------------------------------

def mla_template(cfg: ArchConfig) -> Dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope + m.qk_rope
    return {
        "wdq": leaf((d, m.q_lora), (None, None)),
        "q_norm": leaf((m.q_lora,), (None,), init="ones"),
        "wuq": leaf((m.q_lora, H * qk), (None, "model")),
        "wdkv": leaf((d, m.kv_lora + m.qk_rope), (None, None)),
        "kv_norm": leaf((m.kv_lora,), (None,), init="ones"),
        "wuk": leaf((m.kv_lora, H * m.qk_nope), (None, "model")),
        "wuv": leaf((m.kv_lora, H * m.v_dim), (None, "model")),
        "wo": leaf((H * m.v_dim, d), ("model", None)),
    }


def mla_cache_template(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    m = cfg.mla
    # the latent cache is shared across heads: shard sequence over `model`
    return {
        "ckv": leaf((batch, max_len, m.kv_lora), (DP, "model", None), init="zeros"),
        "krope": leaf((batch, max_len, m.qk_rope), (DP, "model", None), init="zeros"),
    }


def _mla_qkv(cfg: ArchConfig, p: Dict, x: Array, positions: Array):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, S, H, m.qk_nope + m.qk_rope)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
    cos, sin = rope_freqs(m.qk_rope, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    dkv = x @ p["wdkv"]
    ckv = rms_norm(dkv[..., :m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., m.kv_lora:][:, :, None, :], cos, sin)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_attention(cfg: ArchConfig, p: Dict, x: Array, positions: Array, *,
                  mesh=None, cache: Optional[Dict] = None,
                  cache_index: Optional[Array] = None) -> Tuple[Array, Optional[Dict]]:
    """Train/prefill: latent expanded to per-head K/V, blocked flash.
    Decode: *absorbed* attention in the latent space (the MLA trick) — the
    cache stays (kv_lora + qk_rope) wide per token, no per-head expansion."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, ckv, k_rope = _mla_qkv(cfg, p, x, positions)

    if cache is None:
        # expand latent -> per-head keys/values, run blocked flash
        k_nope = (ckv @ p["wuk"]).reshape(B, S, H, m.qk_nope)
        v = (ckv @ p["wuv"]).reshape(B, S, H, m.v_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                      (B, S, H, m.qk_rope))], axis=-1)
        q = shard_hint(q, mesh, DP, None, "model", None)
        k = shard_hint(k, mesh, DP, None, "model", None)
        # pad v's head_dim to match qk for the flash kernel? no: flash allows
        # distinct D only via separate v dim — our scan path requires k/v same
        # trailing dim; pass v separately (it supports (B,S,K,Dv)).
        o = flash_attention(q, k, v, causal=True)
        o = o.reshape(B, S, H * m.v_dim)
        return o @ p["wo"], None

    # ---- absorbed decode ---------------------------------------------------
    ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype),
                                         (0, cache_index, 0))
    kr_c = jax.lax.dynamic_update_slice(cache["krope"], k_rope.astype(cache["krope"].dtype),
                                        (0, cache_index, 0))
    kv_len = cache_index + S
    wuk = p["wuk"].reshape(m.kv_lora, H, m.qk_nope)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))                      # (B,S,H,kv_lora)
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_c.astype(jnp.float32))
              + jnp.einsum("bshp,btp->bhst", q_rope.astype(jnp.float32),
                           kr_c.astype(jnp.float32)))
    scores *= (m.qk_nope + m.qk_rope) ** -0.5
    t_pos = jnp.arange(ckv_c.shape[1])
    valid = t_pos[None, None, None, :] < kv_len
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, ckv_c.astype(jnp.float32))  # latent ctx
    wuv = p["wuv"].reshape(m.kv_lora, H, m.v_dim)
    o = jnp.einsum("bshr,rhv->bshv", ctx, wuv.astype(jnp.float32))
    o = o.reshape(B, S, H * m.v_dim).astype(x.dtype)
    return o @ p["wo"], {"ckv": ckv_c, "krope": kr_c}
