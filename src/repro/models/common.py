"""Shared model substrate: param templates (+ sharding specs), norms, RoPE.

Parameters are declared as :class:`ParamLeaf` templates carrying shape,
dtype, init scale **and** the logical PartitionSpec.  The same template tree
serves both worlds:

* ``materialize(key, tree)``        -> real arrays (CPU smoke tests / examples)
* ``abstractify(tree, mesh)``       -> ShapeDtypeStructs with NamedSharding
                                        (the multi-pod dry-run; no allocation)

Sharding convention (DESIGN.md §6): ``"model"`` is the tensor-parallel axis,
``"data"`` (and ``"pod"``) the batch axes.  Specs below name axes logically;
``dp`` in a spec means "all batch axes" and is resolved against the mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

Array = Any

DP = "__dp__"    # placeholder resolved to ("pod","data") / ("data",) per mesh
DPM = "__dpm__"  # ALL mesh axes (batch + model) — batch-sharded attention


def resolve_spec(spec: Tuple, mesh) -> P:
    """Replace the DP/DPM placeholders with concrete mesh axes."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    all_axes = batch_axes + tuple(a for a in ("model",) if a in mesh.axis_names)
    out = []
    for s in spec:
        if s == DP:
            out.append(batch_axes if len(batch_axes) > 1
                       else (batch_axes[0] if batch_axes else None))
        elif s == DPM:
            out.append(all_axes if len(all_axes) > 1 else (all_axes[0] if all_axes else None))
        else:
            out.append(s)
    return P(*out)


@dataclasses.dataclass(frozen=True)
class ParamLeaf:
    shape: Tuple[int, ...]
    spec: Tuple  # logical PartitionSpec entries (None / 'model' / DP)
    init: str = "normal"     # normal | zeros | ones
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)
    dtype: str = "bfloat16"

    def fan_in(self) -> int:
        return self.shape[-2] if len(self.shape) >= 2 else 1


def leaf(shape, spec=None, init="normal", scale=None, dtype="bfloat16") -> ParamLeaf:
    spec = tuple(spec) if spec is not None else (None,) * len(shape)
    assert len(spec) == len(shape), (shape, spec)
    return ParamLeaf(tuple(int(s) for s in shape), spec, init, scale, dtype)


def is_leaf(x) -> bool:
    return isinstance(x, ParamLeaf)


def stack_templates(tree, n: int):
    """Add a leading layer axis (replicated) to every leaf — for scan."""
    return jax.tree.map(
        lambda l: ParamLeaf((n,) + l.shape, (None,) + l.spec, l.init, l.scale, l.dtype),
        tree, is_leaf=is_leaf)


def materialize(key, tree, dtype_override: Optional[str] = None):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, l in zip(keys, leaves):
        dt = jnp.dtype(dtype_override or l.dtype)
        if l.init == "zeros":
            out.append(jnp.zeros(l.shape, dt))
        elif l.init == "ones":
            out.append(jnp.ones(l.shape, dt))
        elif l.init == "full":
            out.append(jnp.full(l.shape, l.scale, dt))
        else:
            scale = l.scale if l.scale is not None else 1.0 / np.sqrt(max(l.fan_in(), 1))
            out.append((jax.random.normal(k, l.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim (e.g. 3 kv heads on
    a 16-wide model axis) — the leaf falls back to replication on that dim."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(entry if dim % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def abstractify(tree, mesh, dtype_override: Optional[str] = None):
    """ShapeDtypeStruct pytree with NamedSharding — zero allocation."""
    def _one(l: ParamLeaf):
        spec = sanitize_spec(resolve_spec(l.spec, mesh), l.shape, mesh)
        sh = NamedSharding(mesh, spec)
        return jax.ShapeDtypeStruct(l.shape, jnp.dtype(dtype_override or l.dtype), sharding=sh)
    return jax.tree.map(_one, tree, is_leaf=is_leaf)


def spec_tree(tree, mesh):
    return jax.tree.map(lambda l: resolve_spec(l.spec, mesh), tree, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    mu = h.mean(-1, keepdims=True)
    var = ((h - mu) ** 2).mean(-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float, positions):
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (..., S, D/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def apply_mrope(x, positions, sections: Tuple[int, int, int], theta: float):
    """Multimodal RoPE (Qwen2-VL): head_dim/2 freq slots split into (t, h, w)
    sections, each rotated by its own position stream.  The modality frontend
    is a stub, so all three streams carry the text position (structurally
    faithful; degenerates to 1-D RoPE exactly as it does for text tokens)."""
    D = x.shape[-1]
    cos, sin = rope_freqs(D, theta, positions)  # (..., S, D/2)
    # sections indexes the D/2 frequency slots: build per-slot position choice
    # (all streams identical under the text-only stub)
    return apply_rope(x, cos, sin)


def sinusoidal_positions(n: int, d: int):
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


def shard_hint(x, mesh, *spec):
    """with_sharding_constraint against the logical spec (DP resolved;
    indivisible axes dropped)."""
    if mesh is None:
        return x
    s = sanitize_spec(resolve_spec(tuple(spec), mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))


def cross_entropy(logits, labels, *, z_loss: float = 1e-4):
    """Mean token CE (fp32) + z-loss for logit drift control."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                               axis=-1)[..., 0]
    ce = lse - gold
    return (ce + z_loss * lse ** 2).mean()
