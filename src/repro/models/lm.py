"""Full-model assembly for the 10 assigned architectures.

One template/forward/decode implementation per family:

  dense | vlm     — pre-norm GQA transformer (qk-norm / bias / parallel-block
                    / M-RoPE options); vlm prepends stub patch embeddings.
  moe             — DeepSeek: MLA attention + (first_dense dense layers,
                    then expert-parallel MoE layers).
  audio           — Whisper enc-dec; conv/mel frontend is a stub (frame
                    embeddings arrive via the batch).
  ssm             — xLSTM super-blocks (slstm_every-1 mLSTM + 1 sLSTM).
  hybrid          — Zamba2 super-blocks (shared_attn_every Mamba2 blocks +
                    one weight-shared attention/MLP block).

Layers are scanned with stacked parameters (bounded HLO for 61–80-layer
models) and rematerialized per block for training memory.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .. import runtime_flags
from . import attention as A
from . import mamba2 as M2
from . import moe as MOE
from . import xlstm as XL
from .common import (DP, cross_entropy, layer_norm, leaf, rms_norm,
                     shard_hint, sinusoidal_positions, stack_templates)

Array = Any
VLM_PATCHES = 256  # stub vision prefix length for the vlm family


def scan_blocks(name: str, fn, carry, xs):
    """``lax.scan`` over a stacked layer pytree — or, in cost-probe mode, a
    python loop over the first k layers (so cost_analysis sees the FLOPs)."""
    stacks = runtime_flags.probe_stacks()
    if stacks is None:
        return jax.lax.scan(fn, carry, xs)
    k = stacks.get(name, 1)
    ys = []
    for i in range(k):
        carry, y = fn(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)  # match scan's stacking
    else:
        ys = None
    return carry, ys


def layer_stack_sizes(cfg: ArchConfig) -> Dict[str, int]:
    """Real trip count of each layer stack — the dry-run extrapolates probe
    costs with these."""
    if cfg.family in ("dense", "vlm"):
        return {"layers": cfg.n_layers}
    if cfg.family == "moe":
        d = {"layers": cfg.n_layers - cfg.moe.first_dense}
        if cfg.moe.first_dense:
            d["dense_layers"] = cfg.moe.first_dense
        return d
    if cfg.family == "audio":
        return {"layers": cfg.n_layers, "enc_layers": cfg.n_encoder_layers}
    if cfg.family == "ssm":
        return {"layers": cfg.n_layers // cfg.xlstm.slstm_every}
    if cfg.family == "hybrid":
        return {"layers": cfg.n_layers // cfg.shared_attn_every}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# templates
# ---------------------------------------------------------------------------

def _dense_block_template(cfg: ArchConfig) -> Dict:
    return {
        "ln1": leaf((cfg.d_model,), (None,), init="ones"),
        "attn": A.gqa_template(cfg),
        "ln2": leaf((cfg.d_model,), (None,), init="ones"),
        "ffn": MOE.dense_ffn_template(cfg),
    }


def _mla_block_template(cfg: ArchConfig, kind: str) -> Dict:
    t = {
        "ln1": leaf((cfg.d_model,), (None,), init="ones"),
        "attn": A.mla_template(cfg),
        "ln2": leaf((cfg.d_model,), (None,), init="ones"),
    }
    if kind == "moe":
        t["moe"] = MOE.moe_template(cfg)
    else:
        t["ffn"] = MOE.dense_ffn_template(cfg, cfg.moe.d_ff_dense)
    return t


def _whisper_block_template(cfg: ArchConfig, cross: bool) -> Dict:
    d = cfg.d_model
    ln = lambda: {"w": leaf((d,), (None,), init="ones"),
                  "b": leaf((d,), (None,), init="zeros")}
    t = {"ln1": ln(), "attn": A.gqa_template(cfg), "ln3": ln(),
         "ffn": MOE.gelu_ffn_template(cfg)}
    if cross:
        t["ln2"] = ln()
        t["xattn"] = A.gqa_template(cfg)
    return t


def _xlstm_super_template(cfg: ArchConfig) -> Dict:
    k = cfg.xlstm.slstm_every
    return {
        "mlstm": stack_templates({"ln": leaf((cfg.d_model,), (None,), init="ones"),
                                  "cell": XL.mlstm_template(cfg)}, k - 1),
        "slstm": {"ln": leaf((cfg.d_model,), (None,), init="ones"),
                  "cell": XL.slstm_template(cfg)},
    }


def _zamba_super_template(cfg: ArchConfig) -> Dict:
    return {
        "mamba": stack_templates({"ln": leaf((cfg.d_model,), (None,), init="ones"),
                                  "cell": M2.mamba2_template(cfg)},
                                 cfg.shared_attn_every),
    }


def model_template(cfg: ArchConfig) -> Dict:
    d, V = cfg.d_model, cfg.vocab
    t: Dict[str, Any] = {"embed": leaf((V, d), ("model", None), scale=0.02)}
    if not cfg.tie_embeddings:
        t["head"] = leaf((d, V), (None, "model"), scale=0.02)
    t["ln_f"] = leaf((d,), (None,), init="ones")

    if cfg.family in ("dense", "vlm"):
        t["layers"] = stack_templates(_dense_block_template(cfg), cfg.n_layers)
    elif cfg.family == "moe":
        mo = cfg.moe
        if mo.first_dense:
            t["dense_layers"] = stack_templates(_mla_block_template(cfg, "dense"),
                                                mo.first_dense)
        t["layers"] = stack_templates(_mla_block_template(cfg, "moe"),
                                      cfg.n_layers - mo.first_dense)
    elif cfg.family == "audio":
        t["enc_layers"] = stack_templates(_whisper_block_template(cfg, cross=False),
                                          cfg.n_encoder_layers)
        t["layers"] = stack_templates(_whisper_block_template(cfg, cross=True),
                                      cfg.n_layers)
        t["ln_enc"] = {"w": leaf((d,), (None,), init="ones"),
                       "b": leaf((d,), (None,), init="zeros")}
        t["ln_f"] = {"w": leaf((d,), (None,), init="ones"),
                     "b": leaf((d,), (None,), init="zeros")}
    elif cfg.family == "ssm":
        n_super = cfg.n_layers // cfg.xlstm.slstm_every
        t["layers"] = stack_templates(_xlstm_super_template(cfg), n_super)
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.shared_attn_every
        t["layers"] = stack_templates(_zamba_super_template(cfg), n_super)
        t["shared"] = {"ln1": leaf((d,), (None,), init="ones"),
                       "attn": A.gqa_template(cfg),
                       "ln2": leaf((d,), (None,), init="ones"),
                       "ffn": MOE.dense_ffn_template(cfg)}
    else:
        raise ValueError(cfg.family)
    return t


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _logits(cfg, params, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["head"]


def _embed(cfg, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def forward(cfg: ArchConfig, params: Dict, batch: Dict, *, mesh=None) -> Array:
    """Returns logits (B, S, vocab) for train/prefill."""
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    x = _embed(cfg, params, tokens)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    x = shard_hint(x, mesh, DP, None, None)
    S = x.shape[1]
    positions = jnp.arange(S)

    if cfg.family in ("dense", "vlm"):
        def block(h, p):
            if cfg.parallel_block:  # command-r: attn and FFN in parallel
                hn = rms_norm(h, p["ln1"], cfg.norm_eps)
                ao, _ = A.gqa_attention(cfg, p["attn"], hn, positions, mesh=mesh)
                return h + ao + MOE.dense_ffn(p["ffn"], hn), None
            ao, _ = A.gqa_attention(cfg, p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                                    positions, mesh=mesh)
            h = h + ao
            return h + MOE.dense_ffn(p["ffn"], rms_norm(h, p["ln2"], cfg.norm_eps)), None

        x, _ = scan_blocks("layers", jax.checkpoint(block), x, params["layers"])
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = _logits(cfg, params, x)
        return logits[:, -S_tok:] if cfg.family == "vlm" else logits

    if cfg.family == "moe":
        aux_total = jnp.zeros((), jnp.float32)

        def mla_block(kind):
            def block(carry, p):
                h, aux = carry
                ao, _ = A.mla_attention(cfg, p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                                        positions, mesh=mesh)
                h = h + ao
                hn = rms_norm(h, p["ln2"], cfg.norm_eps)
                if kind == "moe":
                    y, a = MOE.moe_layer(cfg, p["moe"], hn, mesh=mesh)
                    return (h + y, aux + a), None
                return (h + MOE.dense_ffn(p["ffn"], hn), aux), None
            return block

        carry = (x, aux_total)
        if cfg.moe.first_dense:
            carry, _ = scan_blocks("dense_layers", jax.checkpoint(mla_block("dense")), carry,
                                   params["dense_layers"])
        carry, _ = scan_blocks("layers", jax.checkpoint(mla_block("moe")), carry, params["layers"])
        x, aux_total = carry
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = _logits(cfg, params, x)
        return logits, aux_total

    if cfg.family == "audio":
        enc = batch["frames"].astype(x.dtype)           # (B, T_enc, d) stub frontend
        enc = enc + sinusoidal_positions(enc.shape[1], cfg.d_model).astype(enc.dtype)

        def enc_block(h, p):
            hn = layer_norm(h, p["ln1"]["w"], p["ln1"]["b"], cfg.norm_eps)
            ao, _ = A.gqa_attention(cfg, p["attn"], hn, jnp.arange(h.shape[1]),
                                    mesh=mesh, causal=False, use_rope=False)
            h = h + ao
            hn = layer_norm(h, p["ln3"]["w"], p["ln3"]["b"], cfg.norm_eps)
            return h + MOE.gelu_ffn(p["ffn"], hn), None

        enc, _ = scan_blocks("enc_layers", jax.checkpoint(enc_block), enc, params["enc_layers"])
        enc = layer_norm(enc, params["ln_enc"]["w"], params["ln_enc"]["b"], cfg.norm_eps)

        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)

        def dec_block(h, p):
            hn = layer_norm(h, p["ln1"]["w"], p["ln1"]["b"], cfg.norm_eps)
            ao, _ = A.gqa_attention(cfg, p["attn"], hn, positions, mesh=mesh,
                                    causal=True, use_rope=False)
            h = h + ao
            hn = layer_norm(h, p["ln2"]["w"], p["ln2"]["b"], cfg.norm_eps)
            co, _ = A.gqa_attention(cfg, p["xattn"], hn, positions, mesh=mesh,
                                    causal=False, kv_x=enc, use_rope=False)
            h = h + co
            hn = layer_norm(h, p["ln3"]["w"], p["ln3"]["b"], cfg.norm_eps)
            return h + MOE.gelu_ffn(p["ffn"], hn), None

        x, _ = scan_blocks("layers", jax.checkpoint(dec_block), x, params["layers"])
        x = layer_norm(x, params["ln_f"]["w"], params["ln_f"]["b"], cfg.norm_eps)
        return _logits(cfg, params, x)

    if cfg.family == "ssm":
        def super_block(h, p):
            def sub(h, pp):
                y, _ = XL.mlstm_block(cfg, pp["cell"],
                                      rms_norm(h, pp["ln"], cfg.norm_eps), mesh=mesh)
                return h + y, None
            h, _ = jax.lax.scan(sub, h, p["mlstm"])
            y, _ = XL.slstm_block(cfg, p["slstm"]["cell"],
                                  rms_norm(h, p["slstm"]["ln"], cfg.norm_eps), mesh=mesh)
            return h + y, None

        x, _ = scan_blocks("layers", jax.checkpoint(super_block), x, params["layers"])
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _logits(cfg, params, x)

    if cfg.family == "hybrid":
        shared = params["shared"]

        def super_block(h, p):
            def sub(h, pp):
                y, _ = M2.mamba2_block(cfg, pp["cell"],
                                       rms_norm(h, pp["ln"], cfg.norm_eps), mesh=mesh)
                return h + y, None
            h, _ = jax.lax.scan(sub, h, p["mamba"])
            hn = rms_norm(h, shared["ln1"], cfg.norm_eps)
            ao, _ = A.gqa_attention(cfg, shared["attn"], hn, positions, mesh=mesh)
            h = h + ao
            h = h + MOE.dense_ffn(shared["ffn"], rms_norm(h, shared["ln2"], cfg.norm_eps))
            return h, None

        x, _ = scan_blocks("layers", jax.checkpoint(super_block), x, params["layers"])
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _logits(cfg, params, x)

    raise ValueError(cfg.family)


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict, *, mesh=None) -> Array:
    out = forward(cfg, params, batch, mesh=mesh)
    aux = 0.0
    if cfg.family == "moe":
        out, aux_total = out
        if not cfg.moe.aux_free_bias:
            aux = 1e-3 * aux_total
    logits, labels = out[:, :-1], batch["tokens"][:, 1:]
    if cfg.family == "audio":
        labels = batch["tokens"][:, 1:]
    return cross_entropy(logits, labels) + aux


# ---------------------------------------------------------------------------
# serving: cache templates + decode step
# ---------------------------------------------------------------------------

def cache_template(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    if cfg.family in ("dense", "vlm"):
        return {"layers": stack_templates(A.gqa_cache_template(cfg, batch, max_len),
                                          cfg.n_layers)}
    if cfg.family == "moe":
        t = {"layers": stack_templates(A.mla_cache_template(cfg, batch, max_len),
                                       cfg.n_layers - cfg.moe.first_dense)}
        if cfg.moe.first_dense:
            t["dense_layers"] = stack_templates(
                A.mla_cache_template(cfg, batch, max_len), cfg.moe.first_dense)
        return t
    if cfg.family == "audio":
        return {
            "layers": stack_templates(A.gqa_cache_template(cfg, batch, max_len),
                                      cfg.n_layers),
            # cross-attention K/V precomputed from the encoder output
            "cross": stack_templates(A.gqa_cache_template(cfg, batch, cfg.enc_len),
                                     cfg.n_layers),
        }
    if cfg.family == "ssm":
        n_super = cfg.n_layers // cfg.xlstm.slstm_every
        return {"layers": stack_templates({
            "mlstm": stack_templates(XL.mlstm_state_template(cfg, batch),
                                     cfg.xlstm.slstm_every - 1),
            "slstm": XL.slstm_state_template(cfg, batch),
        }, n_super)}
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.shared_attn_every
        win = min(cfg.attn_window or max_len, max_len)
        return {
            "layers": stack_templates(
                {"mamba": stack_templates(M2.mamba2_state_template(cfg, batch),
                                          cfg.shared_attn_every)}, n_super),
            # weight-shared attention block: one *cache per application site*
            "shared": stack_templates(A.gqa_cache_template(cfg, batch, win), n_super),
        }
    raise ValueError(cfg.family)


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict, tokens: Array,
                pos: Array, *, mesh=None) -> Tuple[Array, Dict]:
    """One decode step. tokens: (B, 1); pos: scalar index into the cache."""
    B = tokens.shape[0]
    x = _embed(cfg, params, tokens)
    positions = pos + jnp.arange(tokens.shape[1])

    if cfg.family in ("dense", "vlm"):
        def block(h, pc):
            p, c = pc
            ao, c2 = A.gqa_attention(cfg, p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                                     positions, mesh=mesh, cache=c, cache_index=pos)
            if cfg.parallel_block:
                hn = rms_norm(h, p["ln1"], cfg.norm_eps)
                h = h + ao + MOE.dense_ffn(p["ffn"], hn)
            else:
                h = h + ao
                h = h + MOE.dense_ffn(p["ffn"], rms_norm(h, p["ln2"], cfg.norm_eps))
            return h, c2

        x, new_cache = scan_blocks("layers", block, x, (params["layers"], cache["layers"]))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _logits(cfg, params, x), {"layers": new_cache}

    if cfg.family == "moe":
        def mk(kind):
            def block(h, pc):
                p, c = pc
                ao, c2 = A.mla_attention(cfg, p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                                         positions, mesh=mesh, cache=c, cache_index=pos)
                h = h + ao
                hn = rms_norm(h, p["ln2"], cfg.norm_eps)
                if kind == "moe":
                    y, _ = MOE.moe_layer(cfg, p["moe"], hn, mesh=mesh, token_chunks=1)
                    h = h + y
                else:
                    h = h + MOE.dense_ffn(p["ffn"], hn)
                return h, c2
            return block

        new_cache = {}
        if cfg.moe.first_dense:
            x, nc = scan_blocks("dense_layers", mk("dense"), x,
                                (params["dense_layers"], cache["dense_layers"]))
            new_cache["dense_layers"] = nc
        x, nc = scan_blocks("layers", mk("moe"), x, (params["layers"], cache["layers"]))
        new_cache["layers"] = nc
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _logits(cfg, params, x), new_cache

    if cfg.family == "audio":
        max_len = int(cache["layers"]["k"].shape[2])
        x = x + sinusoidal_positions(max_len, cfg.d_model)[pos][None, None, :].astype(x.dtype)

        def block(h, pc):
            p, c_self, c_cross = pc
            hn = layer_norm(h, p["ln1"]["w"], p["ln1"]["b"], cfg.norm_eps)
            ao, c2 = A.gqa_attention(cfg, p["attn"], hn, positions, mesh=mesh,
                                     cache=c_self, cache_index=pos, use_rope=False)
            h = h + ao
            hn = layer_norm(h, p["ln2"]["w"], p["ln2"]["b"], cfg.norm_eps)
            # cross-attn against the precomputed encoder K/V
            from ..kernels.flash_attention.ops import flash_attention
            q = (hn @ p["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hdim)
            co = flash_attention(q, c_cross["k"], c_cross["v"], causal=False)
            h = h + co.reshape(B, 1, -1) @ p["xattn"]["wo"]
            hn = layer_norm(h, p["ln3"]["w"], p["ln3"]["b"], cfg.norm_eps)
            return h + MOE.gelu_ffn(p["ffn"], hn), c2

        x, nc = scan_blocks("layers", block, x, (params["layers"], cache["layers"], cache["cross"]))
        x = layer_norm(x, params["ln_f"]["w"], params["ln_f"]["b"], cfg.norm_eps)
        return _logits(cfg, params, x), {"layers": nc, "cross": cache["cross"]}

    if cfg.family == "ssm":
        def super_block(h, pc):
            p, c = pc

            def sub(h, pcc):
                pp, cc = pcc
                y, c2 = XL.mlstm_block(cfg, pp["cell"], rms_norm(h, pp["ln"], cfg.norm_eps),
                                       mesh=mesh, state=cc)
                return h + y, c2
            h, nc_m = jax.lax.scan(sub, h, (p["mlstm"], c["mlstm"]))
            y, nc_s = XL.slstm_block(cfg, p["slstm"]["cell"],
                                     rms_norm(h, p["slstm"]["ln"], cfg.norm_eps),
                                     mesh=mesh, state=c["slstm"])
            return h + y, {"mlstm": nc_m, "slstm": nc_s}

        x, nc = scan_blocks("layers", super_block, x, (params["layers"], cache["layers"]))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _logits(cfg, params, x), {"layers": nc}

    if cfg.family == "hybrid":
        shared = params["shared"]
        win = cache["shared"]["k"].shape[2]
        # position within the ring buffer of the sliding-window cache
        wpos = jnp.mod(pos, win)

        def super_block(h, pc):
            p, c_m, c_a = pc

            def sub(h, pcc):
                pp, cc = pcc
                y, c2 = M2.mamba2_block(cfg, pp["cell"], rms_norm(h, pp["ln"], cfg.norm_eps),
                                        mesh=mesh, state=cc)
                return h + y, c2
            h, nc_m = jax.lax.scan(sub, h, (p["mamba"], c_m["mamba"]))
            hn = rms_norm(h, shared["ln1"], cfg.norm_eps)
            ao, c_a2 = A.gqa_attention(cfg, shared["attn"], hn, positions, mesh=mesh,
                                       cache=c_a, cache_index=wpos)
            h = h + ao
            h = h + MOE.dense_ffn(shared["ffn"], rms_norm(h, shared["ln2"], cfg.norm_eps))
            return h, ({"mamba": nc_m}, c_a2)

        x, (nc_m, nc_a) = scan_blocks("layers", super_block, x,
                                      (params["layers"], cache["layers"], cache["shared"]))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _logits(cfg, params, x), {"layers": nc_m, "shared": nc_a}

    raise ValueError(cfg.family)
