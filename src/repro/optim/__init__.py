from .adamw import AdamWState, adamw_init, adamw_update, adamw_state_template  # noqa: F401
from .schedule import wsd_schedule  # noqa: F401
