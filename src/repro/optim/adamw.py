"""AdamW with global-norm clipping and optional compressed moments.

``state_bits=8`` stores the first moment as int8 with a per-row fp32 scale
(m is zero-mean; linear quantization is benign) and the second moment as
bfloat16 (v spans many orders of magnitude; bf16's exponent keeps the
relative error ~0.4% where a linear int8 grid would flush small entries to
zero and blow up 1/sqrt(v)).  10 B/param -> 3.1 B/param of optimizer state —
this is what makes deepseek-v3-671b training fit a 256-chip pod (DESIGN.md
§6 / EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models.common import ParamLeaf, is_leaf, leaf

Array = Any


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any
    m_scale: Any  # None (fp32 mode) or per-row scales pytree
    v_scale: Any


def _q8(x):
    """int8 quantize along the last axis; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    return jnp.round(x / scale).astype(jnp.int8), scale.astype(jnp.float32)


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def adamw_state_template(param_tree, state_bits: int = 32):
    """Template tree (ParamLeaf) for m/v (+ scales) mirroring param specs.

    With ``runtime_flags.OPT["zero1_opt_state"]``, each moment additionally
    shards its largest unsharded dim over the data axes (ZeRO-1): GSPMD then
    turns the gradient all-reduce into reduce-scatter + a param all-gather,
    and the resident optimizer state shrinks by the data-axis size.
    """
    from .. import runtime_flags
    from ..models.common import DP
    zero1 = runtime_flags.OPT["zero1_opt_state"]

    def _zero1_spec(l: ParamLeaf):
        if not zero1 or any(s == DP for s in l.spec):
            return l.spec  # already data-sharded (FSDP params)
        cand = [i for i, s in enumerate(l.spec) if s is None and l.shape[i] > 1]
        if not cand:
            return l.spec
        i = max(cand, key=lambda j: l.shape[j])
        return l.spec[:i] + (DP,) + l.spec[i + 1:]

    def moment(dt):
        def f(l: ParamLeaf):
            return ParamLeaf(l.shape, _zero1_spec(l), "zeros", None, dt)
        return f

    def scale(l: ParamLeaf):
        return ParamLeaf(l.shape[:-1] + (1,), l.spec[:-1] + (None,), "zeros", None, "float32")

    m = jax.tree.map(moment("int8" if state_bits == 8 else "float32"),
                     param_tree, is_leaf=is_leaf)
    v = jax.tree.map(moment("bfloat16" if state_bits == 8 else "float32"),
                     param_tree, is_leaf=is_leaf)
    if state_bits == 8:
        ms = jax.tree.map(scale, param_tree, is_leaf=is_leaf)
        vs = None
    else:
        ms = vs = None
    return {"step": ParamLeaf((), (), "zeros", None, "int32"),
            "m": m, "v": v, "m_scale": ms, "v_scale": vs}


def adamw_init(params, state_bits: int = 32) -> AdamWState:
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8 if state_bits == 8
                                         else jnp.float32), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16 if state_bits == 8
                                         else jnp.float32), params)
    if state_bits == 8:
        ms = jax.tree.map(lambda p: jnp.zeros(p.shape[:-1] + (1,), jnp.float32), params)
    else:
        ms = None
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v, m_scale=ms, v_scale=None)


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update_impl(params, state: AdamWState, grads, lr, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0,
                 state_bits: int = 32, update_shardings=None):
    """``update_shardings`` (pytree of NamedSharding matching params): pin
    the fp32 update math to the ZeRO-1 layout — GSPMD then reduce-scatters
    the grads into the sharded moments and all-gathers only the final bf16
    params, instead of materializing fp32 intermediates at the replicated
    param layout."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, msc, vsc, sh):
        pin = (lambda x: jax.lax.with_sharding_constraint(x, sh)) if sh is not None \
            else (lambda x: x)
        g = pin(g.astype(jnp.float32) * scale)
        m_f = _dq8(m, msc) if state_bits == 8 else m
        v_f = v.astype(jnp.float32) if state_bits == 8 else v
        m_f = pin(b1 * m_f + (1 - b1) * g)
        v_f = pin(b2 * v_f + (1 - b2) * g * g)
        upd_ = pin((m_f / bc1) / (jnp.sqrt(v_f / bc2) + eps)
                   + weight_decay * pin(p.astype(jnp.float32)))
        p2 = (pin(p.astype(jnp.float32) - lr * upd_)).astype(p.dtype)
        if state_bits == 8:
            mq, ms2 = _q8(m_f)
            return p2, mq, v_f.astype(jnp.bfloat16), ms2, None
        return p2, m_f, v_f, None, None

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_ms = tdef.flatten_up_to(state.m_scale) if state_bits == 8 else [None] * len(flat_p)
    flat_vs = [None] * len(flat_p)
    flat_sh = (tdef.flatten_up_to(update_shardings) if update_shardings is not None
               else [None] * len(flat_p))
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_ms,
                                      flat_vs, flat_sh)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_ms = tdef.unflatten([o[3] for o in out]) if state_bits == 8 else None
    return new_p, AdamWState(step=step, m=new_m, v=new_v, m_scale=new_ms, v_scale=None), gnorm


#: jitted entry point (no sharding pins) — train steps that pin the update
#: layout call :func:`adamw_update_impl` inside their own jit.
adamw_update = functools.partial(jax.jit, static_argnames=(
    "b1", "b2", "eps", "weight_decay", "clip_norm", "state_bits"),
    donate_argnums=(0, 1))(adamw_update_impl)
