"""Warmup-stable-decay LR schedule (production default)."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, *, peak_lr: float = 3e-4, warmup: int = 200,
                 total: int = 10_000, decay_frac: float = 0.2,
                 min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
    decay_start = total * (1 - decay_frac)
    frac = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
    decay = peak_lr * (1 - (1 - min_ratio) * frac)
    return jnp.where(step < decay_start, warm, jnp.minimum(warm, decay))
