"""Fault-tolerant sharded checkpointing.

Layout (one directory per step):

    <root>/step_000120.tmp/        # written first
        shard_00000.npz            # this host's leaf shards
        manifest.json              # tree structure, shapes, dtypes, mesh
    <root>/step_000120/            # atomic rename = commit

Properties needed at 1000+ nodes:
  * **atomic commit** — a crash mid-write never corrupts the latest
    checkpoint (readers only see renamed directories);
  * **per-host shards** — each host writes only the leaf shards it owns
    (addressable shards of jax.Arrays); no cross-host traffic;
  * **resume** — ``latest_step`` + ``restore_checkpoint`` rebuild the pytree
    with any *new* mesh: restore reads the full logical arrays and reshards,
    which is what makes elastic re-mesh after a node failure work
    (``distributed/fault.py``);
  * **async save** — serialization happens on a background thread, the train
    loop only blocks on the previous save (double-buffered);
  * **keep-K GC**.

On this single-host container "per-host" degenerates to one shard file; the
code paths are the same.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..jax_compat import tree_flatten_with_path

_SEP = "/"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                        for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(root: str, step: int, tree, *, host_id: int = 0,
                    keep: int = 3) -> pathlib.Path:
    rootp = pathlib.Path(root)
    tmp = rootp / f"step_{step:08d}.tmp"
    final = rootp / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        arrays[key.replace("/", "__")] = arr
        manifest["leaves"].append({"key": key, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    np.savez(tmp / f"shard_{host_id:05d}.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(rootp, keep)
    return final


def latest_step(root: str) -> Optional[int]:
    rootp = pathlib.Path(root)
    if not rootp.exists():
        return None
    steps = [int(m.group(1)) for p in rootp.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore_checkpoint(root: str, step: int, like_tree, *, mesh=None,
                       shardings=None):
    """Rebuild ``like_tree``-structured arrays from the checkpoint; reshard
    onto ``shardings`` (same structure) if given — any mesh works (elastic)."""
    final = pathlib.Path(root) / f"step_{step:08d}"
    data: Dict[str, np.ndarray] = {}
    for f in sorted(final.glob("shard_*.npz")):
        with np.load(f) as z:
            data.update({k: z[k] for k in z.files})
    leaves = _flatten_with_paths(like_tree)
    shard_leaves = _flatten_with_paths(shardings)[0:] if shardings is not None else None
    out = []
    for i, (key, leaf) in enumerate(leaves):
        arr = data[key.replace("/", "__")]
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i][1])
        out.append(arr)
    treedef = jax.tree.structure(like_tree)
    return jax.tree.unflatten(treedef, out)


def _gc(rootp: pathlib.Path, keep: int):
    steps = sorted(int(m.group(1)) for p in rootp.iterdir()
                   if (m := re.fullmatch(r"step_(\d+)", p.name)))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(rootp / f"step_{s:08d}", ignore_errors=True)


class CheckpointManager:
    """Async double-buffered checkpointing with resume."""

    def __init__(self, root: str, keep: int = 3, every: int = 100):
        self.root = root
        self.keep = keep
        self.every = every
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, *, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return
        self.wait()  # block on the previous save only
        host_tree = jax.device_get(tree)  # snapshot before training continues
        self._thread = threading.Thread(
            target=save_checkpoint, args=(self.root, step, host_tree),
            kwargs={"keep": self.keep}, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like_tree, *, shardings=None):
        s = latest_step(self.root)
        if s is None:
            return None, None
        return s, restore_checkpoint(self.root, s, like_tree, shardings=shardings)
