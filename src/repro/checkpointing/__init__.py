from .ckpt import CheckpointManager, save_checkpoint, restore_checkpoint, latest_step  # noqa: F401
