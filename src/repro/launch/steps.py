"""Step factories: train_step / prefill_step / decode_step closures for one
(arch, mesh) pair.  Shared by the real trainer (train.py), the dry-run
(dryrun.py) and the smoke tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import lm
from ..models.common import abstractify, materialize, stack_templates
from ..optim.adamw import AdamWState, adamw_update, adamw_update_impl
from ..optim.schedule import wsd_schedule

Array = Any


def opt_state_bits(cfg: ArchConfig) -> int:
    """8-bit moments for the huge-expert models (fits a 256-chip pod)."""
    return 8 if (cfg.moe and cfg.param_count() > 1e11) else 32


def maybe_fsdp(tmpl):
    """OPT["fsdp_params"]: also shard every param's largest unsharded dim
    (size >= 256, so layer-stack dims are skipped) over the data axes.
    GSPMD inserts the per-layer all-gather in forward and produces grads
    reduce-scattered — ZeRO-3 semantics from sharding specs alone."""
    from .. import runtime_flags
    from ..models.common import DP, ParamLeaf, is_leaf

    if not runtime_flags.OPT["fsdp_params"]:
        return tmpl

    def f(l: ParamLeaf):
        if any(s == DP for s in l.spec):
            return l  # already data-sharded (e.g. expert-parallel weights)
        cand = [i for i, s in enumerate(l.spec) if s is None and l.shape[i] >= 256]
        if not cand:
            return l
        i = max(cand, key=lambda j: l.shape[j])
        return ParamLeaf(l.shape, l.spec[:i] + (DP,) + l.spec[i + 1:],
                         l.init, l.scale, l.dtype)

    return jax.tree.map(f, tmpl, is_leaf=is_leaf)


def make_train_step(cfg: ArchConfig, mesh, *, peak_lr: float = 3e-4,
                    total_steps: int = 10_000, microbatches: int = 1,
                    accum_dtype=jnp.float32):
    """``microbatches > 1``: gradient accumulation over batch splits — the
    activation working set shrinks ~linearly while the optimizer math is
    unchanged (§Perf memory lever; accumulate in bf16 for the MoE giants
    where even the fp32 accumulator would not fit)."""
    bits = opt_state_bits(cfg)

    def train_step(params, opt_state: AdamWState, batch):
        def loss(p, b):
            return lm.loss_fn(cfg, p, b, mesh=mesh)

        if microbatches == 1:
            lval, grads = jax.value_and_grad(loss)(params, batch)
        else:
            from .. import runtime_flags
            from ..models.common import is_leaf as _is_leaf
            from ..optim.adamw import adamw_state_template

            constrain = lambda tree: tree
            if runtime_flags.OPT["zero1_opt_state"]:
                # shard the gradient accumulator like the (ZeRO-1) moments:
                # each microbatch's grad lands via reduce-scatter, and the
                # resident accumulator shrinks by the data-axis size
                from ..models.common import abstractify
                mom = adamw_state_template(maybe_fsdp(lm.model_template(cfg)))["m"]
                accum_sh = jax.tree.map(lambda a: a.sharding,
                                        abstractify(mom, mesh), is_leaf=None)

                def constrain(tree):
                    return jax.tree.map(jax.lax.with_sharding_constraint,
                                        tree, accum_sh)

            mb = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            acc0 = (jnp.zeros((), jnp.float32),
                    constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                                           params)))

            def body(acc, b):
                l, g = jax.value_and_grad(loss)(params, b)
                gsum = constrain(jax.tree.map(
                    lambda a, x: a + x.astype(accum_dtype), acc[1], g))
                return (acc[0] + l, gsum), None

            (lsum, gsum), _ = jax.lax.scan(body, acc0, mb)
            lval = lsum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        lr = wsd_schedule(opt_state.step, peak_lr=peak_lr, total=total_steps)
        from .. import runtime_flags
        if runtime_flags.OPT["zero1_opt_state"]:
            from ..models.common import abstractify
            from ..optim.adamw import adamw_state_template
            mom = adamw_state_template(maybe_fsdp(lm.model_template(cfg)))["m"]
            upd_sh = jax.tree.map(lambda a: a.sharding, abstractify(mom, mesh))
            params, opt_state, gnorm = adamw_update_impl(
                params, opt_state, grads, lr, state_bits=bits,
                update_shardings=upd_sh)
        else:
            params, opt_state, gnorm = adamw_update(params, opt_state, grads, lr,
                                                    state_bits=bits)
        metrics = {"loss": lval, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh):
    def prefill_step(params, batch):
        out = lm.forward(cfg, params, batch, mesh=mesh)
        logits = out[0] if cfg.family == "moe" else out
        return logits[:, -1]  # next-token logits

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh):
    def decode_step(params, cache, tokens, pos):
        logits, cache = lm.decode_step(cfg, params, cache, tokens, pos, mesh=mesh)
        return logits[:, -1], cache

    return decode_step


# ---------------------------------------------------------------------------
# abstract inputs for lowering (the dry-run contract)
# ---------------------------------------------------------------------------

def abstract_state(cfg: ArchConfig, mesh, shape_name: str, *, with_opt: bool):
    """(params_abs, opt_abs_or_None, cache_abs_or_None) for one cell."""
    from ..configs.base import SHAPES
    from ..data.pipeline import make_batch_specs
    from ..optim.adamw import adamw_state_template

    S, B, kind = SHAPES[shape_name]
    tmpl = maybe_fsdp(lm.model_template(cfg))
    params_abs = abstractify(tmpl, mesh)
    opt_abs = None
    if with_opt:
        ot = adamw_state_template(tmpl, state_bits=opt_state_bits(cfg))
        flat = abstractify(ot, mesh)
        opt_abs = AdamWState(step=flat["step"], m=flat["m"], v=flat["v"],
                             m_scale=flat["m_scale"], v_scale=flat["v_scale"])
    cache_abs = None
    if kind == "decode":
        ct = lm.cache_template(cfg, B, S)
        cache_abs = abstractify(ct, mesh)
    batch_abs = make_batch_specs(cfg, shape_name, mesh)
    return params_abs, opt_abs, cache_abs, batch_abs
