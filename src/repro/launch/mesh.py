"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod ('data' × 'model'); multi_pod adds a leading
    2-pod 'pod' axis (2×16×16 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the locally visible devices (tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_device_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
