"""Serving launcher: batched prefill + decode driver with a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --requests 8 --max-new 16

A minimal production-shaped server loop: requests arrive with different
prompt lengths, are padded into a fixed decode batch, prefilled via
teacher-forced decode (filling the KV/recurrent cache), then decoded
greedily with per-sequence stop handling.  The same ``decode_step`` is what
the decode_32k / long_500k dry-run cells lower at production shape.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced
from ..models import lm
from ..models.common import materialize
from .mesh import make_host_mesh, make_production_mesh
from .steps import make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    params = materialize(jax.random.PRNGKey(0), lm.model_template(cfg),
                         dtype_override="float32" if args.reduced else None)
    step = jax.jit(make_decode_step(cfg, mesh))

    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab,
                          rng.integers(4, args.max_prompt + 1)).astype(np.int32)
             for _ in range(args.requests)]
    max_len = args.max_prompt + args.max_new
    done_tokens = 0
    t_start = time.time()

    while queue:
        batch_reqs, queue = queue[:args.batch], queue[args.batch:]
        B = len(batch_reqs)
        lens = np.array([len(p) for p in batch_reqs])
        prompts = np.zeros((B, args.max_prompt), np.int32)
        for i, p in enumerate(batch_reqs):
            prompts[i, :len(p)] = p
        cache = materialize(jax.random.PRNGKey(1), lm.cache_template(cfg, B, max_len),
                            dtype_override="float32" if args.reduced else None)
        # prefill: teacher-force prompts through decode, filling the cache
        logits = None
        for pos in range(int(lens.max())):
            tok = jnp.asarray(prompts[:, pos:pos + 1])
            logits, cache = step(params, cache, tok, jnp.asarray(pos, jnp.int32))
        # greedy decode
        out = np.zeros((B, args.max_new), np.int32)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        for i in range(args.max_new):
            out[:, i] = np.asarray(tok[:, 0])
            logits, cache = step(params, cache, tok,
                                 jnp.asarray(int(lens.max()) + i, jnp.int32))
            tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        done_tokens += B * args.max_new
        print(f"served batch of {B}: prompts {lens.tolist()}, "
              f"first seq -> {out[0, :8].tolist()}...", flush=True)

    dt = time.time() - t_start
    print(f"served {args.requests} requests, {done_tokens} tokens "
          f"in {dt:.1f}s ({done_tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
