import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

__doc__ = """Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  * build abstract params / optimizer / cache / batch (ShapeDtypeStruct with
    NamedSharding — zero allocation),
  * ``jax.jit(step).lower(...).compile()`` against the production mesh,
  * record ``memory_analysis()`` (proves it fits), ``cost_analysis()``
    (FLOPs/bytes for §Roofline) and the collective-op byte census parsed
    from the optimized HLO.

Results land in ``reports/dryrun/<mesh>/<arch>__<shape>.json`` (resumable:
existing cells are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # full sweep
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from ..configs.base import SHAPES, all_configs, get_config, shape_applicable
from .mesh import make_production_mesh
from .steps import abstract_state, make_decode_step, make_prefill_step, make_train_step

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "reports" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_census(hlo_text: str):
    """Sum result bytes of collective ops in post-SPMD HLO (per device)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        tuple_body, dtype, dims, op = m.groups()
        if tuple_body is not None:
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_body))
        else:
            b = _shape_bytes(dtype, dims)
        out[op] += b
        counts[op] += 1
    return out, counts


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    d = {}
    for k in keys:
        try:
            d[k] = int(getattr(mem, k))
        except Exception:
            pass
    return d


def _lower_step(cfg, mesh, shape, kind):
    if kind == "train":
        params, opt, _, batch = abstract_state(cfg, mesh, shape, with_opt=True)
        step = make_train_step(cfg, mesh)
        return jax.jit(step, donate_argnums=(0, 1)).lower(params, opt, batch)
    if kind == "prefill":
        params, _, _, batch = abstract_state(cfg, mesh, shape, with_opt=False)
        step = make_prefill_step(cfg, mesh)
        return jax.jit(step).lower(params, batch)
    params, _, cache, batch = abstract_state(cfg, mesh, shape, with_opt=False)
    step = make_decode_step(cfg, mesh)
    pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
    return jax.jit(step, donate_argnums=(1,)).lower(params, cache, batch["tokens"], pos)


def _probe_costs(cfg, mesh, shape, kind) -> dict:
    """Python-unrolled 1/2-layer probes -> extrapolated per-step totals.

    XLA's cost_analysis does not scale while-loop bodies by trip count, so
    FLOPs/bytes/collectives are measured on unrolled probes and extrapolated:
      total = f(base) + sum_s (L_s - 1) * (f(stack s -> 2) - f(base)).

    SSM/hybrid sequence work is linear in S (chunked SSD / mLSTM; the hybrid
    shared attention is windowed at 4096), but fully unrolling 32k/128 = 256
    chunk bodies per layer makes compiles intractable — those cells probe at
    S=4096 and scale the sequence-proportional totals by S/4096 (recorded as
    ``seq_scale``).
    """
    from ..configs.base import SHAPES
    from ..models.lm import layer_stack_sizes
    from .. import runtime_flags

    sizes = layer_stack_sizes(cfg)
    S, B, _ = SHAPES[shape]
    seq_scale = 1.0
    probe_shape = shape
    if cfg.family in ("ssm", "hybrid") and kind in ("train", "prefill") and S > 8192:
        SHAPES["__probe__"] = (4096, B, kind)
        probe_shape = "__probe__"
        seq_scale = S / 4096.0

    def measure(stack_counts):
        runtime_flags.PROBE["stack_counts"] = stack_counts
        runtime_flags.PROBE["unroll"] = True
        try:
            compiled = _lower_step(cfg, mesh, probe_shape, kind).compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            coll, _ = collective_census(compiled.as_text())
            return {"flops": float(cost.get("flops", 0)),
                    "bytes": float(cost.get("bytes accessed", 0)),
                    **{f"coll_{k}": float(v) for k, v in coll.items()}}
        finally:
            runtime_flags.PROBE["stack_counts"] = None
            runtime_flags.PROBE["unroll"] = False

    try:
        base_counts = {s: 1 for s in sizes}
        base = measure(base_counts)
        total = dict(base)
        per_stack = {}
        for s, L in sizes.items():
            if L <= 1:
                continue
            two = measure({**base_counts, s: 2})
            delta = {k: two[k] - base[k] for k in base}
            per_stack[s] = delta
            for k in total:
                total[k] += (L - 1) * delta[k]
        if seq_scale != 1.0:
            total = {k: v * seq_scale for k, v in total.items()}
        return {"totals": total, "base": base, "per_stack_delta": per_stack,
                "stack_sizes": sizes, "seq_scale": seq_scale}
    finally:
        SHAPES.pop("__probe__", None)


def run_cell(arch: str, shape: str, mesh_kind: str, *, force: bool = False,
             probe: bool = True) -> dict:
    cfg = get_config(arch)
    outdir = REPORT_DIR / mesh_kind
    outdir.mkdir(parents=True, exist_ok=True)
    outfile = outdir / f"{arch}__{shape}.json"
    if outfile.exists() and not force:
        return json.loads(outfile.read_text())

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
               "status": "skipped", "reason": reason}
        outfile.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    S, B, kind = SHAPES[shape]
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "kind": kind,
           "seq_len": S, "global_batch": B,
           "params": cfg.param_count(), "active_params": cfg.active_param_count(),
           "n_devices": int(mesh.devices.size)}
    try:
        lowered = _lower_step(cfg, mesh, shape, kind)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        mem = _mem_dict(compiled.memory_analysis())
        coll_bytes, coll_counts = collective_census(compiled.as_text())
        rec.update(status="ok", lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                   flops=float(cost.get("flops", -1)),
                   hlo_bytes_accessed=float(cost.get("bytes accessed", -1)),
                   cost_analysis={k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float)) and (
                                      "bytes" in k or k in ("flops", "transcendentals",
                                                            "optimal_seconds"))},
                   memory=mem, collective_bytes=coll_bytes,
                   collective_counts=coll_counts)
        if probe and mesh_kind == "single":
            rec["probe"] = _probe_costs(cfg, mesh, shape, kind)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    outfile.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multipod", "both"), default="both")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = sorted(all_configs()) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, force=args.force)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                msg = (f"[{mesh_kind:8s}] {arch:20s} {shape:12s} {st:8s}")
                if st == "ok":
                    msg += (f" flops={rec['flops']:.3e} "
                            f"coll={sum(rec['collective_bytes'].values())/1e9:.2f}GB "
                            f"compile={rec['compile_s']:.0f}s")
                elif st == "error":
                    msg += " " + rec["error"][:120]
                print(msg, flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} error={n_err}", flush=True)


if __name__ == "__main__":
    main()
