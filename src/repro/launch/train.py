"""Training launcher: end-to-end driver with checkpoint/restart.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 50 \
      --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--reduced`` runs the smoke-scale config on local devices (the CPU path of
examples/quickstart.py).  At full scale the same driver runs under the
production mesh; nothing in the loop is CPU-specific: the data pipeline is
host-local, checkpointing is per-host, restart is automatic from the latest
committed step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpointing import CheckpointManager
from ..configs import get_config, reduced
from ..data.pipeline import TokenPipeline
from ..models import lm
from ..models.common import materialize, spec_tree
from ..optim.adamw import adamw_init
from .mesh import make_host_mesh, make_production_mesh
from .steps import make_train_step, opt_state_bits


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = (make_production_mesh() if args.production_mesh else make_host_mesh())

    pipe = TokenPipeline(cfg, seq_len=args.seq, global_batch=args.batch)
    tmpl = lm.model_template(cfg)
    params = materialize(jax.random.PRNGKey(0), tmpl,
                         dtype_override="float32" if args.reduced else None)
    opt_state = adamw_init(params, state_bits=opt_state_bits(cfg))
    start = 0

    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every) if args.ckpt_dir else None
    if ckpt is not None:
        restored = ckpt.restore_latest({"params": params, "opt": opt_state})
        if restored[0] is not None:
            start = restored[0] + 1
            params, opt_state = restored[1]["params"], restored[1]["opt"]
            print(f"resumed from step {restored[0]}")

    step_fn = jax.jit(make_train_step(cfg, mesh, peak_lr=args.lr,
                                      total_steps=args.steps),
                      donate_argnums=(0, 1))
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(step).items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if ckpt is not None:
            ckpt.maybe_save(step, {"params": params, "opt": opt_state})
        print(f"step {step:5d} loss {loss:8.4f} gnorm {float(metrics['grad_norm']):8.3f} "
              f"lr {float(metrics['lr']):.2e} {time.time()-t0:6.2f}s", flush=True)
    if ckpt is not None:
        ckpt.maybe_save(args.steps - 1, {"params": params, "opt": opt_state}, force=True)
        ckpt.wait()
    return params


if __name__ == "__main__":
    main()
