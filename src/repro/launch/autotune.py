"""Schedule-aware tile autotuner (paper follow-up: the tiling/partition
configuration is a first-class performance lever, searched per graph class
rather than fixed).

Searches the tile-config lattice — grid (``n_dst_parts`` x ``n_src_parts``)
x ``n_buckets`` x shard count x vertex ``reorder`` (identity / degree,
paper §5.3) x within-tile edge ``layout`` (COO / CSR) — for one compiled
program over a representative graph of a class.  The harness repurposes the
``launch/hillclimb.py`` pattern (variant -> scored JSON-able record,
deltas against a baseline) for this lattice:

1. the *cheap objective* is :func:`~repro.core.simulator.simulate_sharded`'s
   padded cost model over the **kernel-dispatch** schedule (``padded=True``
   charges what the padded tile batch actually executes, which is what the
   config controls);
2. a greedy hill-climb walks one ladder step per dimension from the default
   config, keeping every evaluated trial;
3. the top candidates are *confirmed by wall clock* on the real runner
   (cheap-model ranking decides the search, measured time decides the
   winner among the finalists);
4. the winner lands in a :class:`TuneCache` keyed by program structure +
   graph class, with the realized shard-layout signature recorded for
   provenance — the serving engine consults the cache per size class and
   routes large requests onto the tuned config.

Pure library: no XLA flags are touched at import (unlike the dryrun
hillclimb driver, which forces a 512-device host platform), so it is safe
to import from tests and the serving engine.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import compiler as C
from ..core import isa
from ..core import tiling
from ..core.simulator import simulate_sharded
from ..core.streams import HWConfig
from ..gnn.graphs import Graph

#: ladder per search dimension — one hill-climb step moves to the adjacent
#: rung; powers of two keep every visited config cache-quantization-friendly
_PART_LADDER = (2, 4, 8, 16, 32, 64)
_BUCKET_LADDER = (1, 2, 4, 8)
_SHARD_LADDER = (1, 2, 4, 8)
#: categorical dimensions — the hill-climb move set offers a toggle to every
#: other choice (paper §5.3 degree sorting; CSR-within-tile edge storage)
_REORDER_CHOICES = ("identity", "degree")
_LAYOUT_CHOICES = ("coo", "csr")
_SHARD_MODE_CHOICES = ("cost", "mincut")


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One point of the search lattice."""
    n_dst_parts: int = 8
    n_src_parts: int = 8
    n_buckets: int = 4
    n_shards: int = 1
    #: vertex order fed to the tiler ("identity" | "degree")
    reorder: str = "identity"
    #: within-tile edge storage ("coo" | "csr")
    layout: str = "coo"
    #: shard planner ("cost" LPT | "mincut" locality refinement)
    shard_mode: str = "cost"

    def __post_init__(self):
        if self.reorder not in _REORDER_CHOICES:
            raise ValueError(f"unknown reorder mode {self.reorder!r}")
        if self.layout not in _LAYOUT_CHOICES:
            raise ValueError(f"unknown tile layout {self.layout!r}")
        if self.shard_mode not in _SHARD_MODE_CHOICES:
            raise ValueError(f"unknown shard mode {self.shard_mode!r}")

    def key(self) -> Tuple[int, int, int, int, str, str, str]:
        """Hashable identity used to dedupe trials during the search."""
        return (self.n_dst_parts, self.n_src_parts,
                self.n_buckets, self.n_shards, self.reorder, self.layout,
                self.shard_mode)

    def to_dict(self) -> Dict[str, object]:
        """JSON-able field dict (inverse of :meth:`from_dict`)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "TileConfig":
        """Rebuild a config from :meth:`to_dict` output.  Numeric fields are
        coerced to int so JSON round-trips are exact; the categorical
        reorder/layout fields stay strings.  Records written before those
        fields existed load with their defaults (identity/COO — exactly what
        those tunings searched)."""
        vals: Dict[str, object] = {}
        for f in dataclasses.fields(cls):
            if f.name not in d:
                continue
            v = d[f.name]
            vals[f.name] = str(v) if isinstance(f.default, str) else int(v)
        return cls(**vals)


@dataclasses.dataclass
class Trial:
    """One evaluated config: simulated cycles always, wall clock only for
    confirmed finalists."""
    config: TileConfig
    cycles: int
    balance: float
    exchange_cycles: int
    wall_s: Optional[float] = None

    def to_dict(self) -> Dict:
        """JSON-able record of the trial (config nested via its own dict)."""
        return dict(config=self.config.to_dict(), cycles=self.cycles,
                    balance=self.balance,
                    exchange_cycles=self.exchange_cycles,
                    wall_s=self.wall_s)


@dataclasses.dataclass
class TuneResult:
    """Outcome of one :func:`autotune` run: the winner plus the full
    evaluated-trial record (for reports and for re-ranking offline)."""
    best: Trial
    trials: List[Trial]            # every config the search evaluated
    confirmed: List[Trial]         # finalists with wall_s measured
    n_evals: int

    def to_dict(self) -> Dict:
        """JSON-able report payload (all trials serialized)."""
        return dict(best=self.best.to_dict(), n_evals=self.n_evals,
                    trials=[t.to_dict() for t in self.trials],
                    confirmed=[t.to_dict() for t in self.confirmed])


def build_tiles(graph: Graph, cfg: TileConfig):
    """The tile batch a config realizes (optional degree reorder + sparse
    grid tiling in the config's edge layout + bucketing).  Returns
    ``(tiles, reordering)``; run against ``reordering.graph`` and permute
    vertex IO through the :class:`~repro.core.reorder.Reordering`."""
    return tiling.build_tiles(
        graph, cfg.n_dst_parts, cfg.n_src_parts, sparse=True,
        reorder=cfg.reorder, layout=cfg.layout,
        n_buckets=cfg.n_buckets if cfg.n_buckets > 1 else None)


def padded_cost(compiled: C.CompiledGNN, graph: Graph, cfg: TileConfig,
                hw: Optional[HWConfig] = None,
                kernel_dispatch: bool = True) -> Trial:
    """Cheap objective: simulated padded cycles of the (kernel-dispatch)
    schedule under this config's tile batch and shard count.  The SDE
    templates are emitted for the config's edge layout, so CSR trials are
    costed with the E-proportional gather model rather than the dense
    per-tile matmul."""
    sde = isa.emit_sde(compiled.schedule(kernel_dispatch), layout=cfg.layout)
    tiles, _ = build_tiles(graph, cfg)
    r = simulate_sharded(sde, tiles, hw or HWConfig(), n_chips=cfg.n_shards,
                         padded=True, mode=cfg.shard_mode)
    return Trial(config=cfg, cycles=int(r.cycles), balance=float(r.balance),
                 exchange_cycles=int(r.exchange_cycles))


def _step(ladder: Sequence[int], value: int, direction: int,
          cap: Optional[int] = None) -> Optional[int]:
    if value not in ladder:
        return None
    i = ladder.index(value) + direction
    if not 0 <= i < len(ladder):
        return None
    nxt = ladder[i]
    return nxt if cap is None or nxt <= cap else None


def neighbors(cfg: TileConfig, graph: Graph, max_shards: int = 8,
              kernel_dispatch: bool = True) -> List[TileConfig]:
    """One ladder step in each dimension and direction plus one toggle per
    categorical dimension (the hill-climb move set).  Grid dimensions are
    capped by the vertex count so a tiny class can't tile onto more
    partitions than vertices.  The CSR layout toggle is only offered for
    kernel-dispatch schedules — the scan engine consumes the dense per-tile
    adjacency that CSR storage deliberately drops."""
    out: List[TileConfig] = []
    pcap = max(2, graph.n_vertices)
    for d in (-1, 1):
        for field, ladder, cap in (
                ("n_dst_parts", _PART_LADDER, pcap),
                ("n_src_parts", _PART_LADDER, pcap),
                ("n_buckets", _BUCKET_LADDER, None),
                ("n_shards", _SHARD_LADDER, max_shards)):
            nxt = _step(ladder, getattr(cfg, field), d, cap)
            if nxt is not None:
                out.append(dataclasses.replace(cfg, **{field: nxt}))
    toggles = [("reorder", _REORDER_CHOICES)]
    if kernel_dispatch:
        toggles.append(("layout", _LAYOUT_CHOICES))
    if cfg.n_shards > 1:
        # the planner only matters on a real mesh: single-shard configs
        # keep one canonical key instead of two aliased lattice points
        toggles.append(("shard_mode", _SHARD_MODE_CHOICES))
    for field, choices in toggles:
        for alt in choices:
            if alt != getattr(cfg, field):
                out.append(dataclasses.replace(cfg, **{field: alt}))
    return out


def hillclimb(compiled: C.CompiledGNN, graph: Graph,
              start: Optional[TileConfig] = None, *,
              hw: Optional[HWConfig] = None, max_evals: int = 48,
              max_shards: int = 8,
              kernel_dispatch: bool = True) -> List[Trial]:
    """Greedy deterministic hill-climb over the config lattice.

    From ``start`` (default :class:`TileConfig`), evaluates every neighbor,
    moves to the best strict improvement, repeats until a local optimum or
    ``max_evals`` simulator calls.  Returns ALL evaluated trials sorted by
    cycles ascending (ties broken by config key, so the ranking is stable).
    """
    hw = hw or HWConfig()
    seen: Dict[Tuple, Trial] = {}

    def ev(cfg: TileConfig) -> Trial:
        """Evaluate a config once; repeat lookups are free."""
        if cfg.key() not in seen:
            seen[cfg.key()] = padded_cost(compiled, graph, cfg, hw,
                                          kernel_dispatch)
        return seen[cfg.key()]

    cur = ev(start or TileConfig())
    while len(seen) < max_evals:
        cand = [ev(n)
                for n in neighbors(cur.config, graph, max_shards,
                                   kernel_dispatch=kernel_dispatch)
                if len(seen) < max_evals or n.key() in seen]
        better = [t for t in cand if t.cycles < cur.cycles]
        if not better:
            break
        cur = min(better, key=lambda t: (t.cycles, t.config.key()))
    return sorted(seen.values(), key=lambda t: (t.cycles, t.config.key()))


def confirm_wallclock(compiled: C.CompiledGNN, graph: Graph,
                      trials: Sequence[Trial],
                      inputs: Dict, params: Dict, *, top: int = 2,
                      repeats: int = 3,
                      kernel_dispatch: bool = True) -> List[Trial]:
    """Measure the real runner on the ``top`` cheapest trials (median of
    ``repeats`` after a warmup call) and attach ``wall_s`` in place.  Shard
    counts are clamped to the visible device count — the simulator may
    legitimately prefer an 8-chip layout the host cannot realize."""
    import jax

    from ..core.pipeline import PipelinedRunner, ShardedRunner

    n_dev_avail = len(jax.devices())
    confirmed: List[Trial] = []
    for t in list(trials)[:max(1, top)]:
        cfg = t.config
        tiles, ro = build_tiles(graph, cfg)
        n_dev = min(cfg.n_shards, n_dev_avail)
        if n_dev > 1:
            runner = ShardedRunner(compiled, ro.graph, tiles, n_dev,
                                   mode=cfg.shard_mode,
                                   kernel_dispatch=kernel_dispatch,
                                   reordering=ro)
        else:
            runner = PipelinedRunner(compiled, ro.graph, tiles,
                                     kernel_dispatch=kernel_dispatch,
                                     reordering=ro)
        jax.block_until_ready(runner(inputs, params))        # compile+warm
        times = []
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(runner(inputs, params))
            times.append(time.perf_counter() - t0)
        t.wall_s = float(np.median(times))
        confirmed.append(t)
    return confirmed


def autotune(compiled: C.CompiledGNN, graph: Graph, *,
             inputs: Optional[Dict] = None, params: Optional[Dict] = None,
             start: Optional[TileConfig] = None, hw: Optional[HWConfig] = None,
             max_evals: int = 48, max_shards: int = 8, top: int = 2,
             repeats: int = 3, kernel_dispatch: bool = True) -> TuneResult:
    """Full search: hill-climb on the simulator, then (when ``inputs`` and
    ``params`` are given) wall-clock confirmation of the finalists — the
    measured winner among them becomes :attr:`TuneResult.best`; without
    IO the cheapest simulated trial wins outright."""
    trials = hillclimb(compiled, graph, start, hw=hw, max_evals=max_evals,
                       max_shards=max_shards, kernel_dispatch=kernel_dispatch)
    confirmed: List[Trial] = []
    if inputs is not None and params is not None:
        confirmed = confirm_wallclock(compiled, graph, trials, inputs, params,
                                      top=top, repeats=repeats,
                                      kernel_dispatch=kernel_dispatch)
        best = min(confirmed, key=lambda t: (t.wall_s, t.cycles))
    else:
        best = trials[0]
    return TuneResult(best=best, trials=trials, confirmed=confirmed,
                      n_evals=len(trials))


# ---------------------------------------------------------------------------
# cache: tuned configs by (program structure, graph class)
# ---------------------------------------------------------------------------

def program_key(compiled: C.CompiledGNN, kernel_dispatch: bool = True) -> str:
    """Stable string identity of the scheduled program the tuning ran
    against (kernel tags included, so scan and kernel tunings never alias)."""
    return repr(compiled.structure_signature(kernel_dispatch))


class TuneCache:
    """Tuned-config store keyed by (program structure, graph class).

    The value records the winning :class:`TileConfig` plus the shard-layout
    signature it realized on the representative graph — provenance that a
    consumer (or a later re-tune) can use to detect that the entry was
    produced under a different layout regime.  JSON round-trips, so a tuning
    run can be persisted next to the benchmark reports and loaded into a
    serving process."""

    def __init__(self):
        self._entries: Dict[Tuple[str, str], Dict] = {}

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _k(prog_key: str, class_key) -> Tuple[str, str]:
        return (str(prog_key), repr(class_key))

    def put(self, prog_key: str, class_key, config: TileConfig, *,
            layout_signature=None, cycles: Optional[int] = None) -> None:
        """Record (or overwrite) the winning config for a program + class,
        with optional layout-signature/cycles provenance."""
        self._entries[self._k(prog_key, class_key)] = dict(
            config=config.to_dict(),
            layout_signature=(None if layout_signature is None
                              else repr(layout_signature)),
            cycles=cycles)

    def get(self, prog_key: str, class_key) -> Optional[TileConfig]:
        """The tuned config for a program + class, or ``None`` if untuned
        (the serving engine's per-size-class lookup)."""
        e = self._entries.get(self._k(prog_key, class_key))
        return None if e is None else TileConfig.from_dict(e["config"])

    def entry(self, prog_key: str, class_key) -> Optional[Dict]:
        """The full stored record (config + provenance), or ``None``."""
        return self._entries.get(self._k(prog_key, class_key))

    # ------------------------------------------------------- persistence
    def to_json(self) -> str:
        """Serialize every entry as a sorted JSON list (stable diffs)."""
        return json.dumps(
            [dict(prog_key=pk, class_key=ck, **e)
             for (pk, ck), e in sorted(self._entries.items())], indent=1)

    @classmethod
    def from_json(cls, text: str) -> "TuneCache":
        """Rebuild a cache from :meth:`to_json` text (unknown keys kept
        out; missing provenance fields default to ``None``)."""
        out = cls()
        for row in json.loads(text):
            out._entries[(row["prog_key"], row["class_key"])] = dict(
                config=row["config"],
                layout_signature=row.get("layout_signature"),
                cycles=row.get("cycles"))
        return out

    def save(self, path: str) -> None:
        """Write :meth:`to_json` to ``path``."""
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TuneCache":
        """Read a cache previously written by :meth:`save`."""
        with open(path) as f:
            return cls.from_json(f.read())


def tune_for_class(compiled: C.CompiledGNN, graph: Graph, class_key, *,
                   cache: Optional[TuneCache] = None,
                   kernel_dispatch: bool = True, **kw) -> TuneResult:
    """Tune one graph class and record the winner in ``cache`` under the
    program + class key (the lookup the serving engine performs)."""
    from ..core.pipeline import shard_layout_signature
    from ..core import schedule as S

    result = autotune(compiled, graph, kernel_dispatch=kernel_dispatch, **kw)
    if cache is not None:
        cfg = result.best.config
        sp = compiled.schedule(kernel_dispatch)
        tags = tuple(sorted({g.kernel for ph in sp.phases
                             for g in ph.gathers} - {S.KERNEL_SCAN}))
        sig = shard_layout_signature(build_tiles(graph, cfg)[0],
                                     max(1, cfg.n_shards),
                                     mode=cfg.shard_mode,
                                     kernel_dispatch=kernel_dispatch,
                                     kernels=tags)
        cache.put(program_key(compiled, kernel_dispatch), class_key, cfg,
                  layout_signature=sig, cycles=result.best.cycles)
    return result
