import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

__doc__ = """§Perf hillclimb driver.

Re-lowers the three chosen (arch × shape) cells with optimization variants
and records the roofline deltas next to the recorded baselines.  Variants
are combinations of:

  attn_batch_shard  — shard attention over batch on the model axis when
                      heads don't divide it (smollm's 9 heads on 16)
  moe_rs_combine    — reduce-scatter MoE combine + thin return all_to_all
  mb<N>             — gradient accumulation over N microbatches
  cap<F>            — MoE capacity factor override

Each variant writes reports/dryrun/hillclimb/<cell>__<variant>.json with
the same schema as the baseline cells, so benchmarks.roofline can analyze
them side by side.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell deepseek-v2-236b/train_4k \
      --variant moe_rs_combine
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from .. import runtime_flags
from ..configs.base import SHAPES, get_config
from .dryrun import REPORT_DIR, _mem_dict, _probe_costs, collective_census
from .mesh import make_production_mesh
from .steps import abstract_state, make_decode_step, make_prefill_step, make_train_step


def run_variant(arch: str, shape: str, variant: str, *, force: bool = False) -> dict:
    outdir = REPORT_DIR / "hillclimb"
    outdir.mkdir(parents=True, exist_ok=True)
    outfile = outdir / f"{arch}__{shape}__{variant}.json"
    if outfile.exists() and not force:
        return json.loads(outfile.read_text())

    cfg = get_config(arch)
    microbatches = 1
    flags = dict(runtime_flags.OPT)
    for part in variant.split("+"):
        if part.startswith("mb"):
            microbatches = int(part[2:])
        elif part.startswith("cap"):
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(part[3:])))
        elif part in runtime_flags.OPT:
            flags[part] = True
        elif part == "baseline":
            pass
        else:
            raise ValueError(f"unknown variant token {part}")

    mesh = make_production_mesh(multi_pod=False)
    S, B, kind = SHAPES[shape]
    rec = {"arch": arch, "shape": shape, "variant": variant, "kind": kind,
           "n_devices": int(mesh.devices.size), "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    old = dict(runtime_flags.OPT)
    runtime_flags.OPT.update(flags)
    t0 = time.time()
    try:
        if kind == "train":
            params, opt, _, batch = abstract_state(cfg, mesh, shape, with_opt=True)
            step = make_train_step(cfg, mesh, microbatches=microbatches,
                                   accum_dtype=jax.numpy.bfloat16
                                   if cfg.moe else jax.numpy.float32)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt, batch)
        elif kind == "prefill":
            params, _, _, batch = abstract_state(cfg, mesh, shape, with_opt=False)
            lowered = jax.jit(make_prefill_step(cfg, mesh)).lower(params, batch)
        else:
            params, _, cache, batch = abstract_state(cfg, mesh, shape, with_opt=False)
            pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
            lowered = jax.jit(make_decode_step(cfg, mesh), donate_argnums=(1,)).lower(
                params, cache, batch["tokens"], pos)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        coll, counts = collective_census(compiled.as_text())
        rec.update(status="ok", compile_s=round(time.time() - t0, 1),
                   flops=float(cost.get("flops", -1)),
                   hlo_bytes_accessed=float(cost.get("bytes accessed", -1)),
                   memory=_mem_dict(compiled.memory_analysis()),
                   collective_bytes=coll, collective_counts=counts)
        # probe (unrolled cost extrapolation) under the same flags
        rec["probe"] = _probe_costs(cfg, mesh, shape, kind)
        if microbatches > 1:
            # the microbatch scan is a while loop the probe counts once:
            # scale the per-microbatch totals up (the optimizer's own FLOPs
            # are over-scaled by this, but they are << the model FLOPs)
            rec["probe"]["totals"] = {k: v * microbatches
                                      for k, v in rec["probe"]["totals"].items()}
            rec["microbatches"] = microbatches
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    finally:
        runtime_flags.OPT.update(old)
    outfile.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch/shape")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    arch, shape = args.cell.split("/")
    rec = run_variant(arch, shape, args.variant, force=args.force)
    if rec["status"] == "ok":
        tot = rec.get("probe", {}).get("totals", {})
        coll = sum(v for k, v in tot.items() if k.startswith("coll_"))
        print(f"{arch}/{shape} [{args.variant}] ok "
              f"flops={tot.get('flops', rec['flops']):.3e} coll={coll/1e9:.1f}GB/dev "
              f"temp={rec['memory'].get('temp_size_in_bytes',0)/2**30:.1f}GB "
              f"compile={rec['compile_s']}s")
    else:
        print(f"{arch}/{shape} [{args.variant}] ERROR: {rec['error'][:200]}")
    return rec


if __name__ == "__main__":
    main()
