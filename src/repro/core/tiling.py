"""Grid-based graph tiling (paper §5.1, §5.3).

The adjacency matrix is split into a P (destination partitions) × S (source
partitions) grid of *tiles*.  Each tile uniquely owns the edges whose dst is
in its destination partition and src in its source partition.

* **regular tiling** — a tile's source-vertex set is the *whole* source
  partition (vertices loaded whether or not they have edges in the tile).
* **sparse tiling** — only source vertices with ≥1 edge in the tile are kept
  (compaction); empty tiles are dropped entirely.

JAX needs static shapes, so tiles are padded to (S_max, E_max) with explicit
``n_src`` / ``n_edge`` counts; masked tails contribute nothing (sum) / -inf
(max).  The padded batch is what the pipelined executor ``lax.scan``s over
and what the Pallas tile kernel consumes.

On power-law graphs a single global (S_max, E_max) is dominated by a handful
of dense tiles, so most scan iterations are zero padding.
:func:`bucket_tiles` post-processes a :class:`TileSet` into a
:class:`BucketedTileSet`: tiles are size-binned by (n_edge, n_src) and each
bin is padded only to its own maxima (CSR row-bucketing adapted to grid
tiles).  The pipelined executor runs one scan per bucket with shared
accumulators, so numerics match the global-pad path while the padded
edge-slot waste drops by the bucket-size ratio.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import List, Optional, Tuple

import numpy as np

from ..gnn.graphs import Graph


@dataclasses.dataclass
class TileSet:
    """Padded, partition-ordered tile batch."""

    # per-tile payload (T = number of tiles kept)
    src_ids: np.ndarray     # (T, S_max) int32 — global source-vertex ids
    edge_src: np.ndarray    # (T, E_max) int32 — local index into src_ids row
    edge_dst: np.ndarray    # (T, E_max) int32 — dst offset within the tile's partition
    edge_gid: np.ndarray    # (T, E_max) int32 — global edge index (for edge feats)
    n_src: np.ndarray       # (T,) int32
    n_edge: np.ndarray      # (T,) int32
    part_id: np.ndarray     # (T,) int32 — destination partition of each tile
    # per-partition metadata (P,)
    part_start: np.ndarray  # (P,) int32 — first dst vertex id of the partition
    part_size: np.ndarray   # (P,) int32
    # config
    n_dst_parts: int
    n_src_parts: int
    sparse: bool
    n_vertices: int
    n_edges: int
    # intra-tile edge layout: "coo" keeps edges in arrival order; "csr" sorts
    # the real edge slots of each tile by local dst row and adds per-tile row
    # pointers (see :func:`csr_tiles`), so kernels walk contiguous rows
    # instead of scanning padded edge slots.
    layout: str = "coo"
    row_ptr: Optional[np.ndarray] = None  # (T, D_max+1) int32, csr only

    @property
    def n_tiles(self) -> int:
        return int(self.src_ids.shape[0])

    @property
    def s_max(self) -> int:
        return int(self.src_ids.shape[1])

    @property
    def e_max(self) -> int:
        return int(self.edge_src.shape[1])

    # ---- cost accounting (paper Fig 11: off-chip access model) -------------
    def src_vertex_loads(self) -> int:
        """Total source-vertex embedding rows loaded from off-chip."""
        return int(self.n_src.sum())

    def dst_vertex_loads(self) -> int:
        """Destination rows are loaded once per partition per phase."""
        return int(self.part_size.sum())

    def edge_index_bytes(self) -> int:
        """Edge-index traffic: COO ships (src, dst) int32 pairs per edge;
        CSR ships one column index per edge plus each tile's (D_max+1)-entry
        row-pointer table."""
        E = int(self.n_edge.sum())
        if self.layout == "csr":
            width = self.row_ptr.shape[1] if self.row_ptr is not None else 1
            return E * 4 + self.n_tiles * width * 4
        return E * 2 * 4

    def offchip_read_bytes(self, dim: int, dtype_bytes: int = 4,
                           dst_streams: int = 1) -> int:
        vert = (self.src_vertex_loads() + dst_streams * self.dst_vertex_loads()) * dim * dtype_bytes
        return vert + self.edge_index_bytes()

    def tiles_of_partition(self, p: int) -> np.ndarray:
        return np.nonzero(self.part_id == p)[0]

    # ---- padding accounting (what the static-shape executor actually pays) --
    def padded_src_slots(self) -> int:
        return self.n_tiles * self.s_max

    def padded_edge_slots(self) -> int:
        return self.n_tiles * self.e_max

    def padding_efficiency(self) -> float:
        """Fraction of padded edge slots holding a real edge (1.0 = no waste)."""
        return int(self.n_edge.sum()) / max(self.padded_edge_slots(), 1)

    def padded_dims_of_tile(self, t: int) -> Tuple[int, int]:
        """(src_slots, edge_slots) the executor materializes for tile ``t``."""
        return self.s_max, self.e_max

    # ---- structural identity (program-cache key; serving layer) ------------
    def shape_signature(self) -> Tuple:
        """Everything a jitted runner's compilation depends on — padded tile
        shapes and the partition table — and nothing edge-list-specific.
        Two tile sets with equal signatures can share one compiled program.
        ``layout`` is part of the signature: CSR and COO tile sets lower to
        different kernels and must never alias one cached program."""
        return ("tiles", self.layout, self.n_tiles, self.s_max, self.e_max,
                self.n_dst_parts, self.n_src_parts, self.n_vertices,
                tuple(self.part_start.tolist()),
                tuple(self.part_size.tolist()))


def _even_bounds(n: int, parts: int) -> np.ndarray:
    """parts+1 boundaries of an even split of range(n)."""
    return np.linspace(0, n, parts + 1).round().astype(np.int64)


def grid_tile(graph: Graph, n_dst_parts: int, n_src_parts: int,
              sparse: bool = True, pad_multiple: int = 8,
              layout: str = "coo") -> TileSet:
    """Grid-based tiling; ``sparse=False`` reproduces regular tiling.

    ``layout="csr"`` post-converts the tile batch via :func:`csr_tiles`.
    """
    if layout not in ("coo", "csr"):
        raise ValueError(f"unknown tile layout {layout!r}")
    V, E = graph.n_vertices, graph.n_edges
    db = _even_bounds(V, n_dst_parts)
    sb = _even_bounds(V, n_src_parts)
    dpart = np.searchsorted(db, graph.dst, side="right") - 1
    spart = np.searchsorted(sb, graph.src, side="right") - 1

    # bucket edges by (dst_part, src_part), partition-major order
    key = dpart.astype(np.int64) * n_src_parts + spart
    order = np.argsort(key, kind="stable")
    key_sorted = key[order]
    uniq, starts = np.unique(key_sorted, return_index=True)
    ends = np.append(starts[1:], E)

    tiles = []  # (part, src_part, edge_idx_sorted_slice)
    for k, s, e in zip(uniq, starts, ends):
        tiles.append((int(k // n_src_parts), int(k % n_src_parts), order[s:e]))
    if not sparse:
        # regular tiling keeps every (p, s) cell, even empty ones
        present = {(p, s) for p, s, _ in tiles}
        for p in range(n_dst_parts):
            for s in range(n_src_parts):
                if (p, s) not in present:
                    tiles.append((p, s, np.empty(0, dtype=np.int64)))
        tiles.sort(key=lambda t: (t[0], t[1]))

    rows = []
    for p, s, eidx in tiles:
        esrc_g = graph.src[eidx]
        edst_g = graph.dst[eidx]
        if sparse:
            srcs, esrc_local = np.unique(esrc_g, return_inverse=True)
        else:
            srcs = np.arange(sb[s], sb[s + 1], dtype=np.int64)
            esrc_local = esrc_g - sb[s]
        rows.append({
            "p": p,
            "srcs": srcs.astype(np.int32),
            "esrc": esrc_local.astype(np.int32),
            "edst": (edst_g - db[p]).astype(np.int32),
            "egid": eidx.astype(np.int32),
        })

    def _pad_to(x: int) -> int:
        return max(pad_multiple, int(math.ceil(max(x, 1) / pad_multiple)) * pad_multiple)

    s_max = _pad_to(max((len(r["srcs"]) for r in rows), default=1))
    e_max = _pad_to(max((len(r["esrc"]) for r in rows), default=1))
    T = len(rows)

    src_ids = np.zeros((T, s_max), np.int32)
    edge_src = np.zeros((T, e_max), np.int32)
    edge_dst = np.zeros((T, e_max), np.int32)
    edge_gid = np.zeros((T, e_max), np.int32)
    n_src = np.zeros((T,), np.int32)
    n_edge = np.zeros((T,), np.int32)
    part_id = np.zeros((T,), np.int32)
    for i, r in enumerate(rows):
        k, m = len(r["srcs"]), len(r["esrc"])
        src_ids[i, :k] = r["srcs"]
        edge_src[i, :m] = r["esrc"]
        edge_dst[i, :m] = r["edst"]
        edge_gid[i, :m] = r["egid"]
        n_src[i], n_edge[i], part_id[i] = k, m, r["p"]

    ts = TileSet(
        src_ids=src_ids, edge_src=edge_src, edge_dst=edge_dst, edge_gid=edge_gid,
        n_src=n_src, n_edge=n_edge, part_id=part_id,
        part_start=db[:-1].astype(np.int32),
        part_size=np.diff(db).astype(np.int32),
        n_dst_parts=n_dst_parts, n_src_parts=n_src_parts, sparse=sparse,
        n_vertices=V, n_edges=E)
    return csr_tiles(ts) if layout == "csr" else ts


def csr_tiles(tiles: TileSet) -> TileSet:
    """Convert a COO tile batch to CSR-within-tile layout (§5.3 / ROADMAP 3).

    Per tile, the *real* edge slots ``[:n_edge]`` are stably sorted by local
    destination row — ``edge_src``/``edge_dst``/``edge_gid`` are permuted
    together, so ``edge_src[t, row_ptr[t, d]:row_ptr[t, d+1]]`` is dst row
    ``d``'s contiguous column-index run.  ``row_ptr`` is (T, D_max+1) with
    ``D_max = part_size.max()``; rows past a tile's partition size (and all
    rows of zero-edge filler tiles) get empty ``[ptr, ptr)`` runs.  Padded
    edge slots stay after ``row_ptr[t, -1] == n_edge[t]`` where no row
    pointer can reach them, so CSR kernels need no tail masking.
    """
    if tiles.layout == "csr":
        return tiles
    T = tiles.n_tiles
    dmax = int(tiles.part_size.max()) if tiles.part_size.size else 1
    edge_src = tiles.edge_src.copy()
    edge_dst = tiles.edge_dst.copy()
    edge_gid = tiles.edge_gid.copy()
    row_ptr = np.zeros((T, dmax + 1), np.int32)
    for t in range(T):
        ne = int(tiles.n_edge[t])
        if ne == 0:
            continue
        perm = np.argsort(edge_dst[t, :ne], kind="stable")
        edge_src[t, :ne] = edge_src[t, perm]
        edge_gid[t, :ne] = edge_gid[t, perm]
        edge_dst[t, :ne] = edge_dst[t, perm]
        counts = np.bincount(edge_dst[t, :ne], minlength=dmax)
        row_ptr[t, 1:] = np.cumsum(counts[:dmax]).astype(np.int32)
    return dataclasses.replace(tiles, edge_src=edge_src, edge_dst=edge_dst,
                               edge_gid=edge_gid, layout="csr", row_ptr=row_ptr)


@dataclasses.dataclass
class BucketedTileSet:
    """Size-binned tile batch: each bucket is a :class:`TileSet` padded only
    to its own (S_max, E_max).

    Buckets share the partition metadata of the source tile set; per-bucket
    tile order is partition-major (required by the Pallas FIRST/LAST flag
    protocol) with the heaviest tile of each partition first — a
    deterministic largest-processing-time order that load-balances the
    stream slots.  ``tile_index[b][i]`` is the row of bucket ``b``'s tile
    ``i`` in the original tile set.
    """

    buckets: List[TileSet]
    tile_index: List[np.ndarray]
    source: TileSet

    # ---- flattened view (bucket-major), for cost models over "all tiles" ---
    def __post_init__(self):
        self.n_src = np.concatenate([b.n_src for b in self.buckets])
        self.n_edge = np.concatenate([b.n_edge for b in self.buckets])
        self.part_id = np.concatenate([b.part_id for b in self.buckets])
        self._pad_s = np.concatenate(
            [np.full(b.n_tiles, b.s_max, np.int64) for b in self.buckets])
        self._pad_e = np.concatenate(
            [np.full(b.n_tiles, b.e_max, np.int64) for b in self.buckets])

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_tiles(self) -> int:
        return sum(b.n_tiles for b in self.buckets)

    @property
    def n_dst_parts(self) -> int:
        return self.source.n_dst_parts

    @property
    def n_src_parts(self) -> int:
        return self.source.n_src_parts

    @property
    def sparse(self) -> bool:
        return self.source.sparse

    @property
    def layout(self) -> str:
        return self.source.layout

    @property
    def n_vertices(self) -> int:
        return self.source.n_vertices

    @property
    def n_edges(self) -> int:
        return self.source.n_edges

    @property
    def part_start(self) -> np.ndarray:
        return self.source.part_start

    @property
    def part_size(self) -> np.ndarray:
        return self.source.part_size

    def tiles_of_partition(self, p: int) -> np.ndarray:
        return np.nonzero(self.part_id == p)[0]

    # ---- cost accounting ---------------------------------------------------
    def src_vertex_loads(self) -> int:
        return int(self.n_src.sum())

    def dst_vertex_loads(self) -> int:
        return self.source.dst_vertex_loads()

    def offchip_read_bytes(self, dim: int, dtype_bytes: int = 4,
                           dst_streams: int = 1) -> int:
        return self.source.offchip_read_bytes(dim, dtype_bytes, dst_streams)

    def padded_src_slots(self) -> int:
        return int(self._pad_s.sum())

    def padded_edge_slots(self) -> int:
        return int(self._pad_e.sum())

    def padding_efficiency(self) -> float:
        return int(self.n_edge.sum()) / max(self.padded_edge_slots(), 1)

    def padded_dims_of_tile(self, t: int) -> Tuple[int, int]:
        return int(self._pad_s[t]), int(self._pad_e[t])

    def shape_signature(self) -> Tuple:
        return ("btiles", tuple(b.shape_signature() for b in self.buckets),
                self.source.shape_signature())


def _repack(tiles: TileSet, idx: np.ndarray, pad_multiple: int) -> TileSet:
    """A TileSet over ``tiles[idx]`` re-padded to the selection's own maxima."""
    def _pad_to(x: int) -> int:
        return max(pad_multiple, int(math.ceil(max(x, 1) / pad_multiple)) * pad_multiple)

    s_max = _pad_to(int(tiles.n_src[idx].max(initial=0)))
    e_max = _pad_to(int(tiles.n_edge[idx].max(initial=0)))
    return TileSet(
        src_ids=np.ascontiguousarray(tiles.src_ids[idx, :s_max]),
        edge_src=np.ascontiguousarray(tiles.edge_src[idx, :e_max]),
        edge_dst=np.ascontiguousarray(tiles.edge_dst[idx, :e_max]),
        edge_gid=np.ascontiguousarray(tiles.edge_gid[idx, :e_max]),
        n_src=tiles.n_src[idx].copy(), n_edge=tiles.n_edge[idx].copy(),
        part_id=tiles.part_id[idx].copy(),
        part_start=tiles.part_start, part_size=tiles.part_size,
        n_dst_parts=tiles.n_dst_parts, n_src_parts=tiles.n_src_parts,
        sparse=tiles.sparse, n_vertices=tiles.n_vertices, n_edges=tiles.n_edges,
        layout=tiles.layout,
        row_ptr=None if tiles.row_ptr is None else tiles.row_ptr[idx].copy())


def bucket_tiles(tiles: TileSet, n_buckets: int = 4,
                 pad_multiple: int = 8) -> BucketedTileSet:
    """Post-pass: bin tiles by size so each bin pads to its own maxima.

    Tiles are sorted by (n_edge, n_src) and split into ``n_buckets``
    contiguous equal-count bins.  The realized bucket count is exactly
    ``min(n_buckets, n_tiles)`` — the bin bounds are strictly increasing by
    construction (every bin gets at least one tile), never collapsed through
    rounding or dedup, so a config sweep over ``n_buckets`` (the autotuner)
    maps each requested count onto a distinct, deterministic layout and
    cache keys derived from the bucket shapes stay stable.  Within a bin
    tiles are ordered partition-major, heaviest first per partition —
    deterministic, and load-balanced for the multi-stream schedule.
    """
    T = tiles.n_tiles
    if T == 0:
        return BucketedTileSet(buckets=[tiles],
                               tile_index=[np.empty(0, np.int64)], source=tiles)
    n_buckets = max(1, min(n_buckets, T))
    order = np.lexsort((tiles.n_src, tiles.n_edge))  # (n_edge, n_src) asc
    # i-th bound = i*T//n: strictly increasing whenever T >= n_buckets
    # (guaranteed by the cap above), unlike round()+unique which can merge
    # near-uniform splits and silently change the realized bucket count
    bounds = (np.arange(n_buckets + 1, dtype=np.int64) * T) // n_buckets
    assert len(np.unique(bounds)) == n_buckets + 1

    buckets: List[TileSet] = []
    index: List[np.ndarray] = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        sel = order[lo:hi]
        # partition-major; within a partition largest-first (LPT), ties by row
        sub = np.lexsort((sel, -tiles.n_edge[sel].astype(np.int64),
                          tiles.part_id[sel]))
        sel = sel[sub]
        buckets.append(_repack(tiles, sel, pad_multiple))
        index.append(sel)
    return BucketedTileSet(buckets=buckets, tile_index=index, source=tiles)


def quantize_buckets(bt: BucketedTileSet,
                     pad_multiple: int = 8) -> BucketedTileSet:
    """Snap each bucket's column maxima (s_max, e_max) up to powers of two.

    Bucket row counts are already deterministic per tile count (see
    :func:`bucket_tiles`), so after this pass the whole bucketed shape
    signature is a step function of the size class — structurally-similar
    serving requests that tile and bucket slightly differently still land
    on one compiled sharded program.  Tile order and ``tile_index`` are
    unchanged (only columns grow)."""
    def q(n: int) -> int:
        n = max(int(n), pad_multiple)
        return 1 << (n - 1).bit_length()

    buckets = [pad_tileset(b, b.n_tiles, q(b.s_max), q(b.e_max))
               for b in bt.buckets]
    return BucketedTileSet(buckets=buckets, tile_index=list(bt.tile_index),
                           source=bt.source)


def pad_tileset(tiles: TileSet, n_tiles: int, s_max: int, e_max: int) -> TileSet:
    """Pad a (partition-major) tile set to ``(n_tiles, s_max, e_max)`` with
    zero-edge filler tiles, so structurally-similar graphs snap onto one
    shape signature and share a compiled program (serving cache).

    Filler tiles carry ``part_id = P-1`` and append after the real tiles,
    extending the last partition's run: under the Pallas FIRST/LAST flag
    protocol they add a zero adjacency block to that partition's accumulator
    (or, if the partition had no real tiles, flush an all-zero block — the
    correct empty-gather result), and the ``lax.scan`` path masks them out
    via ``n_edge = 0``.
    """
    if (n_tiles, s_max, e_max) == (tiles.n_tiles, tiles.s_max, tiles.e_max):
        return tiles
    if (n_tiles < tiles.n_tiles or s_max < tiles.s_max or e_max < tiles.e_max):
        raise ValueError(
            f"pad_tileset cannot shrink {(tiles.n_tiles, tiles.s_max, tiles.e_max)}"
            f" -> {(n_tiles, s_max, e_max)}")
    T = tiles.n_tiles

    def grow(a: np.ndarray, cols: int) -> np.ndarray:
        out = np.zeros((n_tiles, cols), a.dtype)
        out[:T, :a.shape[1]] = a
        return out

    def grow1(a: np.ndarray, fill: int = 0) -> np.ndarray:
        out = np.full((n_tiles,), fill, a.dtype)
        out[:T] = a
        return out

    # filler tiles get an all-zero row_ptr: every CSR row run is [0, 0) —
    # the correct empty-tile contribution under the FIRST/LAST protocol
    row_ptr = (None if tiles.row_ptr is None
               else grow(tiles.row_ptr, tiles.row_ptr.shape[1]))
    return TileSet(
        src_ids=grow(tiles.src_ids, s_max),
        edge_src=grow(tiles.edge_src, e_max),
        edge_dst=grow(tiles.edge_dst, e_max),
        edge_gid=grow(tiles.edge_gid, e_max),
        n_src=grow1(tiles.n_src), n_edge=grow1(tiles.n_edge),
        part_id=grow1(tiles.part_id, fill=tiles.n_dst_parts - 1),
        part_start=tiles.part_start, part_size=tiles.part_size,
        n_dst_parts=tiles.n_dst_parts, n_src_parts=tiles.n_src_parts,
        sparse=tiles.sparse, n_vertices=tiles.n_vertices, n_edges=tiles.n_edges,
        layout=tiles.layout, row_ptr=row_ptr)


@dataclasses.dataclass
class ShardPlan:
    """Assignment of destination partitions to mesh shards (multi-device /
    multi-chip execution).

    Because a tile is owned by exactly one destination partition, assigning
    whole partitions to shards keeps every gather accumulator device-local —
    the only cross-shard dataflow is the layer-boundary read of *drained*
    source values (one all-gather in the executed runner, one exchange step
    in the simulator's multi-chip cost model).

    ``parts_of_shard[k]`` lists the global partition ids shard ``k`` owns in
    ascending order; shards are padded to a common ``n_local_parts`` slot
    count (ragged partition counts — ``P`` not divisible by the mesh — leave
    trailing invalid slots on the lighter shards).
    """

    n_shards: int
    parts_of_shard: List[np.ndarray]   # per shard: global partition ids, asc
    shard_of_part: np.ndarray          # (P,) int32
    local_slot_of_part: np.ndarray     # (P,) int32 — slot within owning shard
    part_cost: np.ndarray              # (P,) int64 — padded edge-slot cost
    mode: str
    part_adj: Optional[np.ndarray] = None  # (P, P) int64 directed read counts

    @property
    def n_parts(self) -> int:
        return int(self.shard_of_part.shape[0])

    @property
    def n_local_parts(self) -> int:
        """Local partition slots per shard (max over shards, >= 1)."""
        return max(1, max(len(p) for p in self.parts_of_shard))

    def shard_costs(self) -> np.ndarray:
        """(K,) summed padded-edge cost per shard (balance diagnostic)."""
        return np.array([int(self.part_cost[p].sum())
                         for p in self.parts_of_shard], np.int64)

    def edge_cut(self) -> int:
        """Cross-shard source-read slots: the sum of partition-adjacency
        weights ``w[p, q]`` over pairs assigned to different shards.  This is
        exactly the row traffic a neighbor-restricted boundary exchange must
        ship, so it is the min-cut planner's objective."""
        if self.part_adj is None:
            raise ValueError(
                "plan has no partition adjacency; build it via plan_shards()")
        cross = self.shard_of_part[:, None] != self.shard_of_part[None, :]
        return int(self.part_adj[cross].sum())

    def assignment(self) -> Tuple[Tuple[int, ...], ...]:
        """Exact per-shard partition-id tuples (tests / debugging)."""
        return tuple(tuple(int(i) for i in p) for p in self.parts_of_shard)

    def signature(self) -> Tuple:
        """Stable assignment identity: a short digest of the exact
        assignment rather than the O(P) id lists themselves, so cache keys
        and diagnostics stay small on large graphs.  Use
        :meth:`assignment` when the exact lists are needed."""
        digest = hashlib.sha256(
            repr((self.mode, self.n_shards, self.assignment())).encode()
        ).hexdigest()[:16]
        return ("shardplan", self.mode, self.n_shards, self.n_local_parts,
                digest)


def partition_costs(tiles) -> np.ndarray:
    """(P,) padded edge-slot cost per destination partition — what a
    static-shape executor pays for that partition's tiles.  Vectorized:
    this runs per request on the sharded serving hot path."""
    part_id = np.asarray(tiles.part_id)
    if isinstance(tiles, BucketedTileSet):
        pad_e = np.asarray(tiles._pad_e, np.int64)
    else:
        pad_e = np.full(part_id.shape, tiles.e_max, np.int64)
    cost = np.zeros(tiles.n_dst_parts, np.int64)
    np.add.at(cost, part_id, pad_e)
    return cost


def partition_adjacency(tiles) -> np.ndarray:
    """(P, P) directed read-count matrix over destination partitions.

    ``w[p, q]`` counts the real source-vertex slots that tiles of dst
    partition ``p`` read from vertices *owned* by partition ``q`` (ownership
    by the destination-partition ranges ``part_start``/``part_size``).  Built
    vectorized from the padded tile batch — it runs per request on the
    sharded serving hot path, like :func:`partition_costs`.
    """
    P = tiles.n_dst_parts
    part_start = np.asarray(tiles.part_start)
    w = np.zeros((P, P), np.int64)

    def accumulate(ts: TileSet) -> None:
        if ts.n_tiles == 0 or ts.s_max == 0:
            return
        src_ids = np.asarray(ts.src_ids)
        src_part = np.searchsorted(part_start, src_ids, side="right") - 1
        valid = np.arange(ts.s_max)[None, :] < np.asarray(ts.n_src)[:, None]
        dst_part = np.broadcast_to(
            np.asarray(ts.part_id)[:, None], src_part.shape)
        np.add.at(w, (dst_part[valid], src_part[valid]), 1)

    if isinstance(tiles, BucketedTileSet):
        for b in tiles.buckets:
            accumulate(b)
    else:
        accumulate(tiles)
    return w


def _lpt_assign(cost: np.ndarray, n_shards: int) -> List[List[int]]:
    """Deterministic LPT greedy: heaviest partition to least-loaded shard."""
    order = np.argsort(-cost, kind="stable")          # heaviest first, ties by id
    loads = np.zeros(n_shards, np.int64)
    assign: List[List[int]] = [[] for _ in range(n_shards)]
    for p in order:
        k = int(np.argmin(loads))                     # least-loaded, ties low id
        assign[k].append(int(p))
        loads[k] += cost[p]
    return assign


def _mincut_refine(assign: List[List[int]], cost: np.ndarray,
                   adj: np.ndarray, n_shards: int, balance_tol: float,
                   max_moves: Optional[int] = None) -> List[List[int]]:
    """Deterministic KL-style greedy refinement of a seed assignment.

    Each step applies the best strictly-positive cut-gain *move* (partition
    to another shard) or *swap* (exchange two partitions between shards —
    the step that still works when loads are tight, since it roughly
    preserves them), subject to a padded-cost cap of ``max(seed max load,
    ceil(balance_tol x mean load))``.  The symmetric edge cut strictly
    decreases every step, so the result's :meth:`ShardPlan.edge_cut` never
    exceeds the seed's and termination is guaranteed.
    """
    P = cost.shape[0]
    K = n_shards
    sym = (adj + adj.T).astype(np.float64)
    np.fill_diagonal(sym, 0.0)
    shard_of = np.zeros(P, np.int64)
    loads = np.zeros(K, np.int64)
    for k, ps in enumerate(assign):
        ids = np.asarray(ps, np.int64)
        shard_of[ids] = k
        loads[k] = int(cost[ids].sum()) if len(ids) else 0
    mean = cost.sum() / max(1, K)
    cap = max(int(loads.max()), int(math.ceil(balance_tol * mean)))
    if max_moves is None:
        max_moves = 4 * P
    ar = np.arange(P)
    for _ in range(max_moves):
        onehot = np.zeros((P, K))
        onehot[ar, shard_of] = 1.0
        conn = sym @ onehot                       # conn[p, k]
        own = conn[ar, shard_of]                  # conn to own shard
        # single moves: gain of sending p to shard k
        mgain = conn - own[:, None]
        mfeas = loads[None, :] + cost[:, None] <= cap
        mfeas[ar, shard_of] = False
        mgain = np.where(mfeas, mgain, -np.inf)
        mi = int(np.argmax(mgain))                # ties -> lowest (p, k)
        mp, mk = divmod(mi, K)
        # swaps: exchange p (shard A) and q (shard B); after the swap the
        # pair is still split, hence the -2*sym[p, q] correction
        c_pb = conn[:, shard_of]                  # c_pb[p, q] = conn[p, B_q]
        sgain = c_pb - own[:, None] + c_pb.T - own[None, :] - 2.0 * sym
        load_of = loads[shard_of]
        new_a = load_of[:, None] - cost[:, None] + cost[None, :]
        new_b = load_of[None, :] + cost[:, None] - cost[None, :]
        sfeas = ((shard_of[:, None] != shard_of[None, :])
                 & (new_a <= cap) & (new_b <= cap))
        sgain = np.where(sfeas, sgain, -np.inf)
        si = int(np.argmax(sgain))
        sp_, sq = divmod(si, P)
        best_m = mgain[mp, mk]
        best_s = sgain[sp_, sq]
        if max(best_m, best_s) <= 0:
            break
        if best_m >= best_s:
            loads[shard_of[mp]] -= cost[mp]
            loads[mk] += cost[mp]
            shard_of[mp] = mk
        else:
            a, b = int(shard_of[sp_]), int(shard_of[sq])
            loads[a] += cost[sq] - cost[sp_]
            loads[b] += cost[sp_] - cost[sq]
            shard_of[sp_], shard_of[sq] = b, a
    out: List[List[int]] = [[] for _ in range(K)]
    for p in range(P):
        out[int(shard_of[p])].append(p)
    return out


def plan_shards(tiles, n_shards: int, mode: str = "cost", *,
                balance_tol: float = 1.05) -> ShardPlan:
    """Assign destination partitions to ``n_shards`` mesh shards.

    ``mode="cost"`` runs deterministic LPT (largest processing time) greedy
    balancing on the padded edge-slot cost — best balance for a fixed tile
    set.  ``mode="mincut"`` seeds with the LPT assignment and then runs a
    deterministic greedy refinement over the partition-adjacency graph
    (:func:`partition_adjacency`) that minimizes cross-shard source reads
    subject to a padded-cost cap of ``max(LPT max load, balance_tol x mean)``
    — by construction its :meth:`ShardPlan.edge_cut` never exceeds LPT's.
    ``mode="contiguous"`` splits the partition range evenly — a pure
    function of (P, K), which the serving layer needs so structurally-equal
    requests land on one shard layout regardless of edge distribution.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    P = tiles.n_dst_parts
    cost = partition_costs(tiles)
    adj = partition_adjacency(tiles)
    if mode == "contiguous":
        bounds = _even_bounds(P, n_shards)
        parts = [np.arange(bounds[k], bounds[k + 1], dtype=np.int64)
                 for k in range(n_shards)]
    elif mode == "cost":
        parts = [np.sort(np.asarray(a, np.int64))
                 for a in _lpt_assign(cost, n_shards)]
    elif mode == "mincut":
        assign = _mincut_refine(_lpt_assign(cost, n_shards), cost, adj,
                                n_shards, balance_tol)
        parts = [np.sort(np.asarray(a, np.int64)) for a in assign]
    else:
        raise ValueError(f"unknown shard mode {mode!r}")

    shard_of = np.zeros(P, np.int32)
    slot_of = np.zeros(P, np.int32)
    for k, ps in enumerate(parts):
        shard_of[ps] = k
        slot_of[ps] = np.arange(len(ps), dtype=np.int32)
    return ShardPlan(n_shards=n_shards, parts_of_shard=parts,
                     shard_of_part=shard_of, local_slot_of_part=slot_of,
                     part_cost=cost, mode=mode, part_adj=adj)


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Static neighbor-restricted boundary-exchange sets for a
    :class:`ShardPlan`.

    Derived once per (tile set, plan): which vertex rows each shard's tiles
    *read* as gather sources, which rows each shard therefore has to *send*
    (rows it owns that at least one remote shard reads), and the (K, K)
    pairwise cut-row counts the simulator's restricted-exchange cost model
    consumes.  Rows a shard owns are never in its own receive set — the
    destination-side (``recvDst``) reads are device-local by ShardPlan
    construction, which :func:`repro.core.analysis.hazards.verify_exchange`
    proves statically.
    """

    n_shards: int
    n_vertices: int
    read_rows: np.ndarray           # (K, V) bool — rows shard k reads as src
    owner_of_row: np.ndarray        # (V,) int32 — owning shard per vertex row
    send_rows: Tuple[np.ndarray, ...]  # per shard: owned rows remotes read, asc
    pair_rows: np.ndarray           # (K, K) int64 — rows j reads from owner k

    @property
    def cut_rows(self) -> int:
        """Total rows shipped per boundary by the restricted exchange."""
        off = ~np.eye(self.n_shards, dtype=bool)
        return int(self.pair_rows[off].sum())

    @property
    def max_send(self) -> int:
        """Largest per-shard send set (static send-buffer capacity)."""
        return max((len(r) for r in self.send_rows), default=0)


def exchange_sets(tiles, plan: ShardPlan) -> ExchangePlan:
    """Derive the static send/recv row sets of the restricted exchange.

    A row must be sent by its owning shard iff any *other* shard's tiles
    read it as a gather source.  Reads are taken from the real (unmasked)
    ``src_ids`` slots of every tile, ownership from the destination
    partition ranges — both pure numpy, run per request on the serving path.
    """
    V = tiles.n_vertices
    K = plan.n_shards
    part_start = np.asarray(tiles.part_start)
    reads = np.zeros((K, V), bool)

    def accumulate(ts: TileSet) -> None:
        if ts.n_tiles == 0 or ts.s_max == 0:
            return
        shard = plan.shard_of_part[np.asarray(ts.part_id)]
        valid = np.arange(ts.s_max)[None, :] < np.asarray(ts.n_src)[:, None]
        rows = np.broadcast_to(shard[:, None], valid.shape)
        reads[rows[valid], np.asarray(ts.src_ids)[valid]] = True

    if isinstance(tiles, BucketedTileSet):
        for b in tiles.buckets:
            accumulate(b)
    else:
        accumulate(tiles)

    row_part = np.searchsorted(part_start, np.arange(V), side="right") - 1
    owner = plan.shard_of_part[row_part].astype(np.int32)
    n_readers = reads.sum(axis=0)
    send_rows = []
    pair = np.zeros((K, K), np.int64)
    for k in range(K):
        owned = owner == k
        read_elsewhere = (n_readers - reads[k].astype(np.int64)) > 0
        send_rows.append(np.nonzero(owned & read_elsewhere)[0].astype(np.int64))
        for j in range(K):
            if j != k:
                pair[k, j] = int((owned & reads[j]).sum())
    return ExchangePlan(n_shards=K, n_vertices=V, read_rows=reads,
                        owner_of_row=owner, send_rows=tuple(send_rows),
                        pair_rows=pair)


def build_tiles(graph: Graph, n_dst_parts: int, n_src_parts: int, *,
                sparse: bool = True, pad_multiple: int = 8,
                reorder: Optional[str] = None, n_buckets: Optional[int] = None,
                layout: str = "coo"):
    """One-stop tiling entry: optional degree reordering + grid tiling
    (+ size bucketing).

    ``reorder`` opts into the paper's §5.3 Degree Sorting before tiling:
    ``"degree"``/``"in"`` sort by in-degree, ``"out"`` by out-degree
    (``None`` keeps vertex ids).  Concentrating high-degree vertices into the
    low-id partitions shrinks the sparse tiles elsewhere, which also tightens
    the padded (S_max, E_max) envelope the static-shape executors pay for.
    ``n_buckets`` additionally post-bins tiles via :func:`bucket_tiles`.
    ``layout="csr"`` converts each tile to CSR-within-tile storage
    (:func:`csr_tiles`) before any bucketing.

    Returns ``(tiles, reordering)`` — run with ``reordering.graph`` and
    permute features in / outputs back through the
    :class:`~repro.core.reorder.Reordering` (the identity mapping when
    ``reorder=None``).
    """
    from . import reorder as R

    if reorder in (None, "identity"):
        ro = R.identity_order(graph)
    elif reorder in ("degree", "in", "out"):
        ro = R.degree_sort(graph, by="out" if reorder == "out" else "in")
    else:
        raise ValueError(f"unknown reorder mode {reorder!r}")
    tiles = grid_tile(ro.graph, n_dst_parts, n_src_parts, sparse=sparse,
                      pad_multiple=pad_multiple, layout=layout)
    if n_buckets is not None:
        tiles = bucket_tiles(tiles, n_buckets, pad_multiple=pad_multiple)
    return tiles, ro


def choose_grid(n_vertices: int, dim: int, vmem_budget_bytes: int = 8 << 20,
                dtype_bytes: int = 4) -> Tuple[int, int]:
    """Pick (n_dst_parts, n_src_parts) so a tile's working set — one source
    block + one destination block of embeddings — fits the on-chip budget
    (paper §5.1; adapted from the 21 MB eDRAM UEM to a VMEM budget)."""
    row_bytes = dim * dtype_bytes
    # budget split: half for sources, half for destination accumulators
    rows_per_block = max(64, vmem_budget_bytes // (2 * row_bytes))
    parts = max(1, int(math.ceil(n_vertices / rows_per_block)))
    return parts, parts
