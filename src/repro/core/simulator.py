"""Event-driven, cycle-approximate simulator of the ZIPPER architecture
(paper §7, §8.1 "Performance Simulation").

Executes the stream task DAG from :mod:`repro.core.streams` against the
hardware resources: ``n_mu`` Matrix Units, ``n_vu`` Vector Units, one HBM
channel, and the s/e stream slots.  The two-level scheduling of the paper is
reproduced: a first-ready-first-serve scheduler admits tasks into stream
slots; a dispatcher issues each task's instructions to a free target unit
(FIFO per unit class).

Outputs: total cycles, per-unit busy cycles (utilization), off-chip traffic,
and the energy/area models of §8.1.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import math
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .isa import Instr
from .streams import HWConfig, Task, build_task_graph, instr_cycles
from .isa import SDEFunctions
from .tiling import TileSet

# ---------------------------------------------------------------------------
# energy / area constants (paper §8.1 methodology)
# ---------------------------------------------------------------------------

ENERGY = {
    "mac_pj": 0.56,          # per MAC, 16 nm systolic synthesis class
    "vu_op_pj": 0.12,        # per SIMD lane-op
    "uem_pj_per_byte": 0.35, # eDRAM access (Cacti 6.5, converted to 16 nm)
    "th_pj_per_byte": 0.11,  # SRAM tile hub
    "offchip_pj_per_bit": 7.0,  # paper: 7 pJ/bit HBM
}

AREA_MM2 = {"MU": 1.00, "VU": 0.06, "UEM": 52.31, "TH": 0.15}  # paper Table 5


@dataclasses.dataclass
class SimResult:
    cycles: int
    time_ms: float
    unit_busy: Dict[str, int]
    utilization: Dict[str, float]
    offchip_read: int
    offchip_write: int
    macs: int
    elw_ops: int
    energy_mj: float
    n_tasks: int

    def speedup_over(self, other: "SimResult") -> float:
        return other.time_ms / self.time_ms


def area_mm2(hw: HWConfig) -> float:
    """Paper Table 5 composition for an arbitrary unit count."""
    return (AREA_MM2["MU"] * hw.n_mu + AREA_MM2["VU"] * hw.n_vu
            + AREA_MM2["UEM"] * hw.uem_mbytes / 21.0 + AREA_MM2["TH"])


def _energy_mj(stats: Dict[str, int], hw: HWConfig) -> float:
    onchip_bytes = (stats["macs"] * 2 + stats["elw_ops"] * 2) * hw.dtype_bytes
    pj = (stats["macs"] * ENERGY["mac_pj"]
          + stats["elw_ops"] * ENERGY["vu_op_pj"]
          + onchip_bytes * ENERGY["uem_pj_per_byte"]
          + (stats["offchip_read"] + stats["offchip_write"]) * 8 * ENERGY["offchip_pj_per_bit"])
    return pj * 1e-9  # pJ -> mJ


def simulate(tasks: List[Task], stats: Dict[str, int], hw: HWConfig) -> SimResult:
    """Discrete-event simulation with unit contention and stream slots."""
    n_tasks = len(tasks)
    indeg = [0] * n_tasks
    succs: List[List[int]] = [[] for _ in range(n_tasks)]
    for t in tasks:
        for d in t.deps:
            indeg[t.tid] += 1
            succs[d].append(t.tid)

    # resources: unit -> free count
    free = {"MU": hw.n_mu, "VU": hw.n_vu, "MEM": 1, "CTRL": 1 << 30}
    slots = {"s": hw.n_sstreams, "e": hw.n_estreams, "d": 1}
    busy = {"MU": 0, "VU": 0, "MEM": 0, "CTRL": 0}

    # per-task instruction programs: list of (unit, cycles)
    progs: List[List[Tuple[str, int]]] = []
    for t in tasks:
        prog: List[Tuple[str, int]] = []
        if t.bytes_in:
            prog.append(("MEM", max(1, int(t.bytes_in / hw.hbm_bytes_per_cycle))))
        for ins, m, k, n in t.instrs:
            ins2 = dataclasses.replace(ins, k=k, n=n)
            cyc = instr_cycles(ins2, m, hw)
            if cyc:
                prog.append((ins.unit, cyc))
        if t.bytes_out:
            prog.append(("MEM", max(1, int(t.bytes_out / hw.hbm_bytes_per_cycle))))
        if not prog:
            prog.append(("CTRL", 1))
        progs.append(prog)

    # event heap: (time, seq, kind, payload)
    heap: List[Tuple[int, int, str, tuple]] = []
    seq = 0
    # FIFOs: tasks awaiting a stream slot / (task, pc) awaiting a unit
    ready_q: Dict[str, Deque[int]] = {k: collections.deque() for k in ("s", "e", "d")}
    unit_q: Dict[str, Deque[Tuple[int, int]]] = {u: collections.deque() for u in free}
    pc = [0] * n_tasks

    def admit(tid_: int, now: int):
        """Try to put a ready task into a stream slot."""
        k = tasks[tid_].kind
        if slots[k] > 0:
            slots[k] -= 1
            issue(tid_, now)
        else:
            ready_q[k].append(tid_)

    def issue(tid_: int, now: int):
        """Dispatch the task's next instruction to its unit (or queue)."""
        nonlocal seq
        unit, cyc = progs[tid_][pc[tid_]]
        if free[unit] > 0:
            free[unit] -= 1
            busy[unit] += cyc
            heapq.heappush(heap, (now + cyc, seq, "instr_done", (tid_, unit, cyc)))
            seq += 1
        else:
            unit_q[unit].append((tid_, pc[tid_]))

    now = 0
    for t in tasks:
        if indeg[t.tid] == 0:
            admit(t.tid, 0)

    completed = 0
    while heap:
        now, _, ev, payload = heapq.heappop(heap)
        if ev != "instr_done":
            continue
        tid_, unit, _cyc = payload
        free[unit] += 1
        # feed a queued instruction into the freed unit (first-ready-first-serve)
        if unit_q[unit]:
            qtid, _qpc = unit_q[unit].popleft()
            free[unit] -= 1
            u2, cyc2 = progs[qtid][pc[qtid]]
            assert u2 == unit
            busy[unit] += cyc2
            # the global seq counter keeps re-issued events deterministically
            # ordered among same-cycle completions
            heapq.heappush(heap, (now + cyc2, seq, "instr_done", (qtid, unit, cyc2)))
            seq += 1
        pc[tid_] += 1
        if pc[tid_] < len(progs[tid_]):
            issue(tid_, now)
            continue
        # task complete: release stream slot, wake dependents
        completed += 1
        k = tasks[tid_].kind
        slots[k] += 1
        if ready_q[k]:
            admit(ready_q[k].popleft(), now)
        for s2 in succs[tid_]:
            indeg[s2] -= 1
            if indeg[s2] == 0:
                admit(s2, now)

    assert completed == n_tasks, f"deadlock: {completed}/{n_tasks} tasks done"
    total = max(now, 1)
    n_inst = {"MU": hw.n_mu, "VU": hw.n_vu, "MEM": 1}
    util = {u: busy[u] / (total * n_inst[u]) for u in ("MU", "VU", "MEM")}
    return SimResult(
        cycles=total,
        time_ms=total / (hw.freq_ghz * 1e6),
        unit_busy=dict(busy),
        utilization=util,
        offchip_read=stats["offchip_read"],
        offchip_write=stats["offchip_write"],
        macs=stats["macs"],
        elw_ops=stats["elw_ops"],
        energy_mj=_energy_mj(stats, hw),
        n_tasks=n_tasks,
    )


def simulate_model(sde: SDEFunctions, tiles: TileSet,
                   hw: Optional[HWConfig] = None,
                   padded: bool = False,
                   inter_layer: str = "barrier") -> SimResult:
    """``tiles`` may be a TileSet or BucketedTileSet; ``padded=True`` costs
    each tile at its batch's padded shape (see ``streams.build_task_graph``),
    so bucketed batching's reduced padding shows up as fewer cycles.
    ``inter_layer="pipelined"`` relaxes layer-boundary barriers to their true
    data dependencies (multi-layer programs), modeling the same overlap the
    fused multi-layer schedule exploits."""
    hw = hw or HWConfig()
    tasks, stats = build_task_graph(sde, tiles, hw, padded=padded,
                                    inter_layer=inter_layer)
    return simulate(tasks, stats, hw)


@dataclasses.dataclass
class ShardedSimResult:
    """Multi-chip cost model: per-chip event-driven simulation plus the
    layer-boundary exchange traffic (the one cross-chip all-gather of the
    drained partition layout per boundary)."""

    n_chips: int
    cycles: int                      # max per-chip cycles + exchange stalls
    time_ms: float
    per_chip_cycles: List[int]
    exchange_cycles: int             # total cycles spent in boundary exchanges
    exchange_bytes: int              # total cross-chip traffic
    n_exchanges: int
    chip_results: List[SimResult]
    exchange: str = "restricted"     # exchange cost model used
    model_axis: int = 1              # feature-axis mesh width (2-D mesh)
    edge_cut_rows: int = 0           # rows the restricted exchange ships/boundary

    def speedup_over(self, other) -> float:
        return other.time_ms / self.time_ms

    @property
    def balance(self) -> float:
        """max / mean per-chip cycles (1.0 = perfectly balanced)."""
        mean = sum(self.per_chip_cycles) / max(len(self.per_chip_cycles), 1)
        return max(self.per_chip_cycles) / max(mean, 1.0)


def _scale_sde_model(sde: SDEFunctions, m: int) -> SDEFunctions:
    """Column-parallel feature split for the 2-D mesh's ``model`` axis: each
    of ``m`` ranks computes a ``ceil(n / m)``-wide slice of every
    instruction's output lanes (contractions keep their full ``krows``) and
    loads/stores its slice of the vertex features."""
    def sdim(n: int) -> int:
        return max(1, -(-int(n) // m))

    def scale(bucket):
        return {lvl: [dataclasses.replace(i, n=sdim(i.n)) for i in instrs]
                for lvl, instrs in bucket.items()}

    return dataclasses.replace(
        sde, s=scale(sde.s), e=scale(sde.e), d=scale(sde.d),
        src_load_dim=sdim(sde.src_load_dim),
        dst_load_dim=sdim(sde.dst_load_dim), out_dim=sdim(sde.out_dim))


def simulate_sharded(sde: SDEFunctions, tiles: TileSet,
                     hw: Optional[HWConfig] = None, n_chips: int = 2,
                     padded: bool = False, inter_layer: str = "pipelined",
                     mode: str = "cost",
                     exchange_dim: Optional[int] = None,
                     exchange: str = "restricted",
                     model_axis: int = 1) -> ShardedSimResult:
    """Cost a sharded execution over ``n_chips`` chips, each owning whole
    destination partitions (:func:`~repro.core.tiling.plan_shards`).

    Each chip's task graph (its partitions only) runs through the
    event-driven simulator independently; chips synchronize at the
    ``n_layers - 1`` layer boundaries.  Per-boundary drained widths come
    from the static exchange census (``sde.boundary_dims``) so stacks with
    mixed hidden widths cost each boundary its own width;
    ``exchange_dim`` overrides them all, and the pre-census fallback is
    ``max(src_load_dim, out_dim)``.  Final outputs are written to each
    chip's own HBM (already costed as task ``bytes_out``), so they add no
    exchange.

    ``exchange`` picks the boundary-collective cost model:

    * ``"restricted"`` — the neighbor-restricted exchange: each shard ships
      only the rows remote shards' gather blocks actually read
      (:func:`~repro.core.tiling.exchange_sets`), costed by actual cut
      bytes; per-boundary cycles are the busiest chip's max of send/recv
      bytes over the link bandwidth.
    * ``"allgather"`` — the concat all-gather baseline: every chip receives
      every row (ring model: each link carries ``(K-1)/K`` of the buffer).

    ``model_axis=M > 1`` grows the mesh to 2-D ``("shards", "model")`` for
    wide hidden dims: per-chip compute and the shards-axis exchange width
    shrink to the rank's ``ceil(width / M)`` feature slice, and each
    boundary additionally pays a model-axis gather reassembling full-width
    rows for the next layer's contraction.
    """
    from .tiling import exchange_sets, plan_shards

    if model_axis < 1:
        raise ValueError(f"model_axis must be >= 1, got {model_axis}")
    hw = hw or HWConfig()
    plan = plan_shards(tiles, n_chips, mode=mode)
    sde_rank = _scale_sde_model(sde, model_axis) if model_axis > 1 else sde
    chips: List[SimResult] = []
    for k in range(n_chips):
        tasks, stats = build_task_graph(sde_rank, tiles, hw, padded=padded,
                                        inter_layer=inter_layer,
                                        parts=plan.parts_of_shard[k])
        chips.append(simulate(tasks, stats, hw))

    K, M = n_chips, model_axis
    n_exch = max(sde.n_layers - 1, 0) if (K > 1 or M > 1) else 0
    fallback = max(max(sde.src_load_dim, sde.out_dim), 1)
    if exchange_dim is not None:
        widths = [max(int(exchange_dim), 1)] * n_exch
    elif len(sde.boundary_dims) == n_exch:
        widths = [max(int(w), 1) for w in sde.boundary_dims]
    else:
        widths = [fallback] * n_exch
    rows = int(tiles.part_size.sum())
    ex = exchange_sets(tiles, plan) if (K > 1 and exchange == "restricted") \
        else None
    if K > 1 and exchange not in ("restricted", "allgather"):
        raise ValueError(f"unknown exchange cost model {exchange!r}")
    bw = hw.interconnect_bytes_per_cycle
    exch_cycles = 0
    exch_bytes = 0
    for w in widths:
        wm = max(1, -(-w // M))                  # per-rank feature slice
        if ex is not None:
            out_b = ex.pair_rows.sum(axis=1) * wm * hw.dtype_bytes
            in_b = ex.pair_rows.sum(axis=0) * wm * hw.dtype_bytes
            busiest = int(np.maximum(out_b, in_b).max()) if K > 1 else 0
            exch_cycles += int(math.ceil(busiest / bw))
            exch_bytes += ex.cut_rows * wm * hw.dtype_bytes * M
        elif K > 1:
            full = rows * wm * hw.dtype_bytes
            exch_cycles += int(math.ceil(full * (K - 1) / K / bw))
            exch_bytes += full * (K - 1) * M
        if M > 1:
            # model-axis reassembly: each rank gathers the other (M-1)
            # slices of every row it will read next layer (all rows under
            # all-gather; own + received rows under the restricted exchange)
            if ex is not None:
                need = np.bincount(ex.owner_of_row, minlength=K).astype(
                    np.int64) + ex.pair_rows.sum(axis=0)
                need_max = int(need.max())
            else:
                need_max = rows
            mbytes = need_max * (w - wm) * hw.dtype_bytes
            exch_cycles += int(math.ceil(mbytes * (M - 1) / M / bw))
            exch_bytes += mbytes * K * M

    total = max(c.cycles for c in chips) + exch_cycles
    return ShardedSimResult(
        n_chips=n_chips, cycles=total,
        time_ms=total / (hw.freq_ghz * 1e6),
        per_chip_cycles=[c.cycles for c in chips],
        exchange_cycles=exch_cycles,
        exchange_bytes=int(exch_bytes),
        n_exchanges=n_exch, chip_results=chips,
        exchange=exchange if K > 1 else "local",
        model_axis=model_axis,
        edge_cut_rows=(ex.cut_rows if ex is not None else 0))


def serialized_baseline(sde: SDEFunctions, tiles: TileSet,
                        hw: Optional[HWConfig] = None,
                        padded: bool = False) -> SimResult:
    """Non-pipelined tiling baseline (paper Fig 4b): one stream of each kind,
    so tiles are processed strictly one after another."""
    hw = (hw or HWConfig()).scaled(n_sstreams=1, n_estreams=1)
    tasks, stats = build_task_graph(sde, tiles, hw, padded=padded)
    # serialize: chain every task after the previous one
    for i in range(1, len(tasks)):
        if i - 1 not in tasks[i].deps:
            tasks[i].deps.append(i - 1)
    return simulate(tasks, stats, hw)
