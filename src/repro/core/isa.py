"""ZIPPER ISA (paper Table 2) and SDE-function code generation.

Three instruction classes:
  * computational — ELW (VU), GEMM/BMM (MU), GOP scatter/gather (VU)
  * data-transfer — LD.SRC / LD.DST / LD.EDGE / ST.DST (memory controller)
  * synchronization — SIGNAL / WAIT / FCH.TILE / FCH.PTT / UPD.PTT / CHK.PTT

Instructions are coarse-grained: one instruction operates on all vertices or
edges of a tile (paper §6.1 "ISA").  Codegen lowers an :class:`SDEPlan` into
per-(role, phase) instruction *templates*; row counts (n_src / n_edge /
partition size) are bound per tile by the scheduler / simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from . import ir as IR
from .compiler import SDEPlan
from . import passes

#: dispatch overhead charged per instruction (decoder + operand setup), cycles
DISPATCH_CYCLES = 8

_ELW_OPCODE = {
    "add": "ELW.ADD", "sub": "ELW.SUB", "mul": "ELW.MUL", "div": "ELW.DIV",
    "max2": "ELW.MAX", "min2": "ELW.MIN", "exp": "ELW.EXP", "relu": "ELW.RELU",
    "leaky_relu": "ELW.LRELU", "sigmoid": "ELW.SIG", "tanh": "ELW.TANH",
    "neg": "ELW.NEG", "identity": "ELW.CPY", "sqrt": "ELW.SQRT",
    "rsqrt": "ELW.RSQRT", "bias_add": "ELW.ADDB",
}
_GOP_OPCODE = {
    "recvSrc": "SCTR.OUTE", "recvDst": "SCTR.INE",
    "sendDstSum": "GTHR.DST.SUM", "sendDstMax": "GTHR.DST.MAX",
    "sendDstMean": "GTHR.DST.SUM",  # mean = sum + count (extra ELW.DIV emitted)
}


@dataclasses.dataclass
class Instr:
    opcode: str
    unit: str            # 'MU' | 'VU' | 'MEM' | 'CTRL'
    rows: str = ""       # symbolic row count: 'n_src' | 'n_edge' | 'n_dst'
    k: int = 0           # inner dim (GEMM/GEMV)
    n: int = 1           # output feature dim / ELW width
    weight_bytes: int = 0  # weight-buffer traffic (GEMM/BMM)
    fused: int = 1       # number of IR ops folded into this instruction
    tag: str = ""

    def bound(self, n_src: int, n_edge: int, n_dst: int) -> Tuple[int, int, int]:
        m = {"n_src": n_src, "n_edge": n_edge, "n_dst": n_dst, "": 0}[self.rows]
        return m, self.k, self.n


def _compute_instr(node: IR.IRNode, rows: str) -> Instr:
    if node.op == "matmul":
        k, n = node.attrs["wshape"][-2], node.attrs["wshape"][-1]
        return Instr("GEMM", "MU", rows, k=k, n=n, weight_bytes=4 * k * n, tag=node.op)
    if node.op == "bmm_edge":
        k, n = node.attrs["wshape"][-2], node.attrs["wshape"][-1]
        # index-guided BMM: per-row weight select defeats weight-stationarity
        return Instr("BMM", "MU", rows, k=k, n=n, weight_bytes=4 * k * n, tag=node.op)
    if node.op == "gemv":
        # matrix-vector runs on the VU (paper Table 2 lists GEMV under ELW)
        return Instr("GEMV", "VU", rows, k=node.attrs["wshape"][0], n=1, tag=node.op)
    return Instr(_ELW_OPCODE[node.op], "VU", rows, n=node.dim, tag=node.op)


@dataclasses.dataclass
class SDEFunctions:
    """Instruction templates per (role, phase-level).

    roles: 's' (source / per tile), 'e' (edge / per tile),
           'd' (destination / per partition; includes pre- and post-gather ops)
    """

    s: Dict[int, List[Instr]]
    e: Dict[int, List[Instr]]
    d: Dict[int, List[Instr]]
    src_load_dim: int   # feature width loaded per source vertex
    dst_load_dim: int   # feature width loaded per destination vertex
    edge_feat_dim: int  # per-edge input feature width (etype / efeat)
    out_dim: int        # stored output width per destination vertex
    max_level: int

    def all_levels(self):
        return range(self.max_level + 1)


def emit_sde(plan: SDEPlan, fuse: bool = True) -> SDEFunctions:
    prog = plan.prog
    fusion_nodes: Dict[int, int] = {}  # node id -> fusion group leader id
    if fuse:
        for group in passes.fuse_elementwise(prog):
            for nid in group:
                fusion_nodes[nid] = group[0]

    s: Dict[int, List[Instr]] = {}
    e: Dict[int, List[Instr]] = {}
    d: Dict[int, List[Instr]] = {}

    def _push(bucket: Dict[int, List[Instr]], lvl: int, instr: Instr):
        bucket.setdefault(lvl, []).append(instr)

    src_load_dim = dst_load_dim = edge_feat_dim = out_dim = 0
    for seg in prog.segments:
        for node in seg.toposort():
            lvl = plan.level[node.id]
            if node.op == "input":
                if seg.kind == "vertex":
                    roles = plan.role[node.id]
                    if "src" in roles:
                        src_load_dim += node.dim
                    if "dst" in roles:
                        dst_load_dim += node.dim
                else:
                    edge_feat_dim += node.dim
                continue
            if node.op == "output":
                out_dim += node.dim
                continue
            if seg.kind == "edge":
                if node.is_recv():
                    _push(e, lvl, Instr(_GOP_OPCODE[node.op], "VU", "n_edge", n=node.dim, tag=node.op))
                elif node.is_send():
                    _push(e, lvl, Instr(_GOP_OPCODE[node.op], "VU", "n_edge", n=node.dim, tag=node.op))
                    if node.op == "sendDstMean":
                        _push(d, lvl + 1, Instr("ELW.DIV", "VU", "n_dst", n=node.dim, tag="mean-div"))
                else:
                    _push(e, lvl, _compute_instr(node, "n_edge"))
            else:
                if node.is_send() or node.is_recv():
                    continue  # vertex-side comm is realized by the edge SCTR/GTHR
                roles = plan.role[node.id]
                if "src" in roles:
                    _push(s, lvl, _compute_instr(node, "n_src"))
                if "dst" in roles:
                    _push(d, lvl, _compute_instr(node, "n_dst"))

    # element-wise fusion: collapse adjacent VU ELW instrs that came from one
    # fusion group into a single instruction (saves dispatch overhead)
    if fuse:
        for bucket in (s, e, d):
            for lvl, instrs in bucket.items():
                fused: List[Instr] = []
                for ins in instrs:
                    if (fused and ins.unit == "VU" and fused[-1].unit == "VU"
                            and ins.opcode.startswith("ELW") and fused[-1].opcode.startswith("ELW")
                            and ins.rows == fused[-1].rows):
                        fused[-1] = dataclasses.replace(
                            fused[-1], fused=fused[-1].fused + 1,
                            n=fused[-1].n + ins.n,  # lane-work adds up
                            opcode="ELW.FUSED", tag=fused[-1].tag + "+" + ins.tag)
                    else:
                        fused.append(ins)
                bucket[lvl] = fused

    return SDEFunctions(s=s, e=e, d=d,
                        src_load_dim=src_load_dim, dst_load_dim=dst_load_dim,
                        edge_feat_dim=edge_feat_dim, out_dim=out_dim,
                        max_level=plan.max_level)
