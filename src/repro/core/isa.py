"""ZIPPER ISA (paper Table 2) and SDE-function code generation.

Three instruction classes:
  * computational — ELW (VU), GEMM/BMM (MU), GOP scatter/gather (VU),
    and the fused kernel-block instructions (SPMM.TILE / SFTM.*) emitted
    when a gather block is dispatched to a Pallas hardware block
  * data-transfer — LD.SRC / LD.DST / LD.EDGE / ST.DST (memory controller)
  * synchronization — SIGNAL / WAIT / FCH.TILE / FCH.PTT / UPD.PTT / CHK.PTT

Instructions are coarse-grained: one instruction operates on all vertices or
edges of a tile (paper §6.1 "ISA").  Codegen lowers a
:class:`~repro.core.schedule.ScheduledProgram` — the SAME block structure the
JAX engines interpret — into per-(role, phase) instruction *templates*; row
counts (n_src / n_edge / partition size) are bound per tile by the scheduler
/ simulator.  A plain :class:`~repro.core.compiler.SDEPlan` is accepted for
convenience and lowered internally (``kernel_dispatch=False`` by default, the
paper's pure multi-phase schedule).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple, Union

from . import ir as IR
from .compiler import SDEPlan

#: dispatch overhead charged per instruction (decoder + operand setup), cycles
DISPATCH_CYCLES = 8

_ELW_OPCODE = {
    "add": "ELW.ADD", "sub": "ELW.SUB", "mul": "ELW.MUL", "div": "ELW.DIV",
    "max2": "ELW.MAX", "min2": "ELW.MIN", "exp": "ELW.EXP", "relu": "ELW.RELU",
    "leaky_relu": "ELW.LRELU", "sigmoid": "ELW.SIG", "tanh": "ELW.TANH",
    "neg": "ELW.NEG", "identity": "ELW.CPY", "sqrt": "ELW.SQRT",
    "rsqrt": "ELW.RSQRT", "bias_add": "ELW.ADDB",
}
_GOP_OPCODE = {
    "recvSrc": "SCTR.OUTE", "recvDst": "SCTR.INE",
    "sendDstSum": "GTHR.DST.SUM", "sendDstMax": "GTHR.DST.MAX",
    "sendDstMean": "GTHR.DST.SUM",  # mean = sum + count (extra ELW.DIV emitted)
}


@dataclasses.dataclass
class Instr:
    opcode: str
    unit: str            # 'MU' | 'VU' | 'MEM' | 'CTRL'
    rows: str = ""       # symbolic row count: 'n_src' | 'n_edge' | 'n_dst'
    k: int = 0           # inner dim (GEMM/GEMV)
    krows: str = ""      # symbolic inner dim (kernel blocks: bound per tile)
    n: int = 1           # output feature dim / ELW width
    weight_bytes: int = 0  # weight-buffer traffic (GEMM/BMM)
    fused: int = 1       # number of IR ops folded into this instruction
    tag: str = ""

    def bound(self, n_src: int, n_edge: int, n_dst: int) -> Tuple[int, int, int]:
        dims = {"n_src": n_src, "n_edge": n_edge, "n_dst": n_dst, "": 0}
        m = dims[self.rows]
        k = dims[self.krows] if self.krows else self.k
        return m, k, self.n


def _compute_instr(node: IR.IRNode, rows: str) -> Instr:
    if node.op == "matmul":
        k, n = node.attrs["wshape"][-2], node.attrs["wshape"][-1]
        return Instr("GEMM", "MU", rows, k=k, n=n, weight_bytes=4 * k * n, tag=node.op)
    if node.op == "bmm_edge":
        k, n = node.attrs["wshape"][-2], node.attrs["wshape"][-1]
        # index-guided BMM: per-row weight select defeats weight-stationarity
        return Instr("BMM", "MU", rows, k=k, n=n, weight_bytes=4 * k * n, tag=node.op)
    if node.op == "gemv":
        # matrix-vector runs on the VU (paper Table 2 lists GEMV under ELW)
        return Instr("GEMV", "VU", rows, k=node.attrs["wshape"][0], n=1, tag=node.op)
    return Instr(_ELW_OPCODE[node.op], "VU", rows, n=node.dim, tag=node.op)


def _kernel_instrs(g, layout: str = "coo") -> List[Instr]:
    """Instruction template of one Pallas-dispatched gather block.

    ``layout="coo"``: the dense tile kernels run the aggregation as an
    (n_dst × k) MXU matmul per tile instead of per-edge VU gather
    indirection — that shape shift is exactly what the simulator should
    cost.  ``layout="csr"``: the kernels walk per-tile row pointers, so the
    work is E-proportional VU gather traffic (GTHR-prefixed opcodes pick up
    the per-row indirection surcharge in ``instr_cycles``) rather than a
    dense (n_dst × n_src) matmul over mostly-empty adjacency — on
    heavy-tailed graphs the dense max-partition block is what keeps the
    kernel configs behind the scan incumbent.
    """
    from . import schedule as S

    if layout == "csr":
        if g.kernel == S.KERNEL_SPMM:
            # row-pointer walk + per-edge gather-accumulate of F-wide rows
            return [Instr("GTHR.CSR", "VU", "n_edge", n=g.acc.dim,
                          tag=g.kernel)]
        if g.kernel == S.KERNEL_SPMM_WEIGHTED:
            # no densify pass: weights ride the same per-edge walk (+1 lane
            # for the weight multiply)
            return [Instr("GTHR.CSR", "VU", "n_edge", n=g.acc.dim + 1,
                          tag=g.kernel)]
        if g.kernel == S.KERNEL_SEGMENT_SOFTMAX:
            # per-edge mask/exp/rescale, then the row-pointer-walk reduce
            return [Instr("SFTM.EDGE", "VU", "n_edge", n=3,
                          tag="online-softmax"),
                    Instr("SFTM.CSR", "VU", "n_edge", n=g.acc.dim,
                          tag=g.kernel)]
        raise ValueError(f"unknown kernel tag {g.kernel}")

    if g.kernel == S.KERNEL_SPMM:
        return [Instr("SPMM.TILE", "MU", "n_dst", krows="n_src", n=g.acc.dim,
                      tag=g.kernel)]
    if g.kernel == S.KERNEL_SPMM_WEIGHTED:
        # runtime densification of α (VU scatter) + the dense tile matmul
        return [Instr("DENS.W", "VU", "n_edge", n=1, tag="densify"),
                Instr("SPMM.TILE", "MU", "n_dst", krows="n_src", n=g.acc.dim,
                      tag=g.kernel)]
    if g.kernel == S.KERNEL_SEGMENT_SOFTMAX:
        # one online-softmax pass: per-edge mask/exp/rescale on the VU, then
        # the (n_dst × n_edge) @ (n_edge × F) probability-value matmul
        return [Instr("SFTM.EDGE", "VU", "n_edge", n=3, tag="online-softmax"),
                Instr("SFTM.MM", "MU", "n_dst", krows="n_edge", n=g.acc.dim,
                      tag=g.kernel)]
    raise ValueError(f"unknown kernel tag {g.kernel}")


@dataclasses.dataclass
class SDEFunctions:
    """Instruction templates per (role, phase-level).

    roles: 's' (source / per tile), 'e' (edge / per tile),
           'd' (destination / per partition; includes pre- and post-gather ops)
    """

    s: Dict[int, List[Instr]]
    e: Dict[int, List[Instr]]
    d: Dict[int, List[Instr]]
    src_load_dim: int   # feature width loaded per source vertex
    dst_load_dim: int   # feature width loaded per destination vertex
    edge_feat_dim: int  # per-edge input feature width (etype / efeat)
    out_dim: int        # stored output width per destination vertex
    max_level: int
    #: level -> GNN layer whose tile work runs at that level (stacked models;
    #: the stream scheduler uses this to pipeline across layer boundaries)
    level_layer: Dict[int, int] = dataclasses.field(default_factory=dict)
    n_layers: int = 1
    #: tile edge layout the templates were emitted for ("coo" | "csr") —
    #: the stream builder keys the edge-index traffic model on it
    layout: str = "coo"
    #: feature width drained at each interior layer boundary, in execution
    #: order (len == n_layers - 1); derived from the static exchange census,
    #: empty when the census is unclean (the simulator then falls back to
    #: ``max(src_load_dim, out_dim)`` for every boundary)
    boundary_dims: Tuple[int, ...] = ()

    def all_levels(self):
        return range(self.max_level + 1)

    def layer_of(self, lvl: int) -> int:
        return self.level_layer.get(lvl, 0)


def emit_sde(plan: Union[SDEPlan, "object"], fuse: bool = True,
             kernel_dispatch: bool = False, layout: str = "coo") -> SDEFunctions:
    """Lower a scheduled program into SDE instruction templates.

    Accepts either a :class:`~repro.core.schedule.ScheduledProgram` (costed
    exactly as the JAX engines execute it, kernel blocks included) or an
    :class:`SDEPlan` (lowered internally with ``kernel_dispatch``).
    ``layout`` selects the kernel-block cost templates — CSR tiles replace
    the dense per-tile matmul with E-proportional row-pointer walks (see
    :func:`_kernel_instrs`) and shrink the edge-index load traffic.
    """
    if layout not in ("coo", "csr"):
        raise ValueError(f"unknown tile layout {layout!r}")
    from . import schedule as S

    sp = (S.lower(plan, kernel_dispatch=kernel_dispatch)
          if isinstance(plan, SDEPlan) else plan)

    s: Dict[int, List[Instr]] = {}
    e: Dict[int, List[Instr]] = {}
    d: Dict[int, List[Instr]] = {}

    def _push(bucket: Dict[int, List[Instr]], lvl: int, instr: Instr):
        bucket.setdefault(lvl, []).append(instr)

    for phase in sp.phases:
        lvl = phase.level
        for node in phase.src.fresh:
            _push(s, lvl, _compute_instr(node, "n_src"))
        for node in phase.dst.fresh:
            if node.op != "output":
                _push(d, lvl, _compute_instr(node, "n_dst"))
        for node in phase.edge.fresh:
            if node.is_recv() or node.is_send():
                _push(e, lvl, Instr(_GOP_OPCODE[node.op], "VU", "n_edge",
                                    n=node.dim, tag=node.op))
                if node.op == "sendDstMean":
                    _push(d, lvl + 1, Instr("ELW.DIV", "VU", "n_dst",
                                            n=node.dim, tag="mean-div"))
            else:
                _push(e, lvl, _compute_instr(node, "n_edge"))
        for g in phase.kernel_gathers():
            for ins in _kernel_instrs(g, layout):
                _push(e, lvl, ins)

    # element-wise fusion: collapse adjacent VU ELW instrs into a single
    # instruction (saves dispatch overhead, mirrors the paper's use of
    # "existing DL optimizations" on the IR)
    if fuse:
        for bucket in (s, e, d):
            for lvl, instrs in bucket.items():
                fused: List[Instr] = []
                for ins in instrs:
                    if (fused and ins.unit == "VU" and fused[-1].unit == "VU"
                            and ins.opcode.startswith("ELW") and fused[-1].opcode.startswith("ELW")
                            and ins.rows == fused[-1].rows):
                        fused[-1] = dataclasses.replace(
                            fused[-1], fused=fused[-1].fused + 1,
                            n=fused[-1].n + ins.n,  # lane-work adds up
                            opcode="ELW.FUSED", tag=fused[-1].tag + "+" + ins.tag)
                    else:
                        fused.append(ins)
                bucket[lvl] = fused

    # per-boundary drained widths from the static exchange census: each
    # interior merged collective ships the sum of its drained nodes' dims
    # (stacks with mixed hidden widths cost each boundary its own width).
    # Import is deferred — analysis.hazards imports streams which imports
    # this module, so it must not run at isa import time.
    from .analysis.hazards import exchange_census

    census = exchange_census(sp)
    boundary_dims: Tuple[int, ...] = ()
    if census.n_collectives == sp.n_layers:
        dim_of = {n.id: n.dim for seg in sp.prog.segments
                  for n in seg.nodes.values()}
        boundary_dims = tuple(
            sum(dim_of.get(nid, 0) for nid in grp)
            for grp in census.groups[:-1])

    return SDEFunctions(s=s, e=e, d=d,
                        src_load_dim=sp.src_load_dim,
                        dst_load_dim=sp.dst_load_dim,
                        edge_feat_dim=sp.edge_feat_dim, out_dim=sp.out_dim,
                        max_level=sp.max_level,
                        level_layer=sp.layer_of_level(), n_layers=sp.n_layers,
                        layout=layout, boundary_dims=boundary_dims)
