"""IR verifier (ISSUE 6 pass 1): def-use dataflow, dim re-inference, strict
op vocabulary, channel integrity, layer-tag monotonicity, dead-code warnings.

Everything :meth:`IRProgram.validate` promises is re-checked here *without*
trusting the channel table (the verifier scans send/recv nodes itself, so an
orphaned ``recv`` that ``rebuild_channels`` would drop — or raise on — still
surfaces as a diagnostic instead of an exception).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .. import ir as IR
from .diagnostics import Diagnostic, find_cycle

#: per-op input arity (None = any); weights/etype live in attrs after
#: construct_ir, so GEMM-class ops carry fewer inputs than their trace form
_ARITY = {}
for _op in IR.ELW_UNARY:
    _ARITY[_op] = 1
for _op in IR.ELW_BINARY:
    _ARITY[_op] = 2
_ARITY.update({"matmul": 1, "gemv": 1, "bmm_edge": 2, "output": 1,
               "input": 0, "param": 0, "const": 0})
for _op in IR.SEND_OPS:
    _ARITY[_op] = 1
for _op in IR.RECV_OPS:
    _ARITY[_op] = 0


def _check_dims(n: IR.IRNode, dims_in: List[int],
                anchor: Dict) -> List[Diagnostic]:
    """Re-infer ``n.dim`` from its input dims and attrs; report mismatches."""
    out: List[Diagnostic] = []

    def err(code: str, msg: str):
        out.append(Diagnostic(code, msg, **anchor))

    if n.op in IR.ELW_BINARY:
        a, b = dims_in
        if a != b and 1 not in (a, b):
            err("ZA004", f"{n.op}: operand dims {a} x {b} do not broadcast")
        elif n.dim != max(a, b):
            err("ZA004", f"{n.op}: declared dim {n.dim}, broadcast of "
                         f"{a} x {b} gives {max(a, b)}")
    elif n.op == "bias_add":
        wshape = n.attrs.get("wshape", ())
        if dims_in and n.dim != dims_in[0]:
            err("ZA004", f"bias_add: dim {n.dim} != input dim {dims_in[0]}")
        elif wshape and wshape[-1] not in (n.dim, 1):
            err("ZA005", f"bias_add: bias shape {wshape} incompatible with "
                         f"dim {n.dim}")
    elif n.op in IR.ELW_UNARY:
        if dims_in and n.dim != dims_in[0]:
            err("ZA004", f"{n.op}: dim {n.dim} != input dim {dims_in[0]}")
    elif n.op in ("matmul", "gemv", "bmm_edge"):
        wshape = tuple(n.attrs.get("wshape", ()))
        if len(wshape) < 2:
            err("ZA005", f"{n.op}: missing/short weight shape {wshape}")
            return out
        k, m = wshape[-2], wshape[-1]
        if dims_in and dims_in[0] != k:
            err("ZA005", f"{n.op}: contraction dim {dims_in[0]} != "
                         f"weight {wshape}[-2]={k}")
        want = 1 if n.op == "gemv" else m
        if n.dim != want:
            err("ZA005", f"{n.op}: output dim {n.dim} != {want} from "
                         f"weight {wshape}")
        if n.op == "bmm_edge" and len(dims_in) > 1 and dims_in[1] != 1:
            err("ZA005", f"bmm_edge: etype operand dim {dims_in[1]} != 1")
    elif n.op == "output" or n.is_send():
        if dims_in and n.dim != dims_in[0]:
            err("ZA004", f"{n.op}: dim {n.dim} != input dim {dims_in[0]}")
    return out


def verify_ir(prog: IR.IRProgram) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    nodes: Dict[int, IR.IRNode] = {}
    seg_label: Dict[int, str] = {}
    seg_kind: Dict[int, str] = {}
    for seg in prog.segments:
        for n in seg.nodes.values():
            if n.id in nodes:
                diags.append(Diagnostic(
                    "ZA002", f"node id %{n.id} defined in both "
                             f"{seg_label[n.id]} and {seg.label}",
                    segment=seg.label, node=n.id, origin="ir"))
            nodes[n.id] = n
            seg_label[n.id] = seg.label
            seg_kind[n.id] = seg.kind

    # --- per-node: vocabulary, arity, def-use, dims ------------------------
    for seg in prog.segments:
        for n in seg.nodes.values():
            anchor = dict(segment=seg.label, node=n.id, origin="ir")
            if n.op not in IR.ALL_OPS:
                diags.append(Diagnostic(
                    "ZA001", f"unknown op {n.op!r} (op_unit would silently "
                             f"bucket it into CTRL)", **anchor))
                continue
            want = _ARITY.get(n.op)
            if want is not None and len(n.inputs) != want:
                diags.append(Diagnostic(
                    "ZA016" if not n.is_recv() else "ZA015",
                    f"{n.op} expects {want} input(s), has {len(n.inputs)}",
                    **anchor))
                continue
            if n.is_recv() and n.inputs:
                diags.append(Diagnostic(
                    "ZA015", f"{n.op} carries intra-segment inputs "
                             f"{n.inputs}; recvs read only their channel",
                    **anchor))
            missing = [i for i in n.inputs if i not in seg.nodes]
            for i in missing:
                where = (f"defined in {seg_label[i]}" if i in nodes
                         else "undefined anywhere")
                diags.append(Diagnostic(
                    "ZA002", f"{n.op} input %{i} is not in this segment "
                             f"({where})", **anchor))
            if not missing:
                dims_in = [seg.nodes[i].dim for i in n.inputs]
                diags.extend(_check_dims(n, dims_in, anchor))
            if (n.is_send() or n.is_recv()) and n.comm_id is None:
                diags.append(Diagnostic(
                    "ZA016", f"{n.op} has no comm id", **anchor))

    # --- per-segment cycles ------------------------------------------------
    for seg in prog.segments:
        succs: Dict[int, List[int]] = {nid: [] for nid in seg.nodes}
        for n in seg.nodes.values():
            for i in n.inputs:
                if i in seg.nodes:
                    succs[i].append(n.id)
        cyc = find_cycle(succs)
        if cyc:
            chain = " -> ".join(f"%{c}" for c in cyc)
            diags.append(Diagnostic(
                "ZA003", f"dataflow cycle {chain}", segment=seg.label,
                node=cyc[0], origin="ir"))
            return diags  # downstream checks need a topological order

    # --- channels: scanned independently of rebuild_channels ---------------
    sends: Dict[int, List[int]] = {}
    recvs: Dict[int, List[int]] = {}
    for n in nodes.values():
        if n.comm_id is None:
            continue
        (sends if n.is_send() else recvs if n.is_recv() else {}) \
            .setdefault(n.comm_id, []).append(n.id)
    for cid, ids in sorted(sends.items()):
        if len(ids) > 1:
            diags.append(Diagnostic(
                "ZA011", f"comm {cid} has {len(ids)} sends: "
                         f"{['%%%d' % i for i in ids]}",
                node=ids[0], origin="ir"))
    for cid, ids in sorted(recvs.items()):
        if len(ids) > 1:
            diags.append(Diagnostic(
                "ZA011", f"comm {cid} has {len(ids)} recvs: "
                         f"{['%%%d' % i for i in ids]}",
                node=ids[0], origin="ir"))
    for cid, ids in sorted(recvs.items()):
        if cid not in sends:
            diags.append(Diagnostic(
                "ZA009", f"recv {nodes[ids[0]].op} on comm {cid} has no "
                         f"matching send",
                segment=seg_label[ids[0]], node=ids[0], origin="ir"))
    for cid, ids in sorted(sends.items()):
        if cid not in recvs:
            diags.append(Diagnostic(
                "ZA010", f"send {nodes[ids[0]].op} on comm {cid} has no "
                         f"matching recv",
                segment=seg_label[ids[0]], node=ids[0], origin="ir"))
    send_of_comm: Dict[int, int] = {}
    for cid in sorted(set(sends) & set(recvs)):
        snid, rnid = sends[cid][0], recvs[cid][0]
        send, recv = nodes[snid], nodes[rnid]
        send_of_comm[cid] = snid
        anchor = dict(segment=seg_label[rnid], node=rnid, origin="ir")
        if IR.SEND_TO_RECV.get(send.op) != recv.op:
            diags.append(Diagnostic(
                "ZA006", f"comm {cid}: {send.op} paired with {recv.op} "
                         f"(expected {IR.SEND_TO_RECV.get(send.op)})",
                **anchor))
        want = (("vertex", "edge") if send.op in ("sendOutEdge", "sendInEdge")
                else ("edge", "vertex"))
        have = (seg_kind[snid], seg_kind[rnid])
        if have != want:
            diags.append(Diagnostic(
                "ZA007", f"comm {cid}: {send.op} goes "
                         f"{have[0]}->{have[1]}, must go "
                         f"{want[0]}->{want[1]}", **anchor))
        if send.dim != recv.dim:
            diags.append(Diagnostic(
                "ZA008", f"comm {cid}: send dim {send.dim} != recv dim "
                         f"{recv.dim}", **anchor))

    # --- global dataflow: layer monotonicity, dead code, unused channels ---
    def deps(n: IR.IRNode) -> List[int]:
        if n.is_recv():
            sid = send_of_comm.get(n.comm_id)
            return [sid] if sid is not None else []
        return [i for i in n.inputs if i in nodes]

    for n in nodes.values():
        for d in deps(n):
            if nodes[d].layer > n.layer:
                diags.append(Diagnostic(
                    "ZA012", f"{n.op} (layer {n.layer}) consumes "
                             f"%{d}={nodes[d].op} of later layer "
                             f"{nodes[d].layer}",
                    segment=seg_label[n.id], node=n.id, origin="ir"))

    live = set()
    stack = [n.id for n in nodes.values() if n.op == "output"]
    while stack:
        nid = stack.pop()
        if nid in live:
            continue
        live.add(nid)
        stack.extend(deps(nodes[nid]))
    consumers: Dict[int, int] = {}
    for n in nodes.values():
        for i in n.inputs:
            consumers[i] = consumers.get(i, 0) + 1
    for nid in sorted(nodes):
        n = nodes[nid]
        if n.is_recv() and consumers.get(nid, 0) == 0:
            diags.append(Diagnostic(
                "ZA014", f"{n.op} result on comm {n.comm_id} is never "
                         f"consumed", segment=seg_label[nid], node=nid,
                origin="ir"))
        elif nid not in live:
            diags.append(Diagnostic(
                "ZA013", f"{n.op} does not reach any output",
                segment=seg_label[nid], node=nid, origin="ir"))
    return diags
