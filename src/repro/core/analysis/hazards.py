"""Schedule hazard analysis + static exchange census (ISSUE 6 pass 3).

``analyze_task_graph`` is a static race detector over the stream-task DAG
from :func:`repro.core.streams.build_task_graph`: it proves every
cross-tile / cross-layer read is *ordered after its producing drain task*
through dependency edges alone.  In ``inter_layer="barrier"`` mode that is
the global property (every task of level ``l`` descends from every
level-``l-1`` gather barrier); in ``"pipelined"`` mode the layer boundary
is relaxed to true data dependencies, so the analyzer re-derives — from the
tile set, independently of the builder — which partitions produce each
tile's source vertices and demands exactly those drains as ancestors.

``exchange_census`` re-implements the :class:`ShardedRunner` publish-set
derivation (gather-tainted tile-side reads) *statically* from the
:class:`ScheduledProgram` and counts the collectives a sharded execution
must issue — exactly ``n_layers`` for the paper models — replacing the
regex-over-HLO census as the first-line check.  The derivation covers both
schedule variants: kernel gathers drain into the same per-phase ``publish``
call as scan gathers (their ``src_value_id`` tile reads and receive
accumulators enter ``reads`` identically), so the census invariant holds
with Pallas kernel dispatch on or off.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from .. import schedule as S
from ..streams import Task
from .diagnostics import Diagnostic


# ---------------------------------------------------------------------------
# task-graph hazard analysis
# ---------------------------------------------------------------------------

def _tile_source_parts(tiles) -> List[np.ndarray]:
    """Per flattened tile, the destination partitions owning its source
    vertices — re-derived here so the analyzer never trusts the builder's
    ``_source_partitions``."""
    def one(ts) -> List[np.ndarray]:
        return [np.unique(np.searchsorted(
                    ts.part_start, ts.src_ids[t, :int(ts.n_src[t])],
                    side="right") - 1)
                for t in range(ts.n_tiles)]
    if hasattr(tiles, "buckets"):
        return [ps for b in tiles.buckets for ps in one(b)]
    return one(tiles)


def analyze_task_graph(tasks: Sequence[Task], *, sde=None, tiles=None,
                       inter_layer: str = "barrier",
                       parts: Optional[Sequence[int]] = None
                       ) -> List[Diagnostic]:
    """Static race detection over a stream-task DAG.

    ``sde`` (the :class:`~repro.core.isa.SDEFunctions` the graph was built
    from) supplies the level→layer map for boundary detection; ``tiles``
    supplies the source-partition ground truth for the pipelined checks.
    Without them only the structural (ZH202) and barrier-coverage (ZH203)
    checks run.
    """
    diags: List[Diagnostic] = []
    by_tid: Dict[int, Task] = {}

    # --- ZH202: structural validity (unique tids, backward-only deps) ------
    for t in tasks:
        if t.tid in by_tid:
            diags.append(Diagnostic(
                "ZH202", f"task id {t.tid} used twice ({by_tid[t.tid].label}"
                         f" and {t.label})", block=t.label, origin="hazard"))
        by_tid[t.tid] = t
    for t in tasks:
        for d in t.deps:
            if d not in by_tid:
                diags.append(Diagnostic(
                    "ZH202", f"dep {d} does not exist", block=t.label,
                    origin="hazard"))
            elif d >= t.tid:
                diags.append(Diagnostic(
                    "ZH202", f"dep {d} ({by_tid[d].label}) is not older "
                             f"than this task", block=t.label,
                    origin="hazard"))
    if any(d.code == "ZH202" for d in diags):
        return diags  # ancestor closure needs a sane DAG

    # ancestor closure as bitmasks over tid order (tasks arrive toposorted
    # by construction; ZH202 above guaranteed deps point backwards)
    anc: Dict[int, int] = {}
    for t in sorted(tasks, key=lambda t: t.tid):
        m = 0
        for d in t.deps:
            m |= anc[d] | (1 << d)
        anc[t.tid] = m

    def ordered_after(t: Task, producer_tid: int) -> bool:
        return bool(anc[t.tid] >> producer_tid & 1)

    # --- ZH203: every gather barrier covers its partition's e-tasks --------
    e_of: Dict[Tuple[int, int], List[Task]] = {}
    for t in tasks:
        if t.role == "e":
            e_of.setdefault((t.level, t.part), []).append(t)
    for t in tasks:
        if t.role != "barrier":
            continue
        missing = [e.tid for e in e_of.get((t.level, t.part), [])
                   if not ordered_after(t, e.tid)]
        if missing:
            diags.append(Diagnostic(
                "ZH203", f"barrier does not cover tile task(s) "
                         f"{[by_tid[m].label for m in missing]}",
                phase=t.level, block=t.label, origin="hazard"))

    # per (level, part): the LAST d-kind task — the handle the next level's
    # reads must be ordered after (the barrier when the level has tile work,
    # else the drain itself)
    last_d: Dict[Tuple[int, int], Task] = {}
    drain_of: Dict[Tuple[int, int], Task] = {}
    for t in tasks:
        if t.kind != "d":
            continue
        key = (t.level, t.part)
        if key not in last_d or t.tid > last_d[key].tid:
            last_d[key] = t
        if t.role == "drain":
            drain_of[key] = t
    levels = sorted({t.level for t in tasks})

    if inter_layer == "barrier":
        # --- global property: level l descends from EVERY level-(l-1)
        # barrier (the classic layer-by-layer chain) -----------------------
        for li, lvl in enumerate(levels[1:], start=1):
            prev = [d for (L, _), d in last_d.items() if L == levels[li - 1]]
            for t in tasks:
                if t.level != lvl:
                    continue
                for b in prev:
                    if not ordered_after(t, b.tid):
                        diags.append(Diagnostic(
                            "ZH201", f"not ordered after level-{b.level} "
                                     f"barrier {b.label}", phase=t.level,
                            block=t.label, origin="hazard"))
        return diags

    # --- pipelined: layer boundaries relaxed to data dependencies ----------
    if sde is None:
        return diags
    boundaries = {lvl for i, lvl in enumerate(levels)
                  if i > 0 and sde.layer_of(lvl) != sde.layer_of(levels[i - 1])}
    src_parts = _tile_source_parts(tiles) if tiles is not None else None
    part_set = ({t.part for t in tasks if t.part >= 0}
                if parts is None else {int(p) for p in parts})
    cross_chip = 0

    for t in tasks:
        if t.level not in boundaries:
            continue
        if t.role == "drain":
            # accumulator handoff: the drain reads its OWN partition's
            # previous-layer gather result
            prev_lvl = levels[levels.index(t.level) - 1]
            prod = last_d.get((prev_lvl, t.part))
            if prod is not None and not ordered_after(t, prod.tid):
                diags.append(Diagnostic(
                    "ZH201", f"boundary drain not ordered after its own "
                             f"partition's barrier {prod.label}",
                    phase=t.level, block=t.label, origin="hazard"))
        elif t.role == "s" and src_parts is not None:
            # cross-tile/cross-layer read: source replicas read DRAINED
            # previous-layer values of the partitions that produce them
            need = {int(q) for q in src_parts[t.tile]}
            cross_chip += len(need - part_set)
            for q in sorted(need & part_set):
                prod = drain_of.get((t.level, q))
                if prod is None:
                    diags.append(Diagnostic(
                        "ZH201", f"no drain task for producing partition "
                                 f"{q} at level {t.level}", phase=t.level,
                        block=t.label, origin="hazard"))
                elif not ordered_after(t, prod.tid):
                    diags.append(Diagnostic(
                        "ZH201", f"reads partition {q}'s drained values "
                                 f"but is not ordered after {prod.label}",
                        phase=t.level, block=t.label, origin="hazard"))
    if cross_chip:
        diags.append(Diagnostic(
            "ZH206", f"{cross_chip} boundary source-partition read(s) "
                     f"fall outside this chip's partitions; they are "
                     f"covered by the inter-chip exchange", origin="hazard"))
    return diags


# ---------------------------------------------------------------------------
# static exchange census
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExchangeCensus:
    """What a sharded execution of this program must exchange.

    ``events`` are the individual ``publish()`` calls the runner traces (one
    ``jax.lax.all_gather`` each).  ``n_collectives`` counts them *after*
    merging adjacent events with no tile work in between: a layer boundary
    drains the gather result at the end of phase ``L`` and the dst store at
    the start of phase ``L+1`` back to back, both reading only device-local
    state, so XLA's all-gather combiner folds them into ONE collective in
    the lowered HLO — exactly one per layer boundary, ``n_layers`` total.
    """

    n_collectives: int                       # merged all-gathers per forward
    publish: FrozenSet[int]                  # vertex node ids exchanged
    tainted: FrozenSet[int]                  # gather-tainted vertex nodes
    #: (phase level, "dst"|"gather", ids drained by that publish call)
    events: Tuple[Tuple[int, str, Tuple[int, ...]], ...]
    #: vertex node ids drained per *merged* collective, in execution order
    #: (len == n_collectives); the last group is the output drain, the rest
    #: are layer boundaries — the simulator takes per-boundary widths here
    groups: Tuple[Tuple[int, ...], ...] = ()


def exchange_census(sp: S.ScheduledProgram) -> ExchangeCensus:
    """Re-derive :meth:`ShardedRunner._publish_ids` and the per-phase
    publish calls statically from the scheduled program."""
    tainted: Set[int] = set()
    for seg in sp.prog.vertex_segments():
        for n in seg.toposort():
            if n.op == "recvInEdge" or any(i in tainted for i in n.inputs):
                tainted.add(n.id)

    node_op = {n.id: n.op for seg in sp.prog.segments
               for n in seg.nodes.values()}
    reads: Set[int] = set(sp.outputs)
    for ph in sp.phases:
        for n in ph.src.nodes:
            reads.update(n.inputs)
        for g in ph.gathers:
            if g.src_value_id is not None:
                reads.add(g.src_value_id)
    for rnid, vnid in sp.scatter_value_of.items():
        if node_op.get(rnid) == "recvSrc":
            reads.add(vnid)
    publish = ((reads & tainted) | set(sp.outputs)) \
        - {nid for nid, _ in sp.vertex_inputs}

    # replay the runner's publish() call sites in execution order; a "work"
    # marker between two publishes keeps them in separate combiner groups
    stream: List[object] = []
    for ph in sp.phases:
        drained = tuple(sorted(set(ph.dst.store_ids) & publish))
        if drained:
            stream.append((ph.level, "dst", drained))
        if ph.has_tile_work:
            stream.append("work")
            drained = tuple(sorted(
                {g.acc.recv_id for g in ph.gathers} & publish))
            if drained:
                stream.append((ph.level, "gather", drained))
    events = tuple(ev for ev in stream if ev != "work")
    groups: List[Tuple[int, ...]] = []
    prev_was_pub = False
    for ev in stream:
        if ev == "work":
            prev_was_pub = False
        else:
            ids = ev[2]
            if not prev_was_pub:
                groups.append(tuple(ids))
            else:
                groups[-1] = groups[-1] + tuple(ids)
            prev_was_pub = True
    return ExchangeCensus(n_collectives=len(groups),
                          publish=frozenset(publish),
                          tainted=frozenset(tainted), events=events,
                          groups=tuple(groups))


def verify_exchange(sp: S.ScheduledProgram, *, tiles=None, plan=None,
                    n_shards: Optional[int] = None,
                    mode: str = "mincut") -> List[Diagnostic]:
    """ZH204/ZH205: the census must come out at exactly one collective per
    layer (the boundary drains, plus the final output drain), and nothing
    untainted may ride the exchange (it would be recomputed locally).

    With ``tiles`` (plus either a :class:`~repro.core.tiling.ShardPlan` or
    ``n_shards``/``mode`` to build one) the pass additionally proves the
    *neighbor-restricted* exchange covers every read the sharded runner
    performs: each cross-shard gather-source read must appear in its owning
    shard's send set (ZH207), every ``recvDst`` accumulator row must be
    device-local under the plan (ZH208), and send sets must hold only rows
    their shard owns (ZH209).  A clean proof is recorded as a ZH210 info
    with the cut-vs-all-gather row counts.  The read sets are re-derived
    per tile with explicit ``n_src`` slicing — a deliberately different
    code path from :func:`repro.core.tiling.exchange_sets`, so the checker
    never trusts the builder it is checking.
    """
    census = exchange_census(sp)
    diags: List[Diagnostic] = []
    if census.n_collectives != sp.n_layers:
        where = [f"phase {lvl} ({kind}: {list(ids)})"
                 for lvl, kind, ids in census.events]
        diags.append(Diagnostic(
            "ZH204", f"{census.n_collectives} collective(s) after combiner "
                     f"grouping != {sp.n_layers} layer(s): {where}",
            origin="census"))
    for nid in sorted(census.publish - census.tainted):
        diags.append(Diagnostic(
            "ZH205", f"exchanged value %{nid} is not gather-tainted; "
                     f"source replicas could recompute it locally",
            node=nid, origin="census"))
    if tiles is not None:
        diags += _verify_exchange_coverage(tiles, plan=plan,
                                           n_shards=n_shards, mode=mode)
    return diags


_MAX_COVERAGE_DIAGS = 8      # cap per-code emission; totals go in the message


def _verify_exchange_coverage(tiles, *, plan=None,
                              n_shards: Optional[int] = None,
                              mode: str = "mincut") -> List[Diagnostic]:
    """Statically prove the restricted exchange covers every sharded read."""
    from ..tiling import BucketedTileSet, exchange_sets, plan_shards

    if plan is None:
        if n_shards is None:
            raise ValueError(
                "exchange coverage proof needs plan= or n_shards=")
        plan = plan_shards(tiles, n_shards, mode=mode)
    ex = exchange_sets(tiles, plan)
    K = plan.n_shards
    part_start = np.asarray(tiles.part_start)
    part_size = np.asarray(tiles.part_size)
    send_sets = [frozenset(map(int, rows)) for rows in ex.send_rows]
    diags: List[Diagnostic] = []

    # ZH208 (plan side): every partition must be assigned to exactly one
    # shard, consistently between parts_of_shard and shard_of_part — else a
    # recvDst accumulator would be gathered on one device and read on another
    seen_parts: Set[int] = set()
    for k, ps in enumerate(plan.parts_of_shard):
        for p in map(int, ps):
            if p in seen_parts or int(plan.shard_of_part[p]) != k:
                diags.append(Diagnostic(
                    "ZH208", f"partition {p} assignment inconsistent: listed "
                             f"under shard {k} but owned by shard "
                             f"{int(plan.shard_of_part[p])}",
                    origin="census"))
            seen_parts.add(p)

    # ZH209: a shard's send set may hold only rows it owns (ownership
    # re-derived from the destination partition ranges)
    n209 = 0
    for k, rows in enumerate(ex.send_rows):
        if len(rows) == 0:
            continue
        owner = plan.shard_of_part[
            np.searchsorted(part_start, rows, side="right") - 1]
        bad = owner != k
        for r, o in zip(map(int, np.asarray(rows)[bad]),
                        map(int, owner[bad])):
            n209 += 1
            if n209 <= _MAX_COVERAGE_DIAGS:
                diags.append(Diagnostic(
                    "ZH209", f"shard {k} send set holds row {r} owned by "
                             f"shard {o}", origin="census"))

    # ZH207/ZH208 (tile side): walk every tile with explicit n_src/n_edge
    # slicing and demand each cross-shard source read is in the owner's
    # send set, and each dst accumulator offset stays inside the partition
    n207 = n208 = 0
    cross_slots = 0

    def walk(ts) -> None:
        nonlocal n207, n208, cross_slots
        part_id = np.asarray(ts.part_id)
        for t in range(ts.n_tiles):
            p = int(part_id[t])
            k = int(plan.shard_of_part[p])
            ne = int(ts.n_edge[t])
            if ne:
                off = np.asarray(ts.edge_dst[t, :ne])
                bad = off[(off < 0) | (off >= int(part_size[p]))]
                for o in map(int, bad[:_MAX_COVERAGE_DIAGS]):
                    n208 += 1
                    if n208 <= _MAX_COVERAGE_DIAGS:
                        diags.append(Diagnostic(
                            "ZH208", f"tile {t} dst offset {o} escapes "
                                     f"partition {p} (size "
                                     f"{int(part_size[p])}); its recvDst "
                                     f"row is not local to shard {k}",
                            block="dst", origin="census"))
            rows = np.asarray(ts.src_ids[t, :int(ts.n_src[t])])
            owners = plan.shard_of_part[
                np.searchsorted(part_start, rows, side="right") - 1]
            remote = owners != k
            cross_slots += int(remote.sum())
            for r, o in zip(map(int, rows[remote]), map(int, owners[remote])):
                if r not in send_sets[o]:
                    n207 += 1
                    if n207 <= _MAX_COVERAGE_DIAGS:
                        diags.append(Diagnostic(
                            "ZH207", f"shard {k} reads row {r} owned by "
                                     f"shard {o} but the row is missing "
                                     f"from shard {o}'s send set",
                            block="gather", origin="census"))

    if isinstance(tiles, BucketedTileSet):
        for b in tiles.buckets:
            walk(b)
    else:
        walk(tiles)

    for code, n in (("ZH207", n207), ("ZH208", n208), ("ZH209", n209)):
        if n > _MAX_COVERAGE_DIAGS:
            diags.append(Diagnostic(
                code, f"... {n - _MAX_COVERAGE_DIAGS} further finding(s) "
                      f"of this code suppressed ({n} total)",
                origin="census"))
    if n207 == n208 == n209 == 0 and not diags:
        allgather_rows = tiles.n_vertices * max(0, K - 1)
        diags.append(Diagnostic(
            "ZH210", f"restricted-exchange coverage proven for "
                     f"{plan.mode!r} plan over {K} shard(s): "
                     f"{cross_slots} cross-shard read slot(s) covered by "
                     f"{ex.cut_rows} shipped row(s)/boundary "
                     f"(all-gather would ship {allgather_rows})",
            origin="census"))
    return diags
