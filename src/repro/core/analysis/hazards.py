"""Schedule hazard analysis + static exchange census (ISSUE 6 pass 3).

``analyze_task_graph`` is a static race detector over the stream-task DAG
from :func:`repro.core.streams.build_task_graph`: it proves every
cross-tile / cross-layer read is *ordered after its producing drain task*
through dependency edges alone.  In ``inter_layer="barrier"`` mode that is
the global property (every task of level ``l`` descends from every
level-``l-1`` gather barrier); in ``"pipelined"`` mode the layer boundary
is relaxed to true data dependencies, so the analyzer re-derives — from the
tile set, independently of the builder — which partitions produce each
tile's source vertices and demands exactly those drains as ancestors.

``exchange_census`` re-implements the :class:`ShardedRunner` publish-set
derivation (gather-tainted tile-side reads) *statically* from the
:class:`ScheduledProgram` and counts the collectives a sharded execution
must issue — exactly ``n_layers`` for the paper models — replacing the
regex-over-HLO census as the first-line check.  The derivation covers both
schedule variants: kernel gathers drain into the same per-phase ``publish``
call as scan gathers (their ``src_value_id`` tile reads and receive
accumulators enter ``reads`` identically), so the census invariant holds
with Pallas kernel dispatch on or off.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from .. import schedule as S
from ..streams import Task
from .diagnostics import Diagnostic


# ---------------------------------------------------------------------------
# task-graph hazard analysis
# ---------------------------------------------------------------------------

def _tile_source_parts(tiles) -> List[np.ndarray]:
    """Per flattened tile, the destination partitions owning its source
    vertices — re-derived here so the analyzer never trusts the builder's
    ``_source_partitions``."""
    def one(ts) -> List[np.ndarray]:
        return [np.unique(np.searchsorted(
                    ts.part_start, ts.src_ids[t, :int(ts.n_src[t])],
                    side="right") - 1)
                for t in range(ts.n_tiles)]
    if hasattr(tiles, "buckets"):
        return [ps for b in tiles.buckets for ps in one(b)]
    return one(tiles)


def analyze_task_graph(tasks: Sequence[Task], *, sde=None, tiles=None,
                       inter_layer: str = "barrier",
                       parts: Optional[Sequence[int]] = None
                       ) -> List[Diagnostic]:
    """Static race detection over a stream-task DAG.

    ``sde`` (the :class:`~repro.core.isa.SDEFunctions` the graph was built
    from) supplies the level→layer map for boundary detection; ``tiles``
    supplies the source-partition ground truth for the pipelined checks.
    Without them only the structural (ZH202) and barrier-coverage (ZH203)
    checks run.
    """
    diags: List[Diagnostic] = []
    by_tid: Dict[int, Task] = {}

    # --- ZH202: structural validity (unique tids, backward-only deps) ------
    for t in tasks:
        if t.tid in by_tid:
            diags.append(Diagnostic(
                "ZH202", f"task id {t.tid} used twice ({by_tid[t.tid].label}"
                         f" and {t.label})", block=t.label, origin="hazard"))
        by_tid[t.tid] = t
    for t in tasks:
        for d in t.deps:
            if d not in by_tid:
                diags.append(Diagnostic(
                    "ZH202", f"dep {d} does not exist", block=t.label,
                    origin="hazard"))
            elif d >= t.tid:
                diags.append(Diagnostic(
                    "ZH202", f"dep {d} ({by_tid[d].label}) is not older "
                             f"than this task", block=t.label,
                    origin="hazard"))
    if any(d.code == "ZH202" for d in diags):
        return diags  # ancestor closure needs a sane DAG

    # ancestor closure as bitmasks over tid order (tasks arrive toposorted
    # by construction; ZH202 above guaranteed deps point backwards)
    anc: Dict[int, int] = {}
    for t in sorted(tasks, key=lambda t: t.tid):
        m = 0
        for d in t.deps:
            m |= anc[d] | (1 << d)
        anc[t.tid] = m

    def ordered_after(t: Task, producer_tid: int) -> bool:
        return bool(anc[t.tid] >> producer_tid & 1)

    # --- ZH203: every gather barrier covers its partition's e-tasks --------
    e_of: Dict[Tuple[int, int], List[Task]] = {}
    for t in tasks:
        if t.role == "e":
            e_of.setdefault((t.level, t.part), []).append(t)
    for t in tasks:
        if t.role != "barrier":
            continue
        missing = [e.tid for e in e_of.get((t.level, t.part), [])
                   if not ordered_after(t, e.tid)]
        if missing:
            diags.append(Diagnostic(
                "ZH203", f"barrier does not cover tile task(s) "
                         f"{[by_tid[m].label for m in missing]}",
                phase=t.level, block=t.label, origin="hazard"))

    # per (level, part): the LAST d-kind task — the handle the next level's
    # reads must be ordered after (the barrier when the level has tile work,
    # else the drain itself)
    last_d: Dict[Tuple[int, int], Task] = {}
    drain_of: Dict[Tuple[int, int], Task] = {}
    for t in tasks:
        if t.kind != "d":
            continue
        key = (t.level, t.part)
        if key not in last_d or t.tid > last_d[key].tid:
            last_d[key] = t
        if t.role == "drain":
            drain_of[key] = t
    levels = sorted({t.level for t in tasks})

    if inter_layer == "barrier":
        # --- global property: level l descends from EVERY level-(l-1)
        # barrier (the classic layer-by-layer chain) -----------------------
        for li, lvl in enumerate(levels[1:], start=1):
            prev = [d for (L, _), d in last_d.items() if L == levels[li - 1]]
            for t in tasks:
                if t.level != lvl:
                    continue
                for b in prev:
                    if not ordered_after(t, b.tid):
                        diags.append(Diagnostic(
                            "ZH201", f"not ordered after level-{b.level} "
                                     f"barrier {b.label}", phase=t.level,
                            block=t.label, origin="hazard"))
        return diags

    # --- pipelined: layer boundaries relaxed to data dependencies ----------
    if sde is None:
        return diags
    boundaries = {lvl for i, lvl in enumerate(levels)
                  if i > 0 and sde.layer_of(lvl) != sde.layer_of(levels[i - 1])}
    src_parts = _tile_source_parts(tiles) if tiles is not None else None
    part_set = ({t.part for t in tasks if t.part >= 0}
                if parts is None else {int(p) for p in parts})
    cross_chip = 0

    for t in tasks:
        if t.level not in boundaries:
            continue
        if t.role == "drain":
            # accumulator handoff: the drain reads its OWN partition's
            # previous-layer gather result
            prev_lvl = levels[levels.index(t.level) - 1]
            prod = last_d.get((prev_lvl, t.part))
            if prod is not None and not ordered_after(t, prod.tid):
                diags.append(Diagnostic(
                    "ZH201", f"boundary drain not ordered after its own "
                             f"partition's barrier {prod.label}",
                    phase=t.level, block=t.label, origin="hazard"))
        elif t.role == "s" and src_parts is not None:
            # cross-tile/cross-layer read: source replicas read DRAINED
            # previous-layer values of the partitions that produce them
            need = {int(q) for q in src_parts[t.tile]}
            cross_chip += len(need - part_set)
            for q in sorted(need & part_set):
                prod = drain_of.get((t.level, q))
                if prod is None:
                    diags.append(Diagnostic(
                        "ZH201", f"no drain task for producing partition "
                                 f"{q} at level {t.level}", phase=t.level,
                        block=t.label, origin="hazard"))
                elif not ordered_after(t, prod.tid):
                    diags.append(Diagnostic(
                        "ZH201", f"reads partition {q}'s drained values "
                                 f"but is not ordered after {prod.label}",
                        phase=t.level, block=t.label, origin="hazard"))
    if cross_chip:
        diags.append(Diagnostic(
            "ZH206", f"{cross_chip} boundary source-partition read(s) "
                     f"fall outside this chip's partitions; they are "
                     f"covered by the inter-chip exchange", origin="hazard"))
    return diags


# ---------------------------------------------------------------------------
# static exchange census
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExchangeCensus:
    """What a sharded execution of this program must exchange.

    ``events`` are the individual ``publish()`` calls the runner traces (one
    ``jax.lax.all_gather`` each).  ``n_collectives`` counts them *after*
    merging adjacent events with no tile work in between: a layer boundary
    drains the gather result at the end of phase ``L`` and the dst store at
    the start of phase ``L+1`` back to back, both reading only device-local
    state, so XLA's all-gather combiner folds them into ONE collective in
    the lowered HLO — exactly one per layer boundary, ``n_layers`` total.
    """

    n_collectives: int                       # merged all-gathers per forward
    publish: FrozenSet[int]                  # vertex node ids exchanged
    tainted: FrozenSet[int]                  # gather-tainted vertex nodes
    #: (phase level, "dst"|"gather", ids drained by that publish call)
    events: Tuple[Tuple[int, str, Tuple[int, ...]], ...]


def exchange_census(sp: S.ScheduledProgram) -> ExchangeCensus:
    """Re-derive :meth:`ShardedRunner._publish_ids` and the per-phase
    publish calls statically from the scheduled program."""
    tainted: Set[int] = set()
    for seg in sp.prog.vertex_segments():
        for n in seg.toposort():
            if n.op == "recvInEdge" or any(i in tainted for i in n.inputs):
                tainted.add(n.id)

    node_op = {n.id: n.op for seg in sp.prog.segments
               for n in seg.nodes.values()}
    reads: Set[int] = set(sp.outputs)
    for ph in sp.phases:
        for n in ph.src.nodes:
            reads.update(n.inputs)
        for g in ph.gathers:
            if g.src_value_id is not None:
                reads.add(g.src_value_id)
    for rnid, vnid in sp.scatter_value_of.items():
        if node_op.get(rnid) == "recvSrc":
            reads.add(vnid)
    publish = ((reads & tainted) | set(sp.outputs)) \
        - {nid for nid, _ in sp.vertex_inputs}

    # replay the runner's publish() call sites in execution order; a "work"
    # marker between two publishes keeps them in separate combiner groups
    stream: List[object] = []
    for ph in sp.phases:
        drained = tuple(sorted(set(ph.dst.store_ids) & publish))
        if drained:
            stream.append((ph.level, "dst", drained))
        if ph.has_tile_work:
            stream.append("work")
            drained = tuple(sorted(
                {g.acc.recv_id for g in ph.gathers} & publish))
            if drained:
                stream.append((ph.level, "gather", drained))
    events = tuple(ev for ev in stream if ev != "work")
    groups = 0
    prev_was_pub = False
    for ev in stream:
        if ev == "work":
            prev_was_pub = False
        else:
            if not prev_was_pub:
                groups += 1
            prev_was_pub = True
    return ExchangeCensus(n_collectives=groups,
                          publish=frozenset(publish),
                          tainted=frozenset(tainted), events=events)


def verify_exchange(sp: S.ScheduledProgram) -> List[Diagnostic]:
    """ZH204/ZH205: the census must come out at exactly one collective per
    layer (the boundary drains, plus the final output drain), and nothing
    untainted may ride the exchange (it would be recomputed locally)."""
    census = exchange_census(sp)
    diags: List[Diagnostic] = []
    if census.n_collectives != sp.n_layers:
        where = [f"phase {lvl} ({kind}: {list(ids)})"
                 for lvl, kind, ids in census.events]
        diags.append(Diagnostic(
            "ZH204", f"{census.n_collectives} collective(s) after combiner "
                     f"grouping != {sp.n_layers} layer(s): {where}",
            origin="census"))
    for nid in sorted(census.publish - census.tainted):
        diags.append(Diagnostic(
            "ZH205", f"exchanged value %{nid} is not gather-tainted; "
                     f"source replicas could recompute it locally",
            node=nid, origin="census"))
    return diags
