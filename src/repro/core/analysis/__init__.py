"""Static analysis & verification over compiler artifacts (ISSUE 6).

Three passes, each pure (no execution, no JAX tracing):

* :func:`verify_ir` — dataflow/dim/vocabulary/channel checks on an
  :class:`~repro.core.ir.IRProgram` (codes ``ZA0xx``);
* :func:`verify_schedule` — lowering legality on a
  :class:`~repro.core.schedule.ScheduledProgram`, including independent
  re-derivation of every Pallas kernel's preconditions and the
  published-before-read contract (codes ``ZS1xx``);
* :func:`analyze_task_graph` / :func:`verify_exchange` — drain-ordering
  race detection over the stream-task DAG and the static collective
  census for sharded execution (codes ``ZH2xx``).

:func:`analyze` dispatches on the artifact type; ``compile_gnn`` calls the
first pass by default (``verify=True``).
"""
from __future__ import annotations

from typing import List, Sequence, Union

from .diagnostics import (CODES, ERROR, INFO, SEVERITIES, WARN, Diagnostic,
                          VerificationError, errors, find_cycle, format_cycle,
                          format_report, sort_diags, worst_severity)
from .hazards import (ExchangeCensus, analyze_task_graph, exchange_census,
                      verify_exchange)
from .ir_verifier import verify_ir
from .schedule_verifier import explain_scan_fallback, verify_schedule

__all__ = [
    "CODES", "ERROR", "WARN", "INFO", "SEVERITIES", "Diagnostic",
    "VerificationError",
    "errors", "find_cycle", "format_cycle", "format_report", "sort_diags",
    "worst_severity", "verify_ir", "verify_schedule", "explain_scan_fallback",
    "analyze_task_graph", "exchange_census", "verify_exchange",
    "ExchangeCensus", "analyze",
]


def analyze(obj, **kw) -> List[Diagnostic]:
    """Run every analysis pass that applies to ``obj``.

    ``obj`` may be an :class:`~repro.core.ir.IRProgram`, a
    :class:`~repro.core.schedule.ScheduledProgram`, a
    :class:`~repro.core.compiler.CompiledGNN`, or a stream-task list from
    :func:`~repro.core.streams.build_task_graph` (keyword arguments
    ``sde=``, ``tiles=``, ``inter_layer=``, ``parts=`` are forwarded there).
    """
    from .. import compiler as C
    from .. import ir as IR
    from .. import schedule as S

    if isinstance(obj, IR.IRProgram):
        return verify_ir(obj)
    if isinstance(obj, S.ScheduledProgram):
        return (verify_ir(obj.prog) + verify_schedule(obj)
                + verify_exchange(obj))
    if isinstance(obj, C.CompiledGNN):
        diags = verify_ir(obj.ir)
        for dispatch in (True, False):
            sp = obj.schedule(kernel_dispatch=dispatch)
            diags += verify_schedule(sp)
            if dispatch:            # census is dispatch-invariant
                diags += verify_exchange(sp)
        return diags
    if isinstance(obj, (list, tuple)) and (not obj or hasattr(obj[0], "tid")):
        return analyze_task_graph(obj, **kw)
    raise TypeError(f"analyze() cannot handle {type(obj).__name__}")
