"""ScheduledProgram verifier (ISSUE 6 pass 2).

Independently re-checks what :func:`repro.core.schedule.lower` and its
pattern matcher promise, straight from the IR — gather-block ownership,
covered/fused-level consistency, kernel-tag legality (the Pallas kernel
preconditions are re-derived here, never trusted from
``_match_softmax_motifs`` / ``_classify_gather``), and the
published-before-read dataflow contract every engine relies on.  Also home
of the **missed-kernel lint** (ZS110): for every scan-fallback gather under
``kernel_dispatch=True`` it explains *why* pattern matching failed.  The
lint is schedule-level, so it covers every engine that executes the
kernel-dispatch variant — :class:`~repro.core.pipeline.PipelinedRunner` and
the sharded ``shard_map`` path alike — and feeds the
:mod:`repro.launch.autotune` search, which only tunes schedules whose
gathers actually kernelized.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from .. import ir as IR
from .. import schedule as S
from .diagnostics import Diagnostic

_GATHER_SENDS = ("sendDstSum", "sendDstMax", "sendDstMean")


class _Ctx:
    """Shared lookups over the scheduled program's IR."""

    def __init__(self, sp: S.ScheduledProgram):
        self.sp = sp
        self.nodes: Dict[int, IR.IRNode] = {}
        self.seg_kind: Dict[int, str] = {}
        for seg in sp.prog.segments:
            for n in seg.nodes.values():
                self.nodes[n.id] = n
                self.seg_kind[n.id] = seg.kind
        self.consumers: Dict[int, List[IR.IRNode]] = {}
        for n in self.nodes.values():
            for i in n.inputs:
                self.consumers.setdefault(i, []).append(n)
        self.send_of_comm: Dict[int, int] = {}
        self.recv_of_comm: Dict[int, int] = {}
        for n in self.nodes.values():
            if n.comm_id is None:
                continue
            if n.is_send():
                self.send_of_comm[n.comm_id] = n.id
            elif n.is_recv():
                self.recv_of_comm[n.comm_id] = n.id

    def only_consumer(self, nid: int) -> Optional[IR.IRNode]:
        cons = self.consumers.get(nid, [])
        return cons[0] if len(cons) == 1 else None

    def src_value_of_recv(self, rs: IR.IRNode) -> Optional[int]:
        """recvSrc node -> the vertex node id its scatter send reads."""
        sid = self.send_of_comm.get(rs.comm_id)
        return self.nodes[sid].inputs[0] if sid is not None else None


# ---------------------------------------------------------------------------
# kernel-tag legality: re-derive the preconditions from the IR
# ---------------------------------------------------------------------------

def _check_spmm(g: S.GatherBlock, ctx: _Ctx) -> Optional[str]:
    send = ctx.nodes.get(g.acc.send_id)
    if send is None or send.op != "sendDstSum":
        return f"send is {getattr(send, 'op', '<missing>')}, needs sendDstSum"
    val = ctx.nodes.get(send.inputs[0])
    if val is None or val.op != "recvSrc":
        return f"gather operand is {getattr(val, 'op', '<missing>')}, " \
               f"needs a private recvSrc"
    if ctx.only_consumer(val.id) is not send:
        return f"recvSrc %{val.id} has {len(ctx.consumers.get(val.id, []))} " \
               f"consumers, must feed only the send"
    want_src = ctx.src_value_of_recv(val)
    if g.src_value_id != want_src:
        return f"src_value_id %{g.src_value_id} != scatter source %{want_src}"
    if g.covered != {val.id, send.id}:
        return f"covered {sorted(g.covered)} != {{%{val.id}, %{send.id}}}"
    return None


def _check_spmm_weighted(g: S.GatherBlock, ctx: _Ctx) -> Optional[str]:
    send = ctx.nodes.get(g.acc.send_id)
    if send is None or send.op != "sendDstSum":
        return f"send is {getattr(send, 'op', '<missing>')}, needs sendDstSum"
    val = ctx.nodes.get(send.inputs[0])
    if val is None or val.op != "mul":
        return f"gather operand is {getattr(val, 'op', '<missing>')}, " \
               f"needs recvSrc * weight"
    if ctx.only_consumer(val.id) is not send:
        return f"mul %{val.id} has {len(ctx.consumers.get(val.id, []))} " \
               f"consumers, must feed only the send"
    a, b = (ctx.nodes[i] for i in val.inputs)
    for rs, w in ((a, b), (b, a)):
        if (rs.op == "recvSrc" and w.dim == 1 and not w.is_recv()
                and ctx.only_consumer(rs.id) is val):
            if g.weight_id != w.id:
                return f"weight_id %{g.weight_id} != per-edge scalar %{w.id}"
            want_src = ctx.src_value_of_recv(rs)
            if g.src_value_id != want_src:
                return (f"src_value_id %{g.src_value_id} != scatter source "
                        f"%{want_src}")
            if g.covered != {val.id, rs.id, send.id}:
                return (f"covered {sorted(g.covered)} != "
                        f"{{%{val.id}, %{rs.id}, %{send.id}}}")
            return None
    return (f"mul %{val.id} operands ({a.op} dim={a.dim}, {b.op} dim={b.dim})"
            f" are not recvSrc x private per-edge scalar")


def _walk_softmax(score_id: int, ctx: _Ctx
                  ) -> Tuple[Optional[Dict], Optional[str]]:
    """Forward-walk the fused edge-softmax motif from its raw score node.

    Returns ``(derived, None)`` on success — ``derived`` holds the out send,
    covered set and source value — or ``(None, reason)`` naming the first
    broken link (shared with the missed-kernel lint for sendDstMax fallbacks).
    """
    nodes, only = ctx.nodes, ctx.only_consumer
    e0 = nodes.get(score_id)
    if e0 is None:
        return None, f"score node %{score_id} does not exist"
    cons = ctx.consumers.get(score_id, [])
    smax = next((c for c in cons if c.op == "sendDstMax"), None)
    sub = next((c for c in cons if c.op == "sub"), None)
    if smax is None or sub is None or len(cons) != 2:
        return None, (f"score %{score_id} must feed exactly {{sendDstMax, "
                      f"sub}}, feeds {[c.op for c in cons]}")
    m_recv_id = ctx.recv_of_comm.get(smax.comm_id)
    if m_recv_id is None:
        return None, f"max-gather comm {smax.comm_id} has no recv"
    m_send = only(m_recv_id)
    if m_send is None or m_send.op not in ("sendInEdge", "sendOutEdge"):
        return None, (f"max result %{m_recv_id} must feed exactly one "
                      f"scatter back to the edges")
    m_edge = nodes[ctx.recv_of_comm[m_send.comm_id]]
    if m_edge.op != "recvDst":
        return None, f"max result scatters via {m_edge.op}, needs recvDst"
    if sub.inputs != [score_id, m_edge.id] or only(m_edge.id) is not sub:
        return None, (f"shift must be sub(score, max) with a private max "
                      f"scatter; got sub{sub.inputs}")
    ex = only(sub.id)
    if ex is None or ex.op != "exp":
        return None, f"shifted score must feed exactly one exp"
    ex_cons = ctx.consumers.get(ex.id, [])
    ssum = next((c for c in ex_cons if c.op == "sendDstSum"), None)
    div = next((c for c in ex_cons if c.op == "div"), None)
    if ssum is None or div is None or len(ex_cons) != 2:
        return None, (f"exp %{ex.id} must feed exactly {{sendDstSum, div}}, "
                      f"feeds {[c.op for c in ex_cons]}")
    s_recv_id = ctx.recv_of_comm.get(ssum.comm_id)
    s_send = only(s_recv_id) if s_recv_id is not None else None
    if s_send is None or s_send.op not in ("sendInEdge", "sendOutEdge"):
        return None, (f"sum result %{s_recv_id} must feed exactly one "
                      f"scatter back to the edges")
    s_edge = nodes[ctx.recv_of_comm[s_send.comm_id]]
    if (s_edge.op != "recvDst" or div.inputs != [ex.id, s_edge.id]
            or only(s_edge.id) is not div):
        return None, f"normalizer must be div(exp, private recvDst(sum))"
    mul = only(div.id)
    if mul is None or mul.op != "mul":
        return None, f"alpha %{div.id} must feed exactly one mul"
    other = [i for i in mul.inputs if i != div.id]
    if len(other) != 1:
        return None, f"mul %{mul.id} must pair alpha with one message"
    rs = nodes[other[0]]
    if rs.op != "recvSrc" or only(rs.id) is not mul:
        return None, f"message operand is {rs.op}, needs a private recvSrc"
    out_send = only(mul.id)
    if out_send is None or out_send.op != "sendDstSum":
        return None, f"weighted message must feed exactly one sendDstSum"
    covered = {smax.id, m_recv_id, m_send.id, m_edge.id, sub.id, ex.id,
               ssum.id, s_recv_id, s_send.id, s_edge.id, div.id, rs.id,
               mul.id, out_send.id, ctx.send_of_comm[rs.comm_id]}
    return {"out_send": out_send, "covered": covered,
            "src_value_id": ctx.src_value_of_recv(rs),
            "max_send": smax}, None


def _check_softmax(g: S.GatherBlock, phase: S.Phase, ctx: _Ctx,
                   plan) -> Optional[str]:
    if g.score_id is None:
        return "block carries no score_id"
    derived, reason = _walk_softmax(g.score_id, ctx)
    if derived is None:
        return reason
    if derived["out_send"].id != g.acc.send_id:
        return (f"acc.send_id %{g.acc.send_id} != motif output send "
                f"%{derived['out_send'].id}")
    if g.src_value_id != derived["src_value_id"]:
        return (f"src_value_id %{g.src_value_id} != message source "
                f"%{derived['src_value_id']}")
    if g.covered != derived["covered"]:
        missing = sorted(derived["covered"] - g.covered)
        extra = sorted(g.covered - derived["covered"])
        return f"covered set wrong (missing {missing}, extra {extra})"
    lvl = plan.level.get(derived["max_send"].id)
    if g.fused_levels != (lvl, lvl + 1, lvl + 2):
        return (f"fused_levels {g.fused_levels} != ({lvl}, {lvl + 1}, "
                f"{lvl + 2}) from the max-gather level")
    if phase.level != lvl:
        return f"block scheduled at phase {phase.level}, motif head at {lvl}"
    return None


_KERNEL_CHECKS = {
    S.KERNEL_SPMM: ("ZS104", lambda g, p, ctx, plan: _check_spmm(g, ctx)),
    S.KERNEL_SPMM_WEIGHTED: ("ZS105",
                             lambda g, p, ctx, plan: _check_spmm_weighted(g, ctx)),
    S.KERNEL_SEGMENT_SOFTMAX: ("ZS106", _check_softmax),
}


def explain_scan_fallback(g: S.GatherBlock, ctx: _Ctx) -> str:
    """Why this gather did NOT dispatch to a Pallas kernel (ZS110 lint)."""
    send = ctx.nodes.get(g.acc.send_id)
    if send is None:
        return f"send %{g.acc.send_id} missing from the IR"
    if send.op == "sendDstMean":
        return "mean-reduce gathers have no dedicated kernel (sum + count)"
    if send.op == "sendDstMax":
        _, reason = _walk_softmax(send.inputs[0], ctx)
        return (f"max-reduce alone has no kernel, and the edge-softmax "
                f"motif does not match: {reason}" if reason else
                "max-reduce gather (softmax head handled elsewhere)")
    val = ctx.nodes.get(send.inputs[0])
    if val is None:
        return f"gather operand %{send.inputs[0]} missing from the IR"
    if val.op == "recvSrc":
        cons = ctx.consumers.get(val.id, [])
        return (f"recvSrc message %{val.id} has {len(cons)} consumers "
                f"({[c.op for c in cons]}) — pallas_spmm needs it private "
                f"to the gather")
    if val.op == "mul":
        if ctx.only_consumer(val.id) is not send:
            return (f"weighted message %{val.id} has "
                    f"{len(ctx.consumers.get(val.id, []))} consumers — "
                    f"pallas_spmm_weighted needs it private to the gather")
        a, b = (ctx.nodes[i] for i in val.inputs)
        ops = f"({a.op} dim={a.dim}) * ({b.op} dim={b.dim})"
        if not any(n.op == "recvSrc" for n in (a, b)):
            return f"mul {ops} has no recvSrc message operand"
        rs = a if a.op == "recvSrc" else b
        w = b if rs is a else a
        if ctx.only_consumer(rs.id) is not val:
            return f"recvSrc %{rs.id} is shared beyond the weighted message"
        if w.is_recv():
            return (f"weight operand %{w.id} is a {w.op} — the kernel "
                    f"densifies only edge-computed scalars")
        return (f"weight operand %{w.id} has dim {w.dim} — the densified "
                f"adjacency needs a per-edge scalar (dim 1)")
    return (f"gather operand is {val.op!r} — no kernel matches "
            f"(pallas_spmm wants recvSrc, pallas_spmm_weighted recvSrc * a)")


# ---------------------------------------------------------------------------
# the verifier
# ---------------------------------------------------------------------------

def verify_schedule(sp: S.ScheduledProgram) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    ctx = _Ctx(sp)
    plan = sp.plan

    def gather_anchor(phase: S.Phase, g: S.GatherBlock) -> Dict:
        return dict(phase=phase.level, node=g.acc.send_id,
                    block=f"gather[comm={g.acc.comm_id}]", origin="schedule")

    all_blocks: List[Tuple[S.Phase, S.GatherBlock]] = [
        (p, g) for p in sp.phases for g in p.gathers]

    # --- accumulator specs vs the IR (ZS111) -------------------------------
    for phase, g in all_blocks:
        send = ctx.nodes.get(g.acc.send_id)
        anchor = gather_anchor(phase, g)
        if send is None or send.op not in _GATHER_SENDS:
            diags.append(Diagnostic(
                "ZS111", f"acc.send_id %{g.acc.send_id} is not a gather "
                         f"send", **anchor))
            continue
        kind = IR.GATHER_REDUCE[send.op]
        if g.acc.kind != kind:
            diags.append(Diagnostic(
                "ZS111", f"acc kind {g.acc.kind!r} != {kind!r} of "
                         f"{send.op}", **anchor))
        if g.acc.dim != send.dim:
            diags.append(Diagnostic(
                "ZS111", f"acc dim {g.acc.dim} != send dim {send.dim}",
                **anchor))
        if g.acc.value_id != send.inputs[0]:
            diags.append(Diagnostic(
                "ZS111", f"acc value %{g.acc.value_id} != send operand "
                         f"%{send.inputs[0]}", **anchor))
        if (g.acc.comm_id != send.comm_id
                or ctx.recv_of_comm.get(send.comm_id) != g.acc.recv_id):
            diags.append(Diagnostic(
                "ZS111", f"acc channel (comm={g.acc.comm_id}, "
                         f"recv=%{g.acc.recv_id}) != IR channel "
                         f"(comm={send.comm_id}, "
                         f"recv=%{ctx.recv_of_comm.get(send.comm_id)})",
                **anchor))

    # --- ownership: every gather channel in exactly one block (ZS101) ------
    gather_sends = sorted(n.id for n in ctx.nodes.values()
                          if n.op in _GATHER_SENDS)
    for snid in gather_sends:
        owners = [(p, g) for p, g in all_blocks
                  if g.acc.send_id == snid or snid in g.covered]
        if len(owners) != 1:
            where = [f"phase {p.level}/comm {g.acc.comm_id}"
                     for p, g in owners]
            diags.append(Diagnostic(
                "ZS101", f"gather send %{snid} "
                         f"({ctx.nodes[snid].op}, comm "
                         f"{ctx.nodes[snid].comm_id}) owned by "
                         f"{len(owners)} blocks {where}, need exactly 1",
                node=snid, origin="schedule"))

    # --- covered sets pairwise disjoint (ZS102) ----------------------------
    seen_covered: Dict[int, Tuple[S.Phase, S.GatherBlock]] = {}
    for phase, g in all_blocks:
        for nid in sorted(g.covered):
            if nid in seen_covered:
                p0, g0 = seen_covered[nid]
                diags.append(Diagnostic(
                    "ZS102", f"%{nid} covered by both phase {p0.level}/"
                             f"comm {g0.acc.comm_id} and this block",
                    **gather_anchor(phase, g)))
            else:
                seen_covered[nid] = (phase, g)

    # --- fused_levels / level consistency (ZS103) --------------------------
    levels = {p.level for p in sp.phases}
    for phase, g in all_blocks:
        anchor = gather_anchor(phase, g)
        if g.kernel == S.KERNEL_SEGMENT_SOFTMAX:
            want = (phase.level, phase.level + 1, phase.level + 2)
            if g.fused_levels != want:
                diags.append(Diagnostic(
                    "ZS103", f"fused_levels {g.fused_levels} != {want}",
                    **anchor))
            elif not set(g.fused_levels) <= levels:
                diags.append(Diagnostic(
                    "ZS103", f"fused_levels {g.fused_levels} name phases "
                             f"that do not exist", **anchor))
        elif g.fused_levels:
            diags.append(Diagnostic(
                "ZS103", f"non-fused {g.kernel} block carries fused_levels "
                         f"{g.fused_levels}", **anchor))
        elif (g.acc.send_id in ctx.nodes
              and plan.level.get(g.acc.send_id) != phase.level):
            diags.append(Diagnostic(
                "ZS103", f"send %{g.acc.send_id} has gather level "
                         f"{plan.level.get(g.acc.send_id)} but is scheduled "
                         f"at phase {phase.level}", **anchor))

    # --- kernel-tag legality (ZS104/105/106) + missed-kernel lint (ZS110) --
    for phase, g in all_blocks:
        if g.kernel == S.KERNEL_SCAN:
            if sp.kernel_dispatch:
                diags.append(Diagnostic(
                    "ZS110", explain_scan_fallback(g, ctx),
                    **gather_anchor(phase, g)))
            continue
        if g.kernel not in _KERNEL_CHECKS:
            diags.append(Diagnostic(
                "ZS104", f"unknown kernel tag {g.kernel!r}",
                **gather_anchor(phase, g)))
            continue
        code, check = _KERNEL_CHECKS[g.kernel]
        reason = check(g, phase, ctx, plan)
        if reason:
            diags.append(Diagnostic(
                code, f"{g.kernel} illegal here: {reason}",
                **gather_anchor(phase, g)))

    # --- covered nodes must not leak into any executed block (ZS109) -------
    covered_all: Set[int] = set()
    for _, g in all_blocks:
        covered_all |= g.covered
    for phase in sp.phases:
        for role, nodes in (("src", phase.src.nodes),
                            ("edge", phase.edge.nodes),
                            ("dst", phase.dst.nodes)):
            leaked = sorted(n.id for n in nodes if n.id in covered_all)
            for nid in leaked:
                diags.append(Diagnostic(
                    "ZS109", f"%{nid} ({ctx.nodes[nid].op}) is kernel-"
                             f"covered but still scheduled here",
                    phase=phase.level, node=nid, block=role,
                    origin="schedule"))
        for g in phase.gathers:
            for n in g.edge_nodes:
                if n.id in covered_all:
                    diags.append(Diagnostic(
                        "ZS109", f"%{n.id} ({n.op}) is kernel-covered but "
                                 f"listed in this block's edge operands",
                        **gather_anchor(phase, g)))

    # --- phase layer tags monotone (ZS108) ---------------------------------
    last_layer = 0
    for phase in sp.phases:
        if phase.layer < last_layer:
            diags.append(Diagnostic(
                "ZS108", f"layer tag {phase.layer} after a phase of layer "
                         f"{last_layer}", phase=phase.level,
                origin="schedule"))
        last_layer = max(last_layer, phase.layer)
    if sp.phases and sp.n_layers != sp.phases[-1].layer + 1:
        diags.append(Diagnostic(
            "ZS108", f"program claims {sp.n_layers} layers but the last "
                     f"phase is tagged layer {sp.phases[-1].layer}",
            phase=sp.phases[-1].level, origin="schedule"))

    # --- published-before-read dataflow (ZS107) ----------------------------
    diags.extend(_verify_dataflow(sp, ctx))
    return diags


def _verify_dataflow(sp: S.ScheduledProgram, ctx: _Ctx) -> List[Diagnostic]:
    """The engines' availability contract: every read resolves to a value
    that an earlier (or the same) phase provably produced or published."""
    diags: List[Diagnostic] = []
    vertex_inputs = {nid for nid, _ in sp.vertex_inputs}
    edge_inputs = {nid for nid, _ in sp.edge_inputs}

    #: recvInEdge id -> index of the phase whose gather block produces it
    produced_at: Dict[int, int] = {}
    #: dst-published node id -> first phase index it lands in the store
    published_at: Dict[int, int] = {}
    for pi, phase in enumerate(sp.phases):
        for g in phase.gathers:
            produced_at.setdefault(g.acc.recv_id, pi)
        for nid in phase.dst.store_ids:
            published_at.setdefault(nid, pi)

    def avail_vertex(nid: int, pi: int, src_side: bool,
                     same_phase_store: bool) -> bool:
        """Can a vertex-store read of ``nid`` resolve at phase index ``pi``?
        ``src_side`` additionally allows per-tile recompute via the phase's
        cumulative src block; ``same_phase_store`` allows store_ids of the
        *current* phase (the dst block runs before the tile work)."""
        if nid in vertex_inputs:
            return True
        if nid in produced_at and produced_at[nid] < pi:
            return True
        limit = pi if same_phase_store else pi - 1
        if nid in published_at and published_at[nid] <= limit:
            return True
        if src_side:
            return nid in {n.id for n in sp.phases[pi].src.nodes}
        return False

    for pi, phase in enumerate(sp.phases):
        src_ids = {n.id for n in phase.src.nodes}
        dst_ids = {n.id for n in phase.dst.nodes}

        # dst block: runs first, reads gather results of EARLIER phases
        for n in phase.dst.fresh:
            for i in n.inputs:
                if i in dst_ids or i in vertex_inputs:
                    continue
                if i in produced_at and produced_at[i] < pi:
                    continue
                why = (f"gather result %{i} is produced at phase "
                       f"{sp.phases[produced_at[i]].level}"
                       if i in produced_at else f"%{i} is never published")
                diags.append(Diagnostic(
                    "ZS107", f"dst {n.op} %{n.id} reads %{i} before it is "
                             f"available ({why})",
                    phase=phase.level, node=n.id, block="dst",
                    origin="schedule"))

        # src block: per-tile recompute falls back to the published store
        for n in phase.src.fresh:
            for i in n.inputs:
                if i in src_ids:
                    continue
                if not avail_vertex(i, pi, src_side=False,
                                    same_phase_store=True):
                    diags.append(Diagnostic(
                        "ZS107", f"src {n.op} %{n.id} reads %{i}, which no "
                                 f"phase <= {phase.level} publishes",
                        phase=phase.level, node=n.id, block="src",
                        origin="schedule"))

        # edge lists: scan path and kernel operand closures
        for block, enodes in ([("edge", phase.edge.nodes)]
                              + [(f"gather[comm={g.acc.comm_id}]",
                                  g.edge_nodes) for g in phase.gathers]):
            listed: Set[int] = set()
            for n in enodes:
                if n.op in ("recvSrc", "recvDst"):
                    v = sp.scatter_value_of.get(n.id)
                    ok = v is not None and avail_vertex(
                        v, pi, src_side=(n.op == "recvSrc"),
                        same_phase_store=True)
                    if not ok:
                        diags.append(Diagnostic(
                            "ZS107", f"{n.op} %{n.id} scatters %{v}, which "
                                     f"no phase <= {phase.level} provides",
                            phase=phase.level, node=n.id, block=block,
                            origin="schedule"))
                elif n.op == "recvInEdge":
                    diags.append(Diagnostic(
                        "ZS107", f"gather result %{n.id} listed as edge "
                                 f"compute", phase=phase.level, node=n.id,
                        block=block, origin="schedule"))
                else:
                    for i in n.inputs:
                        if i not in listed and i not in edge_inputs:
                            diags.append(Diagnostic(
                                "ZS107", f"edge {n.op} %{n.id} reads %{i} "
                                         f"before this block computes it",
                                phase=phase.level, node=n.id, block=block,
                                origin="schedule"))
                listed.add(n.id)

        # gather operands: X values and scan/edge value availability
        for g in phase.gathers:
            anchor = dict(phase=phase.level, node=g.acc.send_id,
                          block=f"gather[comm={g.acc.comm_id}]",
                          origin="schedule")
            if g.src_value_id is not None and not avail_vertex(
                    g.src_value_id, pi, src_side=True, same_phase_store=True):
                diags.append(Diagnostic(
                    "ZS107", f"kernel X operand %{g.src_value_id} is not "
                             f"available at phase {phase.level}", **anchor))
            if g.kernel == S.KERNEL_SCAN:
                have = {n.id for n in phase.edge.nodes} | edge_inputs
                if g.acc.value_id not in have:
                    diags.append(Diagnostic(
                        "ZS107", f"scan gather value %{g.acc.value_id} is "
                                 f"not computed by this phase's edge block",
                        **anchor))
            for ref, what in ((g.weight_id, "weight"), (g.score_id, "score")):
                if ref is None:
                    continue
                have = {n.id for n in g.edge_nodes} | edge_inputs
                if ref not in have:
                    diags.append(Diagnostic(
                        "ZS107", f"kernel {what} operand %{ref} is not in "
                                 f"the block's edge closure", **anchor))

    # outputs must be published by some phase
    for o in sp.outputs:
        if o not in published_at:
            diags.append(Diagnostic(
                "ZS107", f"output %{o} is never published by any phase's "
                         f"store_ids", node=o, block="dst",
                origin="schedule"))
    return diags
