"""Structured compiler diagnostics (ISSUE 6).

Every analysis pass reports :class:`Diagnostic` records instead of raising:
a stable code (``ZAxxx`` IR, ``ZSxxx`` schedule, ``ZHxxx`` hazards/census),
a severity, a human-readable message, and a source *anchor* naming the
segment / node / phase / block the finding points at.  Callers decide policy
(the ``compile_gnn(verify=True)`` hook raises on error severity; the CLI
pretty-prints and exits by ``--fail-on``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

ERROR = "error"
WARN = "warn"
INFO = "info"

SEVERITIES = (ERROR, WARN, INFO)
_SEV_RANK = {ERROR: 0, WARN: 1, INFO: 2}

#: stable code -> (default severity, one-line meaning).  Codes are append-only:
#: tests and downstream tooling key on them, so never renumber.
CODES: Dict[str, tuple] = {
    # --- IR verifier (ZA0xx) ----------------------------------------------
    "ZA001": (ERROR, "op not in the IR vocabulary"),
    "ZA002": (ERROR, "def-use: input references an undefined node"),
    "ZA003": (ERROR, "cycle in segment dataflow"),
    "ZA004": (ERROR, "element-wise broadcast dim mismatch"),
    "ZA005": (ERROR, "GEMM contraction/output dim mismatch"),
    "ZA006": (ERROR, "send paired with the wrong recv op"),
    "ZA007": (ERROR, "channel crosses segments in the wrong direction"),
    "ZA008": (ERROR, "channel send/recv dim mismatch"),
    "ZA009": (ERROR, "orphaned recv: comm id has no send"),
    "ZA010": (ERROR, "orphaned send: comm id has no recv"),
    "ZA011": (ERROR, "duplicate comm id on multiple sends/recvs"),
    "ZA012": (ERROR, "layer tag not monotone along dataflow"),
    "ZA013": (WARN, "dead node: not reachable from any output"),
    "ZA014": (WARN, "unused channel: recv value has no consumer"),
    "ZA015": (ERROR, "recv node must not have intra-segment inputs"),
    "ZA016": (ERROR, "node arity wrong for its op"),
    # --- ScheduledProgram verifier (ZS1xx) --------------------------------
    "ZS101": (ERROR, "gather channel not owned by exactly one GatherBlock"),
    "ZS102": (ERROR, "covered sets of two gather blocks overlap"),
    "ZS103": (ERROR, "fused_levels inconsistent with phase levels"),
    "ZS104": (ERROR, "pallas_spmm preconditions not met by the IR"),
    "ZS105": (ERROR, "pallas_spmm_weighted preconditions not met by the IR"),
    "ZS106": (ERROR, "pallas_segment_softmax motif not present in the IR"),
    "ZS107": (ERROR, "value read before any phase publishes it"),
    "ZS108": (ERROR, "phase layer tags not monotone across levels"),
    "ZS109": (ERROR, "kernel-covered node still scheduled in a block"),
    "ZS110": (INFO, "missed kernel: gather fell back to the scan path"),
    "ZS111": (ERROR, "accumulator spec inconsistent with its send node"),
    # --- schedule hazards & exchange census (ZH2xx) -----------------------
    "ZH201": (ERROR, "drain-ordering race: read not ordered after producer"),
    "ZH202": (ERROR, "task dependency references an unknown/forward task"),
    "ZH203": (ERROR, "gather barrier does not cover its partition's tiles"),
    "ZH204": (ERROR, "static exchange census disagrees with layer count"),
    "ZH205": (WARN, "exchanged value is not gather-tainted"),
    "ZH206": (INFO, "cross-chip boundary reads covered by the exchange"),
    "ZH207": (ERROR, "restricted exchange misses a cross-shard source read"),
    "ZH208": (ERROR, "recvDst read is not device-local under the shard plan"),
    "ZH209": (ERROR, "exchange send set holds rows the shard does not own"),
    "ZH210": (INFO, "restricted-exchange coverage proven (cut vs all-gather)"),
}


@dataclasses.dataclass
class Diagnostic:
    """One finding of a static analysis pass."""

    code: str
    message: str
    severity: str = ""                 # defaults from the CODES table
    # -- source anchor (all optional; whatever the pass can name) ----------
    segment: Optional[str] = None      # IR segment label, e.g. "IR.e.0"
    node: Optional[int] = None         # IR node id
    phase: Optional[int] = None        # scheduled phase level
    block: Optional[str] = None        # "src" | "edge" | "gather" | "dst" | task label
    #: which pass emitted it ("ir" | "schedule" | "hazard" | "census")
    origin: str = ""

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")
        if not self.severity:
            self.severity = CODES[self.code][0]
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def anchor(self) -> str:
        parts = []
        if self.segment is not None:
            parts.append(self.segment)
        if self.node is not None:
            parts.append(f"%{self.node}")
        if self.phase is not None:
            parts.append(f"phase {self.phase}")
        if self.block:
            parts.append(self.block)
        return ":".join(parts) if parts else "<program>"

    def format(self) -> str:
        return f"{self.code} [{self.severity:5s}] {self.anchor}: {self.message}"

    def to_dict(self) -> Dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v not in (None, "")}


def errors(diags: Sequence[Diagnostic]) -> List[Diagnostic]:
    return [d for d in diags if d.severity == ERROR]


def worst_severity(diags: Sequence[Diagnostic]) -> Optional[str]:
    return min((d.severity for d in diags), key=_SEV_RANK.get, default=None)


def sort_diags(diags: Sequence[Diagnostic]) -> List[Diagnostic]:
    return sorted(diags, key=lambda d: (_SEV_RANK[d.severity], d.code,
                                        d.node if d.node is not None else -1))


def format_report(diags: Sequence[Diagnostic], title: str = "") -> str:
    lines = []
    if title:
        n_err = len(errors(diags))
        n_warn = sum(1 for d in diags if d.severity == WARN)
        lines.append(f"{title}: {len(diags)} finding(s)"
                     f" ({n_err} error, {n_warn} warn)")
    lines += ["  " + d.format() for d in sort_diags(diags)]
    return "\n".join(lines) if lines else f"{title}: clean"


class VerificationError(ValueError):
    """Raised by ``verify=True`` hooks when error-severity findings exist."""

    def __init__(self, diags: Sequence[Diagnostic], context: str = ""):
        self.diagnostics = list(diags)
        errs = errors(self.diagnostics)
        head = (f"{context}: " if context else "") + \
            f"{len(errs)} error-severity diagnostic(s)"
        super().__init__("\n".join([head] + ["  " + d.format() for d in errs]))


def find_cycle(succs: Dict[int, List[int]]) -> List[int]:
    """One directed cycle in ``succs`` (adjacency: id -> successor ids), or
    ``[]`` if acyclic.  Shared by :meth:`Segment.toposort`'s error message
    and the IR verifier's ZA003 diagnostic so the two never diverge."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {nid: WHITE for nid in succs}
    for root in sorted(succs):
        if color[root] != WHITE:
            continue
        stack = [(root, iter(sorted(succs[root])))]
        path = [root]
        color[root] = GRAY
        while stack:
            nid, it = stack[-1]
            advanced = False
            for s in it:
                if s not in color:
                    continue
                if color[s] == GRAY:
                    return path[path.index(s):] + [s]
                if color[s] == WHITE:
                    color[s] = GRAY
                    path.append(s)
                    stack.append((s, iter(sorted(succs[s]))))
                    advanced = True
                    break
            if not advanced:
                color[nid] = BLACK
                path.pop()
                stack.pop()
    return []


def format_cycle(label: str, cycle: Sequence[int]) -> str:
    chain = " -> ".join(f"%{n}" for n in cycle)
    return f"cycle in segment {label}: {chain}"
