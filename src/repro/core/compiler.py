"""ZIPPER compiler (paper §6): classic whole-graph trace -> graph-native IR
-> tile-level SDE (source / destination / edge) program.

Step 1  construct_ir   : defuse GOPs into send/recv pairs, split the trace
                         into maximal connected vertex/edge segments.
Step 2  (passes.py)    : IR-level optimization — E2V, DCE.
Step 3  plan_sde       : classify vertex ops into source / destination
                         replicas, derive gather-barrier *phases*, and emit
                         the SDE structure the executor / ISA codegen use.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from . import ir as IR
from . import trace as TR


# ---------------------------------------------------------------------------
# Step 1: trace -> IRProgram
# ---------------------------------------------------------------------------

class _UF:
    def __init__(self):
        self.p: Dict[object, object] = {}

    def find(self, x):
        self.p.setdefault(x, x)
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[ra] = rb


_GOP_SEND = {
    "scatter_src": "sendOutEdge",
    "scatter_dst": "sendInEdge",
}
_GATHER_SEND = {"sum": "sendDstSum", "max": "sendDstMax", "mean": "sendDstMean"}


def construct_ir(tr: TR.GnnTrace) -> IR.IRProgram:
    """Paper §6.1 step 1: build the graph-native IR from a whole-graph trace."""
    prog = IR.IRProgram(name=tr.name)
    is_gop = lambda n: n.op in TR.GOP_TRACE_OPS
    is_param = lambda n: n.op == "param"

    # --- component discovery ------------------------------------------------
    # tokens: ('n', id) for non-GOP non-param nodes; ('r', id) for each GOP's recv side
    uf = _UF()
    for n in tr.nodes:
        if is_gop(n) or is_param(n):
            continue
        tok = ("n", n.id)
        uf.find(tok)
        for i in n.inputs:
            m = tr.node(i)
            if is_param(m):
                continue
            if is_gop(m):
                uf.union(tok, ("r", m.id))
            else:
                uf.union(tok, ("n", m.id))
    # GOP chained directly into GOP: the downstream GOP's send lives in the
    # upstream GOP's recv component (create the token so the segment exists).
    for n in tr.nodes:
        if not is_gop(n):
            continue
        uf.find(("r", n.id))

    # component -> segment
    comp_space: Dict[object, str] = {}

    def _space_of_token(tok) -> str:
        kind, nid = tok
        return tr.node(nid).space  # GOP node's output space == recv side space

    comps: Dict[object, List[object]] = {}
    for n in tr.nodes:
        if is_param(n):
            continue
        tok = ("r", n.id) if is_gop(n) else ("n", n.id)
        comps.setdefault(uf.find(tok), []).append(tok)

    seg_of_comp: Dict[object, IR.Segment] = {}
    for root, toks in sorted(comps.items(), key=lambda kv: min(t[1] for t in kv[1])):
        spaces = {_space_of_token(t) for t in toks}
        if len(spaces) != 1:
            raise ValueError(f"mixed-space component {spaces}: GOP defusion failed")
        kind = "vertex" if spaces == {"V"} else "edge"
        seg_of_comp[root] = prog.new_segment(kind)

    def seg_of(tok) -> IR.Segment:
        return seg_of_comp[uf.find(tok)]

    # --- node materialization -------------------------------------------------
    irid_of: Dict[Tuple[str, int], int] = {}  # ('n'|'r', trace id) -> IR node id

    def _mapped_input(i: int) -> int:
        m = tr.node(i)
        key = ("r", m.id) if is_gop(m) else ("n", m.id)
        return irid_of[key]

    for n in tr.nodes:  # trace order is topological
        if is_param(n):
            continue
        lay = tr.layer_of.get(n.id, 0)
        if is_gop(n):
            src_trace = tr.node(n.inputs[0])
            # send lives in the producer's component
            prod_tok = ("r", src_trace.id) if is_gop(src_trace) else ("n", src_trace.id)
            send_seg = seg_of(prod_tok)
            recv_seg = seg_of(("r", n.id))
            cid = prog.fresh_comm()
            if n.op == "gather":
                send_op = _GATHER_SEND[n.attrs["reduce"]]
                recv_op = "recvInEdge"
            else:
                send_op = _GOP_SEND[n.op]
                recv_op = IR.SEND_TO_RECV[send_op]
            send = IR.IRNode(
                id=prog.fresh_id(), op=send_op, inputs=[_mapped_input(n.inputs[0])],
                dim=n.dim, comm_id=cid, layer=lay,
                attrs={"reduce": n.attrs.get("reduce")} if n.op == "gather" else {},
            )
            send_seg.add(send)
            recv = IR.IRNode(id=prog.fresh_id(), op=recv_op, inputs=[], dim=n.dim,
                             comm_id=cid, layer=lay)
            recv_seg.add(recv)
            irid_of[("r", n.id)] = recv.id
            continue
        seg = seg_of(("n", n.id))
        if n.op == "input":
            node = IR.IRNode(id=prog.fresh_id(), op="input", inputs=[], dim=n.dim,
                             layer=lay, attrs={"name": n.attrs["name"]})
        elif n.op == "output":
            node = IR.IRNode(id=prog.fresh_id(), op="output", layer=lay,
                             inputs=[_mapped_input(n.inputs[0])], dim=n.dim)
        elif n.op in ("matmul", "gemv", "bias_add"):
            w = tr.node(n.inputs[1])
            node = IR.IRNode(id=prog.fresh_id(), op=n.op, layer=lay,
                             inputs=[_mapped_input(n.inputs[0])], dim=n.dim,
                             attrs={"weight": w.attrs["name"], "wshape": w.attrs["shape"]})
        elif n.op == "bmm_edge":
            w = tr.node(n.inputs[1])
            node = IR.IRNode(id=prog.fresh_id(), op="bmm_edge", layer=lay,
                             inputs=[_mapped_input(n.inputs[0]), _mapped_input(n.inputs[2])],
                             dim=n.dim,
                             attrs={"weight": w.attrs["name"], "wshape": w.attrs["shape"]})
        else:  # element-wise
            node = IR.IRNode(id=prog.fresh_id(), op=n.op, layer=lay,
                             inputs=[_mapped_input(i) for i in n.inputs], dim=n.dim,
                             attrs=dict(n.attrs))
        seg.add(node)
        irid_of[("n", n.id)] = node.id

    prog.validate()
    return prog


# ---------------------------------------------------------------------------
# Step 3: SDE planning — roles, phases
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SDEPlan:
    """Tile-level execution plan derived from an optimized IRProgram.

    ``level[nid]``     — number of gather barriers the node's value depends on.
    ``role[nid]``      — subset of {"src","dst"} for vertex nodes (paper: the
                         source / destination replicas of a vertex segment).
    ``max_level``      — number of tile-loop phases = max_level + 1.
    """

    prog: IR.IRProgram
    level: Dict[int, int]
    role: Dict[int, Set[str]]
    max_level: int


def plan_sde(prog: IR.IRProgram) -> SDEPlan:
    prog.rebuild_channels()
    # map comm -> send node id for level propagation
    send_of_comm = {cid: (ssi, snid) for cid, (ssi, snid, _, _) in prog.channels.items()}

    # global topological order across segments (follow channels send->recv)
    nodes: Dict[int, IR.IRNode] = {}
    seg_of: Dict[int, IR.Segment] = {}
    for seg in prog.segments:
        for n in seg.nodes.values():
            nodes[n.id] = n
            seg_of[n.id] = seg

    def deps(n: IR.IRNode) -> List[int]:
        if n.is_recv():
            ssi, snid = send_of_comm[n.comm_id]
            return [snid]
        return list(n.inputs)

    # Kahn over the global graph
    indeg = {nid: 0 for nid in nodes}
    succ: Dict[int, List[int]] = {nid: [] for nid in nodes}
    for n in nodes.values():
        for d in deps(n):
            indeg[n.id] += 1
            succ[d].append(n.id)
    frontier = collections.deque(nid for nid, d in sorted(indeg.items()) if d == 0)
    order: List[int] = []
    while frontier:
        nid = frontier.popleft()
        order.append(nid)
        for s in sorted(succ[nid]):
            indeg[s] -= 1
            if indeg[s] == 0:
                frontier.append(s)
    if len(order) != len(nodes):
        raise ValueError("global IR graph has a cycle")

    # levels: recvInEdge (gather result) is available one barrier later
    level: Dict[int, int] = {}
    for nid in order:
        n = nodes[nid]
        base = max((level[d] for d in deps(n)), default=0)
        if n.op == "recvInEdge":
            base += 1
        level[nid] = base

    # roles for vertex nodes: src if it transitively feeds a sendOutEdge,
    # dst if it feeds a sendInEdge / output, or consumes a recvInEdge.
    role: Dict[int, Set[str]] = {nid: set() for nid in nodes}
    # backward propagation over the global graph
    for nid in reversed(order):
        n = nodes[nid]
        if seg_of[nid].kind == "vertex":
            if n.op == "sendOutEdge":
                role[nid].add("src")
            if n.op == "sendInEdge" or n.op == "output" or n.op.startswith("sendDst"):
                role[nid].add("dst")
        for d in deps(n):
            if seg_of[d].kind == "vertex" and seg_of[nid].kind == "vertex":
                role[d] |= role[nid]
            elif seg_of[d].kind == "vertex":
                # vertex value consumed by an edge segment via a send — the
                # role came from the send node itself; nothing to add here.
                pass
    # vertex nodes consuming gather results are dst-side by construction
    for nid, n in nodes.items():
        if seg_of[nid].kind == "vertex" and n.op == "recvInEdge":
            role[nid].add("dst")

    max_level = max(level.values()) if level else 0
    return SDEPlan(prog=prog, level=level, role=role, max_level=max_level)


# ---------------------------------------------------------------------------
# Top-level compile entry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledGNN:
    name: str
    trace: TR.GnnTrace
    naive_ir: IR.IRProgram
    ir: IR.IRProgram          # optimized
    plan: SDEPlan
    opt_report: Dict[str, int]
    #: verify schedules as they are lowered (set from compile_gnn(verify=))
    verify: bool = True
    #: non-fatal findings accumulated by the verification hooks
    diagnostics: List = dataclasses.field(default_factory=list, repr=False)
    _schedules: Dict[bool, object] = dataclasses.field(default_factory=dict,
                                                       repr=False)

    @property
    def n_layers(self) -> int:
        """GNN layers in the lowered program (stacked models; 1 otherwise)."""
        return self.trace.n_layers

    def schedule(self, kernel_dispatch: bool = True):
        """The :class:`~repro.core.schedule.ScheduledProgram` every engine
        interprets (cached per dispatch mode)."""
        from . import schedule as S

        key = bool(kernel_dispatch)
        if key not in self._schedules:
            sp = S.lower(self.plan, kernel_dispatch=key)
            if self.verify:
                from . import analysis as A

                diags = A.verify_schedule(sp)
                errs = A.errors(diags)
                if errs:
                    raise A.VerificationError(
                        diags, context=f"schedule({self.name}, "
                                       f"kernel_dispatch={key})")
                self.diagnostics.extend(diags)
            self._schedules[key] = sp
        return self._schedules[key]

    def structure_signature(self, kernel_dispatch: bool = True):
        """Structural identity of the scheduled program (serving-cache hook):
        two compiled models with equal signatures lower to interchangeable
        programs, so warm runners can be shared between them."""
        return self.schedule(kernel_dispatch).structure_signature()


def compile_gnn(tr: TR.GnnTrace, optimize: bool = True,
                verify: bool = True) -> CompiledGNN:
    """Compile a (possibly multi-layer) whole-graph trace end to end: one
    cross-layer CSE pass on the trace, one IR spanning every layer, one
    SDE plan — engines interpret the whole stack in a single program.

    With ``verify=True`` (the default) the static IR verifier runs over the
    optimized program — and the schedule verifier over each lowering as it
    is produced — raising :class:`~repro.core.analysis.VerificationError`
    on any error-severity diagnostic.  Warnings/infos accumulate on
    ``CompiledGNN.diagnostics``.  The passes are pure graph walks (no
    execution), so the hook is cheap enough to stay on everywhere.
    """
    from . import passes

    naive = construct_ir(tr)
    if optimize:
        deduped, cse_removed = passes.cse_trace(tr)
        opt, report = passes.optimize(construct_ir(deduped))
        report["cse_removed"] = cse_removed
    else:
        opt, report = naive, {"e2v_moved": 0, "dce_removed": 0, "cse_removed": 0}
    if verify:
        from . import analysis as A

        diags = A.verify_ir(opt)
        errs = A.errors(diags)
        if errs:
            raise A.VerificationError(diags, context=f"compile_gnn({tr.name})")
    plan = plan_sde(opt)
    compiled = CompiledGNN(name=tr.name, trace=tr, naive_ir=naive, ir=opt,
                           plan=plan, opt_report=report, verify=verify)
    if verify:
        compiled.diagnostics.extend(diags)
    return compiled
