"""Multi-streamed execution model (paper §5.2, §7.2).

Builds the stream-task dependency graph for a compiled model over a tile
set: one **dStream** processes partitions sequentially; within the current
partition, up to ``n_sstreams`` sStreams and ``n_estreams`` eStreams process
tiles concurrently.  Dependencies reproduce the SIGNAL/WAIT protocol:

    dStream(p).pre  --SIGNAL-->  sStream(tile)  --SIGNAL.E-->  eStream(tile)
    all eStream(tiles of p)  --(gather barrier)-->  dStream(p).post

For multi-layer programs the default (``inter_layer="barrier"``) chains
every level after ALL of the previous level's barriers — the classic
layer-by-layer execution.  ``inter_layer="pipelined"`` relaxes the layer
boundary to its true data dependencies: a layer-``l+1`` tile's sStream task
waits only on the layer-``l`` gather barriers of the partitions that
*produce its source vertices*, so early partitions' next-layer tile compute
interleaves with late partitions' gather drain (the paper's tile × operator
parallelism applied across the whole stacked program).

The event-driven engine that executes this graph against hardware resources
lives in :mod:`repro.core.simulator`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .isa import Instr, SDEFunctions, DISPATCH_CYCLES
from .tiling import BucketedTileSet, TileSet


@dataclasses.dataclass
class HWConfig:
    """ZIPPER hardware configuration (paper Table 4 defaults)."""

    freq_ghz: float = 1.0
    n_mu: int = 1
    n_vu: int = 2
    n_sstreams: int = 4
    n_estreams: int = 4
    # MU: one 32x128 output-stationary systolic array per instance
    mu_rows: int = 32
    mu_cols: int = 128
    # VU: eight 32-wide SIMD cores per instance
    vu_lanes: int = 8 * 32
    # memory
    hbm_gbps: float = 256.0     # HBM-1.0 (paper); TPUv5e profile uses 819
    uem_mbytes: float = 21.0    # unified embedding memory (eDRAM)
    th_kbytes: float = 256.0    # tile hub SRAM
    dtype_bytes: int = 4
    # chip-to-chip link bandwidth (multi-chip scaling, PAPERS.md co-design
    # direction; the paper itself is single-chip)
    interconnect_gbps: float = 100.0

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_gbps / self.freq_ghz  # GB/s / GHz = bytes/ns = bytes/cycle

    @property
    def interconnect_bytes_per_cycle(self) -> float:
        return self.interconnect_gbps / self.freq_ghz

    def scaled(self, **kw) -> "HWConfig":
        return dataclasses.replace(self, **kw)


#: TPU-v5e-like configuration for the hardware-adaptation experiments
TPU_V5E_LIKE = HWConfig(freq_ghz=0.94, n_mu=4, n_vu=4, hbm_gbps=819.0,
                        uem_mbytes=128.0, mu_rows=128, mu_cols=128)


@dataclasses.dataclass
class Task:
    """A stream task: a straight-line instruction burst bound to a tile or
    partition.  ``deps`` are task ids that must complete first."""

    tid: int
    kind: str                      # 's' | 'e' | 'd'
    instrs: List[Tuple[Instr, int, int, int]]  # (template, m, k, n) bound dims
    deps: List[int]
    bytes_in: int = 0              # off-chip loads this task issues
    bytes_out: int = 0
    label: str = ""
    # structured identity (what the label encodes) so analyses never have to
    # parse label strings: SDE level, destination partition, flattened tile
    # index (s/e tasks only), and the dStream role ("drain" = per-partition
    # accumulator/drain compute, "barrier" = end-of-partition gather barrier)
    level: int = -1
    part: int = -1
    tile: int = -1
    role: str = ""                 # "s" | "e" | "drain" | "barrier"


def instr_cycles(ins: Instr, m: int, hw: HWConfig) -> int:
    """Latency model per instruction class (paper §7.1 units)."""
    if m == 0:
        return 0
    if ins.unit == "MU":
        # output-stationary systolic: each (mu_rows x mu_cols) output block
        # streams K inputs plus fill/drain
        blocks = math.ceil(m / hw.mu_rows) * math.ceil(ins.n / hw.mu_cols)
        fill = hw.mu_rows + hw.mu_cols
        cyc = blocks * (ins.k + fill)
        if ins.opcode == "BMM":
            # per-row weight selection defeats weight-stationary reuse:
            # weight stream refetched per block group (paper §8.3 observes
            # BMM dilutes tiling benefit via on-chip access latency)
            cyc = int(cyc * 2.0)
        return cyc + DISPATCH_CYCLES
    if ins.unit == "VU":
        lanework = m * max(ins.n, 1)
        cyc = math.ceil(lanework / hw.vu_lanes)
        if ins.opcode.startswith(("SCTR", "GTHR", "DENS", "SFTM")):
            cyc += m  # edge-list indirection: one TH lookup per item
        if ins.opcode == "GEMV":
            cyc = math.ceil(m * ins.k / hw.vu_lanes)
        # one dispatch per *instruction*: a fused ELW chain pays it once
        return cyc + DISPATCH_CYCLES
    return DISPATCH_CYCLES


def _source_partitions(tiles) -> List[np.ndarray]:
    """Per tile (flattened order), the destination partitions covering its
    source vertices — the partitions whose previous-layer gather results the
    tile's source compute reads."""
    def one(ts: TileSet) -> List[np.ndarray]:
        out = []
        for t in range(ts.n_tiles):
            ids = ts.src_ids[t, :int(ts.n_src[t])]
            out.append(np.unique(
                np.searchsorted(ts.part_start, ids, side="right") - 1))
        return out
    if isinstance(tiles, BucketedTileSet):
        return [ps for b in tiles.buckets for ps in one(b)]
    return one(tiles)


def build_task_graph(sde: SDEFunctions, tiles: TileSet, hw: HWConfig,
                     padded: bool = False, inter_layer: str = "barrier",
                     parts: Optional[Sequence[int]] = None
                     ) -> Tuple[List[Task], Dict[str, int]]:
    """Lower (SDE functions × tile set) into the stream task DAG.

    ``tiles`` may be a :class:`TileSet` or a
    :class:`~repro.core.tiling.BucketedTileSet` (the flattened per-tile view
    is used).  With ``padded=True`` every tile is costed at its batch's
    padded (S_max, E_max) instead of its true (n_src, n_edge) — the cost the
    static-shape ``lax.scan`` executor actually pays, which is what makes
    global padding vs size-bucketed batches comparable in the simulator.

    ``inter_layer`` controls multi-layer scheduling: ``"barrier"`` (default)
    chains each level globally after every barrier of the previous one;
    ``"pipelined"`` relaxes *layer-boundary* levels to per-partition data
    dependencies — a next-layer sStream task waits only on (a) its own
    partition's dStream-pre task (accumulator handoff) and (b) the dStream
    drain tasks of the partitions producing its source vertices, matching
    the executed :class:`~repro.core.pipeline.PipelinedRunner` dataflow
    (source replicas read *drained* previous-layer values, so the drain
    compute of the producing partitions is a true dependency; each drain in
    turn waits only on its own partition's gather barrier).  Within a layer
    the strict chain is kept, so the two modes isolate exactly the
    inter-layer overlap.

    ``parts`` restricts the graph to the given destination partitions — the
    per-chip view of a sharded execution (one chip owns whole partitions,
    see :class:`~repro.core.tiling.ShardPlan`); boundary source-partition
    dependencies on partitions outside the set are cross-chip edges and are
    costed separately by ``simulator.simulate_sharded``.
    """
    if inter_layer not in ("barrier", "pipelined"):
        raise ValueError(f"unknown inter_layer mode {inter_layer!r}")
    pipelined = inter_layer == "pipelined"
    part_list = (list(range(tiles.n_dst_parts)) if parts is None
                 else [int(p) for p in parts])
    tasks: List[Task] = []
    stats = {"offchip_read": 0, "offchip_write": 0, "macs": 0, "elw_ops": 0}
    by = hw.dtype_bytes

    def _bind(instrs: List[Instr], n_src: int, n_edge: int, n_dst: int):
        out = []
        for ins in instrs:
            m, k, n = ins.bound(n_src, n_edge, n_dst)
            out.append((ins, m, k, n))
            if ins.unit == "MU":
                stats["macs"] += m * k * n
            elif ins.unit == "VU":
                stats["elw_ops"] += m * max(n, 1)
        return out

    src_parts = _source_partitions(tiles) if pipelined else None
    tid = 0
    prev_d: Optional[int] = None
    bar_prev: Dict[int, int] = {}   # partition -> its last d-task of lvl-1
    for lvl in sde.all_levels():
        s_t, e_t, d_t = sde.s.get(lvl, []), sde.e.get(lvl, []), sde.d.get(lvl, [])
        has_tile_work = bool(s_t or e_t)
        boundary = (pipelined and lvl > 0
                    and sde.layer_of(lvl) != sde.layer_of(lvl - 1))
        bar_cur: Dict[int, int] = {}
        d_pres: Dict[int, Task] = {}

        def emit_tiles(p: int):
            """s/e tasks + gather barrier for partition ``p`` at ``lvl``."""
            nonlocal tid, prev_d
            d_pre = d_pres[p]
            n_dst = int(tiles.part_size[p])
            e_tasks: List[int] = []
            for t in tiles.tiles_of_partition(p):
                ns, ne = int(tiles.n_src[t]), int(tiles.n_edge[t])
                if ne == 0 and tiles.sparse:
                    continue
                if padded:
                    ns, ne = tiles.padded_dims_of_tile(t)
                sdeps = [d_pre.tid]
                if boundary:
                    # source replicas read the DRAINED previous-layer values,
                    # so the producing partitions' drain tasks are the true
                    # dependency (each drain waits only on its own barrier)
                    sdeps += [d_pres[int(ps)].tid for ps in src_parts[t]
                              if int(ps) in d_pres and int(ps) != p]
                st = Task(tid, "s", _bind(s_t, ns, ne, n_dst), deps=sdeps,
                          bytes_in=ns * sde.src_load_dim * by,
                          label=f"s[{lvl}].{p}.{t}",
                          level=lvl, part=p, tile=int(t), role="s")
                tasks.append(st); tid += 1
                if getattr(sde, "layout", "coo") == "csr":
                    # CSR tile: one column index per edge plus the (n_dst+1)
                    # row-pointer vector, instead of the COO (src, dst) pair
                    eidx_bytes = ne * 4 + (n_dst + 1) * 4
                else:
                    eidx_bytes = ne * 8  # COO pair
                et = Task(tid, "e", _bind(e_t, ns, ne, n_dst), deps=[st.tid],
                          bytes_in=eidx_bytes + ne * sde.edge_feat_dim * by,
                          label=f"e[{lvl}].{p}.{t}",
                          level=lvl, part=p, tile=int(t), role="e")
                tasks.append(et); tid += 1
                e_tasks.append(et.tid)
            # gather barrier: next dStream step waits for all tiles of p
            barrier = Task(tid, "d", [], deps=e_tasks or [d_pre.tid],
                           bytes_out=(n_dst * sde.out_dim * by
                                      if lvl == sde.max_level - 1 or lvl == sde.max_level else 0),
                           label=f"dbar[{lvl}].{p}",
                           level=lvl, part=p, role="barrier")
            tasks.append(barrier); tid += 1
            prev_d = barrier.tid
            bar_cur[p] = barrier.tid

        # dStream "pre" part per (level, partition).  At a pipelined layer
        # boundary every partition's drain is created first (dep: only its
        # own previous barrier) so tile tasks can reference the drains of
        # the partitions producing their source values; otherwise tile tasks
        # interleave with the strict dStream chain as before.
        for p in part_list:
            n_dst = int(tiles.part_size[p])
            if boundary:
                deps = [bar_prev[p]] if p in bar_prev else []
            else:
                deps = [prev_d] if prev_d is not None else []
            d_pre = Task(tid, "d", _bind(d_t, 0, 0, n_dst), deps=deps,
                         bytes_in=n_dst * sde.dst_load_dim * by,
                         label=f"d[{lvl}].{p}",
                         level=lvl, part=p, role="drain")
            tasks.append(d_pre); tid += 1
            prev_d = d_pre.tid
            bar_cur[p] = d_pre.tid
            d_pres[p] = d_pre
            if not boundary and has_tile_work:
                emit_tiles(p)
        if boundary and has_tile_work:
            for p in part_list:
                emit_tiles(p)
        bar_prev = bar_cur

    for t in tasks:
        stats["offchip_read"] += t.bytes_in
        stats["offchip_write"] += t.bytes_out
    return tasks, stats
