"""Optimization passes (paper §6.2).

* Cross-layer CSE (trace level): value-number the whole-graph trace and
  deduplicate ops that recompute an identical value — in stacked models the
  structure-only work (the shared ``dnorm`` scaling, the re-scattered
  unchanged normalized adjacency between GCN layers) repeats per layer and
  collapses to one copy.  Running before GOP defusion means the duplicate
  send/recv channels are never even built.
* E2V (edge-to-vertex): hoist edge-segment ops whose inputs are pure
  source- (or pure destination-) functions into the corresponding vertex
  segment, before the scatter.  Eliminates per-edge redundant compute —
  an op on E edges becomes an op on (at most) V vertices.
* DCE: global dead-code elimination across segments/channels (cleans up the
  orphaned send/recv pairs E2V leaves behind).

E2V and DCE operate on the whole IR program — segments of every layer at
once — so for multi-layer lowerings they hoist and sweep across layer
boundaries for free.
"""
from __future__ import annotations

import copy
from typing import Dict, List, Set, Tuple

from . import ir as IR
from . import trace as TR

_SCATTER_RECVS = ("recvSrc", "recvDst")


def cse_trace(tr: TR.GnnTrace) -> Tuple[TR.GnnTrace, int]:
    """Cross-layer common-subexpression elimination on the whole-graph trace.

    Two nodes are equal when op, space, (remapped) inputs, dim, and attrs all
    match — every traced op (GOPs included) is a pure function of its inputs
    and the symbolic graph, so the later copy can reuse the earlier value.
    Inputs/params are keyed by name; ``output`` indicators are never merged.
    A merged node keeps the *earliest* emitter's layer tag, so deduplicated
    structure-only work is scheduled with the first layer that needs it.

    Returns ``(deduplicated trace, number of nodes removed)``.
    """
    new = TR.GnnTrace(name=tr.name)
    new.params = dict(tr.params)
    remap: Dict[int, int] = {}
    seen: Dict[tuple, int] = {}
    removed = 0
    for n in tr.nodes:
        inputs = tuple(remap[i] for i in n.inputs)
        if n.op == "output":
            key = None                       # keep declaration order/arity
        elif n.op in ("input", "param"):
            key = (n.op, n.space, n.attrs["name"])
        else:
            key = (n.op, n.space, inputs, n.dim,
                   tuple(sorted((k, repr(v)) for k, v in n.attrs.items())))
        if key is not None and key in seen:
            remap[n.id] = seen[key]
            removed += 1
            continue
        nid = len(new.nodes)
        new.nodes.append(TR.TNode(id=nid, op=n.op, space=n.space,
                                  inputs=list(inputs), attrs=dict(n.attrs),
                                  dim=n.dim))
        new.layer_of[nid] = tr.layer_of.get(n.id, 0)
        remap[n.id] = nid
        if key is not None:
            seen[key] = nid
    dedup_inputs: List[int] = []
    for i in tr.inputs:
        if remap[i] not in dedup_inputs:
            dedup_inputs.append(remap[i])
    new.inputs = dedup_inputs
    new.outputs = [remap[o] for o in tr.outputs]
    return new, removed


def _seg_index(prog: IR.IRProgram, seg: IR.Segment) -> int:
    return prog.segments.index(seg)


def global_dce(prog: IR.IRProgram) -> int:
    """Remove nodes not backward-reachable from any ``output``. Returns count."""
    prog.rebuild_channels()
    send_of_comm = {cid: snid for cid, (ssi, snid, _, _) in prog.channels.items()}
    nodes: Dict[int, IR.IRNode] = {}
    for seg in prog.segments:
        nodes.update(seg.nodes)

    def deps(n: IR.IRNode) -> List[int]:
        if n.is_recv():
            return [send_of_comm[n.comm_id]]
        return list(n.inputs)

    live: Set[int] = set()
    stack = [n.id for n in nodes.values() if n.op == "output"]
    while stack:
        nid = stack.pop()
        if nid in live:
            continue
        live.add(nid)
        stack.extend(deps(nodes[nid]))

    removed = 0
    for seg in prog.segments:
        dead = [nid for nid in seg.nodes if nid not in live]
        for nid in dead:
            del seg.nodes[nid]
            removed += 1
    prog.segments = [s for s in prog.segments if s.nodes]
    prog.rebuild_channels()
    return removed


def _consumers(seg: IR.Segment, nid: int) -> List[IR.IRNode]:
    return [n for n in seg.nodes.values() if nid in n.inputs]


def e2v(prog: IR.IRProgram) -> int:
    """Edge-to-vertex hoisting. Returns the number of ops moved.

    A computational node in an edge segment is hoistable when every input is
    a scatter ``recv`` of one kind (all ``recvSrc`` or all ``recvDst``) whose
    paired sends live in the same vertex segment.  The op is then replayed on
    the vertex side (before the scatter) and a fresh scatter channel carries
    the already-computed value to the remaining edge consumers.
    """
    moved = 0
    changed = True
    while changed:
        changed = False
        prog.rebuild_channels()
        send_loc = {cid: (ssi, snid) for cid, (ssi, snid, _, _) in prog.channels.items()}
        for eseg in prog.edge_segments():
            for n in list(eseg.nodes.values()):
                if n.op not in IR.COMPUTE_OPS or not n.inputs:
                    continue
                ins = [eseg.nodes.get(i) for i in n.inputs]
                if any(m is None or not m.is_recv() or m.op not in _SCATTER_RECVS for m in ins):
                    continue
                kinds = {m.op for m in ins}
                if len(kinds) != 1:
                    continue
                vsegs = {send_loc[m.comm_id][0] for m in ins}
                if len(vsegs) != 1:
                    continue
                vsi = vsegs.pop()
                vseg = prog.segments[vsi]
                sends = [vseg.nodes[send_loc[m.comm_id][1]] for m in ins]
                # replay op on the vertex side, on the pre-scatter values
                hoisted = IR.IRNode(
                    id=prog.fresh_id(), op=n.op,
                    inputs=[s.inputs[0] for s in sends],
                    dim=n.dim, attrs=dict(n.attrs), layer=n.layer)
                vseg.add(hoisted)
                # fresh scatter channel for the computed value
                cid = prog.fresh_comm()
                new_send = IR.IRNode(id=prog.fresh_id(), op=sends[0].op,
                                     inputs=[hoisted.id], dim=n.dim, comm_id=cid,
                                     layer=n.layer)
                vseg.add(new_send)
                new_recv = IR.IRNode(id=prog.fresh_id(), op=ins[0].op, inputs=[],
                                     dim=n.dim, comm_id=cid, layer=n.layer)
                eseg.add(new_recv)
                for c in _consumers(eseg, n.id):
                    c.inputs = [new_recv.id if i == n.id else i for i in c.inputs]
                del eseg.nodes[n.id]
                moved += 1
                changed = True
                break  # channel table is stale — rescan from a clean slate
            if changed:
                break
        if changed:
            global_dce(prog)
    return moved


def fuse_elementwise(prog: IR.IRProgram) -> List[List[int]]:
    """Group chains of single-consumer element-wise ops (per segment).

    Purely advisory: the groups are consumed by the simulator / ISA codegen
    (one fused VU instruction per group) — the IR itself is left untouched,
    mirroring how the paper applies "existing DL optimizations" on the IR.
    """
    groups: List[List[int]] = []
    for seg in prog.segments:
        consumed: Set[int] = set()
        cons_count: Dict[int, int] = {}
        for n in seg.nodes.values():
            for i in n.inputs:
                cons_count[i] = cons_count.get(i, 0) + 1
        for n in seg.toposort():
            if n.id in consumed or n.op not in (IR.ELW_UNARY + IR.ELW_BINARY):
                continue
            chain = [n.id]
            cur = n
            while True:
                nxt = [c for c in _consumers(seg, cur.id)
                       if c.op in (IR.ELW_UNARY + IR.ELW_BINARY)
                       and cons_count.get(cur.id, 0) == 1]
                if len(nxt) != 1:
                    break
                cur = nxt[0]
                chain.append(cur.id)
            consumed.update(chain)
            if len(chain) > 1:
                groups.append(chain)
    return groups


def optimize(prog: IR.IRProgram) -> Tuple[IR.IRProgram, Dict[str, int]]:
    opt = copy.deepcopy(prog)
    moved = e2v(opt)
    removed = global_dce(opt)
    opt.validate()
    return opt, {"e2v_moved": moved, "dce_removed": removed,
                 "fusion_groups": len(fuse_elementwise(opt))}
