"""Scheduled phase-program layer (paper §6–§7): one lowering, three engines.

ZIPPER's compiler lowers graph-native IR into a *schedule* that a run-time
scheduler maps onto dedicated hardware blocks.  This module is that layer:
:func:`lower` turns an :class:`~repro.core.compiler.SDEPlan` into an explicit
:class:`ScheduledProgram` — per gather level one :class:`Phase` of typed
blocks — and every engine (``executor.run_tiled``, ``pipeline.PipelinedRunner``,
``isa.emit_sde`` + the cycle simulator) *interprets* the same program instead
of re-deriving levels and roles on its own.

Blocks per phase:

* :class:`SrcBlock`  — source-replica vertex compute, evaluated per tile on
  the compacted source rows.
* :class:`EdgeBlock` — per-edge compute feeding the scan-path gathers
  (recvs + element-wise/BMM chains).
* :class:`GatherBlock` — one per gather channel, carrying its accumulator
  spec and a ``kernel`` tag chosen by the pattern-matching scheduler pass:

  - ``pallas_spmm``            for  recvSrc -> sendDstSum        (pure SpMM)
  - ``pallas_spmm_weighted``   for  recvSrc * α -> sendDstSum    (α: per-edge
    scalar computed on the edge segment)
  - ``pallas_segment_softmax`` for the GAT edge-softmax motif — the THREE
    gather levels (max, sum-of-exp, weighted sum) fuse into one online-softmax
    block (see :func:`_match_softmax_motifs`)
  - ``scan``                   fallback (BMM / max / mean phases, or when
    kernel dispatch is off)

* :class:`DstBlock`  — destination-replica vertex compute, evaluated per
  partition, publishing phase results into the global vertex store.

The lowering is graph-independent (pure compile-time); engines bind it to a
tile set at run time.

Multi-layer programs lower exactly the same way: one :class:`SDEPlan` spans
every stacked layer, each :class:`Phase` carries the ``layer`` whose tile
work it runs, and the stream scheduler / simulator use those tags to
software-pipeline across layer boundaries (``inter_layer="pipelined"``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import ir as IR
from .compiler import SDEPlan

# kernel tags ---------------------------------------------------------------
KERNEL_SCAN = "scan"
KERNEL_SPMM = "pallas_spmm"
KERNEL_SPMM_WEIGHTED = "pallas_spmm_weighted"
KERNEL_SEGMENT_SOFTMAX = "pallas_segment_softmax"

PALLAS_KERNELS = (KERNEL_SPMM, KERNEL_SPMM_WEIGHTED, KERNEL_SEGMENT_SOFTMAX)


# ---------------------------------------------------------------------------
# typed blocks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AccumSpec:
    """Gather accumulator: which channel, which reduction, which result slot."""

    comm_id: int
    kind: str            # 'sum' | 'max' | 'mean'
    dim: int
    send_id: int         # edge-side sendDst* node
    value_id: int        # edge node feeding the send
    recv_id: int         # vertex-side recvInEdge node the result publishes to


@dataclasses.dataclass
class SrcBlock:
    """Source-replica vertex compute (per tile, on compacted source rows)."""

    role = "src"
    nodes: List[IR.IRNode]            # cumulative topo order up to this phase
    fresh: List[IR.IRNode]            # nodes whose own level == this phase


@dataclasses.dataclass
class DstBlock:
    """Destination-replica vertex compute (per partition)."""

    role = "dst"
    nodes: List[IR.IRNode]            # cumulative topo order (incl. outputs)
    fresh: List[IR.IRNode]
    store_ids: List[int]              # node ids published to the vertex store


@dataclasses.dataclass
class EdgeBlock:
    """Per-edge compute feeding the scan-path gathers of this phase."""

    role = "edge"
    nodes: List[IR.IRNode]            # topo order; recvs + compute, no sends
    fresh: List[IR.IRNode]            # all edge nodes of this level (ISA order)


@dataclasses.dataclass
class GatherBlock:
    """One gather channel of this phase, dispatched to a hardware block."""

    acc: AccumSpec
    kernel: str = KERNEL_SCAN
    #: vertex node whose value feeds the kernel's dense X operand
    src_value_id: Optional[int] = None
    #: edge node computing the per-edge scalar weight α (weighted SpMM)
    weight_id: Optional[int] = None
    #: edge node computing the per-edge score e (segment softmax)
    score_id: Optional[int] = None
    #: edge nodes (topo order) to evaluate for the kernel's edge operands
    edge_nodes: List[IR.IRNode] = dataclasses.field(default_factory=list)
    #: node ids subsumed by this block (fused motif internals, skip everywhere)
    covered: Set[int] = dataclasses.field(default_factory=set)
    #: gather levels folded into this block (softmax fusion spans three)
    fused_levels: Tuple[int, ...] = ()


@dataclasses.dataclass
class Phase:
    """All work between two gather barriers."""

    level: int
    src: SrcBlock
    edge: EdgeBlock
    gathers: List[GatherBlock]
    dst: DstBlock
    #: GNN layer whose tile work this phase carries (stacked models).  A
    #: boundary phase drains layer ``layer-1``'s gather in its dst block
    #: while running layer ``layer``'s src/edge/gather tile work.
    layer: int = 0

    @property
    def has_tile_work(self) -> bool:
        return bool(self.edge.nodes or self.gathers)

    def scan_gathers(self) -> List[GatherBlock]:
        return [g for g in self.gathers if g.kernel == KERNEL_SCAN]

    def kernel_gathers(self) -> List[GatherBlock]:
        return [g for g in self.gathers if g.kernel != KERNEL_SCAN]


@dataclasses.dataclass
class ScheduledProgram:
    """The explicit dataflow program every engine interprets."""

    plan: SDEPlan
    prog: IR.IRProgram
    phases: List[Phase]
    outputs: List[int]                     # output node ids, declaration order
    #: recvSrc/recvDst node id -> vertex node id whose value it carries
    scatter_value_of: Dict[int, int]
    #: (node id, input name) pairs for vertex- and edge-space inputs
    vertex_inputs: List[Tuple[int, str]]
    edge_inputs: List[Tuple[int, str]]
    kernel_dispatch: bool
    #: feature widths the data-transfer instructions move (ISA codegen)
    src_load_dim: int = 0
    dst_load_dim: int = 0
    edge_feat_dim: int = 0
    out_dim: int = 0
    #: GNN layers spanned by this program (stacked models; 1 otherwise)
    n_layers: int = 1

    @property
    def max_level(self) -> int:
        return self.phases[-1].level if self.phases else 0

    def layer_of_level(self) -> Dict[int, int]:
        """level -> GNN layer whose tile work runs at that level."""
        return {p.level: p.layer for p in self.phases}

    def kernels_by_level(self) -> Dict[int, List[str]]:
        return {p.level: [g.kernel for g in p.gathers] for p in self.phases
                if p.gathers}

    def gather_kernel(self, level: int) -> Optional[str]:
        """Kernel tag of the (first) gather block at ``level``, if any."""
        for p in self.phases:
            if p.level == level and p.gathers:
                return p.gathers[0].kernel
        return None

    def structure_signature(self) -> Tuple:
        """Cheap structural identity of the lowered program: phase/kernel-tag
        layout plus the feature widths every engine compilation depends on.
        Same signature => the same jitted runner can execute it (the serving
        program cache keys on this together with the tile-set signature).
        Memoized — safe to call on the per-request serving hot path."""
        cached = getattr(self, "_structure_sig", None)
        if cached is not None:
            return cached

        def block(nodes: Sequence[IR.IRNode]) -> Tuple:
            # every attr participates: trace-time constants (leaky_relu slope,
            # weight shapes, ...) bake into the compiled program, so programs
            # differing only there must not share a warm runner
            return tuple((n.op, n.dim,
                          tuple(sorted((k, repr(v))
                                       for k, v in n.attrs.items())))
                         for n in nodes)

        sig = ("sched", self.prog.name, self.kernel_dispatch, self.n_layers,
               tuple((p.level, p.layer, tuple(g.kernel for g in p.gathers),
                      block(p.src.fresh), block(p.edge.fresh),
                      block(p.dst.fresh))
                     for p in self.phases),
               self.src_load_dim, self.dst_load_dim, self.edge_feat_dim,
               self.out_dim)
        self._structure_sig = sig
        return sig

    def pretty(self) -> str:
        lines = [f"ScheduledProgram<{self.prog.name}> "
                 f"(kernel_dispatch={self.kernel_dispatch})"]
        for p in self.phases:
            lines.append(f"  phase {p.level}:")
            if p.src.fresh:
                lines.append(f"    src : {[n.op for n in p.src.fresh]}")
            if p.edge.nodes:
                lines.append(f"    edge: {[n.op for n in p.edge.nodes]}")
            for g in p.gathers:
                lines.append(f"    gather comm={g.acc.comm_id} kind={g.acc.kind}"
                             f" -> {g.kernel}")
            if p.dst.fresh:
                lines.append(f"    dst : {[n.op for n in p.dst.fresh]}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# softmax motif matching (GAT edge softmax, three fused gather levels)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SoftmaxMotif:
    level: int                 # level of the sendDstMax head
    score_id: int              # edge node computing the raw scores e
    src_value_id: int          # vertex node feeding the message recvSrc
    out_send: IR.IRNode        # final sendDstSum
    out_recv_id: int           # recvInEdge publishing the aggregated result
    covered: Set[int]          # every node subsumed by the fused block
    fused_levels: Tuple[int, int, int]


def _match_softmax_motifs(plan: SDEPlan, nodes: Dict[int, IR.IRNode],
                          send_of_comm: Dict[int, int],
                          recv_of_comm: Dict[int, int]) -> List[_SoftmaxMotif]:
    """Find the numerically-stable edge-softmax shape emitted by
    ``trace.GraphRef.edge_softmax`` followed by a weighted sum-gather:

        m  = gather_max(e)                       level L
        ex = exp(e - scatter_dst(m))             level L+1 edge
        s  = gather_sum(ex)                      level L+1
        α  = ex / scatter_dst(s)                 level L+2 edge
        out = gather_sum(recvSrc(h) * α)         level L+2

    All intermediates must be private to the motif (single-consumer chain),
    so fusing them into one online-softmax block is observationally
    equivalent.  Returns one motif per match.
    """
    consumers: Dict[int, List[IR.IRNode]] = {}
    for n in nodes.values():
        for i in n.inputs:
            consumers.setdefault(i, []).append(n)

    def only_consumer(nid: int) -> Optional[IR.IRNode]:
        cons = consumers.get(nid, [])
        return cons[0] if len(cons) == 1 else None

    def recv_of_scatter(vertex_nid: int) -> Optional[IR.IRNode]:
        """vertex value -> its single scatter send -> the edge-side recv."""
        send = only_consumer(vertex_nid)
        if send is None or send.op not in ("sendInEdge", "sendOutEdge"):
            return None
        return nodes[recv_of_comm[send.comm_id]]

    motifs: List[_SoftmaxMotif] = []
    for smax in nodes.values():
        if smax.op != "sendDstMax":
            continue
        lvl = plan.level[smax.id]
        e0 = smax.inputs[0]
        m_recv_id = recv_of_comm[smax.comm_id]          # vertex recvInEdge m
        # m must only feed a scatter_dst back to the edge segment
        m_edge = recv_of_scatter(m_recv_id)
        if m_edge is None or m_edge.op != "recvDst":
            continue
        # shifted = sub(e0, m_edge); the score e0 feeds ONLY smax and sub
        sub = only_consumer(m_edge.id)
        if (sub is None or sub.op != "sub" or sub.inputs != [e0, m_edge.id]
                or {c.id for c in consumers.get(e0, [])} != {smax.id, sub.id}):
            continue
        ex = only_consumer(sub.id)
        if ex is None or ex.op != "exp":
            continue
        # ex feeds the sum-gather and the normalizing division — exactly
        ex_cons = consumers.get(ex.id, [])
        ssum = next((c for c in ex_cons if c.op == "sendDstSum"), None)
        div = next((c for c in ex_cons if c.op == "div"), None)
        if ssum is None or div is None or len(ex_cons) != 2:
            continue
        s_recv_id = recv_of_comm[ssum.comm_id]          # vertex recvInEdge s
        s_edge = recv_of_scatter(s_recv_id)
        if s_edge is None or s_edge.op != "recvDst":
            continue
        if div.inputs != [ex.id, s_edge.id] or only_consumer(s_edge.id) is not div:
            continue
        # msg = mul(recvSrc(h), α) in either operand order
        mul = only_consumer(div.id)
        if mul is None or mul.op != "mul":
            continue
        other = [i for i in mul.inputs if i != div.id]
        if len(other) != 1:
            continue
        rs = nodes[other[0]]
        if rs.op != "recvSrc" or only_consumer(rs.id) is not mul:
            continue
        out_send = only_consumer(mul.id)
        if out_send is None or out_send.op != "sendDstSum":
            continue
        # private vertex-side intermediates: m and s feed nothing else
        m_send = only_consumer(m_recv_id)
        s_send = only_consumer(s_recv_id)
        if m_send is None or s_send is None:
            continue
        src_value_id = nodes[send_of_comm[rs.comm_id]].inputs[0]
        covered = {smax.id, m_recv_id, m_send.id, m_edge.id, sub.id, ex.id,
                   ssum.id, s_recv_id, s_send.id, s_edge.id, div.id, rs.id,
                   mul.id, out_send.id,
                   send_of_comm[rs.comm_id]}
        motifs.append(_SoftmaxMotif(
            level=lvl, score_id=e0, src_value_id=src_value_id,
            out_send=out_send, out_recv_id=recv_of_comm[out_send.comm_id],
            covered=covered, fused_levels=(lvl, lvl + 1, lvl + 2)))
    return motifs


# ---------------------------------------------------------------------------
# per-gather kernel classification
# ---------------------------------------------------------------------------

def _classify_gather(send: IR.IRNode, nodes: Dict[int, IR.IRNode],
                     send_of_comm: Dict[int, int],
                     consumers: Dict[int, List[IR.IRNode]]) -> Tuple[str, Dict]:
    """Pattern-match one gather send onto a hardware block.

    The matched chain must be single-consumer so subsuming it into the
    kernel block leaves nothing dangling for the scan path.
    """
    def private(nid: int) -> bool:
        return len(consumers.get(nid, [])) == 1

    if send.op != "sendDstSum":
        return KERNEL_SCAN, {}
    val = nodes[send.inputs[0]]
    if val.op == "recvSrc" and private(val.id):
        # recvSrc -> sendDstSum: the pure-SpMM aggregation
        src_value = nodes[send_of_comm[val.comm_id]].inputs[0]
        return KERNEL_SPMM, {"src_value_id": src_value, "covered": {val.id}}
    if val.op == "mul" and private(val.id):
        # recvSrc * α -> sendDstSum: weighted SpMM with a runtime-densified
        # adjacency (α must be a per-edge scalar so it can live in A[t,d,s])
        a, b = (nodes[i] for i in val.inputs)
        for rs, w in ((a, b), (b, a)):
            if rs.op == "recvSrc" and w.dim == 1 and not w.is_recv() \
                    and private(rs.id):
                src_value = nodes[send_of_comm[rs.comm_id]].inputs[0]
                return KERNEL_SPMM_WEIGHTED, {
                    "src_value_id": src_value, "weight_id": w.id,
                    "covered": {val.id, rs.id}}
    return KERNEL_SCAN, {}


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def _edge_closure(targets: Sequence[int], nodes: Dict[int, IR.IRNode],
                  seg_of: Dict[int, IR.Segment]) -> Set[int]:
    """Transitive edge-segment dependencies of ``targets`` (incl. recvs)."""
    need: Set[int] = set()
    stack = list(targets)
    while stack:
        nid = stack.pop()
        if nid in need or seg_of[nid].kind != "edge":
            continue
        need.add(nid)
        n = nodes[nid]
        if not n.is_recv():          # recvs cross to the vertex side: stop
            stack.extend(n.inputs)
    return need


_GATHER_KIND = {"sendDstSum": "sum", "sendDstMax": "max", "sendDstMean": "mean"}


def lower(plan: SDEPlan, kernel_dispatch: bool = True) -> ScheduledProgram:
    """Lower an SDE plan into the explicit scheduled phase program.

    ``kernel_dispatch=False`` tags every gather ``scan`` and disables motif
    fusion — the pure multi-phase schedule of the paper.  The result is the
    single source of truth for levels, roles, and block membership: engines
    must not consult ``plan.level`` / ``plan.role`` themselves.
    """
    prog = plan.prog
    prog.rebuild_channels()
    send_of_comm = {cid: snid for cid, (_, snid, _, _) in prog.channels.items()}
    recv_of_comm = {cid: rnid for cid, (_, _, _, rnid) in prog.channels.items()}

    nodes: Dict[int, IR.IRNode] = {}
    seg_of: Dict[int, IR.Segment] = {}
    for seg in prog.segments:
        for n in seg.nodes.values():
            nodes[n.id] = n
            seg_of[n.id] = seg

    consumers: Dict[int, List[IR.IRNode]] = {}
    for n in nodes.values():
        for i in n.inputs:
            consumers.setdefault(i, []).append(n)

    motifs = (_match_softmax_motifs(plan, nodes, send_of_comm, recv_of_comm)
              if kernel_dispatch else [])
    motif_at: Dict[int, List[_SoftmaxMotif]] = {}
    motif_covered: Set[int] = set()
    for m in motifs:
        motif_at.setdefault(m.level, []).append(m)
        motif_covered |= m.covered

    # vertex compute in deterministic (segment, topo) order
    vnodes: List[IR.IRNode] = [n for seg in prog.vertex_segments()
                               for n in seg.toposort()]
    enodes: List[IR.IRNode] = [n for seg in prog.edge_segments()
                               for n in seg.toposort()]

    def vcompute(n: IR.IRNode) -> bool:
        return n.op not in ("input",) and not n.is_send() and not n.is_recv()

    phases: List[Phase] = []
    cur_layer = 0   # phase layer tags are monotone across levels
    for lvl in range(plan.max_level + 1):
        # ---- source block ---------------------------------------------------
        src_nodes = [n for n in vnodes
                     if vcompute(n) and n.op != "output"
                     and "src" in plan.role[n.id] and plan.level[n.id] <= lvl]
        src_fresh = [n for n in src_nodes if plan.level[n.id] == lvl]

        # ---- destination block ----------------------------------------------
        dst_nodes = [n for n in vnodes
                     if vcompute(n) and plan.level[n.id] <= lvl
                     and ("dst" in plan.role[n.id] or n.op == "output")
                     and n.id not in motif_covered]
        dst_fresh = [n for n in dst_nodes if plan.level[n.id] == lvl]
        store_ids = [n.id for n in dst_fresh]

        # ---- gather blocks --------------------------------------------------
        gathers: List[GatherBlock] = []
        kernel_covered: Set[int] = set()        # edge nodes a kernel subsumes
        for m in motif_at.get(lvl, []):
            send = m.out_send
            acc = AccumSpec(comm_id=send.comm_id, kind="sum", dim=send.dim,
                            send_id=send.id, value_id=send.inputs[0],
                            recv_id=m.out_recv_id)
            score_need = _edge_closure([m.score_id], nodes, seg_of)
            # edge inputs are read lazily via the engines' estore lookup
            score_nodes = [n for n in enodes
                           if n.id in score_need and n.op != "input"]
            gathers.append(GatherBlock(
                acc=acc, kernel=KERNEL_SEGMENT_SOFTMAX,
                src_value_id=m.src_value_id, score_id=m.score_id,
                edge_nodes=score_nodes, covered=set(m.covered),
                fused_levels=m.fused_levels))
            kernel_covered |= m.covered

        lvl_sends = [n for n in enodes
                     if n.is_send() and n.op in _GATHER_KIND
                     and plan.level[n.id] == lvl and n.id not in motif_covered]
        for send in lvl_sends:
            acc = AccumSpec(comm_id=send.comm_id, kind=_GATHER_KIND[send.op],
                            dim=send.dim, send_id=send.id,
                            value_id=send.inputs[0],
                            recv_id=recv_of_comm[send.comm_id])
            kernel, extra = (_classify_gather(send, nodes, send_of_comm,
                                              consumers)
                             if kernel_dispatch else (KERNEL_SCAN, {}))
            g = GatherBlock(acc=acc, kernel=kernel,
                            src_value_id=extra.get("src_value_id"),
                            weight_id=extra.get("weight_id"))
            if kernel != KERNEL_SCAN:
                g.covered = set(extra.get("covered", set())) | {send.id}
                if g.weight_id is not None:
                    weight_need = _edge_closure([g.weight_id], nodes, seg_of)
                    g.edge_nodes = [n for n in enodes
                                    if n.id in weight_need and n.op != "input"]
                kernel_covered |= g.covered
            gathers.append(g)

        # ---- edge block: everything the scan path still needs ---------------
        scan_targets = [g.acc.value_id for g in gathers
                        if g.kernel == KERNEL_SCAN]
        scan_need = _edge_closure(scan_targets, nodes, seg_of)
        edge_nodes = [n for n in enodes
                      if n.id in scan_need and not n.is_send()
                      and n.op != "input"]
        edge_fresh = [n for n in enodes
                      if plan.level[n.id] == lvl and n.op != "input"
                      and n.id not in motif_covered
                      and n.id not in kernel_covered]

        cur_layer = max([cur_layer]
                        + [n.layer for n in src_fresh + dst_fresh + edge_fresh]
                        + [nodes[g.acc.send_id].layer for g in gathers])
        phases.append(Phase(
            level=lvl,
            src=SrcBlock(nodes=src_nodes, fresh=src_fresh),
            edge=EdgeBlock(nodes=edge_nodes, fresh=edge_fresh),
            gathers=gathers,
            dst=DstBlock(nodes=dst_nodes, fresh=dst_fresh, store_ids=store_ids),
            layer=cur_layer,
        ))

    scatter_value_of = {
        rnid: nodes[send_of_comm[cid]].inputs[0]
        for cid, rnid in recv_of_comm.items()
        if nodes[rnid].op in ("recvSrc", "recvDst")
    }
    outputs = sorted(n.id for n in nodes.values() if n.op == "output")
    vertex_inputs = [(n.id, n.attrs["name"]) for seg in prog.vertex_segments()
                     for n in seg.toposort() if n.op == "input"]
    edge_inputs = [(n.id, n.attrs["name"]) for seg in prog.edge_segments()
                   for n in seg.toposort() if n.op == "input"]

    src_load_dim = sum(nodes[nid].dim for nid, _ in vertex_inputs
                       if "src" in plan.role[nid])
    dst_load_dim = sum(nodes[nid].dim for nid, _ in vertex_inputs
                       if "dst" in plan.role[nid])
    edge_feat_dim = sum(nodes[nid].dim for nid, _ in edge_inputs)
    out_dim = sum(nodes[nid].dim for nid in outputs)

    return ScheduledProgram(
        plan=plan, prog=prog, phases=phases, outputs=outputs,
        scatter_value_of=scatter_value_of,
        vertex_inputs=vertex_inputs, edge_inputs=edge_inputs,
        kernel_dispatch=kernel_dispatch,
        src_load_dim=src_load_dim, dst_load_dim=dst_load_dim,
        edge_feat_dim=edge_feat_dim, out_dim=out_dim,
        n_layers=max((n.layer for n in nodes.values()), default=0) + 1)
