"""Whole-graph tensor trace — the *classic GNN programming model*.

This is the paper's starting point (§3.3, Figure 5): a GNN is written
against tensors covering all vertices / edges at once ("DGL-like"), which
hides graph semantics.  We reproduce that programming model with a tiny
tracer: user model code manipulates :class:`TT` handles; every operation is
recorded as a :class:`TNode` in a :class:`GnnTrace`.  The compiler
(``core/compiler.py``) consumes the trace and recovers graph semantics.

Tensor *spaces*:
    'V'  — one row per vertex            (shape [n_vertices, dim])
    'E'  — one row per edge              (shape [n_edges, dim])
    'P'  — parameter (shared weights)    (shape attrs['shape'])
Only GOPs (scatter / gather) change the space of a tensor — this property is
what lets the compiler split the program into vertex/edge segments.

Multi-layer programs: ZIPPER's evaluation stacks layers (§8.1), so a trace
may span several GNN layers.  :func:`trace_model` accepts either one build
function or a *sequence of layer builders* ``fn(tr, g, x) -> TT`` — layer
``l``'s output tensor becomes layer ``l+1``'s input — and every emitted node
is tagged with the layer that produced it (``GnnTrace.layer_of``), which the
compiler propagates through the IR into the scheduled phase program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from . import ir as IR


@dataclasses.dataclass
class TNode:
    id: int
    op: str
    space: str  # 'V' | 'E' | 'P'
    inputs: List[int]
    attrs: Dict[str, Any]
    dim: int


class GnnTrace:
    """Recorded whole-graph computation."""

    def __init__(self, name: str = "gnn"):
        self.name = name
        self.nodes: List[TNode] = []
        self.inputs: List[int] = []   # node ids of graph inputs (vertex/edge feats)
        self.outputs: List[int] = []  # node ids of model outputs
        self.params: Dict[str, Tuple[int, ...]] = {}  # name -> shape
        self.layer_of: Dict[int, int] = {}  # node id -> GNN layer that emitted it
        self._layer = 0

    def begin_layer(self, layer: int) -> None:
        """Tag subsequently emitted nodes as belonging to GNN layer ``layer``."""
        self._layer = int(layer)

    @property
    def n_layers(self) -> int:
        return max(self.layer_of.values(), default=0) + 1

    def emit(self, op: str, space: str, inputs: Sequence[int], dim: int, **attrs) -> "TT":
        node = TNode(id=len(self.nodes), op=op, space=space,
                     inputs=list(inputs), attrs=dict(attrs), dim=dim)
        self.nodes.append(node)
        self.layer_of[node.id] = self._layer
        return TT(self, node.id)

    def node(self, nid: int) -> TNode:
        return self.nodes[nid]

    # -- user-facing declaration helpers --------------------------------------
    def input_vertex(self, dim: int, name: str = "x") -> "TT":
        t = self.emit("input", "V", [], dim, name=name)
        self.inputs.append(t.nid)
        return t

    def input_edge(self, dim: int, name: str = "efeat") -> "TT":
        t = self.emit("input", "E", [], dim, name=name)
        self.inputs.append(t.nid)
        return t

    def param(self, name: str, shape: Tuple[int, ...]) -> "TT":
        self.params[name] = tuple(shape)
        return self.emit("param", "P", [], shape[-1], name=name, shape=tuple(shape))

    def mark_output(self, t: "TT") -> None:
        out = self.emit("output", t.space, [t.nid], t.dim)
        self.outputs.append(out.nid)


class TT:
    """Traced tensor handle (whole-graph semantics)."""

    def __init__(self, trace: GnnTrace, nid: int):
        self.trace = trace
        self.nid = nid

    # -- bookkeeping -----------------------------------------------------------
    @property
    def node(self) -> TNode:
        return self.trace.node(self.nid)

    @property
    def space(self) -> str:
        return self.node.space

    @property
    def dim(self) -> int:
        return self.node.dim

    # -- NN ops (GEMM class) ----------------------------------------------------
    def matmul(self, w: "TT") -> "TT":
        """x @ W  — per-item dense transform. W: (dim_in, dim_out)."""
        shape = w.node.attrs["shape"]
        assert shape[0] == self.dim, f"matmul dim mismatch {shape} vs {self.dim}"
        return self.trace.emit("matmul", self.space, [self.nid, w.nid], shape[-1])

    def gemv(self, a: "TT") -> "TT":
        """x @ a  — per-item mat-vec producing a scalar per item. a: (dim_in, 1)."""
        shape = a.node.attrs["shape"]
        assert shape[0] == self.dim and shape[-1] == 1
        return self.trace.emit("gemv", self.space, [self.nid, a.nid], 1)

    def bmm_edge(self, w: "TT", etype: "TT") -> "TT":
        """Edge-type-guided batched matmul (R-GCN): out_e = x_e @ W[etype_e].

        W: (n_types, dim_in, dim_out); etype: per-edge integer type ('E', dim=1).
        """
        shape = w.node.attrs["shape"]
        assert self.space == "E" and etype.space == "E"
        assert shape[1] == self.dim
        return self.trace.emit("bmm_edge", "E", [self.nid, w.nid, etype.nid], shape[-1])

    # -- element-wise ops --------------------------------------------------------
    def _elw2(self, op: str, other: "TT") -> "TT":
        assert self.space == other.space, f"{op}: space mismatch {self.space} vs {other.space}"
        dim = max(self.dim, other.dim)  # (N,1) broadcasting allowed
        return self.trace.emit(op, self.space, [self.nid, other.nid], dim)

    def __add__(self, other: "TT") -> "TT":
        return self._elw2("add", other)

    def __sub__(self, other: "TT") -> "TT":
        return self._elw2("sub", other)

    def __mul__(self, other: "TT") -> "TT":
        return self._elw2("mul", other)

    def __truediv__(self, other: "TT") -> "TT":
        return self._elw2("div", other)

    def max2(self, other: "TT") -> "TT":
        return self._elw2("max2", other)

    def _elw1(self, op: str, **attrs) -> "TT":
        return self.trace.emit(op, self.space, [self.nid], self.dim, **attrs)

    def bias_add(self, b: "TT") -> "TT":
        """x + b where b is a (dim,) parameter."""
        shape = b.node.attrs["shape"]
        assert shape[-1] in (self.dim, 1)
        return self.trace.emit("bias_add", self.space, [self.nid, b.nid], self.dim)

    def relu(self) -> "TT":
        return self._elw1("relu")

    def leaky_relu(self, slope: float = 0.2) -> "TT":
        return self._elw1("leaky_relu", slope=slope)

    def exp(self) -> "TT":
        return self._elw1("exp")

    def sigmoid(self) -> "TT":
        return self._elw1("sigmoid")

    def tanh(self) -> "TT":
        return self._elw1("tanh")


class GraphRef:
    """Handle for GOPs on the (symbolic) input graph."""

    def __init__(self, trace: GnnTrace):
        self.trace = trace

    # scatter: vertex -> edge
    def scatter_src(self, x: TT) -> TT:
        """Copy each source vertex's embedding onto its out-edges."""
        assert x.space == "V"
        return self.trace.emit("scatter_src", "E", [x.nid], x.dim)

    def scatter_dst(self, x: TT) -> TT:
        """Copy each destination vertex's embedding onto its in-edges."""
        assert x.space == "V"
        return self.trace.emit("scatter_dst", "E", [x.nid], x.dim)

    # gather: edge -> vertex (with reduce)
    def gather(self, e: TT, reduce: str = "sum") -> TT:
        assert e.space == "E" and reduce in ("sum", "max", "mean")
        return self.trace.emit("gather", "V", [e.nid], e.dim, reduce=reduce)

    def gather_sum(self, e: TT) -> TT:
        return self.gather(e, "sum")

    def gather_max(self, e: TT) -> TT:
        return self.gather(e, "max")

    def gather_mean(self, e: TT) -> TT:
        return self.gather(e, "mean")

    # composite: numerically-stable edge softmax over in-edges of each dst
    def edge_softmax(self, e: TT) -> TT:
        m = self.gather_max(e)          # V: per-dst max
        shifted = e - self.scatter_dst(m)
        ex = shifted.exp()
        s = self.gather_sum(ex)         # V: per-dst sum
        return ex / self.scatter_dst(s)


GOP_TRACE_OPS = ("scatter_src", "scatter_dst", "gather")


def trace_model(build_fn, name: str = "gnn") -> GnnTrace:
    """Trace a whole-graph model and return the completed trace.

    ``build_fn`` is either

    * one function ``build_fn(trace, graph_ref)`` that declares inputs /
      params and marks outputs itself (the classic single-layer form), or
    * a *sequence of layer builders* ``fn(trace, graph_ref, x) -> TT``:
      layer ``l`` receives layer ``l-1``'s output tensor as ``x`` (``None``
      for the first layer, which declares the graph inputs), returns its own
      output tensor, and the final layer's output is marked automatically.
      Nodes are layer-tagged via :meth:`GnnTrace.begin_layer`.
    """
    tr = GnnTrace(name=name)
    g = GraphRef(tr)
    if callable(build_fn):
        build_fn(tr, g)
    else:
        if not build_fn:
            raise ValueError("trace_model got an empty layer-builder sequence")
        x: Optional[TT] = None
        for layer, fn in enumerate(build_fn):
            tr.begin_layer(layer)
            x = fn(tr, g, x)
            if x is None:
                raise ValueError(f"layer builder {layer} returned no tensor")
        tr.mark_output(x)  # output indicator stays tagged with the last layer
    if not tr.outputs:
        raise ValueError("model marked no outputs")
    return tr
