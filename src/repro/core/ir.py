"""Graph-native GNN IR (ZIPPER paper §6.1).

The IR is a set of DAG *segments*.  Each segment is labeled as a ``vertex``
or ``edge`` segment and contains ops that operate on the data of a *single*
vertex or edge (graph-semantic atomicity).  Communication between segments
happens exclusively through paired ``send``/``recv`` ops, which are the
defused forms of the whole-graph GOPs (scatter / gather):

    scatter (vertex -> edge):  sendOutEdge  ->  recvSrc
                               sendInEdge   ->  recvDst
    gather  (edge -> vertex):  sendDstSum/sendDstMax/...  ->  recvInEdge

Entry/exit indicator ops (``input`` / ``output``) mark the program boundary
(Table 1 of the paper).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Op vocabulary
# ---------------------------------------------------------------------------

#: element-wise ops (VU in hardware) — unary (bias_add carries a param in attrs)
ELW_UNARY = ("relu", "leaky_relu", "exp", "sigmoid", "tanh", "neg",
             "identity", "sqrt", "rsqrt", "bias_add")
#: element-wise ops — binary (support broadcasting (N,1)x(N,F))
ELW_BINARY = ("add", "sub", "mul", "div", "max2", "min2")
#: GEMM-class ops (MU in hardware)
GEMM_OPS = ("matmul", "gemv", "bmm_edge")
#: communication sends (GOP halves)
SEND_OPS = ("sendOutEdge", "sendInEdge", "sendDstSum", "sendDstMax", "sendDstMean")
#: communication recvs (GOP halves)
RECV_OPS = ("recvSrc", "recvDst", "recvInEdge")
#: entry/exit indicators
INDICATOR_OPS = ("input", "output", "param", "const")

COMPUTE_OPS = ELW_UNARY + ELW_BINARY + GEMM_OPS
ALL_OPS = COMPUTE_OPS + SEND_OPS + RECV_OPS + INDICATOR_OPS

#: send -> expected recv pairing
SEND_TO_RECV = {
    "sendOutEdge": "recvSrc",
    "sendInEdge": "recvDst",
    "sendDstSum": "recvInEdge",
    "sendDstMax": "recvInEdge",
    "sendDstMean": "recvInEdge",
}

#: gather sends carry a reduction kind
GATHER_REDUCE = {"sendDstSum": "sum", "sendDstMax": "max", "sendDstMean": "mean"}


def op_unit(op: str, strict: bool = False) -> str:
    """Which hardware unit executes this op (paper §7.1).

    ``strict=True`` raises on ops outside the IR vocabulary instead of
    silently bucketing them into CTRL (the verifier's ZA001 check uses the
    vocabulary directly; codegen paths can opt in here).
    """
    if op in GEMM_OPS:
        return "MU"
    if op in ELW_UNARY or op in ELW_BINARY:
        return "VU"
    if op in SEND_OPS or op in RECV_OPS:
        return "VU"  # GOPs are offloaded to the Vector Unit (paper §7.1)
    if strict and op not in ALL_OPS:
        raise ValueError(f"op {op!r} is not in the IR vocabulary")
    return "CTRL"


# ---------------------------------------------------------------------------
# IR node / segment / program
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IRNode:
    """A single-item op in a segment DAG.

    ``inputs`` reference other node ids *within the same segment*, except for
    ``recv*`` nodes whose ``comm_id`` links them to the matching ``send``
    node in another segment.
    """

    id: int
    op: str
    inputs: List[int] = dataclasses.field(default_factory=list)
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: feature dimension of this node's output (per vertex / edge)
    dim: int = 0
    #: cross-segment communication channel id (send/recv only)
    comm_id: Optional[int] = None
    #: GNN layer that emitted this op (stacked models; 0 for single-layer)
    layer: int = 0

    def is_send(self) -> bool:
        return self.op in SEND_OPS

    def is_recv(self) -> bool:
        return self.op in RECV_OPS

    def short(self) -> str:
        extra = f" comm={self.comm_id}" if self.comm_id is not None else ""
        args = ', '.join('%%%d' % i for i in self.inputs)
        return f"%{self.id} = {self.op}({args}) dim={self.dim}{extra}"


@dataclasses.dataclass
class Segment:
    """A DAG of IRNodes labeled with graph semantics."""

    kind: str  # "vertex" | "edge"
    index: int
    nodes: Dict[int, IRNode] = dataclasses.field(default_factory=dict)

    @property
    def label(self) -> str:
        tag = "v" if self.kind == "vertex" else "e"
        return f"IR.{tag}.{self.index}"

    def add(self, node: IRNode) -> IRNode:
        assert node.id not in self.nodes
        self.nodes[node.id] = node
        return node

    def toposort(self) -> List[IRNode]:
        """Topological order; recv nodes have no intra-segment deps."""
        indeg = {nid: 0 for nid in self.nodes}
        succs: Dict[int, List[int]] = {nid: [] for nid in self.nodes}
        for n in self.nodes.values():
            for i in n.inputs:
                if i in self.nodes:
                    indeg[n.id] += 1
                    succs[i].append(n.id)
        ready = collections.deque(sorted(nid for nid, d in indeg.items() if d == 0))
        order: List[IRNode] = []
        while ready:
            nid = ready.popleft()
            order.append(self.nodes[nid])
            for s in sorted(succs[nid]):
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(order) != len(self.nodes):
            # name the offending nodes, with the same wording the analyzer's
            # ZA003 diagnostic uses (lazy import: analysis depends on ir)
            from .analysis.diagnostics import find_cycle, format_cycle
            raise ValueError(format_cycle(self.label, find_cycle(succs)))
        return order

    def sends(self) -> List[IRNode]:
        return [n for n in self.nodes.values() if n.is_send()]

    def recvs(self) -> List[IRNode]:
        return [n for n in self.nodes.values() if n.is_recv()]


@dataclasses.dataclass
class IRProgram:
    """A full graph-native IR program: multiple disconnected segments."""

    segments: List[Segment] = dataclasses.field(default_factory=list)
    #: comm_id -> (send_segment_idx, send_node_id, recv_segment_idx, recv_node_id)
    channels: Dict[int, Tuple[int, int, int, int]] = dataclasses.field(default_factory=dict)
    name: str = "gnn"
    _next_id: int = 0
    _next_comm: int = 0

    # -- construction helpers -------------------------------------------------
    def fresh_id(self) -> int:
        self._next_id += 1
        return self._next_id - 1

    def fresh_comm(self) -> int:
        self._next_comm += 1
        return self._next_comm - 1

    def new_segment(self, kind: str) -> Segment:
        seg = Segment(kind=kind, index=len([s for s in self.segments if s.kind == kind]))
        self.segments.append(seg)
        return seg

    def rebuild_channels(self) -> None:
        """Recompute the channel table from send/recv comm ids."""
        sends: Dict[int, Tuple[int, int]] = {}
        recvs: Dict[int, Tuple[int, int]] = {}
        for si, seg in enumerate(self.segments):
            for n in seg.nodes.values():
                if n.is_send():
                    sends[n.comm_id] = (si, n.id)
                elif n.is_recv():
                    recvs[n.comm_id] = (si, n.id)
        self.channels = {}
        for cid, (rsi, rnid) in recvs.items():
            if cid not in sends:
                # an orphaned recv would read from nowhere; dropping it
                # silently used to hide defused-GOP bugs
                raise ValueError(f"recv comm {cid} has no send")
        for cid, (ssi, snid) in sends.items():
            if cid not in recvs:
                raise ValueError(f"send comm {cid} has no recv")
            rsi, rnid = recvs[cid]
            self.channels[cid] = (ssi, snid, rsi, rnid)

    # -- queries ---------------------------------------------------------------
    def find_node(self, nid: int) -> Tuple[Segment, IRNode]:
        for seg in self.segments:
            if nid in seg.nodes:
                return seg, seg.nodes[nid]
        raise KeyError(nid)

    def op_count(self, ops: Optional[Iterable[str]] = None) -> int:
        ops = set(ops) if ops is not None else None
        return sum(
            1
            for seg in self.segments
            for n in seg.nodes.values()
            if ops is None or n.op in ops
        )

    def edge_segments(self) -> List[Segment]:
        return [s for s in self.segments if s.kind == "edge"]

    def vertex_segments(self) -> List[Segment]:
        return [s for s in self.segments if s.kind == "vertex"]

    def validate(self) -> None:
        """Structural invariants: paired channels, space-correct sends."""
        self.rebuild_channels()
        for cid, (ssi, snid, rsi, rnid) in self.channels.items():
            send = self.segments[ssi].nodes[snid]
            recv = self.segments[rsi].nodes[rnid]
            if SEND_TO_RECV[send.op] != recv.op:
                raise ValueError(f"channel {cid}: {send.op} paired with {recv.op}")
            # scatter: vertex->edge ; gather: edge->vertex
            if send.op in ("sendOutEdge", "sendInEdge"):
                if self.segments[ssi].kind != "vertex" or self.segments[rsi].kind != "edge":
                    raise ValueError(f"channel {cid}: scatter must go vertex->edge")
            else:
                if self.segments[ssi].kind != "edge" or self.segments[rsi].kind != "vertex":
                    raise ValueError(f"channel {cid}: gather must go edge->vertex")
            if send.dim != recv.dim:
                raise ValueError(f"channel {cid}: dim mismatch {send.dim} vs {recv.dim}")
        for seg in self.segments:
            seg.toposort()  # raises on cycles

    def pretty(self) -> str:
        lines = [f"IRProgram<{self.name}>"]
        for seg in self.segments:
            lines.append(f"  segment {seg.label}:")
            for n in seg.toposort():
                lines.append(f"    {n.short()}" + (f" attrs={n.attrs}" if n.attrs else ""))
        return "\n".join(lines)
