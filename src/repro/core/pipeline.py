"""Inter-tile pipelined execution in JAX (paper Fig 4c, adapted).

On the ZIPPER ASIC, tile pipelining comes from multiple hardware streams.
On TPU/XLA there is one instruction stream per core, but the same effect —
tile *t+1*'s data movement overlapped with tile *t*'s compute — falls out of
(a) ``lax.scan`` over the padded tile batch, which XLA software-pipelines,
and (b) the fused Pallas tile kernels (``kernels/tile_spmm`` +
``kernels/segment_softmax``), whose grid pipelining double-buffers the
HBM→VMEM DMA against the MXU.

This module is the scan-based engine: one jit-compiled function per
(compiled model × tile-set shape).  Like ``executor.run_tiled`` it is an
*interpreter* of the :class:`~repro.core.schedule.ScheduledProgram` — it
derives no levels or roles of its own.  Per phase:

* the destination block runs vectorized over partitions,
* gather blocks tagged ``pallas_spmm`` / ``pallas_spmm_weighted`` dispatch
  one densified kernel call per size bucket (partition outputs summed into
  the shared accumulators),
* a gather block tagged ``pallas_segment_softmax`` dispatches the online-
  softmax kernel over the unbucketed tile batch (softmax state cannot be
  merged across buckets) — GAT's three softmax phases in ONE kernel pass,
* ``scan``-tagged gathers run the pipelined ``lax.scan`` tile loop, one scan
  per bucket with shared accumulators.

``tiles`` may be a :class:`~repro.core.tiling.TileSet` (one global-pad
bucket) or a :class:`~repro.core.tiling.BucketedTileSet`.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compiler as C
from . import schedule as S
from .executor import apply_compute, _NEG_INF
from .tiling import BucketedTileSet, TileSet
from ..gnn.graphs import Graph

Array = Any


def _padded_partition_ids(tiles) -> Tuple[np.ndarray, int]:
    """(P, Dmax) global vertex ids per partition row; invalid slots -> V."""
    P = tiles.n_dst_parts
    dmax = int(tiles.part_size.max())
    V = tiles.n_vertices
    ids = np.full((P, dmax), V, dtype=np.int32)
    for p in range(P):
        n = int(tiles.part_size[p])
        ids[p, :n] = tiles.part_start[p] + np.arange(n, dtype=np.int32)
    return ids, dmax


def _tile_arrays(ts: TileSet) -> Dict[str, Array]:
    return dict(
        src_ids=jnp.asarray(ts.src_ids), edge_src=jnp.asarray(ts.edge_src),
        edge_dst=jnp.asarray(ts.edge_dst), edge_gid=jnp.asarray(ts.edge_gid),
        n_src=jnp.asarray(ts.n_src), n_edge=jnp.asarray(ts.n_edge),
        part_id=jnp.asarray(ts.part_id), part_start=jnp.asarray(ts.part_start),
    )


class PipelinedRunner:
    """Builds and jits the scan/kernel-pipelined executor for one model.

    ``kernel_dispatch`` selects the scheduled program variant: ``True``
    routes pattern-matched gather blocks through the Pallas kernels,
    ``False`` (the default when no ``tile_kernel`` is given) interprets the
    pure multi-phase scan schedule.  ``tile_kernel`` overrides the SpMM
    kernel entry point (signature
    ``kernel(adj, xsrc, part_id, flags, *, n_parts) -> (P, Dmax, F)``).

    A runner's compilation depends only on its *structure signature* — the
    scheduled program plus the tile-set shapes (``signature`` property) —
    never on the concrete edge lists: every graph-specific array is a traced
    argument of the jitted function.  :meth:`bind` re-derives those operands
    for a different tile set with the same signature and :meth:`run_with`
    executes them through the already-compiled program, which is what the
    serving-layer program cache amortizes across requests.

    ``donate_inputs=True`` donates the request's input buffers to XLA on the
    hot path (the serving engine enables this off-CPU, where its padded
    per-request arrays are dead after the call).
    """

    def __init__(self, compiled: C.CompiledGNN, graph: Graph, tiles,
                 tile_kernel: Optional[Callable] = None,
                 kernel_dispatch: Optional[bool] = None,
                 donate_inputs: bool = False):
        from ..kernels.tile_spmm import ops as tops

        if kernel_dispatch is None:
            kernel_dispatch = tile_kernel is not None
        self.c = compiled
        self.sp: S.ScheduledProgram = compiled.schedule(kernel_dispatch)
        self.graph = graph
        self.tiles = tiles
        self.tile_kernel = tile_kernel if tile_kernel is not None else tops.spmm
        self.softmax_kernel = tops.gat_aggregate
        self.part_ids_pad, self.dmax = _padded_partition_ids(tiles)
        self._kernels = {g.kernel for ph in self.sp.phases for g in ph.gathers}
        self._signature = (self.sp.structure_signature(),
                           tiles.shape_signature())
        self._operands: Optional[Tuple] = None   # lazy bind of ctor tiles
        self.donate_inputs = donate_inputs
        self._jitted = jax.jit(self._run,
                               donate_argnums=(0,) if donate_inputs else ())

    @property
    def signature(self) -> Tuple:
        """(program, tile-set) structural identity this compilation serves."""
        return self._signature

    def jit_cache_size(self) -> int:
        """Number of distinct XLA compilations behind this runner (expect 1
        after warmup; the serving tests assert no silent retraces)."""
        try:
            return int(self._jitted._cache_size())
        except AttributeError:   # older jax: no introspection, report unknown
            return -1

    # ------------------------------------------------------------- constants
    def _tile_const(self, ts: TileSet) -> Dict[str, Array]:
        """FIRST/LAST flags + partition presence mask for one tile batch."""
        from ..kernels.tile_spmm.kernel import tile_flags
        P = self.tiles.n_dst_parts
        return dict(flags=jnp.asarray(tile_flags(ts.part_id)),
                    pmask=jnp.asarray(np.isin(np.arange(P), ts.part_id)
                                      .astype(np.float32)))

    def _bucket_const(self, b: TileSet, with_adj: bool) -> Dict[str, Array]:
        """Per-bucket kernel metadata; dense adjacency only for pure SpMM."""
        from ..kernels.tile_spmm.ops import densify_tiles
        kc = self._tile_const(b)
        if with_adj:
            adj, _ = densify_tiles(b)
            kc["adj"] = jnp.asarray(adj)
        return kc

    # ------------------------------------------------------------------ bind
    def bind(self, tiles) -> Tuple:
        """Device operands (tile arrays + kernel constants) for a tile set
        structurally identical to the construction one — the per-request
        rebind step the serving cache runs instead of re-jitting."""
        if tiles.shape_signature() != self.tiles.shape_signature():
            raise ValueError(
                "tile set is not structurally identical to this runner's: "
                f"{tiles.shape_signature()} != {self.tiles.shape_signature()}")
        buckets: List[TileSet] = (
            list(tiles.buckets) if isinstance(tiles, BucketedTileSet) else [tiles])
        tas = tuple(_tile_arrays(b) for b in buckets)
        if self._kernels & set(S.PALLAS_KERNELS):
            kcs = tuple(self._bucket_const(b, S.KERNEL_SPMM in self._kernels)
                        for b in buckets)
        else:
            kcs = tuple({} for _ in buckets)
        # the online-softmax state cannot be merged across buckets, so the
        # segment-softmax block always runs over the unbucketed tile batch
        ta0 = kc0 = None
        if S.KERNEL_SEGMENT_SOFTMAX in self._kernels:
            st = tiles.source if isinstance(tiles, BucketedTileSet) else tiles
            ta0 = _tile_arrays(st)
            kc0 = self._tile_const(st)
        return (tas, kcs, ta0, kc0)

    # ------------------------------------------------------------------ run
    def __call__(self, inputs: Dict[str, Array], params: Dict[str, Array],
                 operands: Optional[Tuple] = None) -> List[Array]:
        if operands is None:
            if self._operands is None:
                self._operands = self.bind(self.tiles)
            operands = self._operands
        tas, kcs, ta0, kc0 = operands
        return self._jitted({k: jnp.asarray(v) for k, v in inputs.items()},
                            {k: jnp.asarray(v) for k, v in params.items()},
                            tas, kcs, ta0, kc0)

    def run_with(self, tiles, inputs: Dict[str, Array],
                 params: Dict[str, Array]) -> List[Array]:
        """Execute a different same-signature tile set through the warm
        compilation (no retrace: operand shapes are identical by contract)."""
        return self(inputs, params, operands=self.bind(tiles))

    # ---------------------------------------------------------- trace-time
    def _run(self, inputs, params, tas, kcs, ta0, kc0) -> List[Array]:
        from ..kernels.tile_spmm.ops import (densify_edge_scores,
                                             densify_edge_weights)

        sp = self.sp
        V = self.graph.n_vertices
        P, dmax = self.tiles.n_dst_parts, self.dmax
        pad_ids = jnp.asarray(self.part_ids_pad)          # (P, Dmax), V = invalid
        pad_valid = (pad_ids < V)[..., None]              # (P, Dmax, 1)
        safe_pad_ids = jnp.minimum(pad_ids, V - 1)

        vstore: Dict[int, Array] = {nid: inputs[name]
                                    for nid, name in sp.vertex_inputs}
        estore: Dict[int, Array] = {nid: inputs[name]
                                    for nid, name in sp.edge_inputs}

        # ---- gather-drain fusion across phase/layer boundaries -------------
        # A gather result lands in padded (P, Dmax, F) partition layout.  The
        # next phase's dst block reads it in exactly that layout, so keeping
        # it in ``pstore`` skips the unpad-scatter + re-gather round trip (the
        # "full barrier" between a layer's gather drain and the next layer's
        # destination compute).  Only values the tile-side paths read — src
        # recompute, edge recvSrc/recvDst, kernel X operands, outputs — are
        # published to the flat (V, F) vertex store.
        tile_side_reads = set(sp.outputs)
        tile_side_reads.update(sp.scatter_value_of.values())
        for ph in sp.phases:
            for n in ph.src.nodes:
                tile_side_reads.update(n.inputs)
            for gb in ph.gathers:
                if gb.src_value_id is not None:
                    tile_side_reads.add(gb.src_value_id)
        pstore: Dict[int, Array] = {}

        def publish_gather(recv_id, padded_val):
            pstore[recv_id] = padded_val
            if recv_id in tile_side_reads:
                vstore[recv_id] = unpad(padded_val)

        def eval_vertex(rows, nodes, padded=False):
            """rows: indices (per-tile (S,) / batched (T,S) / padded (P,Dmax));
            ``padded=True`` (dst blocks) short-circuits gather results still
            sitting in partition layout."""
            env: Dict[int, Array] = {}

            def lookup(nid):
                if nid in env:
                    return env[nid]
                if padded and nid in pstore:
                    return pstore[nid]
                return vstore[nid][rows]

            for n in nodes:
                if n.id not in env and n.id in vstore:
                    # value already drained by an earlier dst block (layer
                    # boundary): the source replica reads the stored rows
                    # instead of recomputing the previous layer per tile
                    continue
                if n.op == "output":
                    env[n.id] = lookup(n.inputs[0])
                else:
                    env[n.id] = apply_compute(n.op, n.attrs, params,
                                              [lookup(i) for i in n.inputs])
            return env

        def edge_env(nodes, xs, senv):
            """Edge-block evaluation for one tile slice ``xs``."""
            eenv: Dict[int, Array] = {}

            def elookup(nid):
                return eenv[nid] if nid in eenv else estore[nid][xs["edge_gid"]]

            for n in nodes:
                if n.op == "recvSrc":
                    src_nid = sp.scatter_value_of[n.id]
                    base = (senv[src_nid] if src_nid in senv
                            else vstore[src_nid][xs["src_ids"]])
                    eenv[n.id] = base[xs["edge_src"]]
                elif n.op == "recvDst":
                    src_nid = sp.scatter_value_of[n.id]
                    eenv[n.id] = vstore[src_nid][xs["dst_global"]]
                else:
                    eenv[n.id] = apply_compute(n.op, n.attrs, params,
                                               [elookup(i) for i in n.inputs])
            return eenv, elookup

        def with_dst(ta):
            """Per-tile scan/vmap operands: (T, ...) arrays only, with the
            global destination rows precomputed from the partition table."""
            xs = {k: ta[k] for k in ("src_ids", "edge_src", "edge_dst",
                                     "edge_gid", "n_edge", "part_id")}
            xs["dst_global"] = jnp.minimum(
                ta["part_start"][ta["part_id"]][:, None] + ta["edge_dst"], V - 1)
            return xs

        def src_value(senv, nid, rows):
            return senv[nid] if nid in senv else vstore[nid][rows]

        def unpad(val):
            """(P, Dmax, d) partition-padded -> (V, d) vertex store."""
            flat = jnp.where(pad_valid, val, 0.0).reshape(P * dmax, -1)
            buf = jnp.zeros((V + 1, flat.shape[-1]), jnp.float32)
            buf = buf.at[pad_ids.reshape(-1)].set(flat)  # invalid rows -> sentinel V
            return buf[:V]

        for phase in sp.phases:
            # ---- destination block (vectorized over partitions; gather
            # results of the previous phase are consumed directly in padded
            # layout — the drain of layer l fuses into layer l+1's dst work)
            if phase.dst.store_ids:
                denv = eval_vertex(safe_pad_ids, phase.dst.nodes, padded=True)
                for nid in phase.dst.store_ids:
                    vstore[nid] = unpad(denv[nid])
            if not phase.has_tile_work:
                continue

            scan_gathers = phase.scan_gathers()

            # ---- accumulators (shared across all buckets of this phase)
            acc: Dict[str, Array] = {}
            for g in scan_gathers:
                cid, dim = g.acc.comm_id, g.acc.dim
                if g.acc.kind in ("sum", "mean"):
                    acc[f"sum{cid}"] = jnp.zeros((P, dmax, dim), jnp.float32)
                    if g.acc.kind == "mean":
                        acc[f"cnt{cid}"] = jnp.zeros((P, dmax, 1), jnp.float32)
                else:
                    acc[f"max{cid}"] = jnp.full((P, dmax, dim), _NEG_INF, jnp.float32)

            # ---- kernel-dispatched gather blocks
            for g in phase.kernel_gathers():
                if g.kernel == S.KERNEL_SEGMENT_SOFTMAX:
                    xs0 = with_dst(ta0)

                    def tile_se(xs):
                        senv = eval_vertex(xs["src_ids"], phase.src.nodes)
                        _, elookup = edge_env(g.edge_nodes, xs, senv)
                        h = src_value(senv, g.src_value_id, xs["src_ids"])
                        return elookup(g.score_id)[:, 0], h[xs["edge_src"]]

                    scores_e, vals = jax.vmap(tile_se)(xs0)    # (T,E), (T,E,F)
                    scores = densify_edge_scores(
                        scores_e, ta0["edge_dst"], ta0["n_edge"], dmax=dmax)
                    out = self.softmax_kernel(scores, vals, ta0["part_id"],
                                              kc0["flags"], n_parts=P)
                    out = jnp.where(kc0["pmask"][:, None, None] > 0, out, 0.0)
                    publish_gather(g.acc.recv_id, out)
                    continue

                # SpMM variants: one densified kernel call per size bucket,
                # partition outputs summed into a shared (P, Dmax, F) buffer
                total = jnp.zeros((P, dmax, g.acc.dim), jnp.float32)
                for ta, kc in zip(tas, kcs):
                    senv = eval_vertex(ta["src_ids"], phase.src.nodes)
                    xsrc = src_value(senv, g.src_value_id, ta["src_ids"])
                    if g.kernel == S.KERNEL_SPMM:
                        adj = kc["adj"]
                    else:        # weighted: densify the runtime edge weights
                        xs_b = with_dst(ta)

                        def tile_w(xs):
                            senv_t = eval_vertex(xs["src_ids"], phase.src.nodes)
                            _, elookup = edge_env(g.edge_nodes, xs, senv_t)
                            return elookup(g.weight_id)[:, 0]

                        w = jax.vmap(tile_w)(xs_b)             # (T, E)
                        adj = densify_edge_weights(
                            w, ta["edge_dst"], ta["edge_src"], ta["n_edge"],
                            dmax=dmax, smax=int(ta["src_ids"].shape[1]))
                    out = self.tile_kernel(adj, xsrc, ta["part_id"],
                                           kc["flags"], n_parts=P)
                    # partitions with no tile in this bucket are never
                    # written by the kernel (uninitialized, may be NaN)
                    total = total + jnp.where(kc["pmask"][:, None, None] > 0,
                                              out, 0.0)
                publish_gather(g.acc.recv_id, total)

            # ---- the pipelined tile loop, one scan per bucket
            if scan_gathers:
                def body(acc, xs):
                    emask = (jnp.arange(xs["edge_src"].shape[0])
                             < xs["n_edge"])[:, None]
                    pid = xs["part_id"]
                    senv = eval_vertex(xs["src_ids"], phase.src.nodes)
                    _, elookup = edge_env(phase.edge.nodes, xs, senv)
                    edst = xs["edge_dst"]
                    for g in scan_gathers:
                        cid = g.acc.comm_id
                        val = elookup(g.acc.value_id)
                        if g.acc.kind in ("sum", "mean"):
                            contrib = jax.ops.segment_sum(
                                jnp.where(emask, val, 0.0), edst, num_segments=dmax)
                            acc[f"sum{cid}"] = acc[f"sum{cid}"].at[pid].add(contrib)
                            if g.acc.kind == "mean":
                                cnt = jax.ops.segment_sum(
                                    jnp.where(emask, 1.0, 0.0), edst, num_segments=dmax)
                                acc[f"cnt{cid}"] = acc[f"cnt{cid}"].at[pid].add(cnt[:, None])
                        else:
                            m = jax.ops.segment_max(
                                jnp.where(emask, val, _NEG_INF), edst, num_segments=dmax)
                            m = jnp.maximum(m, _NEG_INF)
                            acc[f"max{cid}"] = acc[f"max{cid}"].at[pid].max(m)
                    return acc, 0

                for ta in tas:
                    acc, _ = jax.lax.scan(body, acc, with_dst(ta))

                # ---- publish scan-gather results (padded layout; flat (V,)
                # store only when a tile-side path reads them)
                for g in scan_gathers:
                    cid = g.acc.comm_id
                    if g.acc.kind == "sum":
                        val = acc[f"sum{cid}"]
                    elif g.acc.kind == "mean":
                        val = acc[f"sum{cid}"] / jnp.maximum(acc[f"cnt{cid}"], 1.0)
                    else:
                        val = acc[f"max{cid}"]
                    publish_gather(g.acc.recv_id, val)

        return [vstore[o] for o in sp.outputs]


def run_pipelined(compiled: C.CompiledGNN, graph: Graph, tiles,
                  inputs: Dict[str, Array], params: Dict[str, Array],
                  tile_kernel: Optional[Callable] = None,
                  kernel_dispatch: Optional[bool] = None) -> List[Array]:
    return PipelinedRunner(compiled, graph, tiles, tile_kernel=tile_kernel,
                           kernel_dispatch=kernel_dispatch)(inputs, params)
