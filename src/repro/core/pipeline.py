"""Inter-tile pipelined execution in JAX (paper Fig 4c, adapted).

On the ZIPPER ASIC, tile pipelining comes from multiple hardware streams.
On TPU/XLA there is one instruction stream per core, but the same effect —
tile *t+1*'s data movement overlapped with tile *t*'s compute — falls out of
(a) ``lax.scan`` over the padded tile batch, which XLA software-pipelines,
and (b) the fused Pallas tile kernels (``kernels/tile_spmm`` +
``kernels/segment_softmax``), whose grid pipelining double-buffers the
HBM→VMEM DMA against the MXU.

This module is the scan-based engine: one jit-compiled function per
(compiled model × tile-set shape).  Like ``executor.run_tiled`` it is an
*interpreter* of the :class:`~repro.core.schedule.ScheduledProgram` — it
derives no levels or roles of its own.  Per phase:

* the destination block runs vectorized over partitions,
* gather blocks tagged ``pallas_spmm`` / ``pallas_spmm_weighted`` dispatch
  one densified kernel call per size bucket (partition outputs summed into
  the shared accumulators),
* a gather block tagged ``pallas_segment_softmax`` dispatches the online-
  softmax kernel over the unbucketed tile batch (softmax state cannot be
  merged across buckets) — GAT's three softmax phases in ONE kernel pass,
* ``scan``-tagged gathers run the pipelined ``lax.scan`` tile loop, one scan
  per bucket with shared accumulators.

``tiles`` may be a :class:`~repro.core.tiling.TileSet` (one global-pad
bucket) or a :class:`~repro.core.tiling.BucketedTileSet`.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compiler as C
from . import schedule as S
from .executor import apply_compute, _NEG_INF
from .tiling import (BucketedTileSet, ShardPlan, TileSet, exchange_sets,
                     plan_shards)
from ..gnn.graphs import Graph

Array = Any


def _padded_partition_ids(tiles) -> Tuple[np.ndarray, int]:
    """(P, Dmax) global vertex ids per partition row; invalid slots -> V."""
    P = tiles.n_dst_parts
    dmax = int(tiles.part_size.max())
    V = tiles.n_vertices
    ids = np.full((P, dmax), V, dtype=np.int32)
    for p in range(P):
        n = int(tiles.part_size[p])
        ids[p, :n] = tiles.part_start[p] + np.arange(n, dtype=np.int32)
    return ids, dmax


def _tile_arrays(ts: TileSet) -> Dict[str, Array]:
    d = dict(
        src_ids=jnp.asarray(ts.src_ids), edge_src=jnp.asarray(ts.edge_src),
        edge_dst=jnp.asarray(ts.edge_dst), edge_gid=jnp.asarray(ts.edge_gid),
        n_src=jnp.asarray(ts.n_src), n_edge=jnp.asarray(ts.n_edge),
        part_id=jnp.asarray(ts.part_id), part_start=jnp.asarray(ts.part_start),
    )
    if ts.row_ptr is not None:
        d["row_ptr"] = jnp.asarray(ts.row_ptr)
    return d


def _perm_operand(reordering) -> Optional[Dict[str, Array]]:
    """Traced (order, rank) operand pair; ``None`` for the identity (the
    pytree structure is pinned by the runner's reorder-mode signature)."""
    if reordering is None or reordering.is_identity:
        return None
    return dict(order=jnp.asarray(reordering.order),
                rank=jnp.asarray(reordering.rank))


def _check_reorder_mode(expected: str, reordering) -> None:
    mode = "identity" if reordering is None else reordering.mode
    if mode != expected:
        raise ValueError(
            f"reordering mode {mode!r} does not match this runner's "
            f"compiled mode {expected!r}")


# ---- scan-gather accumulator semantics (shared by Pipelined/Sharded) -------
# The masking, mean-count, and _NEG_INF-clamp rules below are the single
# source of truth for the scan path; the two runners differ only in the
# accumulator's partition-row count (global P vs device-local P_loc) and in
# which per-tile id indexes it.

def _init_gather_acc(scan_gathers, n_rows: int, dmax: int) -> Dict[str, Array]:
    acc: Dict[str, Array] = {}
    for g in scan_gathers:
        cid, dim = g.acc.comm_id, g.acc.dim
        if g.acc.kind in ("sum", "mean"):
            acc[f"sum{cid}"] = jnp.zeros((n_rows, dmax, dim), jnp.float32)
            if g.acc.kind == "mean":
                acc[f"cnt{cid}"] = jnp.zeros((n_rows, dmax, 1), jnp.float32)
        else:
            acc[f"max{cid}"] = jnp.full((n_rows, dmax, dim), _NEG_INF,
                                        jnp.float32)
    return acc


def _gather_accumulate(acc: Dict[str, Array], g, val: Array, emask: Array,
                       edst: Array, pid: Array, dmax: int) -> None:
    """Fold one tile's per-edge values into the gather accumulator row
    ``pid`` (in place on the dict)."""
    cid = g.acc.comm_id
    if g.acc.kind in ("sum", "mean"):
        contrib = jax.ops.segment_sum(
            jnp.where(emask, val, 0.0), edst, num_segments=dmax)
        acc[f"sum{cid}"] = acc[f"sum{cid}"].at[pid].add(contrib)
        if g.acc.kind == "mean":
            cnt = jax.ops.segment_sum(
                jnp.where(emask, 1.0, 0.0), edst, num_segments=dmax)
            acc[f"cnt{cid}"] = acc[f"cnt{cid}"].at[pid].add(cnt[:, None])
    else:
        m = jax.ops.segment_max(
            jnp.where(emask, val, _NEG_INF), edst, num_segments=dmax)
        acc[f"max{cid}"] = acc[f"max{cid}"].at[pid].max(
            jnp.maximum(m, _NEG_INF))


def _drain_gather_acc(acc: Dict[str, Array], g) -> Array:
    cid = g.acc.comm_id
    if g.acc.kind == "sum":
        return acc[f"sum{cid}"]
    if g.acc.kind == "mean":
        return acc[f"sum{cid}"] / jnp.maximum(acc[f"cnt{cid}"], 1.0)
    return acc[f"max{cid}"]


class PipelinedRunner:
    """Builds and jits the scan/kernel-pipelined executor for one model.

    ``kernel_dispatch`` selects the scheduled program variant: ``True``
    routes pattern-matched gather blocks through the Pallas kernels,
    ``False`` (the default when no ``tile_kernel`` is given) interprets the
    pure multi-phase scan schedule.  ``tile_kernel`` overrides the SpMM
    kernel entry point (signature
    ``kernel(adj, xsrc, part_id, flags, *, n_parts) -> (P, Dmax, F)``).

    A runner's compilation depends only on its *structure signature* — the
    scheduled program plus the tile-set shapes (``signature`` property) —
    never on the concrete edge lists: every graph-specific array is a traced
    argument of the jitted function.  :meth:`bind` re-derives those operands
    for a different tile set with the same signature and :meth:`run_with`
    executes them through the already-compiled program, which is what the
    serving-layer program cache amortizes across requests.

    ``donate_inputs=True`` donates the request's input buffers to XLA on the
    hot path (the serving engine enables this off-CPU, where its padded
    per-request arrays are dead after the call).
    """

    def __init__(self, compiled: C.CompiledGNN, graph: Graph, tiles,
                 tile_kernel: Optional[Callable] = None,
                 kernel_dispatch: Optional[bool] = None,
                 donate_inputs: bool = False,
                 reordering=None):
        from ..kernels.tile_spmm import ops as tops

        if kernel_dispatch is None:
            kernel_dispatch = tile_kernel is not None
        self.c = compiled
        self.sp: S.ScheduledProgram = compiled.schedule(kernel_dispatch)
        self.graph = graph
        self.tiles = tiles
        self.layout = getattr(tiles, "layout", "coo")
        self.tile_kernel = tile_kernel if tile_kernel is not None else tops.spmm
        self.csr_kernel = tops.spmm_csr
        self.softmax_kernel = tops.gat_aggregate
        self.softmax_csr_kernel = tops.gat_aggregate_csr
        # ``graph`` (and the tiles) live in reordered vertex space when a
        # non-identity ``reordering`` is given; the runner permutes request
        # inputs in and outputs back, so callers stay in original ids
        self.reordering = reordering
        self.reorder_mode = ("identity" if reordering is None
                             else reordering.mode)
        self.part_ids_pad, self.dmax = _padded_partition_ids(tiles)
        self._kernels = {g.kernel for ph in self.sp.phases for g in ph.gathers}
        self._signature = (self.sp.structure_signature(),
                           tiles.shape_signature(), self.reorder_mode)
        self._operands: Optional[Tuple] = None   # lazy bind of ctor tiles
        self.donate_inputs = donate_inputs
        self._jitted = jax.jit(self._run,
                               donate_argnums=(0,) if donate_inputs else ())

    @property
    def signature(self) -> Tuple:
        """(program, tile-set) structural identity this compilation serves."""
        return self._signature

    def jit_cache_size(self) -> int:
        """Number of distinct XLA compilations behind this runner (expect 1
        after warmup; the serving tests assert no silent retraces)."""
        try:
            return int(self._jitted._cache_size())
        except AttributeError:   # older jax: no introspection, report unknown
            return -1

    # ------------------------------------------------------------- constants
    def _tile_const(self, ts: TileSet) -> Dict[str, Array]:
        """FIRST/LAST flags + partition presence mask for one tile batch."""
        from ..kernels.tile_spmm.kernel import tile_flags
        P = self.tiles.n_dst_parts
        return dict(flags=jnp.asarray(tile_flags(ts.part_id)),
                    pmask=jnp.asarray(np.isin(np.arange(P), ts.part_id)
                                      .astype(np.float32)))

    def _bucket_const(self, b: TileSet, with_adj: bool) -> Dict[str, Array]:
        """Per-bucket kernel metadata; dense adjacency only for pure SpMM
        over COO tiles (CSR kernels walk row pointers instead)."""
        from ..kernels.tile_spmm.ops import densify_tiles
        kc = self._tile_const(b)
        if with_adj and b.layout != "csr":
            adj, _ = densify_tiles(b)
            kc["adj"] = jnp.asarray(adj)
        return kc

    # ------------------------------------------------------------------ bind
    def bind(self, tiles, reordering=None) -> Tuple:
        """Device operands (tile arrays + kernel constants + permutation) for
        a tile set structurally identical to the construction one — the
        per-request rebind step the serving cache runs instead of
        re-jitting.  ``reordering`` must realize the same mode the runner
        was compiled with (its (order, rank) arrays are traced operands)."""
        if tiles.shape_signature() != self.tiles.shape_signature():
            raise ValueError(
                "tile set is not structurally identical to this runner's: "
                f"{tiles.shape_signature()} != {self.tiles.shape_signature()}")
        _check_reorder_mode(self.reorder_mode, reordering)
        buckets: List[TileSet] = (
            list(tiles.buckets) if isinstance(tiles, BucketedTileSet) else [tiles])
        tas = tuple(_tile_arrays(b) for b in buckets)
        if self._kernels & set(S.PALLAS_KERNELS):
            kcs = tuple(self._bucket_const(b, S.KERNEL_SPMM in self._kernels)
                        for b in buckets)
        else:
            kcs = tuple({} for _ in buckets)
        # the online-softmax state cannot be merged across buckets, so the
        # segment-softmax block always runs over the unbucketed tile batch
        ta0 = kc0 = None
        if S.KERNEL_SEGMENT_SOFTMAX in self._kernels:
            st = tiles.source if isinstance(tiles, BucketedTileSet) else tiles
            ta0 = _tile_arrays(st)
            kc0 = self._tile_const(st)
        return (tas, kcs, ta0, kc0, _perm_operand(reordering))

    # ------------------------------------------------------------------ run
    def __call__(self, inputs: Dict[str, Array], params: Dict[str, Array],
                 operands: Optional[Tuple] = None) -> List[Array]:
        if operands is None:
            if self._operands is None:
                self._operands = self.bind(self.tiles, self.reordering)
            operands = self._operands
        tas, kcs, ta0, kc0, perm = operands
        return self._jitted({k: jnp.asarray(v) for k, v in inputs.items()},
                            {k: jnp.asarray(v) for k, v in params.items()},
                            tas, kcs, ta0, kc0, perm)

    def run_with(self, tiles, inputs: Dict[str, Array],
                 params: Dict[str, Array], reordering=None) -> List[Array]:
        """Execute a different same-signature tile set through the warm
        compilation (no retrace: operand shapes are identical by contract)."""
        return self(inputs, params, operands=self.bind(tiles, reordering))

    # ---------------------------------------------------------- trace-time
    def _run(self, inputs, params, tas, kcs, ta0, kc0, perm) -> List[Array]:
        from ..kernels.tile_spmm.ops import (densify_edge_scores,
                                             densify_edge_weights)

        sp = self.sp
        V = self.graph.n_vertices
        P, dmax = self.tiles.n_dst_parts, self.dmax
        pad_ids = jnp.asarray(self.part_ids_pad)          # (P, Dmax), V = invalid
        pad_valid = (pad_ids < V)[..., None]              # (P, Dmax, 1)
        safe_pad_ids = jnp.minimum(pad_ids, V - 1)

        if perm is not None:
            # requests arrive in original vertex order; the tiles (and edge
            # arrays, which degree_sort leaves in place) live in reordered
            # space — permute vertex features in, outputs back at the end
            inputs = dict(inputs)
            for name in {name for _, name in sp.vertex_inputs}:
                inputs[name] = inputs[name][perm["order"]]

        vstore: Dict[int, Array] = {nid: inputs[name]
                                    for nid, name in sp.vertex_inputs}
        estore: Dict[int, Array] = {nid: inputs[name]
                                    for nid, name in sp.edge_inputs}

        # ---- gather-drain fusion across phase/layer boundaries -------------
        # A gather result lands in padded (P, Dmax, F) partition layout.  The
        # next phase's dst block reads it in exactly that layout, so keeping
        # it in ``pstore`` skips the unpad-scatter + re-gather round trip (the
        # "full barrier" between a layer's gather drain and the next layer's
        # destination compute).  Only values the tile-side paths read — src
        # recompute, edge recvSrc/recvDst, kernel X operands, outputs — are
        # published to the flat (V, F) vertex store.
        tile_side_reads = set(sp.outputs)
        tile_side_reads.update(sp.scatter_value_of.values())
        for ph in sp.phases:
            for n in ph.src.nodes:
                tile_side_reads.update(n.inputs)
            for gb in ph.gathers:
                if gb.src_value_id is not None:
                    tile_side_reads.add(gb.src_value_id)
        pstore: Dict[int, Array] = {}

        def publish_gather(recv_id, padded_val):
            pstore[recv_id] = padded_val
            if recv_id in tile_side_reads:
                vstore[recv_id] = unpad(padded_val)

        def eval_vertex(rows, nodes, padded=False):
            """rows: indices (per-tile (S,) / batched (T,S) / padded (P,Dmax));
            ``padded=True`` (dst blocks) short-circuits gather results still
            sitting in partition layout."""
            env: Dict[int, Array] = {}

            def lookup(nid):
                if nid in env:
                    return env[nid]
                if padded and nid in pstore:
                    return pstore[nid]
                return vstore[nid][rows]

            for n in nodes:
                if n.id not in env and n.id in vstore:
                    # value already drained by an earlier dst block (layer
                    # boundary): the source replica reads the stored rows
                    # instead of recomputing the previous layer per tile
                    continue
                if n.op == "output":
                    env[n.id] = lookup(n.inputs[0])
                else:
                    env[n.id] = apply_compute(n.op, n.attrs, params,
                                              [lookup(i) for i in n.inputs])
            return env

        def edge_env(nodes, xs, senv):
            """Edge-block evaluation for one tile slice ``xs``."""
            eenv: Dict[int, Array] = {}

            def elookup(nid):
                return eenv[nid] if nid in eenv else estore[nid][xs["edge_gid"]]

            for n in nodes:
                if n.op == "recvSrc":
                    src_nid = sp.scatter_value_of[n.id]
                    base = (senv[src_nid] if src_nid in senv
                            else vstore[src_nid][xs["src_ids"]])
                    eenv[n.id] = base[xs["edge_src"]]
                elif n.op == "recvDst":
                    src_nid = sp.scatter_value_of[n.id]
                    eenv[n.id] = vstore[src_nid][xs["dst_global"]]
                else:
                    eenv[n.id] = apply_compute(n.op, n.attrs, params,
                                               [elookup(i) for i in n.inputs])
            return eenv, elookup

        def with_dst(ta):
            """Per-tile scan/vmap operands: (T, ...) arrays only, with the
            global destination rows precomputed from the partition table."""
            xs = {k: ta[k] for k in ("src_ids", "edge_src", "edge_dst",
                                     "edge_gid", "n_edge", "part_id")}
            xs["dst_global"] = jnp.minimum(
                ta["part_start"][ta["part_id"]][:, None] + ta["edge_dst"], V - 1)
            return xs

        def src_value(senv, nid, rows):
            return senv[nid] if nid in senv else vstore[nid][rows]

        def unpad(val):
            """(P, Dmax, d) partition-padded -> (V, d) vertex store."""
            flat = jnp.where(pad_valid, val, 0.0).reshape(P * dmax, -1)
            buf = jnp.zeros((V + 1, flat.shape[-1]), jnp.float32)
            buf = buf.at[pad_ids.reshape(-1)].set(flat)  # invalid rows -> sentinel V
            return buf[:V]

        for phase in sp.phases:
            # ---- destination block (vectorized over partitions; gather
            # results of the previous phase are consumed directly in padded
            # layout — the drain of layer l fuses into layer l+1's dst work)
            if phase.dst.store_ids:
                denv = eval_vertex(safe_pad_ids, phase.dst.nodes, padded=True)
                for nid in phase.dst.store_ids:
                    vstore[nid] = unpad(denv[nid])
            if not phase.has_tile_work:
                continue

            scan_gathers = phase.scan_gathers()

            # ---- accumulators (shared across all buckets of this phase)
            acc = _init_gather_acc(scan_gathers, P, dmax)

            # ---- kernel-dispatched gather blocks
            for g in phase.kernel_gathers():
                if g.kernel == S.KERNEL_SEGMENT_SOFTMAX:
                    xs0 = with_dst(ta0)

                    def tile_se(xs):
                        senv = eval_vertex(xs["src_ids"], phase.src.nodes)
                        _, elookup = edge_env(g.edge_nodes, xs, senv)
                        h = src_value(senv, g.src_value_id, xs["src_ids"])
                        return elookup(g.score_id)[:, 0], h[xs["edge_src"]]

                    scores_e, vals = jax.vmap(tile_se)(xs0)    # (T,E), (T,E,F)
                    if self.layout == "csr":
                        # per-edge scores/vals feed the kernel directly: the
                        # row-pointer walk replaces the densify pass
                        out = self.softmax_csr_kernel(
                            ta0["row_ptr"], scores_e, vals, ta0["part_id"],
                            kc0["flags"], n_parts=P)
                    else:
                        scores = densify_edge_scores(
                            scores_e, ta0["edge_dst"], ta0["n_edge"], dmax=dmax)
                        out = self.softmax_kernel(scores, vals, ta0["part_id"],
                                                  kc0["flags"], n_parts=P)
                    out = jnp.where(kc0["pmask"][:, None, None] > 0, out, 0.0)
                    publish_gather(g.acc.recv_id, out)
                    continue

                # SpMM variants: one densified kernel call per size bucket,
                # partition outputs summed into a shared (P, Dmax, F) buffer
                total = jnp.zeros((P, dmax, g.acc.dim), jnp.float32)
                for ta, kc in zip(tas, kcs):
                    senv = eval_vertex(ta["src_ids"], phase.src.nodes)
                    xsrc = src_value(senv, g.src_value_id, ta["src_ids"])
                    if self.layout == "csr":
                        if g.kernel == S.KERNEL_SPMM:
                            w = jnp.ones(ta["edge_src"].shape, jnp.float32)
                        else:
                            xs_b = with_dst(ta)

                            def tile_w(xs):
                                senv_t = eval_vertex(xs["src_ids"],
                                                     phase.src.nodes)
                                _, elookup = edge_env(g.edge_nodes, xs, senv_t)
                                return elookup(g.weight_id)[:, 0]

                            w = jax.vmap(tile_w)(xs_b)         # (T, E)
                            # zero padded slots: they are unreachable via the
                            # row pointers but must not inject inf/NaN
                            emask = (jnp.arange(w.shape[1])[None, :]
                                     < ta["n_edge"][:, None])
                            w = jnp.where(emask, w, 0.0)
                        out = self.csr_kernel(ta["row_ptr"], ta["edge_src"],
                                              w, xsrc, ta["part_id"],
                                              kc["flags"], n_parts=P)
                    else:
                        if g.kernel == S.KERNEL_SPMM:
                            adj = kc["adj"]
                        else:    # weighted: densify the runtime edge weights
                            xs_b = with_dst(ta)

                            def tile_w(xs):
                                senv_t = eval_vertex(xs["src_ids"],
                                                     phase.src.nodes)
                                _, elookup = edge_env(g.edge_nodes, xs, senv_t)
                                return elookup(g.weight_id)[:, 0]

                            w = jax.vmap(tile_w)(xs_b)         # (T, E)
                            adj = densify_edge_weights(
                                w, ta["edge_dst"], ta["edge_src"], ta["n_edge"],
                                dmax=dmax, smax=int(ta["src_ids"].shape[1]))
                        out = self.tile_kernel(adj, xsrc, ta["part_id"],
                                               kc["flags"], n_parts=P)
                    # partitions with no tile in this bucket are never
                    # written by the kernel (uninitialized, may be NaN)
                    total = total + jnp.where(kc["pmask"][:, None, None] > 0,
                                              out, 0.0)
                publish_gather(g.acc.recv_id, total)

            # ---- the pipelined tile loop, one scan per bucket
            if scan_gathers:
                def body(acc, xs):
                    emask = (jnp.arange(xs["edge_src"].shape[0])
                             < xs["n_edge"])[:, None]
                    pid = xs["part_id"]
                    senv = eval_vertex(xs["src_ids"], phase.src.nodes)
                    _, elookup = edge_env(phase.edge.nodes, xs, senv)
                    edst = xs["edge_dst"]
                    for g in scan_gathers:
                        _gather_accumulate(acc, g, elookup(g.acc.value_id),
                                           emask, edst, pid, dmax)
                    return acc, 0

                for ta in tas:
                    acc, _ = jax.lax.scan(body, acc, with_dst(ta))

                # ---- publish scan-gather results (padded layout; flat (V,)
                # store only when a tile-side path reads them)
                for g in scan_gathers:
                    publish_gather(g.acc.recv_id, _drain_gather_acc(acc, g))

        outs = [vstore[o] for o in sp.outputs]
        if perm is not None:
            outs = [o[perm["rank"]] for o in outs]
        return outs


def run_pipelined(compiled: C.CompiledGNN, graph: Graph, tiles,
                  inputs: Dict[str, Array], params: Dict[str, Array],
                  tile_kernel: Optional[Callable] = None,
                  kernel_dispatch: Optional[bool] = None,
                  reordering=None) -> List[Array]:
    return PipelinedRunner(compiled, graph, tiles, tile_kernel=tile_kernel,
                           kernel_dispatch=kernel_dispatch,
                           reordering=reordering)(inputs, params)


# ---------------------------------------------------------------------------
# sharded execution: one ScheduledProgram data-parallel over dst partitions
# ---------------------------------------------------------------------------

def _quantize_cap(n: int) -> int:
    """Round a per-shard tile capacity up to the next power of two (serving:
    small per-request variance in shard tile counts must map onto one
    compiled shape)."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def _shard_tile_counts(tiles, plan: ShardPlan) -> List[List[int]]:
    """Per bucket, per shard: number of real (n_edge > 0) tiles assigned."""
    buckets: List[TileSet] = (list(tiles.buckets)
                              if isinstance(tiles, BucketedTileSet) else [tiles])
    out = []
    for b in buckets:
        shard = plan.shard_of_part[b.part_id]
        real = b.n_edge > 0
        out.append([int(np.sum(real & (shard == k)))
                    for k in range(plan.n_shards)])
    return out


def _source_tileset(tiles) -> TileSet:
    return tiles.source if isinstance(tiles, BucketedTileSet) else tiles


def _shard_real_counts(ts: TileSet, plan: ShardPlan) -> List[int]:
    shard = plan.shard_of_part[ts.part_id]
    real = ts.n_edge > 0
    return [int(np.sum(real & (shard == k))) for k in range(plan.n_shards)]


def _exchange_cap(tiles, plan: ShardPlan, quantize_tile_cap: bool) -> int:
    """Static send-buffer capacity of the restricted boundary exchange:
    the largest per-shard send set (rows a shard owns that remote shards'
    gather blocks read), power-of-two quantized under serving's cap
    quantization so small per-request variance shares one compiled shape."""
    cap = max(1, exchange_sets(tiles, plan).max_send)
    return _quantize_cap(cap) if quantize_tile_cap else cap


def shard_layout_signature(tiles, n_devices: int, mode: str = "cost",
                           quantize_tile_cap: bool = False,
                           kernel_dispatch: bool = False,
                           kernels: Tuple[str, ...] = (),
                           model_axis: int = 1) -> Tuple:
    """Shape identity of the sharded execution layout — everything a
    :class:`ShardedRunner` compilation depends on beyond the program and
    tile-set signatures.  Cheap (pure numpy); the serving engine calls it
    per request to key the program cache, so two requests share a warm
    sharded runner iff their shard layouts realize identical shapes.

    ``kernel_dispatch`` (and, when it is on, the program's kernel tags) is
    part of the identity: a scan-scheduled compilation must never alias a
    kernel-dispatched one, and the segment-softmax kernel adds a per-shard
    capacity for the unbucketed tile batch that scan programs don't have.
    Multi-shard layouts append the restricted-exchange send capacity
    (:func:`_exchange_cap`); ``model_axis`` names the 2-D mesh's feature
    axis width — a different feature split never aliases."""
    plan = plan_shards(tiles, n_devices, mode=mode)
    caps = []
    for counts in _shard_tile_counts(tiles, plan):
        cap = max(1, max(counts))
        caps.append(_quantize_cap(cap) if quantize_tile_cap else cap)
    if kernel_dispatch and S.KERNEL_SEGMENT_SOFTMAX in kernels:
        cap0 = max(1, max(_shard_real_counts(_source_tileset(tiles), plan)))
        caps.append(_quantize_cap(cap0) if quantize_tile_cap else cap0)
    if n_devices > 1:
        caps.append(_exchange_cap(tiles, plan, quantize_tile_cap))
    return ("shardlayout", n_devices, mode, int(model_axis),
            plan.n_local_parts, tuple(caps), bool(kernel_dispatch))


def _shard_partition_ids(plan: ShardPlan, part_start: np.ndarray,
                         part_size: np.ndarray, dmax: int,
                         n_vertices: int) -> np.ndarray:
    """(K, P_loc, Dmax) global vertex id per (shard, local slot, offset);
    invalid slots carry the sentinel ``n_vertices``."""
    K, P_loc = plan.n_shards, plan.n_local_parts
    ids = np.full((K, P_loc, dmax), n_vertices, np.int32)
    for k, parts in enumerate(plan.parts_of_shard):
        for j, p in enumerate(parts):
            n = int(part_size[p])
            ids[k, j, :n] = int(part_start[p]) + np.arange(n, dtype=np.int32)
    return ids


def _shard_layout(tiles, plan: ShardPlan, quantize_tile_cap: bool,
                  kernels: frozenset = frozenset()
                  ) -> Tuple[Dict, Dict, Tuple]:
    """Build the per-device operand arrays for a sharded run.

    Returns ``(shard_ops, repl_ops, caps)``: ``shard_ops`` arrays carry a
    leading mesh axis (row ``k`` = shard ``k``'s slice), ``repl_ops`` are
    replicated tables.  Per bucket, each shard receives its partitions' real
    tiles in the bucket's partition-major order (bucket order preserved) and
    is padded to a common capacity with zero-edge filler rows the scan masks
    out.  Filler rows repeat the shard's last real ``part_id``/``local_pid``
    (:func:`~repro.core.tiling.pad_tileset`'s convention), so under the
    Pallas FIRST/LAST flag protocol they extend that partition's run with
    zero blocks instead of corrupting another partition's accumulator.

    When ``kernels`` names Pallas gather blocks, each bucket additionally
    carries the per-shard kernel constants — FIRST/LAST ``flags`` over the
    local-partition sequence, the local-slot presence mask ``pmask``, and
    (pure SpMM only) the stacked dense adjacency blocks ``adj`` — and a
    ``softmax`` entry lays out the *unbucketed* tile batch per shard for the
    segment-softmax kernel (online-softmax state cannot be merged across
    buckets).  All shapes are a pure function of the tile-set signature, the
    plan shape, and the caps — :meth:`ShardedRunner.bind` rebuilds them for
    any structurally-identical tile set.
    """
    from ..kernels.tile_spmm.kernel import tile_flags
    from ..kernels.tile_spmm.ops import densify_tiles

    buckets: List[TileSet] = (list(tiles.buckets)
                              if isinstance(tiles, BucketedTileSet) else [tiles])
    K, P_loc = plan.n_shards, plan.n_local_parts
    dmax = int(tiles.part_size.max())
    counts = _shard_tile_counts(tiles, plan)
    want_kernels = bool(kernels & set(S.PALLAS_KERNELS))

    def shard_stack(b: TileSet, cap: int, adj_np: Optional[np.ndarray]) -> Dict:
        shard = plan.shard_of_part[b.part_id]
        sel_of = [np.nonzero((shard == k) & (b.n_edge > 0))[0]
                  for k in range(K)]

        def stack(a: np.ndarray, fill=0) -> np.ndarray:
            out = np.full((K, cap) + a.shape[1:], fill, a.dtype)
            for k, sel in enumerate(sel_of):
                out[k, :len(sel)] = a[sel]
            return out

        ops = dict(
            src_ids=stack(b.src_ids), edge_src=stack(b.edge_src),
            edge_dst=stack(b.edge_dst), edge_gid=stack(b.edge_gid),
            n_edge=stack(b.n_edge), part_id=stack(b.part_id),
            local_pid=stack(plan.local_slot_of_part[b.part_id].astype(np.int32)),
        )
        if b.row_ptr is not None:
            # filler rows keep the all-zero pointer table: every CSR row run
            # is [0, 0), the correct empty-tile contribution
            ops["row_ptr"] = stack(b.row_ptr)
        # filler rows extend the last real partition run (see docstring)
        for k, sel in enumerate(sel_of):
            if 0 < len(sel) < cap:
                ops["part_id"][k, len(sel):] = ops["part_id"][k, len(sel) - 1]
                ops["local_pid"][k, len(sel):] = ops["local_pid"][k, len(sel) - 1]
        if want_kernels:
            flags = np.zeros((K, cap), np.int32)
            pmask = np.zeros((K, P_loc), np.float32)
            for k, sel in enumerate(sel_of):
                flags[k] = tile_flags(ops["local_pid"][k])
                pmask[k, ops["local_pid"][k, :len(sel)]] = 1.0
            ops["flags"] = flags
            ops["pmask"] = pmask
            if adj_np is not None:
                ops["adj"] = stack(adj_np)
        return ops

    bucket_ops = []
    caps = []
    for b, cnts in zip(buckets, counts):
        cap = max(1, max(cnts))
        if quantize_tile_cap:
            cap = _quantize_cap(cap)
        caps.append(cap)
        adj_np = densify_tiles(b)[0] if (want_kernels and
                                         S.KERNEL_SPMM in kernels and
                                         b.layout != "csr") else None
        bucket_ops.append(shard_stack(b, cap, adj_np))

    pad_ids = _shard_partition_ids(plan, tiles.part_start, tiles.part_size,
                                   dmax, tiles.n_vertices)
    shard_ops = {"pad_ids": pad_ids, "buckets": bucket_ops}
    if want_kernels and S.KERNEL_SEGMENT_SOFTMAX in kernels:
        st = _source_tileset(tiles)
        cap0 = max(1, max(_shard_real_counts(st, plan)))
        if quantize_tile_cap:
            cap0 = _quantize_cap(cap0)
        caps.append(cap0)
        shard_ops["softmax"] = shard_stack(st, cap0, None)
    repl_ops = {"full_pad_ids": pad_ids.reshape(-1).copy()}
    if K > 1:
        # restricted-exchange send sets: per shard, the flat local-buffer
        # slots of the rows it owns that remote shards' gather blocks read,
        # and the replicated global-id table the receive scatter uses
        # (sentinel n_vertices rows are dropped).  Interior boundary
        # publishes all-gather only this compacted buffer.
        ex = exchange_sets(tiles, plan)
        ecap = max(1, ex.max_send)
        if quantize_tile_cap:
            ecap = _quantize_cap(ecap)
        caps.append(ecap)
        part_start = np.asarray(tiles.part_start)
        send_slots = np.zeros((K, ecap), np.int32)
        send_ids = np.full((K, ecap), tiles.n_vertices, np.int32)
        for k, rows in enumerate(ex.send_rows):
            part = np.searchsorted(part_start, rows, side="right") - 1
            slots = (plan.local_slot_of_part[part].astype(np.int64) * dmax
                     + (rows - part_start[part]))
            send_slots[k, :len(rows)] = slots.astype(np.int32)
            send_ids[k, :len(rows)] = rows.astype(np.int32)
        shard_ops["send_slots"] = send_slots
        repl_ops["send_ids"] = send_ids.reshape(-1).copy()
    return shard_ops, repl_ops, tuple(caps)


class ShardedRunner:
    """Data-parallel execution of one :class:`~repro.core.schedule
    .ScheduledProgram` over a 1-D device mesh of ``n_devices`` shards.

    Each shard owns whole destination partitions (a :class:`~repro.core
    .tiling.ShardPlan`), so every gather accumulator and every drained
    partition-layout value stays device-local; the only cross-device
    dataflow is the layer-boundary read of drained source values, exchanged
    as ONE ``all_gather`` of the padded ``(P_loc, Dmax, F)`` layout per
    boundary (values read back through destination replicas — GAT's softmax
    ``recvDst`` statistics, for instance — never leave their device).

    ``kernel_dispatch`` selects the scheduled program variant exactly as in
    :class:`PipelinedRunner`: ``True`` routes pattern-matched gather blocks
    through the Pallas kernels *inside* ``shard_map`` — each shard runs its
    bucketed tile batch through ``pallas_spmm`` / ``pallas_spmm_weighted`` /
    ``pallas_segment_softmax`` with device-local partition slots
    (``n_parts = P_loc``), so kernel outputs land straight in the local
    pstore and the one-all-gather-per-layer-boundary exchange census is
    unchanged.  ``False`` (the default when no ``tile_kernel`` is given)
    interprets the pure multi-phase scan schedule; both variants are
    numerically conformant with the single-device engines.  On CPU, force a
    multi-device mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
    first jax import.

    ``mode`` picks the partition assignment (``"cost"``: LPT-balanced padded
    edge cost; ``"mincut"``: LPT seed + deterministic KL-style refinement
    minimizing cross-shard source reads; ``"contiguous"``: even ranges —
    deterministic across requests, what serving uses),
    ``quantize_tile_cap=True`` rounds per-shard tile capacities to powers of
    two so structurally-similar requests share one compiled shape.

    Interior layer boundaries use a *neighbor-restricted* exchange: each
    shard all-gathers only its compacted send buffer — the rows remote
    shards' gather blocks actually read, a static per-shard set derived from
    the plan (:func:`~repro.core.tiling.exchange_sets`) — and scatters its
    own partitions' rows locally.  Only the final output drain (whose
    results must be replicated on every shard) ships the full padded
    layout.  :func:`~repro.core.analysis.hazards.verify_exchange` proves
    coverage statically.

    ``model_axis=M > 1`` grows the mesh to 2-D ``("shards", "model")`` over
    ``n_devices * M`` devices: compute is replicated over the model axis
    while every boundary exchange ships each rank's ``ceil(F / M)`` feature
    slice over the shards axis and reassembles full width with one tiled
    model-axis all-gather — for wide hidden dims the per-link payload
    shrinks by ``M``.

    Like :class:`PipelinedRunner`, compilation depends only on
    :attr:`signature`; :meth:`bind`/:meth:`run_with` re-derive operands
    for a different same-signature tile set through the warm compilation.
    """

    def __init__(self, compiled: C.CompiledGNN, graph: Graph, tiles,
                 n_devices: Optional[int] = None, *, mode: str = "cost",
                 quantize_tile_cap: bool = False,
                 devices: Optional[List] = None,
                 tile_kernel: Optional[Callable] = None,
                 kernel_dispatch: Optional[bool] = None,
                 reordering=None, model_axis: int = 1):
        from ..kernels.tile_spmm import ops as tops

        devices = list(devices) if devices is not None else list(jax.devices())
        if model_axis < 1:
            raise ValueError(f"model_axis must be >= 1, got {model_axis}")
        if n_devices is None:
            n_devices = max(1, len(devices) // model_axis)
        if n_devices * model_axis > len(devices):
            raise ValueError(
                f"n_devices={n_devices} x model_axis={model_axis} but only "
                f"{len(devices)} jax devices are visible; on CPU set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
                "importing jax")
        if kernel_dispatch is None:
            kernel_dispatch = tile_kernel is not None
        self.c = compiled
        self.kernel_dispatch = bool(kernel_dispatch)
        self.sp: S.ScheduledProgram = compiled.schedule(self.kernel_dispatch)
        self.graph = graph
        self.tiles = tiles
        self.layout = getattr(tiles, "layout", "coo")
        self.mode = mode
        self.quantize_tile_cap = quantize_tile_cap
        self.n_devices = n_devices
        self.model_axis = int(model_axis)
        self.tile_kernel = tile_kernel if tile_kernel is not None else tops.spmm
        self.csr_kernel = tops.spmm_csr
        self.softmax_kernel = tops.gat_aggregate
        self.softmax_csr_kernel = tops.gat_aggregate_csr
        # like PipelinedRunner: graph/tiles in reordered space, requests in
        # original ids; the (order, rank) permutation rides as a replicated
        # traced operand, so it adds no collective to the exchange census
        self.reordering = reordering
        self.reorder_mode = ("identity" if reordering is None
                             else reordering.mode)
        self._kernels = frozenset(g.kernel for ph in self.sp.phases
                                  for g in ph.gathers)
        self.plan = plan_shards(tiles, n_devices, mode=mode)
        self.dmax = int(tiles.part_size.max())
        self._ops_np, self._repl_np, self.caps = _shard_layout(
            tiles, self.plan, quantize_tile_cap, self._kernels)
        if reordering is not None and not reordering.is_identity:
            self._repl_np = dict(self._repl_np,
                                 order=reordering.order, rank=reordering.rank)
        self._publish = self._publish_ids()
        self._signature = ("sharded", n_devices, mode, self.plan.n_local_parts,
                           self.caps, self.kernel_dispatch,
                           self.sp.structure_signature(),
                           tiles.shape_signature(), self.reorder_mode,
                           self.model_axis)
        if self.model_axis > 1:
            grid = np.asarray(
                devices[:n_devices * self.model_axis]).reshape(
                    n_devices, self.model_axis)
            self.mesh = jax.sharding.Mesh(grid, ("shards", "model"))
        else:
            self.mesh = jax.sharding.Mesh(np.asarray(devices[:n_devices]),
                                          ("shards",))
        P = jax.sharding.PartitionSpec
        from ..jax_compat import shard_map
        self._jitted = jax.jit(shard_map(
            self._run, mesh=self.mesh,
            in_specs=(P(), P(), P("shards"), P()), out_specs=P(),
            check_vma=False))
        self._operands: Optional[Tuple] = None

    # ------------------------------------------------------------- identity
    @property
    def signature(self) -> Tuple:
        """(mesh, layout, program, tile-set) identity this compilation
        serves — includes ``n_devices`` so a serving cache can never alias a
        sharded program with a single-device one (or across mesh sizes)."""
        return self._signature

    def jit_cache_size(self) -> int:
        try:
            return int(self._jitted._cache_size())
        except AttributeError:
            return -1

    def _publish_ids(self) -> set:
        """Vertex node ids whose values must be exchanged into the
        replicated flat store: tile-side source reads (and the outputs) of
        values that are *gather-tainted* — transitively derived from a
        gather result, i.e. carrying partition-owned aggregated state.

        Untainted values (pure functions of replicated inputs, like GAT's
        ``h = x @ W``) are recomputed by the source replicas per tile —
        bitwise the same rows, no collective.  Values consumed only through
        destination replicas (``recvDst``) or later dst blocks stay
        device-local either way, so each layer boundary drains exactly one
        all-gather."""
        sp = self.sp
        node_op: Dict[int, str] = {}
        vnodes = []
        for seg in sp.prog.segments:
            for n in seg.nodes.values():
                node_op[n.id] = n.op
        for seg in sp.prog.vertex_segments():
            vnodes.extend(seg.toposort())
        tainted: set = set()
        for n in vnodes:
            if n.op == "recvInEdge" or any(i in tainted for i in n.inputs):
                tainted.add(n.id)

        reads = set(sp.outputs)
        for ph in sp.phases:
            for n in ph.src.nodes:
                reads.update(n.inputs)
            for g in ph.gathers:
                if g.src_value_id is not None:
                    reads.add(g.src_value_id)
        for rnid, vnid in sp.scatter_value_of.items():
            if node_op.get(rnid) == "recvSrc":
                reads.add(vnid)
        pub = (reads & tainted) | set(sp.outputs)
        return pub - {nid for nid, _ in sp.vertex_inputs}

    # ------------------------------------------------------------------ bind
    def bind(self, tiles, reordering=None) -> Tuple:
        """Device operands for a tile set structurally identical to the
        construction one (same tile-set signature AND same realized shard
        layout shapes) — the per-request rebind step of the serving cache.
        ``reordering`` must realize the runner's compiled reorder mode."""
        if tiles.shape_signature() != self.tiles.shape_signature():
            raise ValueError(
                "tile set is not structurally identical to this runner's: "
                f"{tiles.shape_signature()} != {self.tiles.shape_signature()}")
        _check_reorder_mode(self.reorder_mode, reordering)
        plan = plan_shards(tiles, self.n_devices, mode=self.mode)
        if plan.n_local_parts != self.plan.n_local_parts:
            raise ValueError(
                f"shard layout mismatch: {plan.n_local_parts} local "
                f"partition slots != {self.plan.n_local_parts}")
        ops, repl, caps = _shard_layout(tiles, plan, self.quantize_tile_cap,
                                        self._kernels)
        if caps != self.caps:
            raise ValueError(
                f"shard tile capacities changed: {caps} != {self.caps}")
        if reordering is not None and not reordering.is_identity:
            repl = dict(repl, order=reordering.order, rank=reordering.rank)
        return (jax.tree_util.tree_map(jnp.asarray, ops),
                jax.tree_util.tree_map(jnp.asarray, repl))

    def _get_operands(self) -> Tuple:
        if self._operands is None:
            self._operands = (
                jax.tree_util.tree_map(jnp.asarray, self._ops_np),
                jax.tree_util.tree_map(jnp.asarray, self._repl_np))
        return self._operands

    # ------------------------------------------------------------------ run
    def __call__(self, inputs: Dict[str, Array], params: Dict[str, Array],
                 operands: Optional[Tuple] = None) -> List[Array]:
        ops, repl = operands if operands is not None else self._get_operands()
        return self._jitted({k: jnp.asarray(v) for k, v in inputs.items()},
                            {k: jnp.asarray(v) for k, v in params.items()},
                            ops, repl)

    def run_with(self, tiles, inputs: Dict[str, Array],
                 params: Dict[str, Array], reordering=None) -> List[Array]:
        """Execute a different same-signature tile set through the warm
        compilation (no retrace: operand shapes identical by contract)."""
        return self(inputs, params, operands=self.bind(tiles, reordering))

    def lower_text(self, inputs: Dict[str, Array],
                   params: Dict[str, Array]) -> str:
        """Compiled HLO text (collective-census hook for tests/benchmarks)."""
        ops, repl = self._get_operands()
        lowered = self._jitted.lower(
            {k: jnp.asarray(v) for k, v in inputs.items()},
            {k: jnp.asarray(v) for k, v in params.items()}, ops, repl)
        return lowered.compile().as_text()

    # ---------------------------------------------------------- trace-time
    #: per-tile operand keys of the lax.scan body (kernel constants like
    #: ``pmask``/``adj`` ride in the same bucket dicts but must not be
    #: scanned over — their leading axis is not the tile capacity)
    _SCAN_KEYS = ("src_ids", "edge_src", "edge_dst", "edge_gid",
                  "n_edge", "part_id", "local_pid")

    def _run(self, inputs, params, ops, repl) -> List[Array]:
        from ..kernels.tile_spmm.ops import (densify_edge_scores,
                                             densify_edge_weights)

        sp = self.sp
        V = self.graph.n_vertices
        K, P_loc, dmax = self.n_devices, self.plan.n_local_parts, self.dmax
        pad_ids = ops["pad_ids"][0]                       # (P_loc, Dmax)
        pad_valid = (pad_ids < V)[..., None]
        safe_pad_ids = jnp.minimum(pad_ids, V - 1)
        full_ids = repl["full_pad_ids"]                   # (K*P_loc*Dmax,)
        part_start = jnp.asarray(self.tiles.part_start)   # (P,) by contract

        if "order" in repl:
            # replicated permutation of replicated inputs: no collective,
            # the per-layer all-gather census is unchanged
            inputs = dict(inputs)
            for name in {name for _, name in sp.vertex_inputs}:
                inputs[name] = inputs[name][repl["order"]]

        vstore: Dict[int, Array] = {nid: inputs[name]
                                    for nid, name in sp.vertex_inputs}
        estore: Dict[int, Array] = {nid: inputs[name]
                                    for nid, name in sp.edge_inputs}
        # device-local padded (P_loc, Dmax, F) stores: gather results and
        # dst-computed values (the drain pstore of the pipelined runner,
        # kept per shard)
        pstore: Dict[int, Array] = {}
        dstore: Dict[int, Array] = {}

        M = self.model_axis

        def mesh_gather(buf: Array) -> Array:
            """All-gather over the shards axis; under a 2-D mesh each model
            rank ships only its ceil(F / M) column slice and one tiled
            model-axis all-gather reassembles full width."""
            if M == 1:
                return jax.lax.all_gather(buf, "shards", axis=0)
            W = buf.shape[-1]
            wp = -(-W // M)
            pad = [(0, 0)] * (buf.ndim - 1) + [(0, wp * M - W)]
            bufp = jnp.pad(buf, pad)
            m = jax.lax.axis_index("model")
            chunk = jax.lax.dynamic_slice_in_dim(bufp, m * wp, wp, axis=-1)
            full = jax.lax.all_gather(chunk, "shards", axis=0)
            full = jax.lax.all_gather(full, "model", axis=full.ndim - 1,
                                      tiled=True)
            return full[..., :W]

        def publish(pending: Dict[int, Array]) -> None:
            """Exchange device-local padded values into the replicated flat
            (V, F) store: ONE shards-axis all-gather for everything this
            phase drains.  Interior boundaries ship only the compacted
            restricted send buffer (rows remote shards' gather blocks read)
            and scatter the shard's own rows locally; the final output
            drain — whose values must come out replicated — gathers the
            full padded layout."""
            if not pending:
                return
            ids = list(pending)
            widths = [int(pending[i].shape[-1]) for i in ids]
            buf = jnp.concatenate([pending[i] for i in ids], axis=-1)
            restricted = (K > 1 and "send_slots" in ops
                          and not (set(ids) & set(sp.outputs)))
            if restricted:
                flatbuf = buf.reshape(P_loc * dmax, -1)
                send = flatbuf[ops["send_slots"][0]]      # (C, F)
                full = mesh_gather(send)                  # (K, C, F)
                flat = full.reshape(full.shape[0] * full.shape[1], -1)
                store = jnp.zeros((V + 1, flat.shape[-1]), jnp.float32)
                store = store.at[repl["send_ids"]].set(flat)
                # own partitions' rows never ride the exchange: local scatter
                # (invalid padded slots carry the sentinel V and are dropped)
                store = store.at[pad_ids.reshape(-1)].set(flatbuf)[:V]
            else:
                buf = jnp.where(pad_valid, buf, 0.0)
                full = mesh_gather(buf)                   # (K,P_loc,Dmax,F)
                flat = full.reshape(K * P_loc * dmax, -1)
                store = jnp.zeros((V + 1, flat.shape[-1]), jnp.float32)
                store = store.at[full_ids].set(flat)[:V]
            off = 0
            for nid, w in zip(ids, widths):
                vstore[nid] = store[:, off:off + w]
                off += w

        def eval_vertex(rows, nodes, padded=False):
            env: Dict[int, Array] = {}

            def lookup(nid):
                if nid in env:
                    return env[nid]
                if padded:
                    if nid in pstore:
                        return pstore[nid]
                    if nid in dstore:
                        return dstore[nid]
                return vstore[nid][rows]

            for n in nodes:
                if n.id not in env and (n.id in vstore
                                        or (padded and n.id in dstore)):
                    continue        # drained earlier: read the stored value
                if n.op == "output":
                    env[n.id] = lookup(n.inputs[0])
                else:
                    env[n.id] = apply_compute(n.op, n.attrs, params,
                                              [lookup(i) for i in n.inputs])
            return env

        def edge_env(nodes, xs, senv):
            eenv: Dict[int, Array] = {}

            def elookup(nid):
                return eenv[nid] if nid in eenv else estore[nid][xs["edge_gid"]]

            for n in nodes:
                if n.op == "recvSrc":
                    src_nid = sp.scatter_value_of[n.id]
                    base = (senv[src_nid] if src_nid in senv
                            else vstore[src_nid][xs["src_ids"]])
                    eenv[n.id] = base[xs["edge_src"]]
                elif n.op == "recvDst":
                    src_nid = sp.scatter_value_of[n.id]
                    # destination replicas read their OWN partition's rows:
                    # device-local padded layout, no exchange
                    if src_nid in pstore:
                        eenv[n.id] = pstore[src_nid][xs["local_pid"]][xs["edge_dst"]]
                    elif src_nid in dstore:
                        eenv[n.id] = dstore[src_nid][xs["local_pid"]][xs["edge_dst"]]
                    else:
                        eenv[n.id] = vstore[src_nid][xs["dst_global"]]
                else:
                    eenv[n.id] = apply_compute(n.op, n.attrs, params,
                                               [elookup(i) for i in n.inputs])
            return eenv, elookup

        def src_value(senv, nid, rows):
            return senv[nid] if nid in senv else vstore[nid][rows]

        def local(ta, keys):
            """Strip the mesh axis off this shard's slice of ``ta`` and
            derive global destination rows from the partition table."""
            xs = {k: ta[k][0] for k in keys}
            xs["dst_global"] = jnp.minimum(
                part_start[xs["part_id"]][:, None] + xs["edge_dst"], V - 1)
            return xs

        for phase in sp.phases:
            # ---- destination block on the local partitions, then ONE
            # exchange of whatever this boundary drains to tile-side readers
            if phase.dst.store_ids:
                denv = eval_vertex(safe_pad_ids, phase.dst.nodes, padded=True)
                pending: Dict[int, Array] = {}
                for nid in phase.dst.store_ids:
                    dstore[nid] = denv[nid]
                    if nid in self._publish:
                        pending[nid] = denv[nid]
                publish(pending)
            if not phase.has_tile_work:
                continue

            scan_gathers = phase.scan_gathers()
            acc = _init_gather_acc(scan_gathers, P_loc, dmax)
            pending = {}

            def drain(g, val):
                """Gather result stays in the device-local padded store;
                queued for this phase's single exchange only when a
                tile-side path reads it."""
                pstore[g.acc.recv_id] = val
                if g.acc.recv_id in self._publish:
                    pending[g.acc.recv_id] = val

            # ---- kernel-dispatched gather blocks (device-local slots)
            for g in phase.kernel_gathers():
                if g.kernel == S.KERNEL_SEGMENT_SOFTMAX:
                    sm = ops["softmax"]
                    xs0 = local(sm, self._SCAN_KEYS)

                    def tile_se(xs):
                        senv = eval_vertex(xs["src_ids"], phase.src.nodes)
                        _, elookup = edge_env(g.edge_nodes, xs, senv)
                        h = src_value(senv, g.src_value_id, xs["src_ids"])
                        return elookup(g.score_id)[:, 0], h[xs["edge_src"]]

                    scores_e, vals = jax.vmap(tile_se)(xs0)
                    if self.layout == "csr":
                        out = self.softmax_csr_kernel(
                            sm["row_ptr"][0], scores_e, vals,
                            xs0["local_pid"], sm["flags"][0], n_parts=P_loc)
                    else:
                        scores = densify_edge_scores(
                            scores_e, xs0["edge_dst"], xs0["n_edge"], dmax=dmax)
                        out = self.softmax_kernel(scores, vals,
                                                  xs0["local_pid"],
                                                  sm["flags"][0],
                                                  n_parts=P_loc)
                    out = jnp.where(sm["pmask"][0][:, None, None] > 0,
                                    out, 0.0)
                    drain(g, out)
                    continue

                # SpMM variants: one densified kernel call per size bucket,
                # local-slot outputs summed into one (P_loc, Dmax, F) buffer
                total = jnp.zeros((P_loc, dmax, g.acc.dim), jnp.float32)
                for ta in ops["buckets"]:
                    xs = local(ta, self._SCAN_KEYS)
                    senv = eval_vertex(xs["src_ids"], phase.src.nodes)
                    xsrc = src_value(senv, g.src_value_id, xs["src_ids"])

                    def tile_w(x):
                        senv_t = eval_vertex(x["src_ids"], phase.src.nodes)
                        _, elookup = edge_env(g.edge_nodes, x, senv_t)
                        return elookup(g.weight_id)[:, 0]

                    if self.layout == "csr":
                        if g.kernel == S.KERNEL_SPMM:
                            w = jnp.ones(xs["edge_src"].shape, jnp.float32)
                        else:
                            w = jax.vmap(tile_w)(xs)
                            emask = (jnp.arange(w.shape[1])[None, :]
                                     < xs["n_edge"][:, None])
                            w = jnp.where(emask, w, 0.0)
                        out = self.csr_kernel(ta["row_ptr"][0],
                                              xs["edge_src"], w, xsrc,
                                              xs["local_pid"], ta["flags"][0],
                                              n_parts=P_loc)
                    else:
                        if g.kernel == S.KERNEL_SPMM:
                            adj = ta["adj"][0]
                        else:    # weighted: densify the runtime edge weights
                            w = jax.vmap(tile_w)(xs)
                            adj = densify_edge_weights(
                                w, xs["edge_dst"], xs["edge_src"], xs["n_edge"],
                                dmax=dmax, smax=int(xs["src_ids"].shape[1]))
                        out = self.tile_kernel(adj, xsrc, xs["local_pid"],
                                               ta["flags"][0], n_parts=P_loc)
                    # local slots with no tile in this bucket are never
                    # written by the kernel (uninitialized, may be NaN)
                    total = total + jnp.where(
                        ta["pmask"][0][:, None, None] > 0, out, 0.0)
                drain(g, total)

            # ---- the pipelined tile loop, one scan per bucket
            if scan_gathers:
                def body(acc, xs):
                    emask = (jnp.arange(xs["edge_src"].shape[0])
                             < xs["n_edge"])[:, None]
                    pid = xs["local_pid"]
                    senv = eval_vertex(xs["src_ids"], phase.src.nodes)
                    _, elookup = edge_env(phase.edge.nodes, xs, senv)
                    edst = xs["edge_dst"]
                    for g in scan_gathers:
                        _gather_accumulate(acc, g, elookup(g.acc.value_id),
                                           emask, edst, pid, dmax)
                    return acc, 0

                for ta in ops["buckets"]:
                    acc, _ = jax.lax.scan(body, acc,
                                          local(ta, self._SCAN_KEYS))
                for g in scan_gathers:
                    drain(g, _drain_gather_acc(acc, g))

            # everything this phase's gathers drain to tile-side readers
            # leaves in ONE collective (the static census counts on it)
            publish(pending)

        outs = [vstore[o] for o in sp.outputs]
        if "rank" in repl:
            outs = [o[repl["rank"]] for o in outs]
        return outs


def run_sharded(compiled: C.CompiledGNN, graph: Graph, tiles,
                inputs: Dict[str, Array], params: Dict[str, Array],
                n_devices: Optional[int] = None, mode: str = "cost",
                tile_kernel: Optional[Callable] = None,
                kernel_dispatch: Optional[bool] = None,
                reordering=None) -> List[Array]:
    return ShardedRunner(compiled, graph, tiles, n_devices, mode=mode,
                         tile_kernel=tile_kernel,
                         kernel_dispatch=kernel_dispatch,
                         reordering=reordering)(inputs, params)
