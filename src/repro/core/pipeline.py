"""Inter-tile pipelined execution in JAX (paper Fig 4c, adapted).

On the ZIPPER ASIC, tile pipelining comes from multiple hardware streams.
On TPU/XLA there is one instruction stream per core, but the same effect —
tile *t+1*'s data movement overlapped with tile *t*'s compute — falls out of
(a) ``lax.scan`` over the padded tile batch, which XLA software-pipelines,
and (b) the fused Pallas tile kernel (``kernels/tile_spmm``), whose grid
pipelining double-buffers the HBM→VMEM DMA against the MXU.

This module is the scan-based engine: one jit-compiled function per
(compiled model × tile-set shape).  It is numerically equivalent to
``executor.run_tiled`` (the python-loop reference) and is what the GNN
benchmarks execute.  Two execution strategies compose:

* **bucketed batching** — pass a :class:`~repro.core.tiling.BucketedTileSet`
  and each phase runs one ``lax.scan`` per size bucket, threading the same
  gather accumulators through all buckets.  Each bucket is padded only to
  its own (S_max, E_max), so skewed graphs stop paying the global-pad tax.
* **Pallas inner body** — pass ``tile_kernel`` (e.g.
  ``repro.kernels.tile_spmm.ops.spmm``) and any phase whose gathers are pure
  SpMM (every ``sendDstSum`` fed directly by a ``recvSrc``) skips the scan:
  the per-bucket densified adjacency blocks are fed to the tile kernel and
  its per-partition outputs are added into the shared accumulators.  Phases
  with edge compute (GAT softmax, R-GCN BMM, max/mean gathers) fall back to
  the scan body.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compiler as C
from . import ir as IR
from .executor import apply_compute, _NEG_INF
from .tiling import BucketedTileSet, TileSet
from ..gnn.graphs import Graph

Array = Any


def _padded_partition_ids(tiles) -> Tuple[np.ndarray, int]:
    """(P, Dmax) global vertex ids per partition row; invalid slots -> V."""
    P = tiles.n_dst_parts
    dmax = int(tiles.part_size.max())
    V = tiles.n_vertices
    ids = np.full((P, dmax), V, dtype=np.int32)
    for p in range(P):
        n = int(tiles.part_size[p])
        ids[p, :n] = tiles.part_start[p] + np.arange(n, dtype=np.int32)
    return ids, dmax


class PipelinedRunner:
    """Builds and jits the scan-pipelined executor for one compiled model.

    ``tiles`` may be a :class:`TileSet` (one global-pad bucket) or a
    :class:`BucketedTileSet`.  ``tile_kernel`` optionally accelerates
    pure-SpMM gather phases; it must have the signature
    ``kernel(adj, xsrc, part_id, flags, *, n_parts) -> (P, Dmax, F)``.
    """

    def __init__(self, compiled: C.CompiledGNN, graph: Graph, tiles,
                 tile_kernel: Optional[Callable] = None):
        self.c = compiled
        self.prog = compiled.ir
        self.plan = compiled.plan
        self.graph = graph
        self.tiles = tiles
        self.buckets: List[TileSet] = (
            list(tiles.buckets) if isinstance(tiles, BucketedTileSet) else [tiles])
        self.tile_kernel = tile_kernel
        self.prog.rebuild_channels()
        self.send_of_comm = {cid: snid for cid, (_, snid, _, _) in self.prog.channels.items()}
        self.nodes: Dict[int, IR.IRNode] = {}
        self.node_seg: Dict[int, IR.Segment] = {}
        for seg in self.prog.segments:
            for n in seg.nodes.values():
                self.nodes[n.id] = n
                self.node_seg[n.id] = seg
        self.part_ids_pad, self.dmax = _padded_partition_ids(tiles)
        self._spmm_levels = self._find_pure_spmm_levels() if tile_kernel else {}
        self._kernel_const = self._densify_buckets() if self._spmm_levels else None
        self._jitted = jax.jit(self._run)

    # ------------------------------------------------------------- analysis
    def _find_pure_spmm_levels(self) -> Dict[int, List[IR.IRNode]]:
        """Levels whose every gather is ``recvSrc -> sendDstSum`` — the pure
        SpMM aggregation the Pallas tile kernel implements directly."""
        plan = self.plan
        by_level: Dict[int, List[IR.IRNode]] = {}
        for n in self.nodes.values():
            if n.op.startswith("sendDst"):
                by_level.setdefault(plan.level[n.id], []).append(n)
        out: Dict[int, List[IR.IRNode]] = {}
        for lvl, sends in by_level.items():
            if all(s.op == "sendDstSum"
                   and self.nodes[s.inputs[0]].op == "recvSrc"
                   for s in sends):
                out[lvl] = sends
        return out

    def _densify_buckets(self):
        """One-time numpy preprocessing for the kernel path: per-bucket dense
        adjacency blocks, FIRST/LAST flags, and partition presence masks."""
        from ..kernels.tile_spmm.ops import densify_tiles
        const = []
        P = self.tiles.n_dst_parts
        for b in self.buckets:
            adj, flags = densify_tiles(b)
            pmask = np.isin(np.arange(P), b.part_id).astype(np.float32)
            const.append(dict(adj=jnp.asarray(adj), flags=jnp.asarray(flags),
                              pmask=jnp.asarray(pmask)))
        return const

    # ------------------------------------------------------------------ run
    def __call__(self, inputs: Dict[str, Array], params: Dict[str, Array]) -> List[Array]:
        tas = []
        for b in self.buckets:
            tas.append(dict(
                src_ids=jnp.asarray(b.src_ids), edge_src=jnp.asarray(b.edge_src),
                edge_dst=jnp.asarray(b.edge_dst), edge_gid=jnp.asarray(b.edge_gid),
                n_src=jnp.asarray(b.n_src), n_edge=jnp.asarray(b.n_edge),
                part_id=jnp.asarray(b.part_id), part_start=jnp.asarray(b.part_start),
            ))
        kc = self._kernel_const if self._kernel_const is not None else [
            {} for _ in self.buckets]
        return self._jitted({k: jnp.asarray(v) for k, v in inputs.items()},
                            {k: jnp.asarray(v) for k, v in params.items()},
                            tuple(tas), tuple(kc))

    # ---------------------------------------------------------- trace-time
    def _run(self, inputs, params, tas, kcs) -> List[Array]:
        plan, prog = self.plan, self.prog
        V = self.graph.n_vertices
        P, dmax = self.tiles.n_dst_parts, self.dmax
        pad_ids = jnp.asarray(self.part_ids_pad)          # (P, Dmax), V = invalid
        pad_valid = (pad_ids < V)[..., None]              # (P, Dmax, 1)
        safe_pad_ids = jnp.minimum(pad_ids, V - 1)

        vstore: Dict[int, Array] = {}
        estore: Dict[int, Array] = {}
        for seg in prog.segments:
            for n in seg.nodes.values():
                if n.op == "input":
                    (vstore if seg.kind == "vertex" else estore)[n.id] = inputs[n.attrs["name"]]

        def eval_vertex(rows, lvl, roles, on_parts=False):
            """rows: indices (per-tile (S,) or padded (P,Dmax)); returns env."""
            env: Dict[int, Array] = {}

            def lookup(nid):
                if nid in env:
                    return env[nid]
                return vstore[nid][rows]

            for seg in prog.vertex_segments():
                for n in seg.toposort():
                    if plan.level[n.id] > lvl or n.op in ("input", "recvInEdge") or n.is_send():
                        continue
                    if n.op == "output":
                        if "dst" in roles and plan.level[n.id] <= lvl:
                            env[n.id] = lookup(n.inputs[0])
                        continue
                    if not (plan.role[n.id] & set(roles)):
                        continue
                    env[n.id] = apply_compute(n.op, n.attrs, params,
                                              [lookup(i) for i in n.inputs])
            return env

        def scatter_back(env, lvl):
            """Write dst-replica results (padded (P,Dmax,d)) into (V,d) stores."""
            for nid, val in env.items():
                n = self.nodes[nid]
                if plan.level[nid] != lvl:
                    continue
                if not ("dst" in plan.role[nid] or n.op == "output"):
                    continue
                flat = jnp.where(pad_valid, val, 0.0).reshape(P * dmax, -1)
                buf = jnp.zeros((V + 1, flat.shape[-1]), flat.dtype)
                buf = buf.at[pad_ids.reshape(-1)].set(flat)  # invalid rows -> sentinel V
                vstore[nid] = buf[:V]

        def src_value_of_send(s, rows, senv):
            """Pre-scatter vertex value feeding gather send ``s`` (via its
            recvSrc input), evaluated at ``rows``."""
            r = self.nodes[s.inputs[0]]
            src_nid = self.nodes[self.send_of_comm[r.comm_id]].inputs[0]
            return senv[src_nid] if src_nid in senv else vstore[src_nid][rows]

        for lvl in range(plan.max_level + 1):
            # ---- destination/partition scope (vectorized over partitions)
            denv = eval_vertex(safe_pad_ids, lvl, roles=("dst",), on_parts=True)
            scatter_back(denv, lvl)

            edge_nodes = [n for seg in prog.edge_segments() for n in seg.toposort()
                          if plan.level[n.id] <= lvl]
            gather_sends = [n for n in self.nodes.values()
                            if n.op.startswith("sendDst") and plan.level[n.id] == lvl]
            if not any(plan.level[n.id] == lvl for n in edge_nodes):
                continue

            # ---- accumulators (shared across all buckets of this level)
            acc0: Dict[str, Array] = {}
            for s in gather_sends:
                if s.op in ("sendDstSum", "sendDstMean"):
                    acc0[f"sum{s.comm_id}"] = jnp.zeros((P, dmax, s.dim), jnp.float32)
                    if s.op == "sendDstMean":
                        acc0[f"cnt{s.comm_id}"] = jnp.zeros((P, dmax, 1), jnp.float32)
                else:
                    acc0[f"max{s.comm_id}"] = jnp.full((P, dmax, s.dim), _NEG_INF, jnp.float32)
            acc = acc0

            if lvl in self._spmm_levels and gather_sends:
                # ---- Pallas inner body: one densified kernel call per bucket
                for ta, kc in zip(tas, kcs):
                    senv = eval_vertex(ta["src_ids"], lvl, roles=("src",))
                    for s in gather_sends:
                        xsrc = src_value_of_send(s, ta["src_ids"], senv)
                        out = self.tile_kernel(kc["adj"], xsrc, ta["part_id"],
                                               kc["flags"], n_parts=P)
                        # partitions with no tile in this bucket are never
                        # written by the kernel (uninitialized, may be NaN)
                        out = jnp.where(kc["pmask"][:, None, None] > 0, out, 0.0)
                        acc[f"sum{s.comm_id}"] = acc[f"sum{s.comm_id}"] + out
            else:
                # ---- the pipelined tile loop, one scan per bucket
                def body(acc, xs):
                    src_rows = xs["src_ids"]                       # (S,)
                    esrc, edst = xs["edge_src"], xs["edge_dst"]    # (E,)
                    emask = (jnp.arange(esrc.shape[0]) < xs["n_edge"])[:, None]
                    pid = xs["part_id"]
                    dst_global = jnp.minimum(xs["part_start_row"] + edst, V - 1)

                    senv = eval_vertex(src_rows, lvl, roles=("src",))
                    eenv: Dict[int, Array] = {}

                    def elookup(nid):
                        if nid in eenv:
                            return eenv[nid]
                        return estore[nid][xs["edge_gid"]]

                    for n in edge_nodes:
                        if n.op == "recvSrc":
                            src_nid = self.nodes[self.send_of_comm[n.comm_id]].inputs[0]
                            base = senv[src_nid] if src_nid in senv else vstore[src_nid][src_rows]
                            eenv[n.id] = base[esrc]
                        elif n.op == "recvDst":
                            src_nid = self.nodes[self.send_of_comm[n.comm_id]].inputs[0]
                            eenv[n.id] = vstore[src_nid][dst_global]
                        elif n.op == "input":
                            continue
                        elif n.is_send():
                            if plan.level[n.id] != lvl:
                                continue
                            val = elookup(n.inputs[0])
                            if n.op in ("sendDstSum", "sendDstMean"):
                                contrib = jax.ops.segment_sum(
                                    jnp.where(emask, val, 0.0), edst, num_segments=dmax)
                                acc[f"sum{n.comm_id}"] = acc[f"sum{n.comm_id}"].at[pid].add(contrib)
                                if n.op == "sendDstMean":
                                    c = jax.ops.segment_sum(
                                        jnp.where(emask, 1.0, 0.0), edst, num_segments=dmax)
                                    acc[f"cnt{n.comm_id}"] = acc[f"cnt{n.comm_id}"].at[pid].add(c[:, None])
                            else:
                                m = jax.ops.segment_max(
                                    jnp.where(emask, val, _NEG_INF), edst, num_segments=dmax)
                                m = jnp.maximum(m, _NEG_INF)
                                acc[f"max{n.comm_id}"] = acc[f"max{n.comm_id}"].at[pid].max(m)
                        else:
                            eenv[n.id] = apply_compute(n.op, n.attrs, params,
                                                       [elookup(i) for i in n.inputs])
                    return acc, 0

                for ta in tas:
                    xs = dict(src_ids=ta["src_ids"], edge_src=ta["edge_src"],
                              edge_dst=ta["edge_dst"], edge_gid=ta["edge_gid"],
                              n_edge=ta["n_edge"], part_id=ta["part_id"],
                              part_start_row=ta["part_start"][ta["part_id"]])
                    acc, _ = jax.lax.scan(body, acc, xs)

            # ---- publish gather results (padded (P,Dmax) -> (V,))
            for s in gather_sends:
                _, _, _, rnid = prog.channels[s.comm_id]
                if s.op == "sendDstSum":
                    val = acc[f"sum{s.comm_id}"]
                elif s.op == "sendDstMean":
                    val = acc[f"sum{s.comm_id}"] / jnp.maximum(acc[f"cnt{s.comm_id}"], 1.0)
                else:
                    val = acc[f"max{s.comm_id}"]
                flat = jnp.where(pad_valid, val, 0.0).reshape(P * dmax, -1)
                buf = jnp.zeros((V + 1, flat.shape[-1]), jnp.float32)
                buf = buf.at[pad_ids.reshape(-1)].set(flat)
                vstore[rnid] = buf[:V]

        outs = sorted((n for n in self.nodes.values() if n.op == "output"), key=lambda n: n.id)
        return [vstore[o.id] for o in outs]


def run_pipelined(compiled: C.CompiledGNN, graph: Graph, tiles,
                  inputs: Dict[str, Array], params: Dict[str, Array],
                  tile_kernel: Optional[Callable] = None) -> List[Array]:
    return PipelinedRunner(compiled, graph, tiles, tile_kernel=tile_kernel)(inputs, params)
