"""GNN execution engines.

* :func:`run_reference` — whole-graph oracle (the classic programming model,
  "DGL-functional" semantics): every op over the full vertex/edge tensors.
  This is both the correctness oracle and the paper's non-tiled baseline.
* :func:`run_tiled` — faithful ZIPPER execution: phased tile-by-tile
  processing of the compiled SDE plan.  Source ops run per tile on the
  (sparse-)compacted source block, edge ops run per tile, gathers accumulate
  into per-partition destination blocks, destination ops run per partition.
  Gather barriers split the program into phases (needed e.g. for GAT's edge
  softmax, whose edge-normalization depends on a per-destination reduction).

The jit/scan-pipelined variant lives in ``core/pipeline.py``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import compiler as C
from . import ir as IR
from . import trace as TR
from .tiling import TileSet
from ..gnn.graphs import Graph

Array = Any

_NEG_INF = -1e30  # used instead of -inf so max-reduce stays NaN-free on empty segments


# ---------------------------------------------------------------------------
# shared op semantics
# ---------------------------------------------------------------------------

def apply_compute(op: str, attrs: Dict, params: Dict[str, Array], args: Sequence[Array]) -> Array:
    if op == "matmul" or op == "gemv":
        return args[0] @ params[attrs["weight"]]
    if op == "bias_add":
        return args[0] + params[attrs["weight"]]
    if op == "bmm_edge":
        x, et = args
        w = params[attrs["weight"]]  # (n_types, d_in, d_out)
        sel = w[et[..., 0].astype(jnp.int32)]
        return jnp.einsum("ef,efo->eo", x, sel)
    if op == "add":
        return args[0] + args[1]
    if op == "sub":
        return args[0] - args[1]
    if op == "mul":
        return args[0] * args[1]
    if op == "div":
        return args[0] / args[1]
    if op == "max2":
        return jnp.maximum(args[0], args[1])
    if op == "min2":
        return jnp.minimum(args[0], args[1])
    if op == "relu":
        return jax.nn.relu(args[0])
    if op == "leaky_relu":
        return jnp.where(args[0] > 0, args[0], attrs.get("slope", 0.2) * args[0])
    if op == "exp":
        return jnp.exp(args[0])
    if op == "sigmoid":
        return jax.nn.sigmoid(args[0])
    if op == "tanh":
        return jnp.tanh(args[0])
    if op == "neg":
        return -args[0]
    if op == "identity":
        return args[0]
    if op == "sqrt":
        return jnp.sqrt(args[0])
    if op == "rsqrt":
        return jax.lax.rsqrt(args[0])
    raise NotImplementedError(op)


# ---------------------------------------------------------------------------
# whole-graph reference (oracle / non-tiled baseline)
# ---------------------------------------------------------------------------

def run_reference(tr: TR.GnnTrace, graph: Graph, inputs: Dict[str, Array],
                  params: Dict[str, Array]) -> List[Array]:
    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.dst)
    V = graph.n_vertices
    env: Dict[int, Array] = {}
    outs: List[Array] = []
    for n in tr.nodes:
        if n.op == "param":
            continue
        if n.op == "input":
            env[n.id] = jnp.asarray(inputs[n.attrs["name"]])
        elif n.op == "output":
            outs.append(env[n.inputs[0]])
        elif n.op == "scatter_src":
            env[n.id] = env[n.inputs[0]][src]
        elif n.op == "scatter_dst":
            env[n.id] = env[n.inputs[0]][dst]
        elif n.op == "gather":
            e = env[n.inputs[0]]
            red = n.attrs["reduce"]
            if red == "sum":
                env[n.id] = jax.ops.segment_sum(e, dst, num_segments=V)
            elif red == "max":
                m = jax.ops.segment_max(e, dst, num_segments=V)
                env[n.id] = jnp.maximum(m, _NEG_INF)  # empty segments -> -1e30 not -inf
            elif red == "mean":
                s = jax.ops.segment_sum(e, dst, num_segments=V)
                c = jax.ops.segment_sum(jnp.ones((e.shape[0], 1), e.dtype), dst, num_segments=V)
                env[n.id] = s / jnp.maximum(c, 1.0)
            else:
                raise ValueError(red)
        elif n.op in ("matmul", "gemv", "bias_add"):
            w = tr.node(n.inputs[1])
            env[n.id] = apply_compute(n.op, {"weight": w.attrs["name"]}, params, [env[n.inputs[0]]])
        elif n.op == "bmm_edge":
            w = tr.node(n.inputs[1])
            env[n.id] = apply_compute("bmm_edge", {"weight": w.attrs["name"]}, params,
                                      [env[n.inputs[0]], env[n.inputs[2]]])
        else:
            env[n.id] = apply_compute(n.op, n.attrs, params, [env[i] for i in n.inputs])
    return outs


# ---------------------------------------------------------------------------
# tiled ZIPPER execution
# ---------------------------------------------------------------------------

class _TiledRun:
    def __init__(self, compiled: C.CompiledGNN, graph: Graph, tiles: TileSet,
                 inputs: Dict[str, Array], params: Dict[str, Array]):
        self.c = compiled
        self.prog = compiled.ir
        self.plan = compiled.plan
        self.graph = graph
        self.tiles = tiles
        self.params = params
        self.prog.rebuild_channels()
        self.send_of_comm = {cid: snid for cid, (_, snid, _, _) in self.prog.channels.items()}
        self.node_seg: Dict[int, IR.Segment] = {}
        self.nodes: Dict[int, IR.IRNode] = {}
        for seg in self.prog.segments:
            for n in seg.nodes.values():
                self.nodes[n.id] = n
                self.node_seg[n.id] = seg
        # global (V, dim) store: inputs, gather results, dst-computed values
        self.vstore: Dict[int, Array] = {}
        # global (E, dim) store for edge inputs
        self.estore: Dict[int, Array] = {}
        for seg in self.prog.segments:
            for n in seg.nodes.values():
                if n.op == "input":
                    val = jnp.asarray(inputs[n.attrs["name"]])
                    if seg.kind == "vertex":
                        self.vstore[n.id] = val
                    else:
                        self.estore[n.id] = val

    # -- per-tile source-side evaluation ------------------------------------
    def _eval_vertex_rows(self, rows: Array, lvl: int, roles: Sequence[str],
                          store: bool = False, valid: Optional[Array] = None) -> Dict[int, Array]:
        """Evaluate vertex-segment compute nodes for the given vertex rows.

        roles: which replica(s) to evaluate ('src' per tile / 'dst' per part).
        store=True writes level==lvl results back into the global vstore
        (destination replica).  Returns the local env.
        """
        env: Dict[int, Array] = {}

        def lookup(nid: int) -> Array:
            if nid in env:
                return env[nid]
            if nid in self.vstore:
                return self.vstore[nid][rows]
            raise KeyError(f"vertex value %{nid} unavailable")

        for seg in self.prog.vertex_segments():
            for n in seg.toposort():
                if self.plan.level[n.id] > lvl:
                    continue
                if n.op in ("input", "recvInEdge"):
                    continue  # read lazily via lookup
                if n.is_send():
                    continue
                if not (self.plan.role[n.id] & set(roles)) and n.op != "output":
                    continue
                if n.op == "output":
                    if "dst" not in roles or self.plan.level[n.id] != lvl:
                        continue
                    env[n.id] = lookup(n.inputs[0])
                else:
                    env[n.id] = apply_compute(n.op, n.attrs, self.params,
                                              [lookup(i) for i in n.inputs])
                if store and self.plan.level[n.id] == lvl and (
                        "dst" in self.plan.role[n.id] or n.op == "output"):
                    if n.id not in self.vstore:
                        self.vstore[n.id] = jnp.zeros((self.graph.n_vertices, env[n.id].shape[-1]),
                                                      env[n.id].dtype)
                    self.vstore[n.id] = self.vstore[n.id].at[rows].set(env[n.id])
        return env

    # -- main loop -----------------------------------------------------------
    def run(self) -> List[Array]:
        t = self.tiles
        plan = self.plan
        V = self.graph.n_vertices
        for lvl in range(plan.max_level + 1):
            # 1. destination/partition-scope ops at this level
            for p in range(t.n_dst_parts):
                lo = int(t.part_start[p]); n = int(t.part_size[p])
                rows = jnp.arange(lo, lo + n)
                self._eval_vertex_rows(rows, lvl, roles=("dst",), store=True)

            # does this level have tile-scope work?
            edge_lvl_nodes = [n for seg in self.prog.edge_segments()
                              for n in seg.toposort() if plan.level[n.id] == lvl]
            if not edge_lvl_nodes:
                continue

            # 2. gather accumulators for this level
            acc_sum: Dict[int, Array] = {}
            acc_max: Dict[int, Array] = {}
            acc_cnt: Dict[int, Array] = {}
            gather_sends = [n for n in self.nodes.values()
                            if n.op.startswith("sendDst") and plan.level[n.id] == lvl]
            for s in gather_sends:
                if s.op in ("sendDstSum", "sendDstMean"):
                    acc_sum[s.comm_id] = jnp.zeros((V, s.dim), jnp.float32)
                    if s.op == "sendDstMean":
                        acc_cnt[s.comm_id] = jnp.zeros((V, 1), jnp.float32)
                else:
                    acc_max[s.comm_id] = jnp.full((V, s.dim), _NEG_INF, jnp.float32)

            # 3. tile loop
            for ti in range(t.n_tiles):
                ns, ne = int(t.n_src[ti]), int(t.n_edge[ti])
                if ne == 0:
                    continue
                p = int(t.part_id[ti])
                src_rows = jnp.asarray(t.src_ids[ti, :ns])
                esrc = jnp.asarray(t.edge_src[ti, :ne])
                edst_local = jnp.asarray(t.edge_dst[ti, :ne])
                edst_global = edst_local + int(t.part_start[p])
                egid = jnp.asarray(t.edge_gid[ti, :ne])

                senv = self._eval_vertex_rows(src_rows, lvl, roles=("src",))

                eenv: Dict[int, Array] = {}

                def elookup(nid: int) -> Array:
                    if nid in eenv:
                        return eenv[nid]
                    if nid in self.estore:
                        return self.estore[nid][egid]
                    raise KeyError(f"edge value %{nid} unavailable")

                for seg in self.prog.edge_segments():
                    for n in seg.toposort():
                        # values of lower levels are recomputed every pass over
                        # the tiles (each phase re-loads and re-scatters);
                        # gather accumulation only happens at its own level.
                        if plan.level[n.id] > lvl:
                            continue
                        if n.op == "recvSrc":
                            src_nid = self.nodes[self.send_of_comm[n.comm_id]].inputs[0]
                            if src_nid in senv:
                                eenv[n.id] = senv[src_nid][esrc]
                            else:
                                eenv[n.id] = self.vstore[src_nid][src_rows][esrc]
                        elif n.op == "recvDst":
                            src_nid = self.nodes[self.send_of_comm[n.comm_id]].inputs[0]
                            eenv[n.id] = self.vstore[src_nid][edst_global]
                        elif n.op == "input":
                            continue  # lazy via elookup
                        elif n.is_send():
                            if plan.level[n.id] != lvl:
                                continue  # gathers accumulate only at their own phase
                            val = elookup(n.inputs[0])
                            if n.op in ("sendDstSum", "sendDstMean"):
                                acc_sum[n.comm_id] = acc_sum[n.comm_id].at[edst_global].add(val)
                                if n.op == "sendDstMean":
                                    acc_cnt[n.comm_id] = acc_cnt[n.comm_id].at[edst_global].add(
                                        jnp.ones((val.shape[0], 1), jnp.float32))
                            elif n.op.startswith("sendDst"):
                                acc_max[n.comm_id] = acc_max[n.comm_id].at[edst_global].max(val)
                        else:
                            eenv[n.id] = apply_compute(n.op, n.attrs, self.params,
                                                       [elookup(i) for i in n.inputs])

            # 4. publish gather results for the next level
            for s in gather_sends:
                _, _, rsi, rnid = self.prog.channels[s.comm_id]
                if s.op == "sendDstSum":
                    self.vstore[rnid] = acc_sum[s.comm_id]
                elif s.op == "sendDstMean":
                    self.vstore[rnid] = acc_sum[s.comm_id] / jnp.maximum(acc_cnt[s.comm_id], 1.0)
                else:
                    self.vstore[rnid] = acc_max[s.comm_id]

        # outputs, in id order (== declaration order)
        outs = sorted((n for n in self.nodes.values() if n.op == "output"), key=lambda n: n.id)
        return [self.vstore[o.id] for o in outs]


def run_tiled(compiled: C.CompiledGNN, graph: Graph, tiles: TileSet,
              inputs: Dict[str, Array], params: Dict[str, Array]) -> List[Array]:
    return _TiledRun(compiled, graph, tiles, inputs, params).run()
