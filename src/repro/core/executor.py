"""GNN execution engines.

* :func:`run_reference` — whole-graph oracle (the classic programming model,
  "DGL-functional" semantics): every op over the full vertex/edge tensors.
  This is both the correctness oracle and the paper's non-tiled baseline.
* :func:`run_tiled` — faithful ZIPPER execution: an interpreter over the
  compiled :class:`~repro.core.schedule.ScheduledProgram`.  Source blocks run
  per tile on the (sparse-)compacted source rows, edge blocks run per tile,
  gather blocks accumulate into per-partition destination rows, destination
  blocks run per partition.  Gather blocks tagged with a Pallas kernel
  (``pallas_spmm`` / ``pallas_spmm_weighted`` / ``pallas_segment_softmax``)
  dispatch one batched kernel call over the tile set instead of the per-tile
  scan — the paper's run-time mapping of schedule steps onto hardware blocks.

The engine derives no levels or roles of its own: block membership comes
entirely from ``schedule.lower`` (single source of truth).  The jit/scan-
pipelined variant lives in ``core/pipeline.py``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import compiler as C
from . import schedule as S
from .tiling import TileSet
from ..gnn.graphs import Graph

Array = Any

_NEG_INF = -1e30  # used instead of -inf so max-reduce stays NaN-free on empty segments


# ---------------------------------------------------------------------------
# shared op semantics
# ---------------------------------------------------------------------------

def apply_compute(op: str, attrs: Dict, params: Dict[str, Array], args: Sequence[Array]) -> Array:
    if op == "matmul" or op == "gemv":
        return args[0] @ params[attrs["weight"]]
    if op == "bias_add":
        return args[0] + params[attrs["weight"]]
    if op == "bmm_edge":
        x, et = args
        w = params[attrs["weight"]]  # (n_types, d_in, d_out)
        sel = w[et[..., 0].astype(jnp.int32)]
        return jnp.einsum("ef,efo->eo", x, sel)
    if op == "add":
        return args[0] + args[1]
    if op == "sub":
        return args[0] - args[1]
    if op == "mul":
        return args[0] * args[1]
    if op == "div":
        return args[0] / args[1]
    if op == "max2":
        return jnp.maximum(args[0], args[1])
    if op == "min2":
        return jnp.minimum(args[0], args[1])
    if op == "relu":
        return jax.nn.relu(args[0])
    if op == "leaky_relu":
        return jnp.where(args[0] > 0, args[0], attrs.get("slope", 0.2) * args[0])
    if op == "exp":
        return jnp.exp(args[0])
    if op == "sigmoid":
        return jax.nn.sigmoid(args[0])
    if op == "tanh":
        return jnp.tanh(args[0])
    if op == "neg":
        return -args[0]
    if op == "identity":
        return args[0]
    if op == "sqrt":
        return jnp.sqrt(args[0])
    if op == "rsqrt":
        return jax.lax.rsqrt(args[0])
    raise NotImplementedError(op)


# ---------------------------------------------------------------------------
# whole-graph reference (oracle / non-tiled baseline)
# ---------------------------------------------------------------------------

def run_reference(tr, graph: Graph, inputs: Dict[str, Array],
                  params: Dict[str, Array]) -> List[Array]:
    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.dst)
    V = graph.n_vertices
    env: Dict[int, Array] = {}
    outs: List[Array] = []
    for n in tr.nodes:
        if n.op == "param":
            continue
        if n.op == "input":
            env[n.id] = jnp.asarray(inputs[n.attrs["name"]])
        elif n.op == "output":
            outs.append(env[n.inputs[0]])
        elif n.op == "scatter_src":
            env[n.id] = env[n.inputs[0]][src]
        elif n.op == "scatter_dst":
            env[n.id] = env[n.inputs[0]][dst]
        elif n.op == "gather":
            e = env[n.inputs[0]]
            red = n.attrs["reduce"]
            if red == "sum":
                env[n.id] = jax.ops.segment_sum(e, dst, num_segments=V)
            elif red == "max":
                m = jax.ops.segment_max(e, dst, num_segments=V)
                env[n.id] = jnp.maximum(m, _NEG_INF)  # empty segments -> -1e30 not -inf
            elif red == "mean":
                s = jax.ops.segment_sum(e, dst, num_segments=V)
                c = jax.ops.segment_sum(jnp.ones((e.shape[0], 1), e.dtype), dst, num_segments=V)
                env[n.id] = s / jnp.maximum(c, 1.0)
            else:
                raise ValueError(red)
        elif n.op in ("matmul", "gemv", "bias_add"):
            w = tr.node(n.inputs[1])
            env[n.id] = apply_compute(n.op, {"weight": w.attrs["name"]}, params, [env[n.inputs[0]]])
        elif n.op == "bmm_edge":
            w = tr.node(n.inputs[1])
            env[n.id] = apply_compute("bmm_edge", {"weight": w.attrs["name"]}, params,
                                      [env[n.inputs[0]], env[n.inputs[2]]])
        else:
            env[n.id] = apply_compute(n.op, n.attrs, params, [env[i] for i in n.inputs])
    return outs


# ---------------------------------------------------------------------------
# tiled ZIPPER execution: ScheduledProgram interpreter
# ---------------------------------------------------------------------------

class _TiledRun:
    def __init__(self, compiled: C.CompiledGNN, graph: Graph, tiles: TileSet,
                 inputs: Dict[str, Array], params: Dict[str, Array],
                 kernel_dispatch: bool = True):
        self.sp: S.ScheduledProgram = compiled.schedule(kernel_dispatch)
        self.graph = graph
        self.tiles = tiles
        self.params = params
        # global (V, dim) store: inputs, gather results, dst-computed values
        self.vstore: Dict[int, Array] = {
            nid: jnp.asarray(inputs[name]) for nid, name in self.sp.vertex_inputs}
        # global (E, dim) store for edge inputs
        self.estore: Dict[int, Array] = {
            nid: jnp.asarray(inputs[name]) for nid, name in self.sp.edge_inputs}
        self._dense = None      # cached (adj, flags) for pure-SpMM blocks
        self._flags = None      # FIRST/LAST markers (runtime-densified blocks)

    # -- vertex-block evaluation ---------------------------------------------
    def _eval_vertex(self, nodes, rows: Array, store_ids=()) -> Dict[int, Array]:
        """Evaluate a Src/Dst block's node list on the given vertex rows.

        ``store_ids`` writes those results back into the global vstore
        (destination replica).  Returns the local env.
        """
        env: Dict[int, Array] = {}

        def lookup(nid: int) -> Array:
            if nid in env:
                return env[nid]
            if nid in self.vstore:
                return self.vstore[nid][rows]
            raise KeyError(f"vertex value %{nid} unavailable")

        for n in nodes:
            if n.op == "output":
                env[n.id] = lookup(n.inputs[0])
            else:
                env[n.id] = apply_compute(n.op, n.attrs, self.params,
                                          [lookup(i) for i in n.inputs])
            if n.id in store_ids:
                if n.id not in self.vstore:
                    self.vstore[n.id] = jnp.zeros(
                        (self.graph.n_vertices, env[n.id].shape[-1]), env[n.id].dtype)
                self.vstore[n.id] = self.vstore[n.id].at[rows].set(env[n.id])
        return env

    # -- edge-block evaluation (one tile) ------------------------------------
    def _eval_edge(self, nodes, senv: Dict[int, Array], src_rows: Array,
                   esrc: Array, edst_global: Array, egid: Array):
        """Evaluate an edge-block node list for one tile.

        Returns ``(eenv, elookup)``: the local env plus a lookup that falls
        back to the global edge-feature store for edge inputs.
        """
        eenv: Dict[int, Array] = {}

        def elookup(nid: int) -> Array:
            if nid in eenv:
                return eenv[nid]
            if nid in self.estore:
                return self.estore[nid][egid]
            raise KeyError(f"edge value %{nid} unavailable")

        for n in nodes:
            if n.op == "recvSrc":
                src_nid = self.sp.scatter_value_of[n.id]
                base = senv[src_nid] if src_nid in senv else self.vstore[src_nid][src_rows]
                eenv[n.id] = base[esrc]
            elif n.op == "recvDst":
                src_nid = self.sp.scatter_value_of[n.id]
                eenv[n.id] = self.vstore[src_nid][edst_global]
            else:
                eenv[n.id] = apply_compute(n.op, n.attrs, self.params,
                                           [elookup(i) for i in n.inputs])
        return eenv, elookup

    def _tile_coords(self, ti: int):
        t = self.tiles
        p = int(t.part_id[ti])
        src_rows = jnp.asarray(t.src_ids[ti])            # full padded row
        esrc = jnp.asarray(t.edge_src[ti])
        edst_global = jnp.minimum(
            jnp.asarray(t.edge_dst[ti]) + int(t.part_start[p]),
            self.graph.n_vertices - 1)
        egid = jnp.asarray(t.edge_gid[ti])
        return p, src_rows, esrc, edst_global, egid

    # -- kernel-tagged gather blocks -----------------------------------------
    def _run_kernel_gathers(self, phase: S.Phase) -> None:
        from ..kernels.tile_spmm import ops as tops
        from ..kernels.tile_spmm.kernel import tile_flags

        t = self.tiles
        P = t.n_dst_parts
        dmax = int(t.part_size.max())
        if self._flags is None:
            self._flags = jnp.asarray(tile_flags(t.part_id))
        pmask = np.isin(np.arange(P), t.part_id)

        for g in phase.kernel_gathers():
            # per-tile source values (padded rows; padding never contributes)
            xsrc_rows = []
            edge_vals = []
            for ti in range(t.n_tiles):
                p, src_rows, esrc, edst_global, egid = self._tile_coords(ti)
                senv = self._eval_vertex(phase.src.nodes, src_rows)
                h = (senv[g.src_value_id] if g.src_value_id in senv
                     else self.vstore[g.src_value_id][src_rows])
                if g.kernel == S.KERNEL_SPMM:
                    xsrc_rows.append(h)
                    continue
                _, elookup = self._eval_edge(g.edge_nodes, senv, src_rows, esrc,
                                             edst_global, egid)
                if g.kernel == S.KERNEL_SPMM_WEIGHTED:
                    xsrc_rows.append(h)
                    edge_vals.append(elookup(g.weight_id)[:, 0])   # (E,)
                else:   # segment softmax: scores + per-edge source values
                    xsrc_rows.append(h[esrc])                      # (E, F)
                    edge_vals.append(elookup(g.score_id)[:, 0])    # (E,)
            xsrc = jnp.stack(xsrc_rows)
            part_id = jnp.asarray(t.part_id)
            n_edge = jnp.asarray(t.n_edge)

            if t.layout == "csr":
                # CSR tiles skip the densify pass entirely: the kernels walk
                # the per-tile row pointers over per-edge operands
                row_ptr = jnp.asarray(t.row_ptr)
                col = jnp.asarray(t.edge_src)
                if g.kernel == S.KERNEL_SEGMENT_SOFTMAX:
                    out = tops.gat_aggregate_csr(
                        row_ptr, jnp.stack(edge_vals), xsrc, part_id,
                        self._flags, n_parts=P)
                else:
                    if g.kernel == S.KERNEL_SPMM:
                        w = jnp.ones(col.shape, jnp.float32)
                    else:
                        w = jnp.stack(edge_vals)
                        emask = (jnp.arange(w.shape[1])[None, :]
                                 < n_edge[:, None])
                        w = jnp.where(emask, w, 0.0)
                    out = tops.spmm_csr(row_ptr, col, w, xsrc, part_id,
                                        self._flags, n_parts=P)
            elif g.kernel == S.KERNEL_SPMM:
                if self._dense is None:
                    self._dense = tops.densify_tiles(t)
                adj, flags = self._dense
                out = tops.spmm(jnp.asarray(adj), xsrc, part_id,
                                jnp.asarray(flags), n_parts=P)
            elif g.kernel == S.KERNEL_SPMM_WEIGHTED:
                adj = tops.densify_edge_weights(
                    jnp.stack(edge_vals), jnp.asarray(t.edge_dst),
                    jnp.asarray(t.edge_src), n_edge, dmax=dmax, smax=t.s_max)
                out = tops.spmm(adj, xsrc, part_id, self._flags, n_parts=P)
            else:
                scores = tops.densify_edge_scores(
                    jnp.stack(edge_vals), jnp.asarray(t.edge_dst), n_edge,
                    dmax=dmax)
                out = tops.gat_aggregate(scores, xsrc, part_id, self._flags,
                                         n_parts=P)
            # partitions with no tile are never written by the kernel
            out = jnp.where(jnp.asarray(pmask)[:, None, None], out, 0.0)
            buf = jnp.zeros((self.graph.n_vertices, out.shape[-1]), jnp.float32)
            for p in range(P):
                lo, n = int(t.part_start[p]), int(t.part_size[p])
                buf = buf.at[lo:lo + n].set(out[p, :n])
            self.vstore[g.acc.recv_id] = buf

    # -- main loop -----------------------------------------------------------
    def run(self) -> List[Array]:
        t = self.tiles
        V = self.graph.n_vertices
        for phase in self.sp.phases:
            # 1. destination/partition-scope block
            if phase.dst.store_ids:
                for p in range(t.n_dst_parts):
                    lo = int(t.part_start[p]); n = int(t.part_size[p])
                    if n == 0:
                        continue
                    rows = jnp.arange(lo, lo + n)
                    self._eval_vertex(phase.dst.nodes, rows,
                                      store_ids=set(phase.dst.store_ids))
            if not phase.has_tile_work:
                continue

            # 2. kernel-dispatched gather blocks (one batched call each)
            if phase.kernel_gathers():
                self._run_kernel_gathers(phase)

            scan_gathers = phase.scan_gathers()
            if not scan_gathers and not phase.edge.nodes:
                continue

            # 3. accumulators for the scan-path gathers
            acc_sum: Dict[int, Array] = {}
            acc_max: Dict[int, Array] = {}
            acc_cnt: Dict[int, Array] = {}
            for g in scan_gathers:
                cid, dim = g.acc.comm_id, g.acc.dim
                if g.acc.kind in ("sum", "mean"):
                    acc_sum[cid] = jnp.zeros((V, dim), jnp.float32)
                    if g.acc.kind == "mean":
                        acc_cnt[cid] = jnp.zeros((V, 1), jnp.float32)
                else:
                    acc_max[cid] = jnp.full((V, dim), _NEG_INF, jnp.float32)

            # 4. tile loop
            for ti in range(t.n_tiles):
                ns, ne = int(t.n_src[ti]), int(t.n_edge[ti])
                if ne == 0:
                    continue
                p = int(t.part_id[ti])
                src_rows = jnp.asarray(t.src_ids[ti, :ns])
                esrc = jnp.asarray(t.edge_src[ti, :ne])
                edst_global = jnp.asarray(t.edge_dst[ti, :ne]) + int(t.part_start[p])
                egid = jnp.asarray(t.edge_gid[ti, :ne])

                senv = self._eval_vertex(phase.src.nodes, src_rows)
                _, elookup = self._eval_edge(phase.edge.nodes, senv, src_rows,
                                             esrc, edst_global, egid)
                for g in scan_gathers:
                    cid = g.acc.comm_id
                    val = elookup(g.acc.value_id)
                    if g.acc.kind in ("sum", "mean"):
                        acc_sum[cid] = acc_sum[cid].at[edst_global].add(val)
                        if g.acc.kind == "mean":
                            acc_cnt[cid] = acc_cnt[cid].at[edst_global].add(
                                jnp.ones((val.shape[0], 1), jnp.float32))
                    else:
                        acc_max[cid] = acc_max[cid].at[edst_global].max(val)

            # 5. publish scan-gather results for the next phase
            for g in scan_gathers:
                cid = g.acc.comm_id
                if g.acc.kind == "sum":
                    self.vstore[g.acc.recv_id] = acc_sum[cid]
                elif g.acc.kind == "mean":
                    self.vstore[g.acc.recv_id] = acc_sum[cid] / jnp.maximum(
                        acc_cnt[cid], 1.0)
                else:
                    self.vstore[g.acc.recv_id] = acc_max[cid]

        return [self.vstore[o] for o in self.sp.outputs]


def run_tiled(compiled: C.CompiledGNN, graph: Graph, tiles: TileSet,
              inputs: Dict[str, Array], params: Dict[str, Array],
              kernel_dispatch: bool = True) -> List[Array]:
    """Interpret the compiled scheduled program tile-by-tile.

    ``kernel_dispatch=False`` forces every gather block onto the scan path
    (the paper's pure multi-phase schedule, no Pallas blocks).
    """
    return _TiledRun(compiled, graph, tiles, inputs, params,
                     kernel_dispatch=kernel_dispatch).run()
