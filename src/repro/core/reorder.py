"""Graph reordering (paper §5.3): lightweight Degree Sorting.

Vertices are relabeled in descending in-degree order, concentrating the
high-connectivity vertices into the low-id source partitions so sparse tiles
on the high-id side shrink (more blank rows skipped).  Returns the permuted
graph plus the mappings needed to permute features in and outputs back.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from ..gnn.graphs import Graph


@dataclasses.dataclass
class Reordering:
    graph: Graph
    order: np.ndarray  # (V,) old vertex id occupying each new slot: old = order[new]
    rank: np.ndarray   # (V,) new id of each old vertex:            new = rank[old]
    mode: str = "identity"  # provenance tag carried into runner/cache signatures

    def permute_vertex_features(self, x: np.ndarray) -> np.ndarray:
        """X_new[new] = X_old[order[new]]"""
        return x[self.order]

    def unpermute_vertex_outputs(self, y_new: np.ndarray) -> np.ndarray:
        """y_old[old] = y_new[rank[old]]"""
        return y_new[self.rank]

    @property
    def is_identity(self) -> bool:
        return self.mode == "identity"


def identity_order(graph: Graph) -> Reordering:
    order = np.arange(graph.n_vertices, dtype=np.int32)
    return Reordering(graph=graph, order=order, rank=order.copy(), mode="identity")


def degree_sort(graph: Graph, by: str = "in") -> Reordering:
    """Heuristic Degree Sorting (paper Fig 7c): stable sort by degree desc."""
    if by not in ("in", "out"):
        raise ValueError(f"degree_sort: by must be 'in' or 'out', got {by!r}")
    deg = graph.in_degrees() if by == "in" else graph.out_degrees()
    order = np.argsort(-deg, kind="stable").astype(np.int32)
    rank = np.empty_like(order)
    rank[order] = np.arange(graph.n_vertices, dtype=np.int32)
    g2 = Graph(src=rank[graph.src], dst=rank[graph.dst],
               n_vertices=graph.n_vertices, edge_type=graph.edge_type,
               name=graph.name + "+degsort")
    g2.validate()
    mode = "degree" if by == "in" else "degree-out"
    return Reordering(graph=g2, order=order, rank=rank, mode=mode)
