"""Graph data substrate: COO graphs, degree utilities, synthetic generators.

The paper evaluates on six public graphs (Table 3).  This container has no
dataset downloads, so we provide *generators* that reproduce each dataset's
vertex/edge counts and degree skew (power-law for social/collab networks,
near-uniform for road networks).  ``paper_graph(name, scale=...)`` yields a
structurally-matched synthetic stand-in; `scale` shrinks it for CPU runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """Directed graph in COO. Edge e: src[e] -> dst[e]."""

    src: np.ndarray  # int32 (E,)
    dst: np.ndarray  # int32 (E,)
    n_vertices: int
    edge_type: Optional[np.ndarray] = None  # int32 (E,) for R-GCN
    name: str = "graph"

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_vertices).astype(np.int32)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices).astype(np.int32)

    def validate(self) -> None:
        assert self.src.shape == self.dst.shape
        assert self.src.min(initial=0) >= 0 and (self.n_edges == 0 or self.src.max() < self.n_vertices)
        assert self.dst.min(initial=0) >= 0 and (self.n_edges == 0 or self.dst.max() < self.n_vertices)

    def sorted_by_dst(self) -> "Graph":
        order = np.lexsort((self.src, self.dst))
        return Graph(src=self.src[order], dst=self.dst[order], n_vertices=self.n_vertices,
                     edge_type=None if self.edge_type is None else self.edge_type[order],
                     name=self.name)


def random_graph(n_vertices: int, n_edges: int, seed: int = 0,
                 model: str = "powerlaw", n_edge_types: Optional[int] = None,
                 name: str = "synthetic") -> Graph:
    """Synthetic digraph. ``powerlaw``: zipf-skewed endpoints (social-like);
    ``uniform``: iid endpoints (road-network-like)."""
    rng = np.random.default_rng(seed)
    if model == "powerlaw":
        # sample endpoints with probability ∝ rank^{-0.9} (heavy-tailed)
        ranks = np.arange(1, n_vertices + 1, dtype=np.float64)
        probs = ranks ** -0.9
        probs /= probs.sum()
        src = rng.choice(n_vertices, size=n_edges, p=probs).astype(np.int32)
        dst = rng.choice(n_vertices, size=n_edges, p=probs).astype(np.int32)
        # shuffle vertex ids so high-degree vertices are NOT pre-sorted
        perm = rng.permutation(n_vertices).astype(np.int32)
        src, dst = perm[src], perm[dst]
    elif model == "uniform":
        src = rng.integers(0, n_vertices, size=n_edges, dtype=np.int32)
        dst = rng.integers(0, n_vertices, size=n_edges, dtype=np.int32)
    else:
        raise ValueError(model)
    etype = None
    if n_edge_types is not None:
        etype = rng.integers(0, n_edge_types, size=n_edges, dtype=np.int32)
    g = Graph(src=src, dst=dst, n_vertices=n_vertices, edge_type=etype, name=name)
    g.validate()
    return g


#: paper Table 3 — (V, E, degree model)
PAPER_DATASETS: Dict[str, Tuple[int, int, str]] = {
    "ak2010": (45_293, 108_549, "uniform"),        # redistricting set
    "coAuthorsDBLP": (299_068, 977_676, "powerlaw"),
    "hollywood-2009": (1_139_905, 57_515_616, "powerlaw"),
    "cit-Patents": (3_774_768, 16_518_948, "powerlaw"),
    "soc-LiveJournal1": (4_847_571, 43_369_619, "powerlaw"),
    "europe-osm": (50_912_018, 54_054_660, "uniform"),
}


def paper_graph(dataset: str, scale: float = 1.0, seed: int = 0,
                n_edge_types: Optional[int] = None) -> Graph:
    """Synthetic stand-in matched to a paper dataset's V/E counts.

    ``scale`` < 1 shrinks both V and E proportionally (CPU-friendly runs);
    the degree distribution family is preserved.
    """
    v, e, model = PAPER_DATASETS[dataset]
    v, e = max(4, int(v * scale)), max(4, int(e * scale))
    return random_graph(v, e, seed=seed, model=model, n_edge_types=n_edge_types,
                        name=f"{dataset}@{scale:g}")
