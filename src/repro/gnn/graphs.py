"""Graph data substrate: COO graphs, degree utilities, synthetic generators.

The paper evaluates on six public graphs (Table 3).  This container has no
dataset downloads, so we provide *generators* that reproduce each dataset's
vertex/edge counts and degree skew (power-law for social/collab networks,
near-uniform for road networks).  ``paper_graph(name, scale=...)`` yields a
structurally-matched synthetic stand-in; `scale` shrinks it for CPU runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """Directed graph in COO. Edge e: src[e] -> dst[e]."""

    src: np.ndarray  # int32 (E,)
    dst: np.ndarray  # int32 (E,)
    n_vertices: int
    edge_type: Optional[np.ndarray] = None  # int32 (E,) for R-GCN
    name: str = "graph"

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_vertices).astype(np.int32)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices).astype(np.int32)

    def validate(self) -> None:
        assert self.src.shape == self.dst.shape
        assert self.src.min(initial=0) >= 0 and (
            self.n_edges == 0 or self.src.max() < self.n_vertices)
        assert self.dst.min(initial=0) >= 0 and (
            self.n_edges == 0 or self.dst.max() < self.n_vertices)

    def sorted_by_dst(self) -> "Graph":
        order = np.lexsort((self.src, self.dst))
        return Graph(src=self.src[order], dst=self.dst[order], n_vertices=self.n_vertices,
                     edge_type=None if self.edge_type is None else self.edge_type[order],
                     name=self.name)


# ---------------------------------------------------------------------------
# multi-graph batching (serving substrate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GraphBatch:
    """Block-diagonal merge of many small graphs into one super-graph.

    One ScheduledProgram execution over ``graph`` serves every member graph
    at once: vertex ids of graph ``i`` are shifted by ``vertex_offsets[i]``,
    edge rows by ``edge_offsets[i]``, and no cross-graph edges exist, so
    per-member results are exact slices of the merged result.
    """

    graph: Graph
    vertex_offsets: np.ndarray   # int64 (G+1,) — member i owns [o[i], o[i+1])
    edge_offsets: np.ndarray     # int64 (G+1,)
    graph_ids: np.ndarray        # int32 (V,) — member index of each vertex

    @property
    def n_graphs(self) -> int:
        return len(self.vertex_offsets) - 1

    def unbatch_vertex(self, arr) -> List[np.ndarray]:
        """Split a merged (V, d) vertex array back into per-graph arrays."""
        arr = np.asarray(arr)
        o = self.vertex_offsets
        return [arr[o[i]:o[i + 1]] for i in range(self.n_graphs)]

    def unbatch_edge(self, arr) -> List[np.ndarray]:
        """Split a merged (E, d) edge array back into per-graph arrays."""
        arr = np.asarray(arr)
        o = self.edge_offsets
        return [arr[o[i]:o[i + 1]] for i in range(self.n_graphs)]

    def graph_pool(self, arr, reduce: str = "mean") -> np.ndarray:
        """Per-graph readout of a merged (V, d) vertex array -> (G, d).
        Accepts class-padded arrays (rows beyond the real vertices ignored).
        """
        arr = np.asarray(arr)
        V = len(self.graph_ids)
        if arr.shape[0] < V:
            raise ValueError(f"vertex array has {arr.shape[0]} rows, "
                             f"expected >= {V}")
        arr = arr[:V]
        G = self.n_graphs
        out = np.zeros((G,) + arr.shape[1:], np.float64)
        np.add.at(out, self.graph_ids, arr)
        if reduce == "mean":
            sizes = np.diff(self.vertex_offsets).astype(np.float64)
            out /= np.maximum(sizes, 1.0)[:, None]
            # means of integer features are fractional — stay floating
            return out.astype(np.result_type(arr.dtype, np.float32))
        if reduce != "sum":
            raise ValueError(reduce)
        return out.astype(arr.dtype)


def batch_graphs(graphs: Sequence[Graph], name: str = "batch") -> GraphBatch:
    """Merge ``graphs`` into one block-diagonal super-graph (DGL/PyG-style).

    Edge indices are offset per member; ``edge_type`` is concatenated when
    every member carries it (mixing typed and untyped members is an error).
    """
    if not graphs:
        raise ValueError("batch_graphs needs at least one graph")
    vo = np.zeros(len(graphs) + 1, np.int64)
    eo = np.zeros(len(graphs) + 1, np.int64)
    for i, g in enumerate(graphs):
        vo[i + 1] = vo[i] + g.n_vertices
        eo[i + 1] = eo[i] + g.n_edges
    src = np.concatenate([g.src.astype(np.int64) + vo[i]
                          for i, g in enumerate(graphs)]).astype(np.int32)
    dst = np.concatenate([g.dst.astype(np.int64) + vo[i]
                          for i, g in enumerate(graphs)]).astype(np.int32)
    typed = [g.edge_type is not None for g in graphs]
    if any(typed) and not all(typed):
        raise ValueError("cannot batch typed and untyped graphs together")
    etype = (np.concatenate([g.edge_type for g in graphs]).astype(np.int32)
             if all(typed) else None)
    gids = np.concatenate([np.full(g.n_vertices, i, np.int32)
                           for i, g in enumerate(graphs)])
    merged = Graph(src=src, dst=dst, n_vertices=int(vo[-1]), edge_type=etype,
                   name=name)
    merged.validate()
    return GraphBatch(graph=merged, vertex_offsets=vo, edge_offsets=eo,
                      graph_ids=gids)


def pad_graph(graph: Graph, n_vertices: int) -> Graph:
    """Grow the vertex set to ``n_vertices`` with edge-less padding vertices.

    Padding vertices receive no messages and send none, so real-vertex
    results are unchanged; the serving layer uses this to snap a merged
    request batch onto a shared size class (one compiled program per class).
    """
    if n_vertices < graph.n_vertices:
        raise ValueError(f"cannot shrink graph {graph.n_vertices} -> {n_vertices}")
    if n_vertices == graph.n_vertices:
        return graph
    return Graph(src=graph.src, dst=graph.dst, n_vertices=n_vertices,
                 edge_type=graph.edge_type, name=graph.name)


def random_graph(n_vertices: int, n_edges: int, seed: int = 0,
                 model: str = "powerlaw", n_edge_types: Optional[int] = None,
                 name: str = "synthetic") -> Graph:
    """Synthetic digraph. ``powerlaw``: zipf-skewed endpoints (social-like);
    ``uniform``: iid endpoints (road-network-like)."""
    rng = np.random.default_rng(seed)
    if model == "powerlaw":
        # sample endpoints with probability ∝ rank^{-0.9} (heavy-tailed)
        ranks = np.arange(1, n_vertices + 1, dtype=np.float64)
        probs = ranks ** -0.9
        probs /= probs.sum()
        src = rng.choice(n_vertices, size=n_edges, p=probs).astype(np.int32)
        dst = rng.choice(n_vertices, size=n_edges, p=probs).astype(np.int32)
        # shuffle vertex ids so high-degree vertices are NOT pre-sorted
        perm = rng.permutation(n_vertices).astype(np.int32)
        src, dst = perm[src], perm[dst]
    elif model == "uniform":
        src = rng.integers(0, n_vertices, size=n_edges, dtype=np.int32)
        dst = rng.integers(0, n_vertices, size=n_edges, dtype=np.int32)
    else:
        raise ValueError(model)
    etype = None
    if n_edge_types is not None:
        etype = rng.integers(0, n_edge_types, size=n_edges, dtype=np.int32)
    g = Graph(src=src, dst=dst, n_vertices=n_vertices, edge_type=etype, name=name)
    g.validate()
    return g


#: paper Table 3 — (V, E, degree model)
PAPER_DATASETS: Dict[str, Tuple[int, int, str]] = {
    "ak2010": (45_293, 108_549, "uniform"),        # redistricting set
    "coAuthorsDBLP": (299_068, 977_676, "powerlaw"),
    "hollywood-2009": (1_139_905, 57_515_616, "powerlaw"),
    "cit-Patents": (3_774_768, 16_518_948, "powerlaw"),
    "soc-LiveJournal1": (4_847_571, 43_369_619, "powerlaw"),
    "europe-osm": (50_912_018, 54_054_660, "uniform"),
}


def paper_graph(dataset: str, scale: float = 1.0, seed: int = 0,
                n_edge_types: Optional[int] = None) -> Graph:
    """Synthetic stand-in matched to a paper dataset's V/E counts.

    ``scale`` < 1 shrinks both V and E proportionally (CPU-friendly runs);
    the degree distribution family is preserved.
    """
    v, e, model = PAPER_DATASETS[dataset]
    v, e = max(4, int(v * scale)), max(4, int(e * scale))
    return random_graph(v, e, seed=seed, model=model, n_edge_types=n_edge_types,
                        name=f"{dataset}@{scale:g}")
