from . import graphs, models, frontend  # noqa: F401
