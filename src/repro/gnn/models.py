"""The paper's five benchmark GNN models (§8.1), written against the classic
whole-graph programming model.

GCN, GAT (1 head, as in the paper), GraphSAGE (maxpool aggregator), GGNN
(GRU update), R-GCN (3 edge types, as in the paper).  For GAT and SAGE we
also provide the *naive* variants the paper uses to evaluate the compiler's
E2V optimization (Fig 12): per-edge ops that a library author would normally
hand-hoist are left on the edges, and the compiler must hoist them.

Every model is written as a reusable **layer function** ``layer_X(tr, g, x,
out_dim, prefix=...) -> TT`` plus a thin single-layer ``build_X`` wrapper.
:func:`build_stacked` chains layer functions into the stacked variants the
paper evaluates (§8.1 runs multi-layer GCN/GAT/SAGE/GGNN/R-GCN): layer
``l``'s output tensor becomes layer ``l+1``'s input, parameters are
per-layer (``l{l}.`` prefix), and structure-only inputs (``dnorm``,
``etype``) are declared once and shared — the compiler's cross-layer
redundancy pass deduplicates the per-layer re-scatters they induce.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.trace import GnnTrace, GraphRef, TT, trace_model
from .graphs import Graph

EMBED = 128  # the paper's input/output embedding size for all experiments


# ---------------------------------------------------------------------------
# layer functions (trace-time, stackable)
# ---------------------------------------------------------------------------

def layer_gcn(tr: GnnTrace, g: GraphRef, x: TT, out_dim: int, *,
              dnorm: TT, prefix: str = "", edge_norm: bool = False) -> TT:
    """GCN layer: relu(D^-1/2 A D^-1/2 X W)  — norm via precomputed dnorm.

    ``edge_norm=True`` applies the symmetric normalization per edge
    (``scatter_src(dn) * scatter_dst(dn)``, the textbook stacked form):
    numerically identical, but the normalized-adjacency scatters depend only
    on graph structure, so in a stacked model every layer re-emits them and
    the compiler's cross-layer CSE pass must deduplicate.
    """
    w = tr.param(prefix + "W", (x.dim, out_dim))
    if edge_norm:
        h = x.matmul(w)
        escale = g.scatter_src(dnorm) * g.scatter_dst(dnorm)
        agg = g.gather_sum(g.scatter_src(h) * escale)
        return agg.relu()
    h = (x * dnorm).matmul(w)
    agg = g.gather_sum(g.scatter_src(h))
    return (agg * dnorm).relu()


def layer_gat(tr: GnnTrace, g: GraphRef, x: TT, out_dim: int, *,
              prefix: str = "", naive: bool = False) -> TT:
    """GAT layer, single head (paper §8.1). ``naive=True`` leaves the two
    attention mat-vecs on the edges — the compiler's E2V pass must hoist them
    (paper Fig 8b / Fig 12)."""
    w = tr.param(prefix + "W", (x.dim, out_dim))
    a1 = tr.param(prefix + "a_src", (out_dim, 1))
    a2 = tr.param(prefix + "a_dst", (out_dim, 1))
    h = x.matmul(w)
    if naive:
        hs = g.scatter_src(h)
        hd = g.scatter_dst(h)
        e = (hs.gemv(a1) + hd.gemv(a2)).leaky_relu()
    else:
        es = g.scatter_src(h.gemv(a1))
        ed = g.scatter_dst(h.gemv(a2))
        e = (es + ed).leaky_relu()
    alpha = g.edge_softmax(e)
    m = g.scatter_src(h) * alpha
    return g.gather_sum(m)


def layer_sage(tr: GnnTrace, g: GraphRef, x: TT, out_dim: int, *,
               prefix: str = "", naive: bool = False) -> TT:
    """GraphSAGE-maxpool: h_N = max_j relu(W_p x_j + b); out = relu(W1 x + W2 h_N)."""
    in_dim = x.dim
    wp = tr.param(prefix + "W_pool", (in_dim, out_dim))
    bp = tr.param(prefix + "b_pool", (out_dim,))
    w1 = tr.param(prefix + "W_self", (in_dim, out_dim))
    w2 = tr.param(prefix + "W_neigh", (out_dim, out_dim))
    if naive:
        # pooling MLP applied per edge (redundant): E2V must hoist it
        xs = g.scatter_src(x)
        pe = xs.matmul(wp).bias_add(bp).relu()
    else:
        pv = x.matmul(wp).bias_add(bp).relu()
        pe = g.scatter_src(pv)
    hn = g.gather_max(pe)
    return (x.matmul(w1) + hn.matmul(w2)).relu()


def layer_ggnn(tr: GnnTrace, g: GraphRef, x: TT, out_dim: Optional[int] = None, *,
               prefix: str = "") -> TT:
    """GGNN: a = A(X W_msg); h' = GRU(a, x) — GRU from separate ELW+GEMM ops
    (the paper implements the GRU with separate instructions on ZIPPER).
    The GRU state keeps the input width; a differing ``out_dim`` is an error,
    not a silent no-op."""
    d = x.dim
    if out_dim is not None and out_dim != d:
        raise ValueError(f"GGNN preserves the feature dim ({d}); "
                         f"got out_dim={out_dim}")
    wm = tr.param(prefix + "W_msg", (d, d))
    wz, uz = tr.param(prefix + "W_z", (d, d)), tr.param(prefix + "U_z", (d, d))
    wr, ur = tr.param(prefix + "W_r", (d, d)), tr.param(prefix + "U_r", (d, d))
    wh, uh = tr.param(prefix + "W_h", (d, d)), tr.param(prefix + "U_h", (d, d))
    a = g.gather_sum(g.scatter_src(x.matmul(wm)))
    z = (a.matmul(wz) + x.matmul(uz)).sigmoid()
    r = (a.matmul(wr) + x.matmul(ur)).sigmoid()
    hh = (a.matmul(wh) + (r * x).matmul(uh)).tanh()
    # h' = (1-z)*x + z*hh  ==  x + z*(hh - x)
    return x + z * (hh - x)


def layer_rgcn(tr: GnnTrace, g: GraphRef, x: TT, out_dim: int, *,
               etype: TT, prefix: str = "", n_types: int = 3) -> TT:
    """R-GCN with 3 randomly-assigned edge types (paper §8.1): per-edge
    type-selected weights — an index-guided BMM that canNOT be hoisted."""
    wr = tr.param(prefix + "W_rel", (n_types, x.dim, out_dim))
    w0 = tr.param(prefix + "W_self", (x.dim, out_dim))
    xs = g.scatter_src(x)
    m = xs.bmm_edge(wr, etype)
    h = g.gather_sum(m)
    return (h + x.matmul(w0)).relu()


def layer_gin(tr: GnnTrace, g: GraphRef, x: TT, out_dim: int, *,
              prefix: str = "") -> TT:
    """GIN (Xu et al.): h' = MLP((1+eps)·x + sum_j x_j) — beyond the paper's
    five models, exercising the generality claim (sum-agg + vertex MLP)."""
    in_dim = x.dim
    w1 = tr.param(prefix + "W1", (in_dim, out_dim))
    b1 = tr.param(prefix + "b1", (out_dim,))
    w2 = tr.param(prefix + "W2", (out_dim, out_dim))
    eps = tr.param(prefix + "eps_gain", (in_dim, in_dim))  # (1+eps)·x as a learned diag-ish map
    agg = g.gather_sum(g.scatter_src(x))
    h = agg + x.matmul(eps)
    return h.matmul(w1).bias_add(b1).relu().matmul(w2)


# ---------------------------------------------------------------------------
# single-layer builders (classic form; same traces as before the refactor)
# ---------------------------------------------------------------------------

def build_gcn(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED):
    x = tr.input_vertex(in_dim, "x")
    dn = tr.input_vertex(1, "dnorm")  # (V,1): 1/sqrt(max(deg,1))
    tr.mark_output(layer_gcn(tr, g, x, out_dim, dnorm=dn))


def build_gat(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED,
              naive: bool = False):
    x = tr.input_vertex(in_dim, "x")
    tr.mark_output(layer_gat(tr, g, x, out_dim, naive=naive))


def build_gat_naive(tr, g, in_dim: int = EMBED, out_dim: int = EMBED):
    return build_gat(tr, g, in_dim, out_dim, naive=True)


def build_sage(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED,
               naive: bool = False):
    x = tr.input_vertex(in_dim, "x")
    tr.mark_output(layer_sage(tr, g, x, out_dim, naive=naive))


def build_sage_naive(tr, g, in_dim: int = EMBED, out_dim: int = EMBED):
    return build_sage(tr, g, in_dim, out_dim, naive=True)


def build_ggnn(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: Optional[int] = None):
    x = tr.input_vertex(in_dim, "x")
    tr.mark_output(layer_ggnn(tr, g, x, out_dim))


def build_rgcn(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED,
               n_types: int = 3):
    x = tr.input_vertex(in_dim, "x")
    et = tr.input_edge(1, "etype")
    tr.mark_output(layer_rgcn(tr, g, x, out_dim, etype=et, n_types=n_types))


def build_gin(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED):
    x = tr.input_vertex(in_dim, "x")
    tr.mark_output(layer_gin(tr, g, x, out_dim))


@dataclasses.dataclass
class ModelSpec:
    name: str
    build: Callable
    layer: Optional[Callable] = None     # stackable layer fn (None: 1-layer only)
    needs_etype: bool = False
    needs_dnorm: bool = False
    n_edge_types: int = 3
    #: extra kwargs the stacked variant passes to ``layer`` (e.g. GCN's
    #: per-edge normalization, whose structure-only scatters repeat per layer)
    stacked_kw: Dict = dataclasses.field(default_factory=dict)


MODELS: Dict[str, ModelSpec] = {
    "gcn": ModelSpec("gcn", build_gcn, layer_gcn, needs_dnorm=True,
                     stacked_kw={"edge_norm": True}),
    "gat": ModelSpec("gat", build_gat, layer_gat),
    "gat_naive": ModelSpec("gat_naive", build_gat_naive, None),
    "sage": ModelSpec("sage", build_sage, layer_sage),
    "sage_naive": ModelSpec("sage_naive", build_sage_naive, None),
    "ggnn": ModelSpec("ggnn", build_ggnn, layer_ggnn),
    "rgcn": ModelSpec("rgcn", build_rgcn, layer_rgcn, needs_etype=True),
    "gin": ModelSpec("gin", build_gin, layer_gin),
}

PAPER_MODELS = ("gcn", "gat", "sage", "ggnn", "rgcn")


def trace_named(name: str, in_dim: int = EMBED, out_dim: int = EMBED) -> GnnTrace:
    spec = MODELS[name]
    return trace_model(lambda tr, g: spec.build(tr, g, in_dim, out_dim), name=name)


# ---------------------------------------------------------------------------
# stacked (multi-layer) variants — the paper's §8.1 evaluation models
# ---------------------------------------------------------------------------

def build_stacked(name: str, n_layers: int, in_dim: int = EMBED,
                  hidden_dim: int = EMBED, out_dim: int = EMBED) -> List[Callable]:
    """Per-layer builders for a stacked ``name`` model, consumable by
    :func:`~repro.core.trace.trace_model`.

    Layer ``l`` receives layer ``l-1``'s output tensor; parameters get an
    ``l{l}.`` prefix (per-layer weights); structure-only inputs (``dnorm``,
    ``etype``) are declared by the first layer and shared by all of them.
    """
    spec = MODELS[name]
    if spec.layer is None:
        raise ValueError(f"model {name!r} has no stackable layer function")
    if n_layers < 1:
        raise ValueError("n_layers must be >= 1")
    shared: Dict[int, Dict[str, TT]] = {}  # per-trace shared structure inputs

    def make(layer_idx: int) -> Callable:
        def build(tr: GnnTrace, g: GraphRef, x: Optional[TT]) -> TT:
            if layer_idx == 0:
                shared.clear()   # only the trace being built is ever needed
            if x is None:
                x = tr.input_vertex(in_dim, "x")
            sh = shared.setdefault(id(tr), {})
            if spec.needs_dnorm and "dnorm" not in sh:
                sh["dnorm"] = tr.input_vertex(1, "dnorm")
            if spec.needs_etype and "etype" not in sh:
                sh["etype"] = tr.input_edge(1, "etype")
            d_out = out_dim if layer_idx == n_layers - 1 else hidden_dim
            return spec.layer(tr, g, x, d_out, prefix=f"l{layer_idx}.",
                              **sh, **spec.stacked_kw)
        return build

    return [make(layer) for layer in range(n_layers)]


def trace_stacked(name: str, n_layers: int, in_dim: int = EMBED,
                  hidden_dim: int = EMBED, out_dim: int = EMBED) -> GnnTrace:
    """Trace an ``n_layers``-deep stack of ``name`` layers (one program)."""
    return trace_model(
        build_stacked(name, n_layers, in_dim, hidden_dim, out_dim),
        name=f"{name}_x{n_layers}")


# ---------------------------------------------------------------------------
# parameter / input initialization
# ---------------------------------------------------------------------------

def init_params(tr: GnnTrace, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in tr.params.items():
        fan_in = shape[0] if len(shape) > 1 else 1
        params[name] = (rng.standard_normal(shape) / np.sqrt(max(fan_in, 1))).astype(np.float32)
    return params


def init_inputs(tr: GnnTrace, graph: Graph, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed + 1)
    inputs: Dict[str, np.ndarray] = {}
    for n in tr.nodes:
        if n.op != "input":
            continue
        name = n.attrs["name"]
        if name == "dnorm":
            deg = graph.in_degrees().astype(np.float32)
            inputs[name] = (1.0 / np.sqrt(np.maximum(deg, 1.0)))[:, None]
        elif name == "etype":
            assert graph.edge_type is not None, "graph has no edge types"
            inputs[name] = graph.edge_type[:, None].astype(np.float32)
        elif n.space == "V":
            inputs[name] = rng.standard_normal((graph.n_vertices, n.dim)).astype(np.float32)
        else:
            inputs[name] = rng.standard_normal((graph.n_edges, n.dim)).astype(np.float32)
    return inputs
