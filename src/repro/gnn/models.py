"""The paper's five benchmark GNN models (§8.1), written against the classic
whole-graph programming model.

GCN, GAT (1 head, as in the paper), GraphSAGE (maxpool aggregator), GGNN
(GRU update), R-GCN (3 edge types, as in the paper).  For GAT and SAGE we
also provide the *naive* variants the paper uses to evaluate the compiler's
E2V optimization (Fig 12): per-edge ops that a library author would normally
hand-hoist are left on the edges, and the compiler must hoist them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..core.trace import GnnTrace, GraphRef, trace_model
from .graphs import Graph

EMBED = 128  # the paper's input/output embedding size for all experiments


# ---------------------------------------------------------------------------
# model builders (trace-time)
# ---------------------------------------------------------------------------

def build_gcn(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED):
    """GCN layer: relu(D^-1/2 A D^-1/2 X W)  — norm via precomputed dnorm."""
    x = tr.input_vertex(in_dim, "x")
    dn = tr.input_vertex(1, "dnorm")  # (V,1): 1/sqrt(max(deg,1))
    w = tr.param("W", (in_dim, out_dim))
    h = (x * dn).matmul(w)
    m = g.scatter_src(h)
    agg = g.gather_sum(m)
    tr.mark_output((agg * dn).relu())


def build_gat(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED,
              naive: bool = False):
    """GAT layer, single head (paper §8.1). ``naive=True`` leaves the two
    attention mat-vecs on the edges — the compiler's E2V pass must hoist them
    (paper Fig 8b / Fig 12)."""
    x = tr.input_vertex(in_dim, "x")
    w = tr.param("W", (in_dim, out_dim))
    a1 = tr.param("a_src", (out_dim, 1))
    a2 = tr.param("a_dst", (out_dim, 1))
    h = x.matmul(w)
    if naive:
        hs = g.scatter_src(h)
        hd = g.scatter_dst(h)
        e = (hs.gemv(a1) + hd.gemv(a2)).leaky_relu()
    else:
        es = g.scatter_src(h.gemv(a1))
        ed = g.scatter_dst(h.gemv(a2))
        e = (es + ed).leaky_relu()
    alpha = g.edge_softmax(e)
    m = g.scatter_src(h) * alpha
    tr.mark_output(g.gather_sum(m))


def build_gat_naive(tr, g, in_dim: int = EMBED, out_dim: int = EMBED):
    return build_gat(tr, g, in_dim, out_dim, naive=True)


def build_sage(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED,
               naive: bool = False):
    """GraphSAGE-maxpool: h_N = max_j relu(W_p x_j + b); out = relu(W1 x + W2 h_N)."""
    x = tr.input_vertex(in_dim, "x")
    wp = tr.param("W_pool", (in_dim, out_dim))
    bp = tr.param("b_pool", (out_dim,))
    w1 = tr.param("W_self", (in_dim, out_dim))
    w2 = tr.param("W_neigh", (out_dim, out_dim))
    if naive:
        # pooling MLP applied per edge (redundant): E2V must hoist it
        xs = g.scatter_src(x)
        pe = xs.matmul(wp).bias_add(bp).relu()
    else:
        pv = x.matmul(wp).bias_add(bp).relu()
        pe = g.scatter_src(pv)
    hn = g.gather_max(pe)
    tr.mark_output((x.matmul(w1) + hn.matmul(w2)).relu())


def build_sage_naive(tr, g, in_dim: int = EMBED, out_dim: int = EMBED):
    return build_sage(tr, g, in_dim, out_dim, naive=True)


def build_ggnn(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: Optional[int] = None):
    """GGNN: a = A(X W_msg); h' = GRU(a, x) — GRU from separate ELW+GEMM ops
    (the paper implements the GRU with separate instructions on ZIPPER)."""
    d = in_dim
    x = tr.input_vertex(d, "x")
    wm = tr.param("W_msg", (d, d))
    wz, uz = tr.param("W_z", (d, d)), tr.param("U_z", (d, d))
    wr, ur = tr.param("W_r", (d, d)), tr.param("U_r", (d, d))
    wh, uh = tr.param("W_h", (d, d)), tr.param("U_h", (d, d))
    a = g.gather_sum(g.scatter_src(x.matmul(wm)))
    z = (a.matmul(wz) + x.matmul(uz)).sigmoid()
    r = (a.matmul(wr) + x.matmul(ur)).sigmoid()
    hh = (a.matmul(wh) + (r * x).matmul(uh)).tanh()
    # h' = (1-z)*x + z*hh  ==  x + z*(hh - x)
    tr.mark_output(x + z * (hh - x))


def build_rgcn(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED,
               n_types: int = 3):
    """R-GCN with 3 randomly-assigned edge types (paper §8.1): per-edge
    type-selected weights — an index-guided BMM that canNOT be hoisted."""
    x = tr.input_vertex(in_dim, "x")
    et = tr.input_edge(1, "etype")
    wr = tr.param("W_rel", (n_types, in_dim, out_dim))
    w0 = tr.param("W_self", (in_dim, out_dim))
    xs = g.scatter_src(x)
    m = xs.bmm_edge(wr, et)
    h = g.gather_sum(m)
    tr.mark_output((h + x.matmul(w0)).relu())


def build_gin(tr: GnnTrace, g: GraphRef, in_dim: int = EMBED, out_dim: int = EMBED):
    """GIN (Xu et al.): h' = MLP((1+eps)·x + sum_j x_j) — beyond the paper's
    five models, exercising the generality claim (sum-agg + vertex MLP)."""
    x = tr.input_vertex(in_dim, "x")
    w1 = tr.param("W1", (in_dim, out_dim))
    b1 = tr.param("b1", (out_dim,))
    w2 = tr.param("W2", (out_dim, out_dim))
    eps = tr.param("eps_gain", (in_dim, in_dim))  # (1+eps)·x as a learned diag-ish map
    agg = g.gather_sum(g.scatter_src(x))
    h = agg + x.matmul(eps)
    tr.mark_output(h.matmul(w1).bias_add(b1).relu().matmul(w2))


@dataclasses.dataclass
class ModelSpec:
    name: str
    build: Callable
    needs_etype: bool = False
    needs_dnorm: bool = False
    n_edge_types: int = 3


MODELS: Dict[str, ModelSpec] = {
    "gcn": ModelSpec("gcn", build_gcn, needs_dnorm=True),
    "gat": ModelSpec("gat", build_gat),
    "gat_naive": ModelSpec("gat_naive", build_gat_naive),
    "sage": ModelSpec("sage", build_sage),
    "sage_naive": ModelSpec("sage_naive", build_sage_naive),
    "ggnn": ModelSpec("ggnn", build_ggnn),
    "rgcn": ModelSpec("rgcn", build_rgcn, needs_etype=True),
    "gin": ModelSpec("gin", build_gin),
}

PAPER_MODELS = ("gcn", "gat", "sage", "ggnn", "rgcn")


def trace_named(name: str, in_dim: int = EMBED, out_dim: int = EMBED) -> GnnTrace:
    spec = MODELS[name]
    return trace_model(lambda tr, g: spec.build(tr, g, in_dim, out_dim), name=name)


# ---------------------------------------------------------------------------
# parameter / input initialization
# ---------------------------------------------------------------------------

def init_params(tr: GnnTrace, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in tr.params.items():
        fan_in = shape[0] if len(shape) > 1 else 1
        params[name] = (rng.standard_normal(shape) / np.sqrt(max(fan_in, 1))).astype(np.float32)
    return params


def init_inputs(tr: GnnTrace, graph: Graph, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed + 1)
    inputs: Dict[str, np.ndarray] = {}
    for n in tr.nodes:
        if n.op != "input":
            continue
        name = n.attrs["name"]
        if name == "dnorm":
            deg = graph.in_degrees().astype(np.float32)
            inputs[name] = (1.0 / np.sqrt(np.maximum(deg, 1.0)))[:, None]
        elif name == "etype":
            assert graph.edge_type is not None, "graph has no edge types"
            inputs[name] = graph.edge_type[:, None].astype(np.float32)
        elif n.space == "V":
            inputs[name] = rng.standard_normal((graph.n_vertices, n.dim)).astype(np.float32)
        else:
            inputs[name] = rng.standard_normal((graph.n_edges, n.dim)).astype(np.float32)
    return inputs
