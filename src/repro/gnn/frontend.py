"""The *classic GNN programming model* frontend (paper Figure 5).

Thin re-export of the whole-graph tracer — model authors write against
``TT`` tensors and ``GraphRef`` GOPs exactly as they would against DGL/PyG
whole-graph tensors; the ZIPPER compiler recovers graph semantics from the
recorded trace.
"""
from ..core.trace import GnnTrace, GraphRef, TT, trace_model  # noqa: F401
