"""DeepSeek-V2 236B — MLA (kv_lora=512), 2 shared + 160 routed top-6,
1 leading dense layer. [arXiv:2405.04434; hf]"""
from .base import ArchConfig, MLACfg, MoECfg, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    mla=MLACfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoECfg(n_routed=160, n_shared=2, top_k=6, d_ff_expert=1536,
               d_ff_dense=12288, first_dense=1, norm_topk=False),
    rope_theta=1e4,
    source="arXiv:2405.04434",
))
