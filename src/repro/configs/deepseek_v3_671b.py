"""DeepSeek-V3 671B — MLA attention, 1 shared + 256 routed experts top-8,
aux-loss-free balancing bias, 3 leading dense layers.  (MTP head omitted —
noted in DESIGN.md.) [arXiv:2412.19437; hf]"""
from .base import ArchConfig, MLACfg, MoECfg, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280,
    mla=MLACfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoECfg(n_routed=256, n_shared=1, top_k=8, d_ff_expert=2048,
               d_ff_dense=18432, first_dense=3, norm_topk=True,
               aux_free_bias=True),
    rope_theta=1e4,
    source="arXiv:2412.19437",
))
