"""xLSTM-1.3B — sLSTM + mLSTM blocks (7:1 mix), no separate FFN (d_ff=0:
the blocks carry their own up/down projections). [arXiv:2405.04517; unverified]"""
from .base import ArchConfig, XLSTMCfg, register

CONFIG = register(ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    xlstm=XLSTMCfg(proj_factor=2.0, conv_width=4, slstm_every=8, chunk=128),
    source="arXiv:2405.04517",
))
