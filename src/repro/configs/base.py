"""Architecture configuration schema + registry.

One ``configs/<arch_id>.py`` per assigned architecture instantiates an
:class:`ArchConfig`.  ``reduced()`` derives the CPU smoke-test config of the
same family (small widths / few layers / few experts) — the full config is
exercised only through the dry-run (ShapeDtypeStructs, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_routed: int
    n_shared: int
    top_k: int
    d_ff_expert: int
    d_ff_dense: int          # dense-FFN width for the first_dense leading layers
    first_dense: int = 0     # leading dense layers (DeepSeek)
    norm_topk: bool = True
    aux_free_bias: bool = False   # DeepSeek-V3 aux-loss-free balancing
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int
    kv_lora: int
    qk_nope: int
    qk_rope: int
    v_dim: int


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    proj_factor: float = 2.0
    conv_width: int = 4
    slstm_every: int = 8      # every k-th layer is an sLSTM block
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    parallel_block: bool = False     # command-r style parallel attn+FFN
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    # hybrid (zamba2): one shared attn+MLP block applied every k layers
    shared_attn_every: int = 0
    # enc-dec (whisper): encoder layer count; frontend is a stub that feeds
    # precomputed frame embeddings of length enc_len
    encdec: bool = False
    n_encoder_layers: int = 0
    enc_len: int = 1500
    # long-context decode: sliding window for attention blocks (hybrids);
    # None => full attention (arch is then skipped for long_500k)
    attn_window: Optional[int] = None
    dtype: str = "bfloat16"
    # citation / provenance tag
    source: str = ""

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-window)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm",):
            # xlstm: per-block ~ (proj in/out + qkv)   rough model
            pf = self.xlstm.proj_factor if self.xlstm else 2.0
            blk = int(d * d * pf * 2 + 3 * (d * pf) * (d * pf) / 4)
            return emb + L * blk
        if self.family == "hybrid" and self.ssm:
            di = self.ssm.expand * d
            blk = d * 2 * di + di * d + di * 16  # in/out proj + misc
            shared = 4 * d * d + 3 * d * self.d_ff
            return emb + L * blk + shared
        attn = 2 * d * (self.n_heads * self.hdim) + 2 * d * (self.n_kv_heads * self.hdim)
        if self.mla:
            m = self.mla
            attn = (d * m.q_lora + m.q_lora * self.n_heads * (m.qk_nope + m.qk_rope)
                    + d * (m.kv_lora + m.qk_rope)
                    + m.kv_lora * self.n_heads * (m.qk_nope + m.v_dim)
                    + self.n_heads * m.v_dim * d)
        if self.moe:
            mo = self.moe
            n_moe_layers = L - mo.first_dense
            ffn = 3 * d * mo.d_ff_expert * (mo.n_routed + mo.n_shared)
            dense_ffn = 3 * d * mo.d_ff_dense
            total = emb + L * attn + n_moe_layers * (ffn + d * mo.n_routed) \
                + mo.first_dense * dense_ffn
            return int(total)
        enc_mult = 2 if self.encdec else 1  # decoder adds cross-attn
        layers = L + self.n_encoder_layers
        return int(emb + layers * (attn * (1.5 if self.encdec else 1.0) + 3 * d * self.d_ff))

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only routed top-k)."""
        if not self.moe:
            return self.param_count()
        d, L, mo = self.d_model, self.n_layers, self.moe
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        m = self.mla
        attn = (d * m.q_lora + m.q_lora * self.n_heads * (m.qk_nope + m.qk_rope)
                + d * (m.kv_lora + m.qk_rope)
                + m.kv_lora * self.n_heads * (m.qk_nope + m.v_dim)
                + self.n_heads * m.v_dim * d) if m else \
            (2 * d * self.n_heads * self.hdim + 2 * d * self.n_kv_heads * self.hdim)
        ffn_act = 3 * d * mo.d_ff_expert * (mo.top_k + mo.n_shared)
        return int(emb + L * attn + (L - mo.first_dense) * (ffn_act + d * mo.n_routed)
                   + mo.first_dense * 3 * d * mo.d_ff_dense)


#: the four assigned input-shape cells (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        from . import ALL_ARCHS  # noqa: F401  (forces registration)
    return _REGISTRY[name]


def all_configs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        from . import ALL_ARCHS  # noqa: F401
    return dict(_REGISTRY)


def shape_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch × shape) cell."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention — long_500k requires sub-quadratic (DESIGN.md §5)"
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke", family=cfg.family,
        n_layers=min(cfg.n_layers, 4) if cfg.shared_attn_every or (cfg.xlstm is not None) else 2,
        d_model=64,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0, vocab=256, head_dim=16,
        qk_norm=cfg.qk_norm, qkv_bias=cfg.qkv_bias, parallel_block=cfg.parallel_block,
        mrope_sections=(4, 6, 6) if cfg.mrope_sections else None,
        rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
        tie_embeddings=cfg.tie_embeddings,
        encdec=cfg.encdec, n_encoder_layers=2 if cfg.encdec else 0,
        enc_len=16 if cfg.encdec else 1500,
        attn_window=min(cfg.attn_window, 32) if cfg.attn_window else None,
        dtype="float32", source=cfg.source,
    )
    if cfg.moe:
        kw["moe"] = MoECfg(n_routed=8, n_shared=cfg.moe.n_shared, top_k=2,
                           d_ff_expert=32, d_ff_dense=96,
                           first_dense=min(cfg.moe.first_dense, 1),
                           norm_topk=cfg.moe.norm_topk,
                           aux_free_bias=cfg.moe.aux_free_bias)
        kw["n_layers"] = 3 if cfg.moe.first_dense else 2
    if cfg.mla:
        kw["mla"] = MLACfg(q_lora=32, kv_lora=32, qk_nope=16, qk_rope=8, v_dim=16)
        kw["head_dim"] = None
    if cfg.ssm:
        kw["ssm"] = SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16,
                           n_groups=1, chunk=16)
    if cfg.xlstm:
        kw["xlstm"] = XLSTMCfg(proj_factor=2.0, conv_width=4, slstm_every=2, chunk=16)
        kw["n_layers"] = 4
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 2
        kw["n_layers"] = 4
    return ArchConfig(**kw)
