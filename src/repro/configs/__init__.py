"""Architecture config registry: one module per assigned architecture."""
from . import base
from .base import ArchConfig, SHAPES, all_configs, get_config, reduced, shape_applicable

from . import (  # noqa: F401  — importing registers each config
    qwen2_vl_72b, smollm_135m, command_r_35b, qwen3_32b, qwen2_1_5b,
    deepseek_v3_671b, deepseek_v2_236b, whisper_large_v3, xlstm_1_3b,
    zamba2_2_7b,
)

ALL_ARCHS = tuple(sorted(base._REGISTRY))
