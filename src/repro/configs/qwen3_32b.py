"""Qwen3-32B — GQA with per-head q/k RMSNorm. [hf:Qwen/Qwen3-32B (family per
Qwen/Qwen3-8B card); hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B family",
))
