"""Whisper-large-v3 — encoder-decoder; the conv/mel frontend is a STUB
(input_specs provides precomputed frame embeddings, enc_len=1500).
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    encdec=True, n_encoder_layers=32, enc_len=1500,
    rope_theta=1e4,  # unused: whisper uses sinusoidal absolute positions
    source="arXiv:2212.04356",
))
