"""Qwen2-VL-72B — VLM; transformer backbone only (patch-embed frontend is a
stub per spec: input_specs feeds precomputed patch/frame embeddings for the
vision pathway; the LM path tokenizes normally).  M-RoPE sections per the
tech report. [arXiv:2409.12191; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, head_dim=128,
    qkv_bias=True,                      # qwen2 family uses QKV bias
    mrope_sections=(16, 24, 24),        # M-RoPE (t, h, w) sections
    rope_theta=1e6, tie_embeddings=False,
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B-Instruct",
))
