"""Command-R 35B — GQA, no biases, parallel attention+FFN block.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000, head_dim=128,
    parallel_block=True, rope_theta=8e6, tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
))
