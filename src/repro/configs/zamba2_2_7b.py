"""Zamba2-2.7B — Mamba2 backbone + a shared attention+MLP block applied every
6 layers (weights shared across applications).  The shared attention uses a
sliding window at long context (deviation noted in DESIGN.md §5), making the
arch sub-quadratic and long_500k-eligible. [arXiv:2411.15242; hf]"""
from .base import ArchConfig, SSMCfg, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
    shared_attn_every=6, attn_window=4096,
    source="arXiv:2411.15242",
))
