"""Gradient compression for cross-pod (DCN-bound) data parallelism.

int8 block-quantized all-reduce with error feedback: per-row fp32 scales,
residuals carried to the next step so quantization error does not bias the
expectation.  Inside a pod the ICI is fast enough for fp32/bf16 reductions;
across pods (the 'pod' axis of the multi-pod mesh) gradient bytes shrink 4×.

Used by ``launch/train.py --compress-pod-grads`` via a shard_map over the
'pod' axis; the pure functions below are unit-tested on their own.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = Any


def quantize_grads(tree, residuals=None):
    """tree of fp grads -> (int8 tree, scale tree, new residual tree)."""
    leaves, treedef = jax.tree.flatten(tree)
    res = jax.tree.leaves(residuals) if residuals is not None else [None] * len(leaves)
    qs, scales, new_res = [], [], []
    for g, r in zip(leaves, res):
        g32 = g.astype(jnp.float32)
        if r is not None:
            g32 = g32 + r
        flat = g32.reshape(-1)
        amax = jnp.max(jnp.abs(flat))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_res.append((flat - deq).reshape(g.shape))          # error feedback
        qs.append(q.reshape(g.shape))
        scales.append(scale)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, new_res))


def dequantize_grads(q_tree, scale_tree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)


def compressed_psum(tree, axis_name: str, residuals=None):
    """Error-feedback int8 psum over ``axis_name`` (inside shard_map).

    Quantize locally -> sum int8 payloads in fp32 (wire format int8; the
    reduction itself upcasts, as a real DCN allreduce would accumulate in
    higher precision) -> divide by world size.  Returns (mean_grads,
    residuals) — carry residuals into the next step.
    """
    q, s, new_res = quantize_grads(tree, residuals)
    n = jax.lax.psum(1, axis_name)

    def _reduce(qi, si):
        contrib = qi.astype(jnp.float32) * si
        return jax.lax.psum(contrib, axis_name) / n

    return jax.tree.map(_reduce, q, s), new_res
