from .fault import FailureDetector, ElasticPlan, plan_remesh  # noqa: F401
from .compression import quantize_grads, dequantize_grads, compressed_psum  # noqa: F401
