"""Fault tolerance and elastic scaling logic.

At 1000+ nodes the relevant machinery is:

* **failure detection** — heartbeat registry with a timeout; on a real
  cluster heartbeats arrive over the control plane, here they are injected
  by tests (the *logic* — who is declared dead, when — is what we own);
* **elastic re-mesh** — given the surviving host set, compute the largest
  usable (data × model) mesh, a deterministic host→coordinate assignment,
  and the checkpoint-resharding plan.  Restore runs through
  ``checkpointing.restore_checkpoint`` with the new mesh's shardings: the
  checkpoint stores full logical arrays, so *any* smaller mesh can resume;
* **straggler mitigation** — the data pipeline is a pure function of
  (seed, step, shard), so re-assigning a straggler's shard to a spare is a
  table update (``reassign_shards``), not a data migration.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class FailureDetector:
    """Heartbeat timeout detector (control-plane logic)."""

    timeout_s: float = 30.0
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)

    def heartbeat(self, host: int, now: Optional[float] = None):
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items() if now - t > self.timeout_s)

    def alive_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items() if now - t <= self.timeout_s)


@dataclasses.dataclass
class ElasticPlan:
    data: int                      # new data-axis size
    model: int                     # new model-axis size (kept fixed: TP is
                                   # topology-bound inside a host/板)
    host_of_coord: Dict[Tuple[int, int], int]
    dropped_hosts: List[int]
    note: str = ""

    @property
    def n_devices(self) -> int:
        return self.data * self.model


def plan_remesh(alive: Sequence[int], *, devices_per_host: int = 4,
                model: int = 16) -> ElasticPlan:
    """Largest (data × model) mesh the survivors can form.

    The model axis is preserved (TP segments must stay within their ICI
    domain); the data axis shrinks to the largest multiple the surviving
    device count supports.  Host→coordinate assignment is deterministic in
    the sorted survivor order, so every host derives the same plan without
    coordination.
    """
    alive = sorted(alive)
    total = len(alive) * devices_per_host
    if total < model:
        raise RuntimeError(f"not enough devices ({total}) for model={model}")
    data = total // model
    # deterministic snake assignment of hosts to mesh rows
    host_of_coord: Dict[Tuple[int, int], int] = {}
    flat = 0
    for d in range(data):
        for m in range(model):
            host_of_coord[(d, m)] = alive[(flat // devices_per_host) % len(alive)]
            flat += 1
    return ElasticPlan(data=data, model=model, host_of_coord=host_of_coord,
                       dropped_hosts=[],
                       note=f"{len(alive)} hosts -> mesh ({data},{model})")


def reassign_shards(step: int, n_shards: int, alive: Sequence[int],
                    stragglers: Sequence[int] = ()) -> Dict[int, int]:
    """shard -> host map; stragglers' shards move to the fastest survivors.

    Deterministic in (step, survivor set): every host computes the same map.
    """
    workers = [h for h in sorted(alive) if h not in set(stragglers)]
    if not workers:
        raise RuntimeError("no healthy workers")
    return {s: workers[(s + step) % len(workers)] for s in range(n_shards)}
