"""Deterministic sharded token pipeline.

No external datasets ship with this container, so the pipeline synthesizes a
reproducible token stream (hash-mixed counter — NOT jax PRNG, so batches are
computable on any host without device state).  What matters for the
framework is the *contract*:

* the global batch for step ``s`` is a pure function of ``(seed, s)`` — any
  host can regenerate any shard, which is what makes restart/elastic
  reshard and straggler re-assignment trivial (DESIGN.md §6);
* ``shard_for(step, host, n_hosts)`` returns the host's slice;
* ``make_batch_specs`` produces the ShapeDtypeStructs the dry-run lowers
  against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, SHAPES
from ..models.common import DP, resolve_spec, sanitize_spec
from ..models.lm import VLM_PATCHES


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64 — stateless hash of a counter array."""
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass
class TokenPipeline:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.global_batch, self.seq_len
        base = np.uint64(self.seed) * np.uint64(1 << 40) + np.uint64(step) * np.uint64(B * S)
        ctr = base + np.arange(B * S, dtype=np.uint64)
        toks = (_mix(ctr) % np.uint64(self.cfg.vocab)).astype(np.int32).reshape(B, S)
        out = {"tokens": toks}
        if self.cfg.family == "vlm":
            emb = (_mix(ctr[: B * VLM_PATCHES * 4]).astype(np.float32) / 2**64 - 0.5)
            out["tokens"] = toks[:, : S - VLM_PATCHES]
            out["patch_embeds"] = np.resize(
                emb, (B, VLM_PATCHES, self.cfg.d_model)).astype(np.float32)
        if self.cfg.family == "audio":
            fr = (_mix(ctr[: B * 16]).astype(np.float32) / 2**64 - 0.5)
            out["frames"] = np.resize(
                fr, (B, self.cfg.enc_len, self.cfg.d_model)).astype(np.float32)
        return out

    def shard_for(self, step: int, host: int, n_hosts: int) -> Dict[str, np.ndarray]:
        gb = self.global_batch_at(step)
        per = self.global_batch // n_hosts
        return {k: v[host * per:(host + 1) * per] for k, v in gb.items()}


def make_batch_specs(cfg: ArchConfig, shape_name: str, mesh,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs (with shardings) for one (arch × shape) cell."""
    S, B, kind = SHAPES[shape_name]

    def sds(shape, dt, spec):
        s = sanitize_spec(resolve_spec(spec, mesh), shape, mesh)
        return jax.ShapeDtypeStruct(shape, dt, sharding=NamedSharding(mesh, s))

    if kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32, (DP, None))}
    S_tok = S - VLM_PATCHES if cfg.family == "vlm" else S
    specs = {"tokens": sds((B, S_tok), jnp.int32, (DP, None))}
    if cfg.family == "vlm":
        specs["patch_embeds"] = sds((B, VLM_PATCHES, cfg.d_model), dtype, (DP, None, None))
    if cfg.family == "audio":
        specs["frames"] = sds((B, cfg.enc_len, cfg.d_model), dtype, (DP, None, None))
    return specs
