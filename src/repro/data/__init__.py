from .pipeline import TokenPipeline, make_batch_specs  # noqa: F401
