"""Static analysis CLI (ISSUE 6).

Runs the compiler verifier stack — IR dataflow checks, schedule legality,
kernel-dispatch lints, the stream-task race detector, and the static
exchange census — over the paper-model matrix without executing anything.

Usage:
  PYTHONPATH=src python -m repro.analyze                       # 5 models x {1,2,3} layers
  PYTHONPATH=src python -m repro.analyze --models gcn,gat --layers 2
  PYTHONPATH=src python -m repro.analyze --all --fail-on error # CI gate (+ task graphs)
  PYTHONPATH=src python -m repro.analyze --json report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

from .core import analysis as A
from .core import compiler, isa, tiling
from .core.streams import HWConfig, build_task_graph
from .gnn import graphs, models

#: deterministic tile-set substrate for the task-graph analyses (--all)
_GRAPH_SPEC = dict(n_vertices=150, n_edges=600, seed=3, model="powerlaw",
                   n_edge_types=3)


def _cell_name(name: str, n_layers: int) -> str:
    return f"{name} x{n_layers}"


def analyze_matrix(names: List[str], layer_counts: List[int], dim: int,
                   with_task_graphs: bool) -> Dict[str, List[A.Diagnostic]]:
    """Every analysis pass over every (model, layers) cell; returns
    cell title -> diagnostics (compile failures become ZA-coded errors
    via the raised VerificationError's own diagnostics)."""
    report: Dict[str, List[A.Diagnostic]] = {}
    g = graphs.random_graph(**_GRAPH_SPEC) if with_task_graphs else None
    for name in names:
        for n_layers in layer_counts:
            tr = models.trace_stacked(name, n_layers, dim, dim, dim)
            # verify=False: the CLI reports findings instead of raising
            c = compiler.compile_gnn(tr, verify=False)
            diags = A.verify_ir(c.ir)
            for dispatch in (True, False):
                sp = c.schedule(kernel_dispatch=dispatch)
                diags += A.verify_schedule(sp)
                # ShardedRunner executes either schedule variant, so the
                # exchange census must hold for both: exactly n_layers
                # gather-tainted collectives, kernels on or off
                diags += A.verify_exchange(sp)
            if with_task_graphs:
                ts = tiling.grid_tile(g, 4, 4, sparse=True)
                sde = isa.emit_sde(c.schedule(True))
                hw = HWConfig()
                for mode in ("barrier", "pipelined"):
                    tasks, _ = build_task_graph(sde, ts, hw, inter_layer=mode)
                    diags += A.analyze_task_graph(tasks, sde=sde, tiles=ts,
                                                  inter_layer=mode)
                # per-chip view: boundary reads outside the chip's
                # partitions must surface as cross-chip (ZH206), not races
                tasks, _ = build_task_graph(sde, ts, hw,
                                            inter_layer="pipelined",
                                            parts=[0, 1])
                diags += A.analyze_task_graph(tasks, sde=sde, tiles=ts,
                                              inter_layer="pipelined",
                                              parts=[0, 1])
            report[_cell_name(name, n_layers)] = diags
    return report


def render_codes_doc() -> str:
    """``docs/DIAGNOSTICS.md``, generated from the ``analysis.CODES``
    registry so the doc can never drift from the code (a test pins the file
    to this function's output; regenerate with ``--write-codes-doc``)."""
    families = (
        ("ZA", "IR verifier (`verify_ir`)",
         "Structural checks over the optimized `IRProgram`: op vocabulary, "
         "def-use, dim re-inference, channel pairing, cycles, layer tags."),
        ("ZS", "Schedule verifier (`verify_schedule`)",
         "Legality of the lowered `ScheduledProgram`: gather ownership, "
         "kernel-tag preconditions re-derived from the IR, cross-phase "
         "dataflow, accumulator specs, missed-kernel lints."),
        ("ZH", "Hazard analyzer & exchange census (`analyze_task_graph`, "
         "`verify_exchange`)",
         "Races and collective structure over stream-task graphs: drain "
         "ordering, barrier coverage, the exactly-one-collective-per-layer "
         "census, gather taint of exchanged values, and the "
         "restricted-exchange coverage proof (every cross-shard source "
         "read in its owner's send set, `recvDst` rows device-local, "
         "send sets owned by their shard)."),
    )
    lines = [
        "# Diagnostics catalog",
        "",
        "Every code the static analysis layer (`src/repro/core/analysis/`) "
        "can emit, with its default severity.  Codes are **append-only** — "
        "tests and downstream tooling key on them, so they are never "
        "renumbered.  See [ARCHITECTURE.md](../ARCHITECTURE.md) for where "
        "each pass runs; `python -m repro.analyze --all` sweeps the full "
        "paper-model matrix.",
        "",
        "This file is generated from `repro.analysis.CODES` by",
        "`python -m repro.analyze --write-codes-doc docs/DIAGNOSTICS.md`;",
        "`tests/test_docs.py` pins it byte-for-byte, so regenerate after "
        "touching the registry.",
    ]
    for prefix, title, blurb in families:
        lines += ["", f"## {prefix}xxx — {title}", "", blurb, "",
                  "| code | severity | meaning |", "| --- | --- | --- |"]
        for code in sorted(c for c in A.CODES if c.startswith(prefix)):
            sev, meaning = A.CODES[code]
            lines.append(f"| `{code}` | {sev} | {meaning} |")
    lines += ["",
              f"Total: {len(A.CODES)} registered codes "
              f"({sum(1 for s, _ in A.CODES.values() if s == 'error')} error, "
              f"{sum(1 for s, _ in A.CODES.values() if s == 'warn')} warn, "
              f"{sum(1 for s, _ in A.CODES.values() if s == 'info')} info).",
              ""]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="Static verification of compiled GNN programs.")
    ap.add_argument("--models", default=",".join(models.PAPER_MODELS),
                    help="comma-separated model names "
                         f"(default: {','.join(models.PAPER_MODELS)})")
    ap.add_argument("--layers", default="1,2,3",
                    help="comma-separated layer counts (default: 1,2,3)")
    ap.add_argument("--dim", type=int, default=16, help="feature dim")
    ap.add_argument("--all", action="store_true",
                    help="also analyze stream-task graphs (barrier, "
                         "pipelined, and a per-chip pipelined view)")
    ap.add_argument("--fail-on", default="error",
                    choices=["error", "warn", "info", "never"],
                    help="exit non-zero if a finding at or above this "
                         "severity exists (default: error)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write all findings to PATH as JSON")
    ap.add_argument("--write-codes-doc", metavar="PATH", default=None,
                    help="write the diagnostics catalog (docs/DIAGNOSTICS.md)"
                         " generated from the CODES registry, then exit")
    args = ap.parse_args(argv)

    if args.write_codes_doc:
        parent = os.path.dirname(args.write_codes_doc)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.write_codes_doc, "w") as f:
            f.write(render_codes_doc())
        print(f"wrote {args.write_codes_doc} ({len(A.CODES)} codes)")
        return 0

    names = [m.strip() for m in args.models.split(",") if m.strip()]
    for m in names:
        if m not in models.MODELS:
            ap.error(f"unknown model {m!r} (have: {sorted(models.MODELS)})")
    layer_counts = [int(x) for x in args.layers.split(",") if x.strip()]

    report = analyze_matrix(names, layer_counts, args.dim, args.all)

    worst_rank = len(A.SEVERITIES)
    for cell, diags in report.items():
        print(A.format_report(diags, title=cell))
        w = A.worst_severity(diags)
        if w is not None:
            worst_rank = min(worst_rank, A.SEVERITIES.index(w))
    n_findings = sum(len(d) for d in report.values())
    n_errors = sum(len(A.errors(d)) for d in report.values())
    print(f"== {len(report)} cell(s), {n_findings} finding(s), "
          f"{n_errors} error(s)")

    if args.json:
        payload = {cell: [d.to_dict() for d in A.sort_diags(diags)]
                   for cell, diags in report.items()}
        parent = os.path.dirname(args.json)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")

    if args.fail_on != "never" and worst_rank <= \
            A.SEVERITIES.index(args.fail_on):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
