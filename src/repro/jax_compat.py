"""Version-portable aliases for JAX APIs that moved between releases.

The repo targets the container's pinned jax (0.4.x) but uses names that
were promoted to the top-level namespace in later releases.  Everything
here resolves the best available implementation at import time so call
sites stay on the modern spelling.

* ``tree_flatten_with_path`` — ``jax.tree.flatten_with_path`` (>= 0.5) vs
  ``jax.tree_util.tree_flatten_with_path`` (all 0.4.x).
* ``shard_map`` — ``jax.shard_map`` with ``check_vma`` (>= 0.6) vs
  ``jax.experimental.shard_map.shard_map`` with ``check_rep``.
"""
from __future__ import annotations

import jax

if hasattr(getattr(jax, "tree", None), "flatten_with_path"):
    tree_flatten_with_path = jax.tree.flatten_with_path
else:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` signature, runnable on 0.4.x.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name); both toggle
    the replication/varying-axes check.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
