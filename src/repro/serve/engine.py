"""`InferenceServer` — the batched multi-graph serving front door.

``submit(graphs, inputs)`` serves a whole request batch through ONE
ScheduledProgram execution per size class:

1. group incoming graphs by :func:`~repro.serve.signature.size_class`;
2. per group, :func:`~repro.gnn.graphs.batch_graphs` merges the members into
   a block-diagonal super-graph, padded (vertices, edge-input rows, tile
   batch) onto the class's registered canonical shapes
   (:class:`~repro.serve.signature.ShapeRegistry`);
3. the structural signature keys the :class:`~repro.serve.cache.ProgramCache`
   — a hit reuses a warm jitted :class:`~repro.core.pipeline.PipelinedRunner`
   via ``run_with`` (rebind tile operands, no retrace, no recompile);
4. merged outputs are sliced back into per-graph arrays.

Request padding is pure overhead the quantization keeps bounded (< 2x rows
worst case); compilation cost is amortized across every request of a class.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core import compiler as C
from ..core import schedule as S
from ..core.pipeline import (PipelinedRunner, ShardedRunner,
                             shard_layout_signature)
from ..gnn import models as M
from ..gnn.graphs import Graph, batch_graphs
from .cache import ProgramCache
from .signature import (ShapeRegistry, quantize, size_class,
                        structure_signature)

Array = np.ndarray


def _pad_rows(arr: Array, rows: int) -> Array:
    arr = np.asarray(arr)
    if arr.shape[0] == rows:
        return arr
    out = np.zeros((rows,) + arr.shape[1:], arr.dtype)
    out[: arr.shape[0]] = arr
    return out


class InferenceServer:
    """Serve streams of small graphs through cached compiled programs.

    ``model`` may be a registered model name (``repro.gnn.models.MODELS``) or
    a pre-compiled :class:`~repro.core.compiler.CompiledGNN`; ``params`` set
    here are the default weights for every request.  ``donate_inputs=None``
    auto-enables XLA buffer donation for the per-request padded arrays on
    accelerator backends (donation is a no-op warning on CPU).

    ``shard_devices=N`` routes *large* size classes — padded vertex count >=
    ``shard_min_vertices`` — through a data-parallel
    :class:`~repro.core.pipeline.ShardedRunner` over an N-device mesh
    (contiguous partition assignment + power-of-two per-shard tile caps, so
    structurally-similar requests share one compiled shape).  The cache key
    then carries the device count, the realized shard layout, and the
    ``kernel_dispatch`` flag: a sharded program can never alias a
    single-device one, a different mesh size, or a scan-scheduled variant.
    Both routes honor ``kernel_dispatch`` — sharded requests run the Pallas
    gather blocks inside ``shard_map`` when it is on.

    ``tune_cache`` (a :class:`~repro.launch.autotune.TuneCache`) routes size
    classes with a tuned entry onto the tuned tile config: the tuned grid,
    vertex reorder, and edge layout replace the
    :func:`~repro.serve.signature.serving_grid` defaults, the canonical
    tile batch is size-bucketed with registry-managed per-bucket caps
    (monotone growth, so bucketed shapes converge instead of flaking at
    power-of-two boundaries), and the tuned shard count caps the mesh
    size.  Tuned and default registrations/cache keys never alias — both
    carry the tuned config key, including its reorder/layout fields.
    """

    def __init__(self, model: Union[str, C.CompiledGNN],
                 params: Optional[Dict[str, Array]] = None, *,
                 n_layers: int = 1, kernel_dispatch: bool = True,
                 cache_capacity: int = 32, target_part: int = 256,
                 donate_inputs: Optional[bool] = None,
                 shard_devices: Optional[int] = None,
                 shard_min_vertices: int = 2048,
                 shard_model_axis: int = 1,
                 tune_cache=None,
                 cache: Optional[ProgramCache] = None,
                 shapes: Optional[ShapeRegistry] = None,
                 cache_owner: Optional[str] = None):
        """Build a server around one compiled model.

        Args:
            model: registered model name or a pre-compiled
                :class:`~repro.core.compiler.CompiledGNN`.
            params: default weights for every request (a request may
                override them).
            n_layers: stack depth when ``model`` is a name; must agree with
                a pre-compiled model's layer count.
            kernel_dispatch: run Pallas gather kernels (else the scan
                schedule).
            cache_capacity: LRU capacity when no shared ``cache`` is given.
            target_part: vertices per destination partition for the
                default serving grid.
            donate_inputs: XLA buffer donation for padded request arrays
                (``None`` auto-enables off-CPU).
            shard_devices: route large classes over an N-device mesh.
            shard_min_vertices: padded-vertex threshold for the sharded
                route.
            shard_model_axis: feature-axis width of the sharded route's
                2-D ``("shards", "model")`` mesh — ``M > 1`` splits each
                boundary exchange into per-rank ``ceil(F / M)`` column
                slices over ``shard_devices * M`` devices (wide hidden
                dims); part of the cache key, so different splits never
                alias.
            tune_cache: optional :class:`~repro.launch.autotune.TuneCache`
                routing tuned classes onto tuned tile configs.
            cache: a shared :class:`ProgramCache` (multi-tenant serving);
                defaults to a private cache of ``cache_capacity``.
            shapes: a shared :class:`ShapeRegistry`; defaults to private.
            cache_owner: tenant tag for per-owner cache budgets; defaults
                to the compiled model's name.

        Raises:
            ValueError: on a layer-count conflict or an unrealizable
                ``shard_devices``.
        """
        if isinstance(model, str):
            self.compiled = C.compile_gnn(
                M.trace_named(model) if n_layers == 1
                else M.trace_stacked(model, n_layers))
        else:
            if n_layers != 1 and n_layers != model.n_layers:
                raise ValueError(
                    f"n_layers={n_layers} conflicts with the pre-compiled "
                    f"model's {model.n_layers} layers")
            self.compiled = model
        self.params = params
        self.kernel_dispatch = kernel_dispatch
        self.target_part = target_part
        if donate_inputs is None:
            import jax
            donate_inputs = jax.default_backend() != "cpu"
        self.donate_inputs = donate_inputs
        if shard_model_axis < 1:
            raise ValueError(
                f"shard_model_axis must be >= 1, got {shard_model_axis}")
        if shard_devices is not None:
            import jax
            if shard_devices < 1:
                raise ValueError(
                    f"shard_devices must be >= 1, got {shard_devices}")
            # fail at configuration time, not when the first large batch
            # arrives hours into a serving session
            if shard_devices * shard_model_axis > len(jax.devices()):
                raise ValueError(
                    f"shard_devices={shard_devices} x model_axis="
                    f"{shard_model_axis} but only "
                    f"{len(jax.devices())} jax devices are visible; on CPU "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                    "before importing jax")
        self.shard_devices = shard_devices
        self.shard_min_vertices = shard_min_vertices
        self.shard_model_axis = shard_model_axis
        self.tune_cache = tune_cache
        sp = self.compiled.schedule(self.kernel_dispatch)
        self._kernel_tags = tuple(sorted(
            {g.kernel for ph in sp.phases for g in ph.gathers}
            - {S.KERNEL_SCAN}))
        self.cache = cache if cache is not None \
            else ProgramCache(capacity=cache_capacity)
        self.shapes = shapes if shapes is not None \
            else ShapeRegistry(target_part=target_part)
        self.cache_owner = (cache_owner if cache_owner is not None
                            else self.compiled.name)
        self._stats_lock = threading.Lock()
        self._requests = 0
        self._graphs_served = 0
        self._batches_run = 0
        self._sharded_batches = 0

    # ------------------------------------------------------------------ API
    def submit(self, graphs: Sequence[Graph],
               inputs: Sequence[Dict[str, Array]],
               params: Optional[Dict[str, Array]] = None
               ) -> List[List[Array]]:
        """Run the model over every graph; returns per-graph output lists
        (vertex-space arrays, same order as the model's declared outputs)."""
        if len(graphs) != len(inputs):
            raise ValueError(f"{len(graphs)} graphs but {len(inputs)} inputs")
        if not graphs:
            return []
        params = params if params is not None else self.params
        if params is None:
            raise ValueError("no params bound to the server or the request")

        groups: Dict[tuple, List[int]] = {}
        for i, g in enumerate(graphs):
            groups.setdefault(size_class(g), []).append(i)

        results: List[Optional[List[Array]]] = [None] * len(graphs)
        for idxs in groups.values():
            outs = self._run_group([graphs[i] for i in idxs],
                                   [inputs[i] for i in idxs], params)
            for i, out in zip(idxs, outs):
                results[i] = out
        with self._stats_lock:
            self._requests += 1
            self._graphs_served += len(graphs)
        return results  # fully populated: every index belongs to one group

    def stats(self) -> Dict:
        """Serving counters: requests/graphs/batches served, cache size and
        hit/miss/compile counts, layer count, sharded-batch count."""
        return dict(requests=self._requests, graphs=self._graphs_served,
                    batches=self._batches_run, cache_size=len(self.cache),
                    n_layers=self.compiled.n_layers,
                    sharded_batches=self._sharded_batches,
                    cache=self.cache.stats.as_dict())

    @property
    def compile_count(self) -> int:
        """Total runner compilations so far (flat after warmup on a
        repeated-signature stream)."""
        return self.cache.stats.compiles

    @property
    def cache_hits(self) -> int:
        """Request batches served by a warm compiled runner."""
        return self.cache.stats.hits

    @property
    def cache_misses(self) -> int:
        """Request batches that had to build (and compile) a runner."""
        return self.cache.stats.misses

    # ------------------------------------------------------------ internals
    def _run_group(self, graphs: List[Graph],
                   inputs: List[Dict[str, Array]],
                   params: Dict[str, Array]) -> List[List[Array]]:
        batch = batch_graphs(graphs)
        V_real = batch.graph.n_vertices
        # class keys carry the program identity (name + layer count): shape
        # registrations of a 1-layer and a 2-layer program of the same model
        # must never alias, even if two servers share a registry
        class_key = (self.compiled.name, self.compiled.n_layers,
                     size_class(graphs[0]), quantize(len(graphs), floor=1))
        tuned = None
        if self.tune_cache is not None:
            from ..launch.autotune import program_key
            tuned = self.tune_cache.get(
                program_key(self.compiled, self.kernel_dispatch), class_key)
        if tuned is not None:
            # tuned route: tuned grid + reorder + edge layout +
            # size-bucketed tile batch; the registration key carries the
            # config (reorder/layout included) so default and tuned
            # canonical shapes of one class never alias
            tuned_key = ("tuned",) + tuned.key()
            merged_graph, tiles, E_pad, ro = self.shapes.canonical(
                class_key + (tuned_key,), batch.graph,
                grid=(tuned.n_dst_parts, tuned.n_src_parts),
                reorder=tuned.reorder, layout=tuned.layout,
                n_buckets=tuned.n_buckets)
        else:
            tuned_key = ()
            merged_graph, tiles, E_pad, ro = self.shapes.canonical(
                class_key, batch.graph)
        V_pad = merged_graph.n_vertices

        sp = self.compiled.schedule(self.kernel_dispatch)
        merged_inputs: Dict[str, Array] = {}
        for _, name in sp.vertex_inputs:
            merged_inputs[name] = _pad_rows(
                np.concatenate([np.asarray(inp[name]) for inp in inputs]), V_pad)
        for _, name in sp.edge_inputs:
            merged_inputs[name] = _pad_rows(
                np.concatenate([np.asarray(inp[name]) for inp in inputs]), E_pad)

        n_dev = (self.shard_devices
                 if self.shard_devices and self.shard_devices > 1
                 and V_pad >= self.shard_min_vertices else 1)
        if tuned is not None and n_dev > 1:
            # the tuned shard count caps (never raises) the mesh size
            n_dev = max(1, min(n_dev, tuned.n_shards))
        if n_dev > 1:
            # sharded route over an n_dev mesh, kernel dispatch honored
            # inside shard_map; key carries the mesh size, the realized
            # shard layout, the dispatch flag, the reorder mode, and the
            # tuned config.  The runner holds the graph/tiles in reordered
            # vertex space; requests stay in original ids and the rebind
            # ships the permutation as a replicated traced operand.
            key = structure_signature(self.compiled, tiles, E_pad,
                                      self.kernel_dispatch,
                                      reorder=ro.mode) + (
                shard_layout_signature(tiles, n_dev, mode="contiguous",
                                       quantize_tile_cap=True,
                                       kernel_dispatch=self.kernel_dispatch,
                                       kernels=self._kernel_tags,
                                       model_axis=self.shard_model_axis),
                tuned_key)
            runner = self.cache.get_or_build(
                key, lambda: ShardedRunner(self.compiled, ro.graph, tiles,
                                           n_dev, mode="contiguous",
                                           quantize_tile_cap=True,
                                           kernel_dispatch=self.kernel_dispatch,
                                           reordering=ro,
                                           model_axis=self.shard_model_axis),
                owner=self.cache_owner)
            with self._stats_lock:
                self._sharded_batches += 1
        else:
            key = structure_signature(self.compiled, tiles, E_pad,
                                      self.kernel_dispatch,
                                      reorder=ro.mode) + (tuned_key,)
            runner = self.cache.get_or_build(
                key, lambda: PipelinedRunner(self.compiled, ro.graph, tiles,
                                             kernel_dispatch=self.kernel_dispatch,
                                             donate_inputs=self.donate_inputs,
                                             reordering=ro),
                owner=self.cache_owner)
        outs = runner.run_with(tiles, merged_inputs, params, reordering=ro)
        with self._stats_lock:
            self._batches_run += 1

        per_output = [batch.unbatch_vertex(np.asarray(o)[:V_real])
                      for o in outs]
        return [[per_output[o][g] for o in range(len(per_output))]
                for g in range(len(graphs))]
