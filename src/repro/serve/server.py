"""Asynchronous serving front-end: continuous batching under latency SLOs.

:class:`AsyncInferenceServer` turns the synchronous batch-at-a-time
:class:`~repro.serve.engine.InferenceServer` into a service loop (ROADMAP
item 1).  Individual graphs arrive via :meth:`~AsyncInferenceServer.submit`
with a per-request deadline and get a :class:`Ticket` back immediately; a
scheduler thread forms batches **by size class and deadline** — ship a
partial batch when the oldest member's slack is about to expire, fill to
the class cap otherwise — and a small worker pool overlaps the
pad/compile/run of different size classes.  The request lifecycle is
documented end to end in ``docs/SERVING.md``:

    submit -> admission control -> per-(model, size-class) queue
           -> batch former (deadline- and cap-driven)
           -> worker pool -> InferenceServer.submit (pad + cached runner)
           -> per-request tickets resolved, metrics recorded

Admission control keeps the queue bounded: when full, the configured
shed policy either rejects the new request (``reject-new``) or evicts the
globally oldest pending one (``drop-oldest``); either way the victim's
ticket resolves to a structured :class:`Overloaded` result — callers never
see an exception from the middle of the pipeline.

Multi-tenancy: several models (and layer counts) registered on one server
share one :class:`~repro.serve.cache.ProgramCache`, each under its own
eviction budget (:meth:`~repro.serve.cache.ProgramCache.set_budget`), so a
chatty tenant cannot flush another tenant's warm runners.

Background warmup (:meth:`~AsyncInferenceServer.start`) pre-compiles each
registered model's canonical shapes through the exact serving path, so the
first real request of a warmed class never pays a compile; a real request
racing the warmup for the same class blocks on the in-flight build inside
the cache and still compiles exactly once.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..core import compiler as C
from ..gnn.graphs import Graph
from .cache import ProgramCache
from .engine import InferenceServer
from .metrics import ServeMetrics
from .signature import ShapeRegistry, size_class

#: structured shed reasons (the ``Overloaded.reason`` vocabulary)
QUEUE_FULL = "queue-full"
DROPPED_OLDEST = "dropped-oldest"
DEADLINE_EXPIRED = "deadline-expired"
SHUTDOWN = "shutdown"

SHED_POLICIES = ("reject-new", "drop-oldest")
FILL_POLICIES = ("pad", "none")


@dataclasses.dataclass(frozen=True)
class Overloaded:
    """Structured admission-control result: the request was shed, not served.

    Returned through :meth:`Ticket.result` instead of raising, so a caller
    under load sees a typed, inspectable outcome (reason + queue state) and
    can retry, back off, or degrade gracefully.
    """

    #: one of ``queue-full`` / ``dropped-oldest`` / ``deadline-expired`` /
    #: ``shutdown``
    reason: str
    #: pending requests at shed time (the pressure signal)
    queue_depth: int
    model: str = ""
    message: str = ""


class Ticket:
    """Handle for one in-flight request (a minimal thread-safe future).

    Resolves exactly once — either with the request's per-output arrays,
    with a structured :class:`Overloaded`, or with an exception raised by
    the execution path (re-raised from :meth:`result`).
    """

    def __init__(self, model: str, deadline_s: float):
        """Create an unresolved ticket (done by the serving machinery)."""
        self.model = model
        self.deadline_s = deadline_s
        self.t_enqueue = time.monotonic()
        self._done = threading.Event()
        self._value: Union[None, List, Overloaded] = None
        self._exc: Optional[BaseException] = None

    # ------------------------------------------------------------ resolution
    def _resolve(self, value) -> None:
        self._value = value
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._done.set()

    # ------------------------------------------------------------ inspection
    def done(self) -> bool:
        """Whether the ticket has resolved (served, shed, or failed)."""
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until resolved (or ``timeout`` seconds); returns done()."""
        return self._done.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        """The request's outputs, or an :class:`Overloaded` if it was shed.

        Raises:
            TimeoutError: not resolved within ``timeout`` seconds.
            BaseException: whatever the execution path raised, re-raised
                here (never from inside the scheduler).
        """
        if not self._done.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._value

    @property
    def ok(self) -> bool:
        """True once resolved with real outputs (not shed, not failed)."""
        return (self.done() and self._exc is None
                and not isinstance(self._value, Overloaded))


@dataclasses.dataclass
class _Request:
    """One queued unit of work: a graph + inputs + its ticket."""

    graph: Graph
    inputs: Dict
    ticket: Ticket
    deadline: float                   # absolute, time.monotonic() terms
    seq: int                          # admission order (drop-oldest victim key)


class _Tenant:
    """One registered model: its engine plus batching/warmup settings."""

    def __init__(self, name: str, engine: InferenceServer, max_batch: int,
                 warmup_graphs: Sequence[Graph]):
        self.name = name
        self.engine = engine
        self.max_batch = max_batch
        self.warmup_graphs = list(warmup_graphs)


class AsyncInferenceServer:
    """Continuous-batching async serving tier over cached compiled programs.

    Typical use::

        server = AsyncInferenceServer(max_queue=256, shed_policy="reject-new")
        server.register_model("gcn", compiled, params,
                              warmup_graphs=[representative_graph])
        server.start()                       # background warmup begins
        t = server.submit(graph, inputs, model="gcn", deadline_s=0.5)
        out = t.result(timeout=2.0)          # arrays, or Overloaded
        server.close()                       # graceful drain

    The scheduler ships a batch for a (model, size-class) queue when it
    reaches the model's ``max_batch`` cap, or earlier when the oldest
    member's remaining slack drops to ``dispatch_margin_s`` (the estimated
    service time) — so p99 stays bounded by the configured deadline while
    throughput comes from full batches whenever load allows.
    """

    def __init__(self, *, max_queue: int = 256,
                 shed_policy: str = "reject-new",
                 default_deadline_s: float = 2.0,
                 dispatch_margin_s: float = 0.25,
                 n_workers: int = 2,
                 cache_capacity: int = 64,
                 fill_policy: str = "pad",
                 metrics: Optional[ServeMetrics] = None):
        """Configure the serving tier (no threads start until
        :meth:`start`).

        Args:
            max_queue: bound on total pending requests across all models.
            shed_policy: ``reject-new`` (bounce the arriving request) or
                ``drop-oldest`` (evict the globally oldest pending one).
            default_deadline_s: deadline slack for requests that give none.
            dispatch_margin_s: ship a partial batch when the oldest
                member's remaining slack falls to this margin (set it near
                the expected batch service time).
            n_workers: worker threads running pad/compile/run — >1 overlaps
                size classes (and warmup with real traffic).
            cache_capacity: total entries of the shared program cache.
            fill_policy: ``pad`` duplicates the last member of a partial
                batch up to the class cap (stable canonical shapes, zero
                steady-state recompiles at any fill); ``none`` ships
                partial batches as-is (less compute, but each distinct
                quantized batch count registers its own shapes once).
            metrics: a shared :class:`~repro.serve.metrics.ServeMetrics`;
                defaults to a fresh registry.

        Raises:
            ValueError: on an unknown policy or a non-positive bound.
        """
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of {SHED_POLICIES}, "
                             f"got {shed_policy!r}")
        if fill_policy not in FILL_POLICIES:
            raise ValueError(f"fill_policy must be one of {FILL_POLICIES}, "
                             f"got {fill_policy!r}")
        if max_queue < 1 or n_workers < 1:
            raise ValueError("max_queue and n_workers must be >= 1")
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.default_deadline_s = default_deadline_s
        self.dispatch_margin_s = dispatch_margin_s
        self.n_workers = n_workers
        self.fill_policy = fill_policy
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.cache = ProgramCache(capacity=cache_capacity)
        self.shapes = ShapeRegistry()
        self._tenants: Dict[str, _Tenant] = {}
        self._queues: Dict[Tuple, List[_Request]] = {}
        self._depth = 0
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._closed = False
        self._started = False
        self._scheduler: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._inflight = 0                 # batches handed to the pool

    # ----------------------------------------------------------- registration
    def register_model(self, name: str,
                       model: Union[str, C.CompiledGNN],
                       params: Dict, *,
                       n_layers: int = 1,
                       max_batch: int = 16,
                       cache_budget: Optional[int] = None,
                       warmup_graphs: Sequence[Graph] = (),
                       **engine_kw) -> InferenceServer:
        """Register a tenant model and build its engine over the shared cache.

        Args:
            name: tenant name — the ``model=`` key requests are routed by
                (distinct names may wrap the same model at different layer
                counts; cache keys never alias).
            model: model name or pre-compiled program (engine semantics).
            params: the tenant's weights.
            n_layers: stack depth when ``model`` is a name.
            max_batch: the tenant's batch cap per dispatched batch.
            cache_budget: max program-cache entries this tenant may hold
                (``None`` = only the global capacity bounds it).
            warmup_graphs: representative graphs whose size classes
                :meth:`start` pre-compiles in the background.
            **engine_kw: forwarded to
                :class:`~repro.serve.engine.InferenceServer`.

        Returns:
            The tenant's engine (exposed for stats/introspection).

        Raises:
            ValueError: duplicate name, bad cap, or registration after
                :meth:`close`.
        """
        with self._lock:
            if self._closed:
                raise ValueError("server is closed")
            if name in self._tenants:
                raise ValueError(f"model {name!r} already registered")
            if max_batch < 1:
                raise ValueError("max_batch must be >= 1")
        engine = InferenceServer(model, params, n_layers=n_layers,
                                 cache=self.cache, shapes=self.shapes,
                                 cache_owner=name, **engine_kw)
        if cache_budget is not None:
            self.cache.set_budget(name, cache_budget)
        tenant = _Tenant(name, engine, max_batch, warmup_graphs)
        with self._lock:
            self._tenants[name] = tenant
        return engine

    # ------------------------------------------------------------- lifecycle
    def start(self, warmup: bool = True) -> "AsyncInferenceServer":
        """Start the scheduler thread and worker pool (idempotent).

        With ``warmup=True`` every registered tenant's ``warmup_graphs``
        are pre-compiled in the background through the real serving path
        (full-cap batches, so the canonical class shapes and the compiled
        runner both land before the first real request of the class).
        """
        with self._lock:
            if self._started:
                return self
            self._started = True
            self._pool = ThreadPoolExecutor(
                max_workers=self.n_workers,
                thread_name_prefix="serve-worker")
            self._scheduler = threading.Thread(
                target=self._scheduler_loop, name="serve-scheduler",
                daemon=True)
            self._scheduler.start()
        if warmup:
            self._launch_warmup()
        return self

    def __enter__(self) -> "AsyncInferenceServer":
        """Context-manager entry: :meth:`start` with warmup."""
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        """Context-manager exit: graceful :meth:`close`."""
        self.close()

    def close(self, drain: bool = True, timeout: Optional[float] = None
              ) -> None:
        """Stop the server; idempotent, safe with zero requests ever sent.

        Args:
            drain: serve everything already queued before stopping
                (``False`` sheds the backlog with reason ``shutdown``).
            timeout: max seconds to wait for the scheduler to finish
                draining (``None`` = wait for a full drain).

        New submissions after close resolve immediately as
        :class:`Overloaded` (reason ``shutdown``).
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            # an unstarted server has no scheduler to drain the backlog, so
            # a "graceful" close must still resolve every pending ticket
            if not drain or not self._started:
                for q in self._queues.values():
                    for r in q:
                        self._shed_locked(r, SHUTDOWN)
                    del q[:]
                self._depth = 0
            started = self._started
            self._cond.notify_all()
        if started:
            self._scheduler.join(timeout)
            self._pool.shutdown(wait=True)

    # -------------------------------------------------------------- ingress
    def submit(self, graph: Graph, inputs: Dict, *,
               model: Optional[str] = None,
               deadline_s: Optional[float] = None) -> Ticket:
        """Enqueue one graph; returns immediately with a :class:`Ticket`.

        Args:
            graph: the request graph.
            inputs: the model's per-graph input arrays.
            model: tenant name (optional when exactly one is registered).
            deadline_s: latency budget from now; ``None`` uses the server
                default.  A non-positive budget sheds immediately
                (``deadline-expired``) — the caller asked for an answer in
                the past.

        Returns:
            The request's ticket (already resolved when shed at admission).

        Raises:
            KeyError: unknown ``model``.
            ValueError: no model registered, or ambiguous default.
        """
        name = self._resolve_model(model)
        slack = (self.default_deadline_s if deadline_s is None
                 else float(deadline_s))
        ticket = Ticket(name, slack)
        req = _Request(graph=graph, inputs=inputs, ticket=ticket,
                       deadline=ticket.t_enqueue + slack,
                       seq=next(self._seq))
        with self._lock:
            if self._closed:
                self._shed_locked(req, SHUTDOWN)
                return ticket
            if slack <= 0:
                self._shed_locked(req, DEADLINE_EXPIRED)
                return ticket
            if self._depth >= self.max_queue:
                if self.shed_policy == "reject-new":
                    self._shed_locked(req, QUEUE_FULL)
                    return ticket
                self._drop_oldest_locked()
            key = (name, size_class(graph))
            self._queues.setdefault(key, []).append(req)
            self._depth += 1
            self.metrics.on_submit(self._depth)
            self._cond.notify_all()
        return ticket

    def submit_many(self, graphs: Sequence[Graph], inputs: Sequence[Dict],
                    **kw) -> List[Ticket]:
        """Vector :meth:`submit` — one ticket per graph, same options."""
        if len(graphs) != len(inputs):
            raise ValueError(f"{len(graphs)} graphs but {len(inputs)} inputs")
        return [self.submit(g, i, **kw) for g, i in zip(graphs, inputs)]

    # ------------------------------------------------------------ inspection
    @property
    def queue_depth(self) -> int:
        """Pending (admitted, not yet dispatched) requests right now."""
        with self._lock:
            return self._depth

    def stats(self) -> Dict:
        """Aggregated serving state: metrics snapshot, per-tenant engine
        stats, shared-cache counters and per-owner entry counts."""
        with self._lock:
            tenants = dict(self._tenants)
            depth = self._depth
        return dict(queue_depth=depth,
                    metrics=self.metrics.snapshot(),
                    cache=dict(self.cache.stats.as_dict(),
                               size=len(self.cache),
                               owners=self.cache.owner_counts()),
                    models={n: t.engine.stats() for n, t in tenants.items()})

    # ---------------------------------------------------------- shed helpers
    def _resolve_model(self, model: Optional[str]) -> str:
        with self._lock:
            if model is not None:
                if model not in self._tenants:
                    raise KeyError(f"model {model!r} not registered "
                                   f"(have {sorted(self._tenants)})")
                return model
            if len(self._tenants) == 1:
                return next(iter(self._tenants))
            raise ValueError(
                "model= is required when zero or several models are "
                f"registered (have {sorted(self._tenants)})")

    def _shed_locked(self, req: _Request, reason: str) -> None:
        self.metrics.on_shed(reason)
        req.ticket._resolve(Overloaded(
            reason=reason, queue_depth=self._depth, model=req.ticket.model,
            message=f"request shed at admission/queue ({reason})"))

    def _drop_oldest_locked(self) -> None:
        """Evict the globally oldest pending request (drop-oldest policy)."""
        oldest_key, oldest_idx, oldest_seq = None, -1, None
        for key, q in self._queues.items():
            for i, r in enumerate(q):
                if oldest_seq is None or r.seq < oldest_seq:
                    oldest_key, oldest_idx, oldest_seq = key, i, r.seq
        if oldest_seq is None:           # queue bound hit with nothing queued
            return
        victim = self._queues[oldest_key].pop(oldest_idx)
        self._depth -= 1
        self._shed_locked(victim, DROPPED_OLDEST)

    # -------------------------------------------------------------- scheduler
    def _scheduler_loop(self) -> None:
        """Batch former: runs until closed and (when draining) drained."""
        while True:
            batches: List[Tuple[_Tenant, List[_Request]]] = []
            with self._lock:
                while True:
                    now = time.monotonic()
                    batches = self._form_batches_locked(now)
                    if batches:
                        break
                    if self._closed and self._depth == 0:
                        return
                    self._cond.wait(timeout=self._wake_in_locked(now))
            for tenant, reqs in batches:
                live = self._expire_batch(reqs)
                if not live:
                    continue
                with self._lock:
                    self._inflight += 1
                self._pool.submit(self._run_batch, tenant, live)

    def _form_batches_locked(self, now: float
                             ) -> List[Tuple[_Tenant, List[_Request]]]:
        """Pop every group that is ripe: full to its cap, deadline-pressed,
        or unconditionally when the server is draining for shutdown."""
        out: List[Tuple[_Tenant, List[_Request]]] = []
        for key in list(self._queues):
            q = self._queues[key]
            if not q:
                del self._queues[key]
                continue
            tenant = self._tenants[key[0]]
            ripe = (len(q) >= tenant.max_batch
                    or self._closed
                    or min(r.deadline for r in q) - now
                    <= self.dispatch_margin_s)
            if not ripe:
                continue
            take = q[:tenant.max_batch]
            self._queues[key] = q[tenant.max_batch:]
            self._depth -= len(take)
            self.metrics.on_batch(len(take), tenant.max_batch, self._depth)
            out.append((tenant, take))
        return out

    def _wake_in_locked(self, now: float) -> float:
        """Sleep until the next deadline gets margin-close (bounded 0.5s)."""
        soonest = min((r.deadline for q in self._queues.values() for r in q),
                      default=now + 0.5)
        return min(max(soonest - self.dispatch_margin_s - now, 0.001), 0.5)

    def _expire_batch(self, reqs: List[_Request]) -> List[_Request]:
        """Shed members whose deadline already passed; keep the rest."""
        now = time.monotonic()
        live: List[_Request] = []
        for r in reqs:
            if r.deadline < now:
                with self._lock:
                    self._shed_locked(r, DEADLINE_EXPIRED)
            else:
                live.append(r)
        return live

    # ---------------------------------------------------------------- worker
    def _run_batch(self, tenant: _Tenant, reqs: List[_Request]) -> None:
        """Worker-pool body: pad/fill, run the engine, resolve tickets."""
        try:
            graphs = [r.graph for r in reqs]
            inputs = [r.inputs for r in reqs]
            t_dispatch = time.monotonic()
            if self.fill_policy == "pad" and len(graphs) < tenant.max_batch:
                # duplicate the last member up to the cap: the quantized
                # batch count — hence the canonical class shapes — stays
                # identical for every fill level, so partial batches can
                # never trigger a steady-state recompile
                fill = tenant.max_batch - len(graphs)
                graphs = graphs + [graphs[-1]] * fill
                inputs = inputs + [inputs[-1]] * fill
            outs = tenant.engine.submit(graphs, inputs)
            now = time.monotonic()
            for r, out in zip(reqs, outs):
                self.metrics.on_complete(
                    now - r.ticket.t_enqueue, t_dispatch - r.ticket.t_enqueue)
                r.ticket._resolve(out)
        except BaseException as exc:      # surfaced via ticket.result()
            for r in reqs:
                if not r.ticket.done():
                    r.ticket._fail(exc)
        finally:
            with self._lock:
                self._inflight -= 1
                self._cond.notify_all()

    # ---------------------------------------------------------------- warmup
    def _launch_warmup(self) -> None:
        """Queue one background warmup task per (tenant, warmup graph)."""
        specs: List[Tuple[_Tenant, Graph]] = []
        with self._lock:
            for tenant in self._tenants.values():
                for g in tenant.warmup_graphs:
                    specs.append((tenant, g))
        if not specs:
            return
        total = len(specs)
        self.metrics.on_warmup(0, total)
        done = itertools.count(1)

        def _one(tenant: _Tenant, g: Graph) -> None:
            self._warm_class(tenant, g)
            self.metrics.on_warmup(next(done), total)

        for tenant, g in specs:
            self._pool.submit(_one, tenant, g)

    def _warm_class(self, tenant: _Tenant, graph: Graph) -> None:
        """Compile one size class by serving a synthetic full-cap batch.

        Runs the *real* path (register canonical shapes, build + jit the
        runner, execute once), so the class is warm in every layer the
        first genuine request will touch.  Failures are swallowed after
        being counted — warmup must never take the serving loop down.
        """
        from ..gnn import models as M

        try:
            inputs = M.init_inputs(tenant.engine.compiled.trace, graph)
            n = tenant.max_batch if self.fill_policy == "pad" else 1
            tenant.engine.submit([graph] * n, [inputs] * n)
        except Exception:
            self.metrics.on_shed("warmup-failed")

    def warmup_done(self) -> bool:
        """Whether every background warmup task has finished."""
        snap = self.metrics.snapshot()["warmup"]
        return snap["done"] >= snap["total"]
