"""Batched multi-graph inference serving (ROADMAP north star: many small
graphs per second, one compilation per *structure* instead of per graph).

Layers:

* :mod:`repro.serve.signature` — size-class quantization and the structural
  request signature (tile shapes + kernel tags + feature dims).
* :mod:`repro.serve.cache` — the LRU compiled-program cache with hit/miss/
  compile/eviction counters.
* :mod:`repro.serve.engine` — :class:`InferenceServer`, the front door:
  ``submit(graphs, inputs) -> per-graph outputs``.
"""
from .cache import CacheStats, ProgramCache  # noqa: F401
from .engine import InferenceServer  # noqa: F401
from .signature import (  # noqa: F401
    ShapeRegistry,
    canonical_tiles,
    quantize,
    serving_grid,
    size_class,
    structure_signature,
)
