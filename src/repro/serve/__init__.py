"""Batched multi-graph inference serving (ROADMAP north star: many small
graphs per second, one compilation per *structure* instead of per graph).

Layers:

* :mod:`repro.serve.signature` — size-class quantization and the structural
  request signature (tile shapes + kernel tags + feature dims).
* :mod:`repro.serve.cache` — the thread-safe LRU compiled-program cache with
  hit/miss/compile/eviction counters and per-tenant eviction budgets.
* :mod:`repro.serve.engine` — :class:`InferenceServer`, the synchronous
  batch-at-a-time core: ``submit(graphs, inputs) -> per-graph outputs``.
* :mod:`repro.serve.server` — :class:`AsyncInferenceServer`, the async tier:
  per-request deadlines, continuous batching by size class, admission
  control with structured :class:`Overloaded` shedding, background warmup,
  multi-tenant cache budgeting.
* :mod:`repro.serve.metrics` — :class:`ServeMetrics`, p50/p99 latency,
  queue depth, batch fill, shed counts (exported as JSON).

``docs/SERVING.md`` walks the whole request lifecycle.
"""
from .cache import CacheStats, ProgramCache  # noqa: F401
from .engine import InferenceServer  # noqa: F401
from .metrics import Histogram, ServeMetrics  # noqa: F401
from .server import (  # noqa: F401
    AsyncInferenceServer,
    Overloaded,
    Ticket,
)
from .signature import (  # noqa: F401
    ShapeRegistry,
    canonical_tiles,
    quantize,
    serving_grid,
    size_class,
    structure_signature,
)
