"""Structural request signatures and size-class quantization.

The serving cache must key compiled programs on *structure*, never on edge
lists: a :class:`~repro.core.pipeline.PipelinedRunner`'s compilation depends
only on the scheduled program (kernel tags + feature dims) and the padded
tile-set shapes.  Everything here exists to make those shapes *repeat*
across a stream of similar-but-not-identical graphs:

* :func:`quantize` snaps counts up to powers of two, so small variance in
  V/E maps onto one size class;
* :func:`serving_grid` picks the tiling grid deterministically from the
  padded vertex count;
* :class:`ShapeRegistry` fixes each class's padded shapes from its first
  request (plus growth headroom), so every later request of the class pads
  onto *identical* shapes — pure quantization would flake whenever a
  realized dimension straddles a power-of-two boundary;
* :func:`canonical_tiles` is the stateless power-of-two variant for one-shot
  use;
* :func:`structure_signature` combines the program and tile signatures into
  the cache key.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Hashable, Optional, Tuple, Union

from ..core import compiler as C
from ..core.tiling import (BucketedTileSet, TileSet, bucket_tiles, grid_tile,
                           pad_tileset)
from ..gnn.graphs import Graph, pad_graph


def quantize(n: int, floor: int = 8) -> int:
    """Round ``n`` up to the next power of two, at least ``floor``."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def size_class(graph: Graph) -> Tuple[int, int, bool]:
    """Coarse per-graph bucket the server groups requests by: quantized
    (V, E) plus whether the graph carries edge types."""
    return (quantize(graph.n_vertices), quantize(max(graph.n_edges, 1)),
            graph.edge_type is not None)


def serving_grid(n_vertices: int, target_part: int = 256,
                 max_parts: int = 64) -> Tuple[int, int]:
    """Deterministic (n_dst_parts, n_src_parts) for a quantized vertex count
    — the same size class must always tile on the same grid."""
    parts = min(quantize(max(n_vertices // target_part, 1), floor=1), max_parts)
    return parts, parts


def canonical_tiles(graph: Graph, grid: Tuple[int, int],
                    pad_multiple: int = 8) -> TileSet:
    """Sparse-tile ``graph`` and snap the batch onto quantized shapes.

    The result's :meth:`~repro.core.tiling.TileSet.shape_signature` is stable
    across graphs of one size class with similar degree structure, which is
    what turns a stream of distinct graphs into program-cache hits.
    """
    ts = grid_tile(graph, grid[0], grid[1], sparse=True,
                   pad_multiple=pad_multiple)
    return pad_tileset(ts, quantize(ts.n_tiles, floor=1),
                       quantize(ts.s_max), quantize(ts.e_max))


def _round_up(x: float, multiple: int) -> int:
    return int(math.ceil(x / multiple)) * multiple


class ShapeRegistry:
    """Per-size-class canonical padded shapes, fixed at first sight.

    Keys are caller-chosen; :class:`~repro.serve.engine.InferenceServer`
    prefixes them with the compiled program's identity (model name + layer
    count), so multi-layer and single-layer programs of one model never
    alias a registration even when a registry is shared.

    The first request of a class registers padded dimensions with
    ``headroom`` (default 25%) over what it realized; every later request of
    the class pads onto exactly those shapes — a guaranteed program-cache
    hit.  Only a request that *exceeds* a registered dimension bumps the
    class (shapes grow monotonically, costing one recompile), so a
    steady-state stream converges to zero recompilations regardless of where
    realized sizes sit relative to power-of-two boundaries.
    """

    def __init__(self, headroom: float = 0.25, target_part: int = 256,
                 pad_multiple: int = 8):
        """Create an empty registry.

        Args:
            headroom: growth factor applied over the first-seen dimensions
                (0.25 = register 25% above what the first request realized).
            target_part: vertices per destination partition fed to
                :func:`serving_grid` when no explicit grid is given.
            pad_multiple: row-count multiple tile shapes are padded to.
        """
        self.headroom = headroom
        self.target_part = target_part
        self.pad_multiple = pad_multiple
        self._shapes: Dict[Hashable, Dict] = {}
        # the async tier canonicalizes concurrently from worker threads; the
        # grow-monotonically registration must not interleave
        self._lock = threading.RLock()

    def __len__(self) -> int:
        return len(self._shapes)

    def canonical(self, key: Hashable, graph: Graph,
                  grid: Optional[Tuple[int, int]] = None,
                  reorder: Optional[str] = None, layout: str = "coo",
                  n_buckets: Optional[int] = None
                  ) -> Tuple[Graph, Union[TileSet, BucketedTileSet], int,
                             "Reordering"]:
        """Pad ``graph`` and its tile batch onto the class's registered
        shapes; returns (padded graph, padded tiles, padded edge-row count,
        reordering).  ``grid`` overrides the deterministic
        :func:`serving_grid` choice — the autotuned-config route; callers
        must then key the registration by the tuned config too, so default
        and tuned shapes never alias.  ``reorder``/``layout`` select the
        paper §5.3 degree sort and the within-tile edge storage: the degree
        permutation is computed over the *padded* graph (filler vertices are
        degree-0 and sink to the tail), the returned
        :class:`~repro.core.reorder.Reordering` maps request-order vertex IO
        into/out of the runner, and the tiles are built over the reordered
        graph — callers keying registrations by the tuned config therefore
        also key them by reorder + layout.  ``n_buckets > 1`` additionally
        size-buckets the padded batch with *registered* per-bucket column
        caps: bucket tile counts are a pure function of the registered tile
        count, and the caps grow monotonically exactly like the raw tile
        dims, so bucketed shapes cannot flake across requests the way bare
        power-of-two snapping does when a realized bucket maximum straddles
        a boundary (degree reordering makes that variance routine).
        Thread-safe: concurrent calls for one class serialize, so the
        registered dimensions only ever grow.
        """
        from ..core import reorder as R

        with self._lock:
            grow = 1.0 + self.headroom
            entry = self._shapes.setdefault(
                key, dict(v_pad=0, e_rows=0, tile=(0, 0, 0)))
            V, E = graph.n_vertices, max(graph.n_edges, 1)
            if V > entry["v_pad"]:
                entry["v_pad"] = _round_up(V * grow, 64)
            if E > entry["e_rows"]:
                entry["e_rows"] = _round_up(E * grow, 64)
            padded = pad_graph(graph, entry["v_pad"])
            if reorder in (None, "identity"):
                ro = R.identity_order(padded)
            elif reorder in ("degree", "in", "out"):
                ro = R.degree_sort(padded,
                                   by="out" if reorder == "out" else "in")
            else:
                raise ValueError(f"unknown reorder mode {reorder!r}")
            if grid is None:
                grid = serving_grid(entry["v_pad"], self.target_part)
            raw = grid_tile(ro.graph, grid[0], grid[1], sparse=True,
                            pad_multiple=self.pad_multiple, layout=layout)
            T, s, e = entry["tile"]
            if raw.n_tiles > T:
                T = _round_up(raw.n_tiles * grow, 2)
            T = max(T, 1)    # an edgeless graph tiles to zero tiles; keep one
            # filler so the kernels always see a non-empty grid
            if raw.s_max > s:
                s = _round_up(raw.s_max * grow, self.pad_multiple)
            if raw.e_max > e:
                e = _round_up(raw.e_max * grow, self.pad_multiple)
            entry["tile"] = (T, s, e)
            ts = pad_tileset(raw, T, s, e)
            if n_buckets is None or n_buckets <= 1:
                return padded, ts, entry["e_rows"], ro
            bt = bucket_tiles(ts, n_buckets, pad_multiple=self.pad_multiple)
            caps = entry.setdefault("buckets", {}).setdefault(n_buckets, [])
            grown = []
            for i, b in enumerate(bt.buckets):
                if i >= len(caps):
                    caps.append((0, 0))
                cs, ce = caps[i]
                if b.s_max > cs:
                    cs = _round_up(b.s_max * grow, self.pad_multiple)
                if b.e_max > ce:
                    ce = _round_up(b.e_max * grow, self.pad_multiple)
                caps[i] = (cs, ce)
                grown.append(pad_tileset(b, b.n_tiles, cs, ce))
            bt = BucketedTileSet(buckets=grown,
                                 tile_index=list(bt.tile_index),
                                 source=bt.source)
            return padded, bt, entry["e_rows"], ro


def structure_signature(model: Union[str, C.CompiledGNN],
                        tiles: Union[TileSet, BucketedTileSet],
                        padded_edges: int = 0,
                        kernel_dispatch: bool = True,
                        reorder: str = "identity") -> Tuple:
    """The compiled-program cache key: program structure + tile shapes +
    the padded edge-input row count (edge-space input arrays are traced, so
    their length is a compilation input too) + the vertex reorder mode.
    Raw edge lists never enter.  The tile shape signature leads with the
    edge layout and the runner's compiled permutation plumbing depends on
    the reorder mode, so CSR/COO and identity/degree programs can never
    alias one cache entry.
    """
    if isinstance(model, str):
        from ..gnn import models as M
        model = C.compile_gnn(M.trace_named(model))
    return (model.structure_signature(kernel_dispatch),
            tiles.shape_signature(), int(padded_edges), str(reorder))
