"""Serving-path metrics: latency percentiles, queue depth, shed counts.

Every number the async tier reports flows through one thread-safe
:class:`ServeMetrics` registry so the scheduler, the worker pool, and the
warmup thread never hand-roll their own counters.  The registry is cheap to
update on the hot path (a lock + ring-buffer append), snapshots to a plain
JSON-able dict (:meth:`ServeMetrics.snapshot`), and is what
``benchmarks/bench_serving_async.py`` asserts against and exports to
``reports/bench_serving_async.json``.

Metric families (glossary lives in ``docs/SERVING.md``):

* **latency** — end-to-end seconds from ``submit`` to ticket resolution,
  reported as p50/p99/mean/max over a bounded reservoir;
* **queue depth** — pending requests sampled at every enqueue/dequeue;
* **batch fill** — realized batch size over the class cap per dispatched
  batch (1.0 = the scheduler always filled to the cap);
* **shed** — admission-control rejections, broken down by reason
  (``queue-full``, ``dropped-oldest``, ``deadline-expired``, ``shutdown``);
* **warmup** — background compile progress (done / total).
"""
from __future__ import annotations

import json
import threading
from typing import Dict, List, Optional, Sequence


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of ``samples`` (``q`` in [0, 100]).

    Args:
        samples: observed values; order does not matter.
        q: the percentile to report, e.g. ``50`` or ``99``.

    Returns:
        The nearest-rank percentile, or ``0.0`` for an empty sample set
        (serving dashboards prefer a zero row over a crash).
    """
    if not samples:
        return 0.0
    xs = sorted(samples)
    if q <= 0:
        return xs[0]
    rank = max(1, -(-len(xs) * q // 100))        # ceil(n*q/100), >= 1
    return xs[min(int(rank), len(xs)) - 1]


class Histogram:
    """Bounded-reservoir histogram with exact percentiles over the window.

    Keeps the most recent ``window`` observations (plus running count / sum /
    max over the full lifetime), so percentiles reflect recent behavior and
    memory stays bounded no matter how long the server runs.
    """

    def __init__(self, window: int = 4096):
        """Create an empty histogram keeping at most ``window`` samples."""
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self._samples: List[float] = []
        self._next = 0                     # ring-buffer write cursor
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, value: float) -> None:
        """Add one observation (ring-buffer overwrite once full)."""
        value = float(value)
        if len(self._samples) < self.window:
            self._samples.append(value)
        else:
            self._samples[self._next] = value
            self._next = (self._next + 1) % self.window
        self.count += 1
        self.total += value
        self.max = max(self.max, value)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the retained window."""
        return percentile(self._samples, q)

    @property
    def mean(self) -> float:
        """Lifetime mean (not just the retained window)."""
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        """JSON-able summary: count, mean, max, p50/p90/p99."""
        return dict(count=self.count, mean=round(self.mean, 6),
                    max=round(self.max, 6),
                    p50=round(self.percentile(50), 6),
                    p90=round(self.percentile(90), 6),
                    p99=round(self.percentile(99), 6))


class ServeMetrics:
    """Thread-safe registry of every async-serving metric.

    One instance is shared by the scheduler thread, the worker pool, and the
    warmup task; all mutation happens under one lock (updates are tiny —
    integer bumps and ring-buffer appends).
    """

    def __init__(self, window: int = 4096):
        """Create an empty registry; ``window`` bounds each histogram."""
        self._lock = threading.Lock()
        self.latency = Histogram(window)          # end-to-end seconds
        self.queue_wait = Histogram(window)       # enqueue -> dispatch seconds
        self.batch_fill = Histogram(window)       # realized / cap per batch
        self.queue_depth = Histogram(window)      # depth sampled on transitions
        self.submitted = 0
        self.completed = 0
        self.batches = 0
        self.shed: Dict[str, int] = {}
        self.warmup_total = 0
        self.warmup_done = 0

    # ------------------------------------------------------------ recording
    def on_submit(self, queue_depth: int) -> None:
        """Record an admitted request and the resulting queue depth."""
        with self._lock:
            self.submitted += 1
            self.queue_depth.record(queue_depth)

    def on_shed(self, reason: str) -> None:
        """Count one shed request under its structured reason."""
        with self._lock:
            self.shed[reason] = self.shed.get(reason, 0) + 1

    def on_batch(self, n_requests: int, cap: int, queue_depth: int) -> None:
        """Record one dispatched batch: fill ratio + post-dispatch depth."""
        with self._lock:
            self.batches += 1
            self.batch_fill.record(n_requests / max(cap, 1))
            self.queue_depth.record(queue_depth)

    def on_complete(self, latency_s: float,
                    queue_wait_s: Optional[float] = None) -> None:
        """Record one served request's end-to-end (and queue-wait) latency."""
        with self._lock:
            self.completed += 1
            self.latency.record(latency_s)
            if queue_wait_s is not None:
                self.queue_wait.record(queue_wait_s)

    def on_warmup(self, done: int, total: int) -> None:
        """Update background-warmup progress (``done`` of ``total`` specs)."""
        with self._lock:
            self.warmup_done = done
            self.warmup_total = total

    # ------------------------------------------------------------ reporting
    @property
    def shed_count(self) -> int:
        """Total requests shed across every reason."""
        with self._lock:
            return sum(self.shed.values())

    def snapshot(self) -> Dict:
        """One JSON-able dict of every metric family (the export format)."""
        with self._lock:
            return dict(
                submitted=self.submitted,
                completed=self.completed,
                batches=self.batches,
                shed=dict(self.shed),
                shed_total=sum(self.shed.values()),
                warmup=dict(done=self.warmup_done, total=self.warmup_total),
                latency_s=self.latency.snapshot(),
                queue_wait_s=self.queue_wait.snapshot(),
                batch_fill=self.batch_fill.snapshot(),
                queue_depth=self.queue_depth.snapshot(),
            )

    def to_json(self, indent: int = 1) -> str:
        """Serialize :meth:`snapshot` as JSON text."""
        return json.dumps(self.snapshot(), indent=indent)

    def write(self, path: str) -> None:
        """Write :meth:`to_json` to ``path``."""
        with open(path, "w") as f:
            f.write(self.to_json())
