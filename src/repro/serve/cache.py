"""LRU cache of lowered programs / warm jitted runners, with counters.

A cache *hit* means a request batch reuses an existing compilation — the
whole point of the serving layer, since per-graph jit dominates small-graph
inference cost.  Every miss invokes the builder exactly once, so
``compiles`` is the miss count under a clearer name; tests assert it stays
flat after warmup.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, Hashable, Iterator, Optional


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def compiles(self) -> int:
        """Builder invocations — one per miss, by construction."""
        return self.misses

    @property
    def requests(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.requests if self.requests else 0.0

    def as_dict(self) -> Dict[str, float]:
        return dict(hits=self.hits, misses=self.misses, compiles=self.compiles,
                    evictions=self.evictions, hit_rate=round(self.hit_rate, 4))


class ProgramCache:
    """Bounded LRU mapping structure signatures -> warm compiled runners."""

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: "collections.OrderedDict[Hashable, Any]" = \
            collections.OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self) -> Iterator[Hashable]:
        return iter(self._entries.keys())

    def get(self, key: Hashable) -> Optional[Any]:
        """Peek without counting a request (no builder, no LRU eviction)."""
        if key in self._entries:
            self._entries.move_to_end(key)
            return self._entries[key]
        return None

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        if key in self._entries:
            self.stats.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.stats.misses += 1
        value = builder()
        self._entries[key] = value
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return value

    def clear(self) -> None:
        self._entries.clear()

    def reset_counters(self) -> None:
        self.stats = CacheStats()
