"""LRU cache of lowered programs / warm jitted runners, with counters.

A cache *hit* means a request batch reuses an existing compilation — the
whole point of the serving layer, since per-graph jit dominates small-graph
inference cost.  Every miss invokes the builder exactly once, so
``compiles`` is the miss count under a clearer name; tests assert it stays
flat after warmup.

The cache is **thread-safe** for the async serving tier: concurrent
``get_or_build`` calls for *different* keys build in parallel (overlapping
compilation across size classes is the point of the worker pool), while
concurrent calls for the *same* key build once — later arrivals block on
the in-flight build and count as hits (this is what lets a background
warmup compile race a real request without duplicating the jit).

Multi-tenancy: entries may carry an ``owner`` (the model a runner belongs
to) and :meth:`ProgramCache.set_budget` caps how many entries one owner may
hold — an owner over budget evicts its *own* LRU entry, so one chatty model
cannot evict another tenant's warm runners out of a shared cache.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable, Dict, Hashable, Iterator, Optional


@dataclasses.dataclass
class CacheStats:
    """Hit/miss/eviction counters for one :class:`ProgramCache`."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def compiles(self) -> int:
        """Builder invocations — one per miss, by construction."""
        return self.misses

    @property
    def requests(self) -> int:
        """Total lookups (hits + misses)."""
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups served warm (0.0 when no lookups yet)."""
        return self.hits / self.requests if self.requests else 0.0

    def as_dict(self) -> Dict[str, float]:
        """JSON-able view of every counter plus the derived rate."""
        return dict(hits=self.hits, misses=self.misses, compiles=self.compiles,
                    evictions=self.evictions, hit_rate=round(self.hit_rate, 4))


class ProgramCache:
    """Bounded LRU mapping structure signatures -> warm compiled runners.

    ``capacity`` bounds total entries; per-owner budgets (optional, see
    :meth:`set_budget`) additionally bound any one tenant's share.  All
    public methods are thread-safe; builders run *outside* the lock so
    distinct keys compile concurrently.
    """

    def __init__(self, capacity: int = 32):
        """Create an empty cache holding at most ``capacity`` entries.

        Raises:
            ValueError: if ``capacity`` is less than one.
        """
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: "collections.OrderedDict[Hashable, Any]" = \
            collections.OrderedDict()
        self._owners: Dict[Hashable, str] = {}
        self._budgets: Dict[str, int] = {}
        self._building: Dict[Hashable, threading.Event] = {}
        self._lock = threading.RLock()
        self.stats = CacheStats()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self) -> Iterator[Hashable]:
        """Iterate over the cached keys (snapshot, LRU -> MRU order)."""
        with self._lock:
            return iter(list(self._entries.keys()))

    # -------------------------------------------------------- multi-tenancy
    def set_budget(self, owner: str, max_entries: int) -> None:
        """Cap how many entries ``owner`` may hold at once.

        An insert that takes the owner over budget evicts the owner's own
        least-recently-used entry first; other tenants are untouched.

        Raises:
            ValueError: if ``max_entries`` is less than one.
        """
        if max_entries < 1:
            raise ValueError("budget must be >= 1")
        with self._lock:
            self._budgets[owner] = int(max_entries)

    def owner_counts(self) -> Dict[str, int]:
        """Entries currently held per owner (unowned entries under ``""``)."""
        with self._lock:
            out: Dict[str, int] = {}
            for key in self._entries:
                own = self._owners.get(key, "")
                out[own] = out.get(own, 0) + 1
            return out

    # --------------------------------------------------------------- lookup
    def get(self, key: Hashable) -> Optional[Any]:
        """Peek without counting a request (no builder, no LRU eviction)."""
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return self._entries[key]
            return None

    def get_or_build(self, key: Hashable, builder: Callable[[], Any],
                     owner: Optional[str] = None) -> Any:
        """Return the cached value for ``key``, building it on first miss.

        Args:
            key: hashable structure signature.
            builder: zero-arg callable producing the value; invoked at most
                once per distinct key across all threads (a failed build
                releases the key so a later call may retry).
            owner: optional tenant tag for per-owner eviction budgets.

        Returns:
            The cached (or freshly built) value.
        """
        while True:
            with self._lock:
                if key in self._entries:
                    self.stats.hits += 1
                    self._entries.move_to_end(key)
                    return self._entries[key]
                pending = self._building.get(key)
                if pending is None:
                    self._building[key] = threading.Event()
                    self.stats.misses += 1
                    break
            # another thread is building this key: wait, then re-check (the
            # re-check counts as a hit — we never invoked the builder)
            pending.wait()
        try:
            value = builder()
        except BaseException:
            with self._lock:
                self._building.pop(key).set()     # unblock waiters; they retry
            raise
        with self._lock:
            self._entries[key] = value
            if owner is not None:
                self._owners[key] = owner
            self._evict_locked(owner)
            self._building.pop(key).set()
        return value

    def _evict_locked(self, owner: Optional[str]) -> None:
        """Apply the owner budget (if any) then the global capacity."""
        budget = self._budgets.get(owner) if owner is not None else None
        if budget is not None:
            while sum(1 for k in self._entries
                      if self._owners.get(k) == owner) > budget:
                victim = next(k for k in self._entries
                              if self._owners.get(k) == owner)
                self._drop_locked(victim)
        while len(self._entries) > self.capacity:
            self._drop_locked(next(iter(self._entries)))

    def _drop_locked(self, key: Hashable) -> None:
        del self._entries[key]
        self._owners.pop(key, None)
        self.stats.evictions += 1

    # ------------------------------------------------------------- plumbing
    def clear(self) -> None:
        """Drop every entry (counters are kept; see :meth:`reset_counters`)."""
        with self._lock:
            self._entries.clear()
            self._owners.clear()

    def reset_counters(self) -> None:
        """Zero the hit/miss/eviction counters without touching entries."""
        with self._lock:
            self.stats = CacheStats()
