"""Jitted wrappers: TileSet -> block-dense tensors -> Pallas tile kernels.

``densify_tiles`` turns a ZIPPER :class:`TileSet` (or each bucket of a
:class:`BucketedTileSet`) plus source features into the (adj, xsrc)
block-dense form the TPU kernels consume.  ``spmm`` / ``gat_aggregate`` are
the public entry points: the GNN benchmarks call them directly, and
``core/pipeline.py`` passes ``spmm`` as ``tile_kernel`` so pure-SpMM gather
phases run on the Pallas kernel (one call per size bucket, partition
outputs summed across buckets) instead of the ``lax.scan`` body.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ...core.tiling import BucketedTileSet, TileSet
from .kernel import (segment_softmax_csr_pallas, segment_softmax_pallas,
                     tile_flags, tile_spmm_csr_pallas, tile_spmm_pallas)
from .ref import (segment_softmax_csr_ref, segment_softmax_ref,
                  tile_spmm_csr_ref, tile_spmm_ref)


def densify_tiles(tiles: Union[TileSet, BucketedTileSet],
                  edge_weight: Optional[np.ndarray] = None):
    """Build dense per-tile adjacency blocks A (T, Dmax, Smax).

    A[t, d, s] = sum of weights of edges (s -> d) in tile t (1.0 default).
    Also returns the FIRST/LAST flags.  numpy, one-time preprocessing —
    the analogue of the paper's offline tiling pass.

    For a :class:`BucketedTileSet` the result is one (adj, flags) pair per
    bucket — Smax differs per bucket (that is the point of bucketing) while
    Dmax stays the shared partition maximum, so per-bucket kernel outputs
    can be summed into one (P, Dmax, F) accumulator.
    """
    if isinstance(tiles, BucketedTileSet):
        return [densify_tiles(b, edge_weight) for b in tiles.buckets]
    T, S = tiles.edge_src.shape
    D = int(tiles.part_size.max())
    Smax = tiles.s_max
    adj = np.zeros((T, D, Smax), np.float32)
    for t in range(T):
        ne = int(tiles.n_edge[t])
        w = np.ones(ne, np.float32) if edge_weight is None else \
            edge_weight[tiles.edge_gid[t, :ne]]
        np.add.at(adj[t], (tiles.edge_dst[t, :ne], tiles.edge_src[t, :ne]), w)
    return adj, tile_flags(tiles.part_id)


def gather_sources(tiles: Union[TileSet, BucketedTileSet], x):
    """(T, Smax, F) compacted source features (sparse tiling's gather);
    one array per bucket for a :class:`BucketedTileSet`."""
    if isinstance(tiles, BucketedTileSet):
        return [gather_sources(b, x) for b in tiles.buckets]
    return jnp.asarray(x)[jnp.asarray(tiles.src_ids)]


_NEG = -1e30  # matches the segment-softmax kernel's "no edge" sentinel


@functools.partial(jax.jit, static_argnames=("dmax", "smax"))
def densify_edge_weights(weights, edge_dst, edge_src, n_edge, *,
                         dmax: int, smax: int):
    """Runtime analogue of :func:`densify_tiles` for *computed* edge weights.

    weights: (T, Emax) per-edge scalars (e.g. attention α evaluated on the
    edge segment); edge_dst/edge_src: (T, Emax) tile-local indices; n_edge:
    (T,) true counts.  Returns (T, dmax, smax) dense adjacency blocks with
    parallel edges summed — the A operand of the weighted-SpMM kernel block.
    """
    T, E = weights.shape
    emask = jnp.arange(E)[None, :] < n_edge[:, None]
    w = jnp.where(emask, weights, 0.0).astype(jnp.float32)

    def per_tile(w_t, ed, es):
        return jnp.zeros((dmax, smax), jnp.float32).at[ed, es].add(w_t)

    return jax.vmap(per_tile)(w, edge_dst, edge_src)


@functools.partial(jax.jit, static_argnames=("dmax",))
def densify_edge_scores(scores, edge_dst, n_edge, *, dmax: int):
    """Per-edge-COLUMN score densification for the segment-softmax kernel.

    scores: (T, Emax) per-edge attention logits.  Returns (T, dmax, Emax)
    blocks where column ``j`` holds edge ``j``'s score at its destination row
    and the ``_NEG`` sentinel everywhere else.  Giving every edge its own
    column (instead of compacting onto source columns) keeps parallel edges
    in separate softmax slots, so multigraphs stay exact.
    """
    T, E = scores.shape
    emask = jnp.arange(E)[None, :] < n_edge[:, None]
    s = jnp.where(emask, scores, _NEG).astype(jnp.float32)

    def per_tile(s_t, ed):
        return jnp.full((dmax, E), _NEG, jnp.float32).at[ed, jnp.arange(E)].set(s_t)

    return jax.vmap(per_tile)(s, edge_dst)


@functools.partial(jax.jit, static_argnames=("n_parts", "use_pallas", "interpret"))
def spmm(adj, xsrc, part_id, flags, *, n_parts: int, use_pallas: bool = True,
         interpret: bool = True):
    if use_pallas:
        return tile_spmm_pallas(adj, xsrc, part_id, flags, n_parts=n_parts,
                                interpret=interpret)
    return tile_spmm_ref(adj, xsrc, part_id, n_parts)


@functools.partial(jax.jit, static_argnames=("n_parts", "use_pallas", "interpret"))
def gat_aggregate(scores, vals, part_id, flags, *, n_parts: int,
                  use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return segment_softmax_pallas(scores, vals, part_id, flags,
                                      n_parts=n_parts, interpret=interpret)
    return segment_softmax_ref(scores, vals, part_id, n_parts)


# ---------------------------------------------------------------------------
# CSR-within-tile entry points: no densify pass — ``col`` IS the CSR-ordered
# ``edge_src`` and weights/scores stay per-edge vectors.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_parts", "use_pallas", "interpret"))
def spmm_csr(row_ptr, col, w, xsrc, part_id, flags, *, n_parts: int,
             use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return tile_spmm_csr_pallas(row_ptr, col, w, xsrc, part_id, flags,
                                    n_parts=n_parts, interpret=interpret)
    return tile_spmm_csr_ref(row_ptr, col, w, xsrc, part_id, n_parts)


@functools.partial(jax.jit, static_argnames=("n_parts", "use_pallas", "interpret"))
def gat_aggregate_csr(row_ptr, scores, vals, part_id, flags, *, n_parts: int,
                      use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return segment_softmax_csr_pallas(row_ptr, scores, vals, part_id,
                                          flags, n_parts=n_parts,
                                          interpret=interpret)
    return segment_softmax_csr_ref(row_ptr, scores, vals, part_id, n_parts)
