"""Pallas TPU kernel for ZIPPER tiled SpMM (the paper's core dataflow).

Hardware adaptation (DESIGN.md §2): the ASIC's per-edge gather/scatter units
have no TPU analogue, so a tile's sparse structure is *densified* into an
adjacency block A_t (Dmax × Smax) over the **compacted** sources — sparsity
is exploited structurally (sparse tiling keeps Smax small and drops empty
tiles) while the MXU gets dense work, and the VPU never chases pointers.

Grid = tiles, partition-major.  Scalar-prefetched tile metadata (the "tile
hub"): ``part_id`` drives the output BlockSpec index map (all tiles of one
partition revisit the same output block), ``tile_flags`` marks first/last
tile of each partition for accumulator init/flush.  The Pallas grid pipeline
overlaps tile t+1's A/X DMA with tile t's MXU matmul — the paper's
inter-tile pipelining, realized by the hardware DMA engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

FIRST, LAST = 1, 2


def _kernel(flags_ref, part_ref, a_ref, x_ref, o_ref, acc_ref):
    t = pl.program_id(0)
    flags = flags_ref[t]

    @pl.when(flags & FIRST != 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0].astype(jnp.float32)          # (D, S)
    x = x_ref[0].astype(jnp.float32)          # (S, F)
    acc_ref[...] += jax.lax.dot(a, x, preferred_element_type=jnp.float32)

    @pl.when(flags & LAST != 0)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def tile_flags(part_id: np.ndarray) -> np.ndarray:
    """FIRST/LAST markers per tile (partition-major tile order)."""
    T = len(part_id)
    f = np.zeros((T,), np.int32)
    for i in range(T):
        if i == 0 or part_id[i] != part_id[i - 1]:
            f[i] |= FIRST
        if i == T - 1 or part_id[i] != part_id[i + 1]:
            f[i] |= LAST
    return f


@functools.partial(jax.jit, static_argnames=("n_parts", "interpret"))
def tile_spmm_pallas(adj, xsrc, part_id, flags, *, n_parts: int,
                     interpret: bool = True):
    """adj: (T, D, S); xsrc: (T, S, F); part_id/flags: (T,) int32.

    Returns (P, D, F).  Tiles must be partition-major (grid_tile order)."""
    T, D, S = adj.shape
    F = xsrc.shape[-1]
    grid = (T,)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,   # flags, part_id -> SMEM (the tile hub)
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, D, S), lambda t, flags, part: (t, 0, 0)),
                pl.BlockSpec((1, S, F), lambda t, flags, part: (t, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, D, F), lambda t, flags, part: (part[t], 0, 0)),
            scratch_shapes=[pltpu.VMEM((D, F), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_parts, D, F), xsrc.dtype),
        interpret=interpret,
    )(flags.astype(jnp.int32), part_id.astype(jnp.int32), adj, xsrc)
    return out


# ---------------------------------------------------------------------------
# online-softmax variant (GAT edge softmax in ONE pass over tiles —
# the beyond-paper optimization replacing the 3-phase schedule, §Perf)
# ---------------------------------------------------------------------------

def _softmax_kernel(flags_ref, part_ref, s_ref, v_ref, o_ref,
                    acc_ref, m_ref, l_ref):
    t = pl.program_id(0)
    flags = flags_ref[t]

    @pl.when(flags & FIRST != 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    s = s_ref[0].astype(jnp.float32)              # (D, S) masked with <= -1e30
    v = v_ref[0].astype(jnp.float32)              # (S, F)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(s > -1e29, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(flags & LAST != 0)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# CSR-within-tile variants (§5.3 / ROADMAP 3): instead of densifying into a
# (D, S) adjacency block, the kernel walks per-tile row pointers.  Edge
# columns are gathered once into an (E, F) block and a row-selector matrix —
# sel[d, e] = 1 iff row_ptr[d] <= e < row_ptr[d+1] — reduces it on the MXU.
# Padded edge slots sit at e >= row_ptr[-1] where no row's run reaches, so
# no tail masking is needed; index traffic shrinks from 2 int32 per edge
# (COO pair) to 1 per edge + one (D+1) pointer table per tile.
# ---------------------------------------------------------------------------

def _csr_row_select(rp, n_rows: int, n_cols: int):
    """(D, E) float32 selector: sel[d, e] = 1 iff e is in dst row d's run."""
    eidx = jax.lax.broadcasted_iota(jnp.int32, (n_rows, n_cols), 1)
    lo = rp[:-1][:, None]
    hi = rp[1:][:, None]
    return (eidx >= lo) & (eidx < hi)


def _csr_kernel(flags_ref, part_ref, rp_ref, col_ref, w_ref, x_ref,
                o_ref, acc_ref):
    t = pl.program_id(0)
    flags = flags_ref[t]

    @pl.when(flags & FIRST != 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rp = rp_ref[0]                                 # (D+1,)
    col = col_ref[0]                               # (E,) local src index
    w = w_ref[0].astype(jnp.float32)               # (E,) edge weights
    x = x_ref[0].astype(jnp.float32)               # (S, F)
    gathered = w[:, None] * jnp.take(x, col, axis=0)   # (E, F)
    D = acc_ref.shape[0]
    E = col.shape[0]
    sel = _csr_row_select(rp, D, E).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(sel, gathered,
                                preferred_element_type=jnp.float32)

    @pl.when(flags & LAST != 0)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_parts", "interpret"))
def tile_spmm_csr_pallas(row_ptr, col, w, xsrc, part_id, flags, *,
                         n_parts: int, interpret: bool = True):
    """CSR tile SpMM: row_ptr (T, D+1); col/w (T, E); xsrc (T, S, F).

    ``col`` is the tile-local source index per edge (CSR-ordered
    ``edge_src``); ``w`` carries per-edge weights (ones for a pure gather).
    Returns (P, D, F); tiles must be partition-major."""
    T, E = col.shape
    D = row_ptr.shape[1] - 1
    S, F = xsrc.shape[-2:]
    out = pl.pallas_call(
        _csr_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(T,),
            in_specs=[
                pl.BlockSpec((1, D + 1), lambda t, flags, part: (t, 0)),
                pl.BlockSpec((1, E), lambda t, flags, part: (t, 0)),
                pl.BlockSpec((1, E), lambda t, flags, part: (t, 0)),
                pl.BlockSpec((1, S, F), lambda t, flags, part: (t, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, D, F), lambda t, flags, part: (part[t], 0, 0)),
            scratch_shapes=[pltpu.VMEM((D, F), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_parts, D, F), xsrc.dtype),
        interpret=interpret,
    )(flags.astype(jnp.int32), part_id.astype(jnp.int32),
      row_ptr.astype(jnp.int32), col.astype(jnp.int32), w, xsrc)
    return out


def _csr_softmax_kernel(flags_ref, part_ref, rp_ref, s_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref):
    t = pl.program_id(0)
    flags = flags_ref[t]

    @pl.when(flags & FIRST != 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    rp = rp_ref[0]                                 # (D+1,)
    s_e = s_ref[0].astype(jnp.float32)             # (E,) per-edge scores
    v = v_ref[0].astype(jnp.float32)               # (E, F) per-edge values
    D = acc_ref.shape[0]
    E = s_e.shape[0]
    sel = _csr_row_select(rp, D, E)
    s = jnp.where(sel, s_e[None, :], -1e30)        # (D, E)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(sel, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(flags & LAST != 0)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_parts", "interpret"))
def segment_softmax_csr_pallas(row_ptr, scores, vals, part_id, flags, *,
                               n_parts: int, interpret: bool = True):
    """CSR single-pass segment softmax: row_ptr (T, D+1); scores (T, E);
    vals (T, E, F) per-edge source values (already gathered)."""
    T, E = scores.shape
    D = row_ptr.shape[1] - 1
    F = vals.shape[-1]
    out = pl.pallas_call(
        _csr_softmax_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(T,),
            in_specs=[
                pl.BlockSpec((1, D + 1), lambda t, flags, part: (t, 0)),
                pl.BlockSpec((1, E), lambda t, flags, part: (t, 0)),
                pl.BlockSpec((1, E, F), lambda t, flags, part: (t, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, D, F), lambda t, flags, part: (part[t], 0, 0)),
            scratch_shapes=[pltpu.VMEM((D, F), jnp.float32),
                            pltpu.VMEM((D, 1), jnp.float32),
                            pltpu.VMEM((D, 1), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_parts, D, F), vals.dtype),
        interpret=interpret,
    )(flags.astype(jnp.int32), part_id.astype(jnp.int32),
      row_ptr.astype(jnp.int32), scores, vals)
    return out


@functools.partial(jax.jit, static_argnames=("n_parts", "interpret"))
def segment_softmax_pallas(scores, vals, part_id, flags, *, n_parts: int,
                           interpret: bool = True):
    """Single-pass segment softmax over partition tiles (flash-style)."""
    T, D, S = scores.shape
    F = vals.shape[-1]
    out = pl.pallas_call(
        _softmax_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(T,),
            in_specs=[
                pl.BlockSpec((1, D, S), lambda t, flags, part: (t, 0, 0)),
                pl.BlockSpec((1, S, F), lambda t, flags, part: (t, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, D, F), lambda t, flags, part: (part[t], 0, 0)),
            scratch_shapes=[pltpu.VMEM((D, F), jnp.float32),
                            pltpu.VMEM((D, 1), jnp.float32),
                            pltpu.VMEM((D, 1), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_parts, D, F), vals.dtype),
        interpret=interpret,
    )(flags.astype(jnp.int32), part_id.astype(jnp.int32), scores, vals)
    return out
