"""Pure-jnp oracle for tiled SpMM (gather -> aggregate per destination).

Given ZIPPER tiles in block-dense form — per tile a dense adjacency block
A (Dmax, Smax) over the compacted sources and the gathered source features
X (Smax, F) — the reference accumulates  out[p] = sum_{tiles t of p} A_t X_t.
"""
from __future__ import annotations

import jax.numpy as jnp


def tile_spmm_ref(adj, xsrc, part_id, n_parts: int):
    """adj: (T, D, S); xsrc: (T, S, F); part_id: (T,) -> out (P, D, F)."""
    T, D, S = adj.shape
    F = xsrc.shape[-1]
    out = jnp.zeros((n_parts, D, F), jnp.float32)
    contrib = jnp.einsum("tds,tsf->tdf", adj.astype(jnp.float32),
                         xsrc.astype(jnp.float32))
    return out.at[part_id].add(contrib)


def segment_softmax_ref(scores, vals, part_id, n_parts: int):
    """Online-softmax aggregation oracle.

    scores: (T, D, S) masked with -inf where no edge; vals: (T, S, F).
    out[p, d] = sum_e softmax(scores over all tiles of p at row d) * vals.
    """
    T, D, S = scores.shape
    F = vals.shape[-1]
    s = scores.astype(jnp.float32)
    # global per-(partition,row) max and sum across that partition's tiles
    neg = -1e30
    m = jnp.full((n_parts, D), neg).at[part_id].max(s.max(-1))
    m = jnp.maximum(m, neg)
    p = jnp.exp(s - m[part_id][..., None])
    p = jnp.where(s > neg / 2, p, 0.0)
    l = jnp.zeros((n_parts, D)).at[part_id].add(p.sum(-1))
    acc = jnp.zeros((n_parts, D, F)).at[part_id].add(
        jnp.einsum("tds,tsf->tdf", p, vals.astype(jnp.float32)))
    return acc / jnp.maximum(l, 1e-30)[..., None]
