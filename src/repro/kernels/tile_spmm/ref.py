"""Pure-jnp oracle for tiled SpMM (gather -> aggregate per destination).

Given ZIPPER tiles in block-dense form — per tile a dense adjacency block
A (Dmax, Smax) over the compacted sources and the gathered source features
X (Smax, F) — the reference accumulates  out[p] = sum_{tiles t of p} A_t X_t.
"""
from __future__ import annotations

import jax.numpy as jnp


def tile_spmm_ref(adj, xsrc, part_id, n_parts: int):
    """adj: (T, D, S); xsrc: (T, S, F); part_id: (T,) -> out (P, D, F)."""
    T, D, S = adj.shape
    F = xsrc.shape[-1]
    out = jnp.zeros((n_parts, D, F), jnp.float32)
    contrib = jnp.einsum("tds,tsf->tdf", adj.astype(jnp.float32),
                         xsrc.astype(jnp.float32))
    return out.at[part_id].add(contrib)


def _csr_select(row_ptr, n_edge_cols: int):
    """(T, D, E) selector from (T, D+1) row pointers: 1 iff edge e in row d."""
    e = jnp.arange(n_edge_cols)[None, None, :]
    lo = row_ptr[:, :-1, None]
    hi = row_ptr[:, 1:, None]
    return (e >= lo) & (e < hi)


def tile_spmm_csr_ref(row_ptr, col, w, xsrc, part_id, n_parts: int):
    """CSR oracle: row_ptr (T, D+1); col/w (T, E); xsrc (T, S, F)."""
    T, E = col.shape
    F = xsrc.shape[-1]
    D = row_ptr.shape[1] - 1
    gathered = w[..., None].astype(jnp.float32) * \
        jnp.take_along_axis(xsrc.astype(jnp.float32), col[..., None], axis=1)
    sel = _csr_select(row_ptr, E).astype(jnp.float32)       # (T, D, E)
    contrib = jnp.einsum("tde,tef->tdf", sel, gathered)
    return jnp.zeros((n_parts, D, F), jnp.float32).at[part_id].add(contrib)


def segment_softmax_csr_ref(row_ptr, scores, vals, part_id, n_parts: int):
    """CSR softmax oracle: scores (T, E) per edge; vals (T, E, F) per edge."""
    T, E = scores.shape
    F = vals.shape[-1]
    D = row_ptr.shape[1] - 1
    sel = _csr_select(row_ptr, E)                           # (T, D, E)
    s = jnp.where(sel, scores.astype(jnp.float32)[:, None, :], -1e30)
    neg = -1e30
    m = jnp.full((n_parts, D), neg).at[part_id].max(s.max(-1))
    m = jnp.maximum(m, neg)
    p = jnp.exp(s - m[part_id][..., None])
    p = jnp.where(sel, p, 0.0)
    l = jnp.zeros((n_parts, D)).at[part_id].add(p.sum(-1))
    acc = jnp.zeros((n_parts, D, F)).at[part_id].add(
        jnp.einsum("tde,tef->tdf", p, vals.astype(jnp.float32)))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def segment_softmax_ref(scores, vals, part_id, n_parts: int):
    """Online-softmax aggregation oracle.

    scores: (T, D, S) masked with -inf where no edge; vals: (T, S, F).
    out[p, d] = sum_e softmax(scores over all tiles of p at row d) * vals.
    """
    T, D, S = scores.shape
    F = vals.shape[-1]
    s = scores.astype(jnp.float32)
    # global per-(partition,row) max and sum across that partition's tiles
    neg = -1e30
    m = jnp.full((n_parts, D), neg).at[part_id].max(s.max(-1))
    m = jnp.maximum(m, neg)
    p = jnp.exp(s - m[part_id][..., None])
    p = jnp.where(s > neg / 2, p, 0.0)
    l = jnp.zeros((n_parts, D)).at[part_id].add(p.sum(-1))
    acc = jnp.zeros((n_parts, D, F)).at[part_id].add(
        jnp.einsum("tds,tsf->tdf", p, vals.astype(jnp.float32)))
    return acc / jnp.maximum(l, 1e-30)[..., None]
