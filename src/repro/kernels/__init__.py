"""Pallas TPU kernels (pl.pallas_call + BlockSpec) with jnp oracles.

  flash_attention/  blocked online-softmax attention (train/prefill/decode)
  moe_dispatch/     capacity-bucket grouped FFN (ZIPPER tiling over tokens)
  tile_spmm/        block-dense SpMM over graph tiles (the paper's dataflow)
  segment_softmax/  GAT edge softmax, single-pass online variant
Each provides kernel.py (Pallas), ops.py (jit wrapper), ref.py (oracle).
"""
