"""Pure-jnp oracle for routed MoE (dense evaluation).

Computes every expert's FFN for every token and combines with the routing
weights — O(T·E·ff), tiny shapes only.  The production capacity-bucketed
path (ops.py) and the Pallas grouped-FFN kernel are checked against this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def moe_ref(x, router_w, w_gate, w_up, w_down, top_k: int,
            *, norm_topk: bool = True):
    """x: (T, d); router_w: (d, E); w_*: (E, d, f)/(E, f, d). Returns (T, d).

    Top-k softmax routing (softmax over all experts, then renormalized over
    the selected k when ``norm_topk``), no capacity limit (the oracle never
    drops tokens).
    """
    probs = jax.nn.softmax((x @ router_w).astype(jnp.float32), axis=-1)  # (T, E)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    if norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-20)
    T, E = probs.shape
    # dense: every expert over every token
    h = jnp.einsum("td,edf->tef", x, w_gate)
    u = jnp.einsum("td,edf->tef", x, w_up)
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, w_down)  # (T, E, d)
    sel = jnp.zeros((T, E), x.dtype)
    sel = sel.at[jnp.arange(T)[:, None], top_i].add(top_p.astype(x.dtype))
    return jnp.einsum("ted,te->td", y_all, sel)
