"""Pallas grouped-FFN kernel over expert capacity buckets.

The TPU-native rendering of ZIPPER's sparse tiling + inter-tile pipelining
for MoE: grid = (expert, row-block); per-expert live token counts are
scalar-prefetched into SMEM (the "tile hub"), and row-blocks past an
expert's live count are *skipped structurally* — exactly the paper's "do not
load / compute source vertices without edges".  The Pallas grid pipeline
double-buffers the next bucket's DMA against the current bucket's MXU work.

Computes a SwiGLU FFN per expert: y = (silu(x·Wg) ⊙ (x·Wu)) · Wd.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(counts_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, block_c: int):
    e = pl.program_id(0)
    cb = pl.program_id(1)
    live = counts_ref[e]

    @pl.when(cb * block_c < live)   # ZIPPER sparse tiling: skip dead tiles
    def _compute():
        x = x_ref[0].astype(jnp.float32)          # (block_c, d)
        wg = wg_ref[0].astype(jnp.float32)        # (d, f)
        wu = wu_ref[0].astype(jnp.float32)
        wd = wd_ref[0].astype(jnp.float32)        # (f, d)
        h = jax.lax.dot(x, wg, preferred_element_type=jnp.float32)
        u = jax.lax.dot(x, wu, preferred_element_type=jnp.float32)
        act = jax.nn.silu(h) * u
        o_ref[0] = jax.lax.dot(act, wd, preferred_element_type=jnp.float32
                               ).astype(o_ref.dtype)

    @pl.when(cb * block_c >= live)
    def _skip():
        o_ref[0] = jnp.zeros_like(o_ref[0])


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def grouped_ffn_pallas(buckets, w_gate, w_up, w_down, counts, *,
                       block_c: int = 128, interpret: bool = True):
    """buckets: (E, C, d); w_gate/w_up: (E, d, f); w_down: (E, f, d);
    counts: (E,) live rows per expert. Returns (E, C, d)."""
    E, C, d = buckets.shape
    f = w_gate.shape[-1]
    block_c = min(block_c, C)
    nc = -(-C // block_c)
    pad = nc * block_c - C
    if pad:
        buckets = jnp.pad(buckets, ((0, 0), (0, pad), (0, 0)))
    grid = (E, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, block_c=block_c),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,           # counts -> SMEM ("tile hub")
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_c, d), lambda e, c, counts: (e, c, 0)),
                pl.BlockSpec((1, d, f), lambda e, c, counts: (e, 0, 0)),
                pl.BlockSpec((1, d, f), lambda e, c, counts: (e, 0, 0)),
                pl.BlockSpec((1, f, d), lambda e, c, counts: (e, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_c, d), lambda e, c, counts: (e, c, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((E, nc * block_c, d), buckets.dtype),
        interpret=interpret,
    )(counts.astype(jnp.int32), buckets, w_gate, w_up, w_down)
    return out[:, :C]
