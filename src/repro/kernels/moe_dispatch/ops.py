"""Capacity-bucketed MoE dispatch/combine — ZIPPER tiling over the
token→expert bipartite graph (DESIGN.md §4).

The token→expert assignment is a sparse graph: tokens are source vertices,
experts are destination partitions.  We reproduce the paper's machinery:

* **degree-sort reorder** — tokens are sorted by assigned expert, so each
  expert's tokens are contiguous;
* **sparse tiling**       — tokens land in per-expert *capacity buckets*
  (static-shape tiles); row-blocks beyond an expert's live count are dead
  tiles the Pallas kernel skips structurally;
* **inter-tile pipelining** — the Pallas grid double-buffers the gather of
  bucket t+1 against the expert GEMM of bucket t.

All functions here are device-local (no collectives): the shard_map wrapper
that adds expert/tensor parallelism lives in ``repro.models.moe``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Routing:
    """Static-shape routing plan for one device's tokens."""

    bucket_idx: jnp.ndarray   # (T*k,) position in the flattened (E*C) buckets
    token_idx: jnp.ndarray    # (T*k,) source token of each assignment (sorted order)
    keep: jnp.ndarray         # (T*k,) bool — False = dropped by capacity
    weight: jnp.ndarray       # (T*k,) routing weight of each assignment
    counts: jnp.ndarray       # (E,) live tokens per expert (pre-capacity-clip)
    aux_loss: jnp.ndarray     # load-balance auxiliary loss (scalar)


def route(x, router_w, top_k: int, capacity: int, *, norm_topk: bool = True,
          router_bias: Optional[jnp.ndarray] = None) -> Routing:
    """Top-k routing + capacity-bucket assignment. x: (T, d)."""
    T = x.shape[0]
    logits = (x @ router_w).astype(jnp.float32)
    if router_bias is not None:  # aux-loss-free balancing bias (DeepSeek-V3)
        logits = logits + router_bias
    probs = jax.nn.softmax(logits, axis=-1)
    E = probs.shape[-1]
    top_p, top_i = jax.lax.top_k(probs, top_k)
    if norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-20)

    flat_e = top_i.reshape(-1)                        # (T*k,)
    flat_w = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    order = jnp.argsort(flat_e, stable=True)          # degree-sort reorder
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * top_k) - first               # rank within expert
    keep = pos < capacity
    bucket_idx = jnp.where(keep, se * capacity + pos, E * capacity)  # sentinel slot

    counts = jnp.bincount(flat_e, length=E)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f = counts.astype(jnp.float32) / jnp.maximum(T * top_k, 1)
    p_mean = probs.mean(0)
    aux = E * jnp.sum(f * p_mean)
    return Routing(bucket_idx=bucket_idx, token_idx=st, keep=keep,
                   weight=sw.astype(x.dtype), counts=counts, aux_loss=aux)


def dispatch(x, r: Routing, n_experts: int, capacity: int) -> jnp.ndarray:
    """Gather tokens into (E, C, d) buckets (dead slots are zero)."""
    d = x.shape[-1]
    buckets = jnp.zeros((n_experts * capacity + 1, d), x.dtype)
    buckets = buckets.at[r.bucket_idx].set(x[r.token_idx])
    return buckets[:-1].reshape(n_experts, capacity, d)


def combine(y_buckets, r: Routing, n_tokens: int) -> jnp.ndarray:
    """Scatter expert outputs back to tokens, applying routing weights."""
    E, C, d = y_buckets.shape
    flat = jnp.concatenate([y_buckets.reshape(E * C, d),
                            jnp.zeros((1, d), y_buckets.dtype)])
    vals = flat[r.bucket_idx] * (r.weight * r.keep)[:, None]
    return jax.ops.segment_sum(vals, r.token_idx, num_segments=n_tokens)


def expert_ffn_einsum(buckets, w_gate, w_up, w_down):
    """Reference per-expert SwiGLU over buckets: (E, C, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", buckets, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buckets, w_up)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down)


def moe_block(x, router_w, w_gate, w_up, w_down, *, top_k: int, capacity: int,
              norm_topk: bool = True, router_bias=None, use_pallas: bool = False):
    """Device-local routed MoE: route -> dispatch -> grouped FFN -> combine.

    Returns (y, aux_loss)."""
    E = w_gate.shape[0]
    r = route(x, router_w, top_k, capacity, norm_topk=norm_topk,
              router_bias=router_bias)
    buckets = dispatch(x, r, E, capacity)
    if use_pallas:
        from .kernel import grouped_ffn_pallas
        y_buckets = grouped_ffn_pallas(buckets, w_gate, w_up, w_down,
                                       jnp.minimum(r.counts, capacity))
    else:
        y_buckets = expert_ffn_einsum(buckets, w_gate, w_up, w_down)
    return combine(y_buckets, r, x.shape[0]), r.aux_loss
