"""Pure-jnp oracle for blocked (flash) attention.

Naive materialized softmax attention with GQA, causal and sliding-window
masking.  Small shapes only — this is the correctness reference the Pallas
kernel and the scan-based production path are checked against.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None, kv_len: Optional[jnp.ndarray] = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D) with H % K == 0 (GQA).

    ``window``: sliding-window size (a query attends to keys in
    [pos - window + 1, pos]).  ``kv_len``: optional (B,) valid kv length
    (decode with a partially-filled cache).  Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, K, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned queries
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask = mask[None] & (k_pos[None] < kv_len[:, None, None])  # (B, Sq, Sk)
        s = jnp.where(mask[:, None, None], s, -jnp.inf)
    else:
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p / denom, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
