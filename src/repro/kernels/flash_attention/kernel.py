"""Pallas TPU flash-attention kernel (explicit VMEM tiling).

Grid: (batch, q_head, q_block, kv_block) — the kv_block axis is the ZIPPER
tile axis: Pallas grid pipelining double-buffers the HBM->VMEM DMA of block
j+1 against the MXU matmul of block j (inter-tile pipelining, DESIGN.md §2).
Online-softmax state (o, m, l) lives in VMEM scratch and persists across the
sequential kv_block iterations; the output is finalized on the last block.

GQA is handled in the index maps (kv head = q head // G) — no KV replication
in HBM or VMEM.  Validated against ``ref.attention_ref`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: Optional[int], scale: float,
            block_q: int, block_k: int, seq_q: int, seq_k: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + (seq_k - seq_q)   # right-aligned query positions
    k_start = kj * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                    # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_prev * alpha + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    if causal:
        # skip fully-masked blocks (strictly above the diagonal)
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 512,
                           kv_len: Optional[jnp.ndarray] = None,
                           interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D) -> (B, Sq, H, D).

    ``interpret=True`` (default here) runs the kernel body in Python — this
    container is CPU-only; on a real TPU pass ``interpret=False``.
    ``kv_len`` is not supported by the kernel path (used only for ragged
    decode); callers fall back to the scan path for that case.
    """
    assert kv_len is None, "ragged kv_len: use the scan path"
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    block_q = max(8, min(block_q, Sq))
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Sk
    qt = jnp.moveaxis(q, 2, 1)  # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, scale=D ** -0.5,
        block_q=block_q, block_k=block_k, seq_q=Sq, seq_k=Sk,
        n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)  # (B, Sq, H, D)
