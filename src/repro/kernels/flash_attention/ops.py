"""Blocked (flash) attention — production entry point.

Two interchangeable implementations of the same online-softmax tiling:

* ``flash_attention(..., use_pallas=False)`` — ``lax.scan`` over KV blocks.
  Pure jnp: compiles on every backend, is GSPMD-shardable, and never
  materializes the (Sq, Sk) score matrix.  This is what the LM stack uses
  for training / prefill / decode on arbitrary meshes.
* ``use_pallas=True`` — the TPU Pallas kernel in ``kernel.py`` (explicit
  VMEM BlockSpecs, MXU-aligned tiles); validated in interpret mode on CPU.

ZIPPER mapping (DESIGN.md §4): KV blocks are the tiles; the scan/grid is the
inter-tile pipeline that overlaps the memory-bound KV loads ("GOP") of block
t+1 with the MXU matmuls ("GEMM") of block t.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_NEG = -1e30


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    block_k: int = 512, kv_len: Optional[jnp.ndarray] = None,
                    use_pallas: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D). Returns (B, Sq, H, D).

    Queries are right-aligned against keys (decode: Sq=1 attends the whole
    cache).  ``kv_len`` masks a partially-filled cache.
    """
    if use_pallas:
        from .kernel import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_k=block_k, kv_len=kv_len)
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    Dv = v.shape[-1]  # v head dim may differ (MLA: qk=192, v=128)
    G = H // K
    scale = D ** -0.5
    orig_dtype = q.dtype
    qg = (q * scale).reshape(B, Sq, K, G, D).astype(jnp.float32)

    block_k = min(block_k, Sk)
    nblk = -(-Sk // block_k)
    pad = nblk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_k, K, D).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    vb = v.reshape(B, nblk, block_k, K, Dv).transpose(1, 0, 2, 3, 4).astype(jnp.float32)

    q_pos = jnp.arange(Sq) + (Sk - Sq)
    base_len = jnp.full((B,), Sk, jnp.int32) if kv_len is None else kv_len

    def body(carry, xs):
        o, m, l = carry
        kblk, vblk, blk_i = xs
        k_pos = blk_i * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk)          # (B,K,G,Sq,bk)
        msk = jnp.ones((Sq, block_k), bool)
        if causal:
            msk &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            msk &= k_pos[None, :] > q_pos[:, None] - window
        msk = msk[None] & (k_pos[None, None, :] < base_len[:, None, None])
        s = jnp.where(msk[:, None, None], s, _NEG)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(-1)
        o = o * alpha[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vblk)
        return (o, m_new, l), 0

    o0 = jnp.zeros((B, K, G, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, K, G, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    from ... import runtime_flags
    # checkpoint the block body: backward recomputes each block's scores
    # instead of saving the (Sq, block_k) residuals — the flash-attention
    # backward memory profile (carries between blocks are O(Sq·D))
    (o, m, l), _ = jax.lax.scan(jax.checkpoint(body), (o0, m0, l0),
                                (kb, vb, jnp.arange(nblk, dtype=jnp.int32)),
                                unroll=runtime_flags.probe_unroll())
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(orig_dtype)
