"""Segment softmax over ZIPPER partition tiles (GAT edge softmax).

Implementation lives beside the tile-SpMM kernel (same block-dense tile
layout, shared scalar-prefetch metadata); this package re-exports it under
the kernel taxonomy's name.
"""
from ..tile_spmm.kernel import (segment_softmax_csr_pallas,  # noqa: F401
                                segment_softmax_pallas)      # noqa: F401
from ..tile_spmm.ref import (segment_softmax_csr_ref,        # noqa: F401
                             segment_softmax_ref)            # noqa: F401
from ..tile_spmm.ops import (densify_edge_scores,            # noqa: F401
                             gat_aggregate, gat_aggregate_csr)  # noqa: F401
